"""Pallas kernel for the fused sparsify + error-accumulation update.

Alg. 1 lines 7-8 of the paper in one memory-bound sweep:

    ghat = mask . acc        (the sparsified gradient sent upstream)
    eps' = acc - ghat        (the error carried to iteration t+1)

Invariant (property-tested): acc == ghat + eps' bit-exactly, because
eps' is computed as a subtraction of the masked copy — this is the
error-feedback *conservation law* that makes TOP-k/REGTOP-k unbiased
over time.  Oracle: ``ref.error_feedback``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16384


def _ef_kernel(acc_ref, mask_ref, ghat_ref, eps_ref):
    acc = acc_ref[...]
    ghat = mask_ref[...] * acc
    ghat_ref[...] = ghat
    eps_ref[...] = acc - ghat


@functools.partial(jax.jit, static_argnames=("block",))
def error_feedback(acc, mask, *, block=BLOCK):
    """Fused (ghat, eps_next) update; matches ``ref.error_feedback``."""
    (j,) = acc.shape
    pad = (-j) % block
    padded = j + pad

    def pad1(x):
        return jnp.pad(x, (0, pad)) if pad else x

    grid = (padded // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    ghat, eps = pl.pallas_call(
        _ef_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), acc.dtype),
            jax.ShapeDtypeStruct((padded,), acc.dtype),
        ],
        interpret=True,
    )(pad1(acc), pad1(mask))
    return ghat[:j], eps[:j]
