"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *single source of truth* for kernel numerics: each Pallas
kernel in `regtopk.py`, `topk_mask.py`, `error_feedback.py` and `sgd.py`
must match its oracle here to float tolerance (see python/tests/).  The
rust-native sparsifier implementations are additionally cross-checked
against golden vectors produced from these oracles.

All functions follow Algorithm 1 of the paper (REGTOP-k, Bereyhi et al.,
2024) and use its notation:

    a_n^t      accumulated gradient         (``acc``)
    eps_n^t    sparsification error         (``eps``)
    g_n^t      local gradient               (``grad``)
    g^{t-1}    previous aggregated gradient (``gagg_prev``)
    s_n^{t-1}  previous sparsification mask (``mask_prev``)
    Delta_n^t  posterior distortion         (``delta``)
    omega_n    aggregation weight
    mu, Q      REGTOP-k hyper-parameters
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# Division guard: entries with |omega * a| below this are treated as
# "locally dead" and receive the never-sent prior Q (their score is ~0
# anyway because score = a * tanh(.)).
DIV_EPS = 1e-30


def accumulate(eps, grad):
    """Accumulated gradient  a_n^t = eps_n^t + g_n^t  (Alg. 1, line 4)."""
    return eps + grad


def posterior_distortion(acc, acc_prev, gagg_prev, mask_prev, omega, q):
    """Posterior distortion Delta_n^t (Alg. 1, line 5).

    Delta = s^{t-1} . [(g^{t-1} - omega * a^{t-1}) / (omega * a^t)]
            + Q * (1 - s^{t-1})

    Entries where ``omega * acc`` is (numerically) zero are mapped to Q:
    their regularized score is zero regardless, and this keeps the
    division well-defined (matches the rust implementation in the
    positions that matter).
    """
    denom = omega * acc
    safe = jnp.abs(denom) > DIV_EPS
    num = gagg_prev - omega * acc_prev
    delta_sent = jnp.where(safe, num / jnp.where(safe, denom, 1.0), q)
    return mask_prev * delta_sent + q * (1.0 - mask_prev)


def regularizer(delta, mu):
    """u_mu(|1 + Delta|) = tanh(|1 + Delta| / mu)   (Prop. 2 / eq. 15)."""
    return jnp.tanh(jnp.abs(1.0 + delta) / mu)


def regtopk_score(eps, grad, acc_prev, gagg_prev, mask_prev, omega, mu, q):
    """Fused score pass: returns (acc, score) with

    acc   = eps + grad
    score = acc * tanh(|1 + Delta| / mu)          (eq. 16)

    Selection is Top_k over |score|; the *sent values* are ``acc`` (not
    the score) — eq. (16) only reorders the selection.
    """
    acc = accumulate(eps, grad)
    delta = posterior_distortion(acc, acc_prev, gagg_prev, mask_prev, omega, q)
    return acc, acc * regularizer(delta, mu)


def topk_mask(score, k):
    """Exact Top_k selector over amplitudes (eq. 5).

    Ties are broken toward the *lower index* (stable), matching the rust
    `sparse::topk` implementation.  Returns a {0,1} float mask.
    """
    j = score.shape[-1]
    k = min(k, j)
    if k == 0:
        return jnp.zeros_like(score)
    mag = jnp.abs(score)
    # lax.top_k is stable: ties break toward the lower index, matching
    # the rust `sparse::topk` implementation.
    idx = lax.top_k(mag, k)[1]
    return jnp.zeros_like(score).at[idx].set(1.0)


def threshold_mask(score, tau):
    """Mask of entries with |score| >= tau (phase-2 of two-phase top-k)."""
    return (jnp.abs(score) >= tau).astype(score.dtype)


def error_feedback(acc, mask):
    """Sparsify + error update (Alg. 1, lines 7-8).

    ghat = mask . acc  (sent to the server)
    eps' = acc - ghat  (carried to iteration t+1)

    Invariant:  acc == ghat + eps'   exactly (fp-exact: subtraction of a
    masked copy).
    """
    ghat = mask * acc
    return ghat, acc - ghat


def sgd_apply(w, grad, eta):
    """Plain SGD step  w' = w - eta * g."""
    return w - eta * grad


def momentum_apply(w, m, grad, eta, beta):
    """Heavy-ball momentum:  m' = beta*m + g ;  w' = w - eta*m'."""
    m_next = beta * m + grad
    return w - eta * m_next, m_next


def block_absmax(score, block):
    """Per-block max |score| — phase-1 statistics for two-phase top-k."""
    j = score.shape[-1]
    pad = (-j) % block
    mag = jnp.abs(jnp.pad(score, (0, pad)))
    return mag.reshape(-1, block).max(axis=-1)


def regtopk_step(eps, grad, acc_prev, gagg_prev, mask_prev, omega, mu, q, k):
    """One full REGTOP-k worker step (Alg. 1 lines 4-8), dense oracle.

    Returns (ghat, eps_next, mask, acc, score).  Used by the algorithm-
    level tests and by the golden-vector generator for the rust side.
    """
    acc, score = regtopk_score(
        eps, grad, acc_prev, gagg_prev, mask_prev, omega, mu, q
    )
    mask = topk_mask(score, k)
    ghat, eps_next = error_feedback(acc, mask)
    return ghat, eps_next, mask, acc, score


def topk_step(eps, grad, k):
    """One classical TOP-k worker step (the paper's baseline)."""
    acc = accumulate(eps, grad)
    mask = topk_mask(acc, k)
    ghat, eps_next = error_feedback(acc, mask)
    return ghat, eps_next, mask, acc


def quantize_sr(x, noise, bits):
    """Scaled stochastic-rounding quantizer (oracle for quantize.py;
    matches rust ``comm::Quantizer`` given identical noise)."""
    if bits >= 32:
        return x
    levels = float(max((1 << (bits - 1)) - 1, 1))
    maxabs = jnp.max(jnp.abs(x))
    scale = jnp.where(maxabs > 0, maxabs / levels, 1.0)
    xs = x / scale
    lo = jnp.floor(xs)
    frac = xs - lo
    q = jnp.where(noise < frac, lo + 1.0, lo)
    return q * scale
