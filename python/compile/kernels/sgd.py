"""Pallas kernels for the optimizer apply step (server side).

Two fused memory-bound sweeps:

    sgd_apply:       w' = w - eta * g
    momentum_apply:  m' = beta * m + g ;  w' = w - eta * m'

These run on the server after aggregation; fusing keeps the parameter
vector's HBM traffic at the minimum (1R+1W for SGD, 2R+2W for
momentum).  Oracles: ``ref.sgd_apply`` / ``ref.momentum_apply``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16384


def _sgd_kernel(w_ref, g_ref, eta_ref, out_ref):
    out_ref[...] = w_ref[...] - eta_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_apply(w, grad, eta, *, block=BLOCK):
    """w' = w - eta*g; matches ``ref.sgd_apply``."""
    (j,) = w.shape
    pad = (-j) % block
    padded = j + pad

    def pad1(x):
        return jnp.pad(x, (0, pad)) if pad else x

    eta_arr = jnp.asarray(eta, dtype=w.dtype).reshape(1)
    grid = (padded // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((padded,), w.dtype),
        interpret=True,
    )(pad1(w), pad1(grad), eta_arr)
    return out[:j]


def _momentum_kernel(w_ref, m_ref, g_ref, scal_ref, w_out_ref, m_out_ref):
    eta = scal_ref[0]
    beta = scal_ref[1]
    m_next = beta * m_ref[...] + g_ref[...]
    m_out_ref[...] = m_next
    w_out_ref[...] = w_ref[...] - eta * m_next


@functools.partial(jax.jit, static_argnames=("block",))
def momentum_apply(w, m, grad, eta, beta, *, block=BLOCK):
    """(w', m') heavy-ball update; matches ``ref.momentum_apply``."""
    (j,) = w.shape
    pad = (-j) % block
    padded = j + pad

    def pad1(x):
        return jnp.pad(x, (0, pad)) if pad else x

    scal = jnp.array([eta, beta], dtype=w.dtype)
    grid = (padded // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    w_out, m_out = pl.pallas_call(
        _momentum_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), w.dtype),
            jax.ShapeDtypeStruct((padded,), w.dtype),
        ],
        interpret=True,
    )(pad1(w), pad1(m), pad1(grad), scal)
    return w_out[:j], m_out[:j]
