"""Pallas kernel: scaled stochastic-rounding quantization of the
transmitted values (the compression axis orthogonal to sparsity).

Randomness is supplied as a uniform-[0,1) noise input so the kernel
stays pure (and matches the rust `comm::Quantizer` given the same
noise); the scale (max|x| / levels) is computed by a first reduction
pass, mirroring the two-phase structure of the top-k kernels.

Oracle: ``ref.quantize_sr``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16384


def _quant_kernel(x_ref, noise_ref, scal_ref, out_ref):
    scale = scal_ref[0]
    x = x_ref[...] / scale
    lo = jnp.floor(x)
    frac = x - lo
    q = jnp.where(noise_ref[...] < frac, lo + 1.0, lo)
    out_ref[...] = q * scale


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def quantize_sr(x, noise, bits, *, block=BLOCK):
    """Quantize to ``bits`` with stochastic rounding; returns the
    dequantized (lossy) values.  ``noise`` is uniform [0,1) of x's
    shape.  bits >= 32 is a passthrough."""
    if bits >= 32:
        return x
    (j,) = x.shape
    levels = float(max((1 << (bits - 1)) - 1, 1))
    maxabs = jnp.max(jnp.abs(x))
    scale = jnp.where(maxabs > 0, maxabs / levels, 1.0)
    pad = (-j) % block
    padded = j + pad

    def pad1(v):
        return jnp.pad(v, (0, pad)) if pad else v

    grid = (padded // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((padded,), x.dtype),
        interpret=True,
    )(pad1(x), pad1(noise), scale.reshape(1))
    return out[:j]
