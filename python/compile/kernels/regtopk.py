"""Pallas kernel for the REGTOP-k score pass (the paper's compute hot-spot).

One fused element-wise sweep computes, per gradient entry j (Alg. 1
lines 4-6 of the paper):

    a     = eps + g                                   (accumulate)
    Delta = s_prev ? (gagg_prev - omega*a_prev)/(omega*a) : Q
    score = a * tanh(|1 + Delta| / mu)                (eq. 16)

Fusing the three lines means each of the five input vectors is read
from HBM exactly once and the two outputs written once — the pass is
memory-bound (arithmetic intensity ~= 1.3 flop/byte), so single-sweep
is the roofline-optimal structure on TPU.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel is a pure
VPU pass; we view the length-J vector as rows of (8, 128) lanes and
tile ``BLOCK`` elements per grid step so every live block fits in VMEM
(7 inputs/outputs x BLOCK x 4 B; BLOCK=16384 -> ~448 KiB << 16 MiB,
leaving room for double-buffering).  ``interpret=True`` is mandatory in
this image: real-TPU lowering emits a Mosaic custom-call the CPU PJRT
plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per grid step.  Multiple of 8*128 (TPU VPU tile); see module
# docstring for the VMEM budget.
BLOCK = 16384

# Must match ref.DIV_EPS.
DIV_EPS = 1e-30


def _regtopk_kernel(
    eps_ref,
    grad_ref,
    acc_prev_ref,
    gagg_prev_ref,
    mask_prev_ref,
    scal_ref,  # (3,) = [omega, mu, q] in SMEM-like small block
    acc_out_ref,
    score_out_ref,
):
    omega = scal_ref[0]
    mu = scal_ref[1]
    q = scal_ref[2]

    acc = eps_ref[...] + grad_ref[...]
    denom = omega * acc
    safe = jnp.abs(denom) > DIV_EPS
    num = gagg_prev_ref[...] - omega * acc_prev_ref[...]
    delta_sent = jnp.where(safe, num / jnp.where(safe, denom, 1.0), q)
    delta = mask_prev_ref[...] * delta_sent + q * (1.0 - mask_prev_ref[...])
    reg = jnp.tanh(jnp.abs(1.0 + delta) / mu)

    acc_out_ref[...] = acc
    score_out_ref[...] = acc * reg


@functools.partial(jax.jit, static_argnames=("block",))
def regtopk_score(
    eps, grad, acc_prev, gagg_prev, mask_prev, omega, mu, q, *, block=BLOCK
):
    """Fused REGTOP-k score pass; matches ``ref.regtopk_score``.

    All vector arguments are rank-1 with identical length J (any J >= 1;
    internally padded to a multiple of ``block``).  ``omega``, ``mu``,
    ``q`` are python or 0-d floats.  Returns ``(acc, score)``.
    """
    (j,) = eps.shape
    dtype = eps.dtype
    pad = (-j) % block
    padded = j + pad

    def pad1(x):
        return jnp.pad(x, (0, pad)) if pad else x

    # Padded tail: mask_prev=0 and acc=0 there, so delta=Q and score=0 —
    # the pad lanes never affect real lanes (element-wise kernel).
    args = tuple(pad1(x) for x in (eps, grad, acc_prev, gagg_prev, mask_prev))
    scal = jnp.array([omega, mu, q], dtype=dtype)

    grid = (padded // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    acc, score = pl.pallas_call(
        _regtopk_kernel,
        grid=grid,
        in_specs=[spec] * 5 + [pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), dtype),
            jax.ShapeDtypeStruct((padded,), dtype),
        ],
        interpret=True,  # CPU-PJRT: Mosaic custom-calls are TPU-only.
    )(*args, scal)
    return acc[:j], score[:j]
