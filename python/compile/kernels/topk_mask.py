"""Pallas kernels for two-phase top-k selection support.

Exact data-dependent top-k is selection, which the TPU vector units do
not natively perform.  The standard TPU scheme (mirrored from the GPU
`torch.topk` the paper used) is two-phase:

  phase 1 (device, this file): per-block magnitude statistics
           (`block_absmax`) reduce J lanes to J/BLOCK candidates;
  phase 2 (host / scalar core): find the k-th magnitude tau among the
           surviving candidates (rust `sparse::topk` does this with
           quickselect), then
  phase 3 (device, this file): `threshold_mask` re-sweeps the vector and
           emits the {0,1} mask of entries with |score| >= tau.

Phases 1 and 3 are single memory-bound sweeps; phase 2 touches only the
reduced candidate set.  Oracles: ``ref.block_absmax``/``ref.threshold_mask``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16384


def _absmax_kernel(score_ref, out_ref):
    out_ref[0] = jnp.max(jnp.abs(score_ref[...]))


@functools.partial(jax.jit, static_argnames=("block",))
def block_absmax(score, *, block=BLOCK):
    """Per-block max |score|; phase-1 statistics (matches ref.block_absmax)."""
    (j,) = score.shape
    pad = (-j) % block
    padded = j + pad
    x = jnp.pad(score, (0, pad)) if pad else score  # pad lanes are 0
    grid = (padded // block,)
    out = pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded // block,), score.dtype),
        interpret=True,
    )(x)
    return out


def _threshold_kernel(score_ref, tau_ref, mask_ref):
    mask_ref[...] = (jnp.abs(score_ref[...]) >= tau_ref[0]).astype(
        score_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block",))
def threshold_mask(score, tau, *, block=BLOCK):
    """{0,1} mask of |score| >= tau; phase-3 sweep (matches ref.threshold_mask)."""
    (j,) = score.shape
    pad = (-j) % block
    padded = j + pad
    x = jnp.pad(score, (0, pad)) if pad else score
    tau_arr = jnp.asarray(tau, dtype=score.dtype).reshape(1)
    grid = (padded // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    mask = pl.pallas_call(
        _threshold_kernel,
        grid=grid,
        in_specs=[spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((padded,), score.dtype),
        interpret=True,
    )(x, tau_arr)
    return mask[:j]
