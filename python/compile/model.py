"""L2: JAX model definitions (build-time only; never on the request path).

Every training workload in the paper is defined here as a pure function
over a *flat* parameter vector ``w ∈ R^J`` — the sparsification
algorithms (L1/L3) operate on flat gradient vectors, so the flat-vector
interface is the contract between the layers:

  * linear regression (least squares)      — Fig. 2 workload (§4.1)
  * logistic regression                    — Fig. 1 toy (§1.2)
  * MLP classifier                         — extra workload
  * ResNet-CIFAR family (resnet8/20/18)    — Fig. 3 workload (§4.2)

For each model there are three exported graphs:

  ``*_loss(w, ...)``        scalar empirical loss F_n(w)         (eq. 4)
  ``*_grad(w, ...)``        (loss, flat gradient)
  ``worker_step(grad_fn)``  fused L2+L1 graph: gradient + REGTOP-k
                            accumulate/score (calls the Pallas kernel
                            so it lowers into the same HLO module)

``aot.py`` lowers concrete-shape instances of these to HLO text that
the rust runtime loads via PJRT.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import regtopk as k_regtopk

# ---------------------------------------------------------------------------
# Flat-parameter packing
# ---------------------------------------------------------------------------


class ParamSpec:
    """Ordered (name, shape) list defining the layout of the flat vector.

    The same layout is exported to ``artifacts/manifest.json`` so the
    rust side can slice per-layer statistics out of flat vectors.
    """

    def __init__(self, entries: list[tuple[str, tuple[int, ...]]]):
        self.entries = entries
        self.sizes = [int(np.prod(s)) if s else 1 for _, s in entries]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)])
        self.total = int(self.offsets[-1])

    def unflatten(self, w: jax.Array) -> dict[str, jax.Array]:
        out = {}
        for (name, shape), size, off in zip(
            self.entries, self.sizes, self.offsets[:-1]
        ):
            out[name] = lax.dynamic_slice(w, (int(off),), (size,)).reshape(shape)
        return out

    def flatten(self, params: dict[str, jax.Array]) -> jax.Array:
        return jnp.concatenate(
            [params[name].reshape(-1) for name, _ in self.entries]
        )

    def init(self, seed: int) -> np.ndarray:
        """He-normal init for weight tensors, zeros for biases/BN-beta,
        ones for BN-gamma.  Deterministic given ``seed``."""
        rng = np.random.default_rng(seed)
        chunks = []
        for name, shape in self.entries:
            n = int(np.prod(shape)) if shape else 1
            if name.endswith("gamma"):
                chunks.append(np.ones(n, np.float32))
            elif name.endswith(("beta", "bias", "b")):
                chunks.append(np.zeros(n, np.float32))
            else:
                fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
                std = math.sqrt(2.0 / max(fan_in, 1))
                chunks.append(rng.normal(0.0, std, n).astype(np.float32))
        return np.concatenate(chunks)

    def manifest(self) -> list[dict[str, Any]]:
        return [
            {"name": n, "shape": list(s), "offset": int(o), "size": int(z)}
            for (n, s), z, o in zip(self.entries, self.sizes, self.offsets[:-1])
        ]


# ---------------------------------------------------------------------------
# Linear regression (Fig. 2, §4.1) — least-squares loss
# ---------------------------------------------------------------------------


def linreg_loss(w, x, y):
    """F_n(w) = 1/(2 D) * ||X w - y||^2  (LS loss used by the paper's
    linear-regression testbed; the 1/2 makes grad = X^T(Xw-y)/D)."""
    r = x @ w - y
    return 0.5 * jnp.mean(r * r)


def linreg_grad(w, x, y):
    return jax.value_and_grad(linreg_loss)(w, x, y)


# ---------------------------------------------------------------------------
# Logistic regression (Fig. 1 toy, §1.2)
# ---------------------------------------------------------------------------


def logistic_loss(w, x, y):
    """Cross-entropy with ±1 labels: mean log(1 + exp(-y <w;x>))."""
    z = (x @ w) * y
    return jnp.mean(jnp.logaddexp(0.0, -z))


def logistic_grad(w, x, y):
    return jax.value_and_grad(logistic_loss)(w, x, y)


# ---------------------------------------------------------------------------
# MLP classifier (extra workload; exercises multi-layer flat packing)
# ---------------------------------------------------------------------------


def mlp_spec(in_dim: int, hidden: list[int], classes: int) -> ParamSpec:
    entries = []
    dims = [in_dim] + hidden + [classes]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        entries.append((f"fc{i}.w", (a, b)))
        entries.append((f"fc{i}.b", (b,)))
    return ParamSpec(entries)


def mlp_logits(spec: ParamSpec, w, x):
    p = spec.unflatten(w)
    h = x
    n_layers = len(spec.entries) // 2
    for i in range(n_layers):
        h = h @ p[f"fc{i}.w"] + p[f"fc{i}.b"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def mlp_loss(spec: ParamSpec, w, x, y):
    return softmax_xent(mlp_logits(spec, w, x), y)


def mlp_grad(spec: ParamSpec, w, x, y):
    return jax.value_and_grad(lambda ww: mlp_loss(spec, ww, x, y))(w)


# ---------------------------------------------------------------------------
# ResNet-CIFAR family (Fig. 3, §4.2)
# ---------------------------------------------------------------------------
#
# Two variants:
#   * resnet_cifar(n, width):  He et al. CIFAR family, depth 6n+2, stage
#     widths (w, 2w, 4w).  resnet8 = (n=1, w=8): CPU-tractable e2e runs.
#   * resnet18(width=64):      the paper's model — ImageNet basic-block
#     layout [2,2,2,2] with widths (w, 2w, 4w, 8w) adapted to 32x32
#     inputs (3x3 stem, no max-pool), 11.2M params at w=64.
#
# BatchNorm uses training-mode batch statistics (stateless — no running
# averages), which is the behaviour that matters for gradient statistics.


def _conv(x, k, stride):
    return lax.conv_general_dilated(
        x,
        k,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _bn(x, gamma, beta, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return gamma * (x - mean) * lax.rsqrt(var + eps) + beta


class ResNetDef:
    """Architecture description + flat-parameter forward pass."""

    def __init__(self, stage_blocks: list[int], widths: list[int], classes=10):
        assert len(stage_blocks) == len(widths)
        self.stage_blocks = stage_blocks
        self.widths = widths
        self.classes = classes
        self.spec = self._build_spec()

    def _build_spec(self) -> ParamSpec:
        e: list[tuple[str, tuple[int, ...]]] = []
        w0 = self.widths[0]
        e.append(("stem.conv", (3, 3, 3, w0)))
        e.append(("stem.gamma", (w0,)))
        e.append(("stem.beta", (w0,)))
        c_in = w0
        for s, (nb, c_out) in enumerate(zip(self.stage_blocks, self.widths)):
            for b in range(nb):
                pre = f"s{s}b{b}"
                cin = c_in if b == 0 else c_out
                e.append((f"{pre}.conv1", (3, 3, cin, c_out)))
                e.append((f"{pre}.gamma1", (c_out,)))
                e.append((f"{pre}.beta1", (c_out,)))
                e.append((f"{pre}.conv2", (3, 3, c_out, c_out)))
                e.append((f"{pre}.gamma2", (c_out,)))
                e.append((f"{pre}.beta2", (c_out,)))
                if b == 0 and cin != c_out:
                    e.append((f"{pre}.proj", (1, 1, cin, c_out)))
            c_in = c_out
        e.append(("fc.w", (self.widths[-1], self.classes)))
        e.append(("fc.b", (self.classes,)))
        return ParamSpec(e)

    def logits(self, w, x):
        p = self.spec.unflatten(w)
        h = jax.nn.relu(
            _bn(_conv(x, p["stem.conv"], 1), p["stem.gamma"], p["stem.beta"])
        )
        for s, (nb, c_out) in enumerate(zip(self.stage_blocks, self.widths)):
            for b in range(nb):
                pre = f"s{s}b{b}"
                stride = 2 if (b == 0 and s > 0) else 1
                y = jax.nn.relu(
                    _bn(
                        _conv(h, p[f"{pre}.conv1"], stride),
                        p[f"{pre}.gamma1"],
                        p[f"{pre}.beta1"],
                    )
                )
                y = _bn(
                    _conv(y, p[f"{pre}.conv2"], 1),
                    p[f"{pre}.gamma2"],
                    p[f"{pre}.beta2"],
                )
                if f"{pre}.proj" in p:
                    h = _conv(h, p[f"{pre}.proj"], stride)
                elif stride != 1:
                    h = h[:, ::stride, ::stride, :]
                h = jax.nn.relu(h + y)
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        return h @ p["fc.w"] + p["fc.b"]

    def loss(self, w, x, y):
        return softmax_xent(self.logits(w, x), y)

    def grad(self, w, x, y):
        return jax.value_and_grad(lambda ww: self.loss(ww, x, y))(w)

    @property
    def param_count(self) -> int:
        return self.spec.total


def resnet_cifar(n: int, width: int = 16) -> ResNetDef:
    """He-et-al CIFAR ResNet: depth 6n+2, widths (w, 2w, 4w)."""
    return ResNetDef([n, n, n], [width, 2 * width, 4 * width])


def resnet8(width: int = 8) -> ResNetDef:
    return resnet_cifar(1, width)


def resnet20(width: int = 16) -> ResNetDef:
    return resnet_cifar(3, width)


def resnet18(width: int = 64) -> ResNetDef:
    """The paper's model: [2,2,2,2] basic blocks, 11.2M params at w=64."""
    return ResNetDef([2, 2, 2, 2], [width, 2 * width, 4 * width, 8 * width])


# ---------------------------------------------------------------------------
# Fused worker step  (L2 gradient + L1 REGTOP-k score in one HLO module)
# ---------------------------------------------------------------------------


def worker_step(grad_fn):
    """Wrap a ``(w, x, y) -> (loss, grad)`` graph into the fused
    REGTOP-k worker step used by the rust coordinator:

        inputs : w, eps, acc_prev, gagg_prev, mask_prev, x, y,
                 scal = [omega, mu, q]          (f32[3])
        outputs: (loss, acc, score)

    One PJRT round-trip per worker per iteration; selection (top-k over
    |score|) and the error update happen in rust on the returned
    vectors (or via the error_feedback artifact).
    """

    def step(w, eps, acc_prev, gagg_prev, mask_prev, x, y, scal):
        loss, g = grad_fn(w, x, y)
        acc, score = k_regtopk.regtopk_score(
            eps, g, acc_prev, gagg_prev, mask_prev, scal[0], scal[1], scal[2]
        )
        return loss, acc, score

    return step
