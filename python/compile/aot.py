"""AOT pipeline: lower every (model, shape) variant to HLO *text*.

Interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust
crate's XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Outputs (all under ``artifacts/``):

  <name>.hlo.txt        one per artifact (see ARTIFACT REGISTRY below)
  init_<model>.f32      seeded initial flat parameter vector (raw LE f32)
  manifest.json         artifact input/output specs + model param layouts

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile
target `artifacts` does this, skipping the rebuild when inputs are
unchanged).  ``--full`` additionally lowers the 11.2M-param resnet18
graphs (slow; not needed by the default test/bench suite).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import error_feedback as k_ef
from .kernels import quantize as k_quant
from .kernels import regtopk as k_regtopk
from .kernels import sgd as k_sgd


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


_DTYPE_NAMES = {np.dtype("float32"): "f32", np.dtype("int32"): "i32"}


class Registry:
    """Collects artifacts, writes HLO files + the JSON manifest."""

    def __init__(self, out_dir: pathlib.Path):
        self.out_dir = out_dir
        self.manifest: dict = {"artifacts": {}, "models": {}}

    def add(self, name: str, fn, in_specs: list, n_outputs: int, doc: str):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = self.out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        self.manifest["artifacts"][name] = {
            "file": path.name,
            "doc": doc,
            "inputs": [
                {"shape": list(s.shape), "dtype": _DTYPE_NAMES[np.dtype(s.dtype)]}
                for s in in_specs
            ],
            "outputs": n_outputs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {name}: {len(text)} chars -> {path.name}")

    def add_model(self, name: str, pspec: M.ParamSpec, seed: int):
        w0 = pspec.init(seed)
        init_file = f"init_{name}.f32"
        (self.out_dir / init_file).write_bytes(w0.astype("<f4").tobytes())
        self.manifest["models"][name] = {
            "param_count": pspec.total,
            "init_file": init_file,
            "init_seed": seed,
            "layers": pspec.manifest(),
        }
        print(f"  model {name}: J={pspec.total} ({init_file})")

    def finish(self):
        (self.out_dir / "manifest.json").write_text(
            json.dumps(self.manifest, indent=1)
        )
        print(f"  manifest.json: {len(self.manifest['artifacts'])} artifacts")


# ---------------------------------------------------------------------------
# ARTIFACT REGISTRY
# ---------------------------------------------------------------------------

# Fig. 2 geometry (paper §4.1): J=100 features, D=500 points per worker.
LINREG_J, LINREG_D = 100, 500
# Fig. 3 geometry (paper §4.2): batch 20 per worker, 32x32x3 inputs.
CNN_BATCH, EVAL_BATCH = 20, 100
# Standalone kernel artifacts at a generic large J (2^17) for the
# runtime's large-vector sparsification path + kernel benches.
KERNEL_J = 1 << 17


def build(out_dir: pathlib.Path, full: bool) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    reg = Registry(out_dir)

    f32 = jnp.float32
    i32 = jnp.int32

    # ---- linear regression (Fig. 2) -----------------------------------
    wj = spec([LINREG_J])
    xs = spec([LINREG_D, LINREG_J])
    ys = spec([LINREG_D])
    reg.add(
        "linreg_grad",
        M.linreg_grad,
        [wj, xs, ys],
        2,
        "LS loss+grad, Fig.2 geometry (J=100, D=500)",
    )
    reg.add(
        "linreg_worker_step",
        M.worker_step(M.linreg_grad),
        [wj, wj, wj, wj, wj, xs, ys, spec([3])],
        3,
        "fused grad + REGTOP-k score (L2+L1), Fig.2 geometry",
    )

    # ---- MLP on flattened CIFAR-like inputs ---------------------------
    mlp = M.mlp_spec(3072, [128], 10)
    wm = spec([mlp.total])
    xm = spec([CNN_BATCH, 3072])
    ym = spec([CNN_BATCH], i32)
    reg.add(
        "mlp_grad",
        lambda w, x, y: M.mlp_grad(mlp, w, x, y),
        [wm, xm, ym],
        2,
        "MLP(3072-128-10) loss+grad, batch 20",
    )
    reg.add_model("mlp", mlp, seed=7)

    # ---- ResNet-8 (Fig. 3 default substrate) --------------------------
    net = M.resnet8()
    wc = spec([net.param_count])
    xc_ = spec([CNN_BATCH, 32, 32, 3])
    yc = spec([CNN_BATCH], i32)
    reg.add(
        "cnn_grad_resnet8",
        net.grad,
        [wc, xc_, yc],
        2,
        "ResNet-8 loss+grad, batch 20 (Fig.3 substrate)",
    )
    reg.add(
        "cnn_eval_resnet8",
        net.logits,
        [wc, spec([EVAL_BATCH, 32, 32, 3])],
        1,
        "ResNet-8 logits, eval batch 100",
    )
    reg.add(
        "cnn_worker_step_resnet8",
        M.worker_step(net.grad),
        [wc, wc, wc, wc, wc, xc_, yc, spec([3])],
        3,
        "fused ResNet-8 grad + REGTOP-k score (L2+L1)",
    )
    reg.add_model("resnet8", net.spec, seed=42)

    # ---- resnet18 (paper-exact model; opt-in, slow to lower) ----------
    if full:
        net18 = M.resnet18()
        w18 = spec([net18.param_count])
        reg.add(
            "cnn_grad_resnet18",
            net18.grad,
            [w18, xc_, yc],
            2,
            "ResNet-18 (11.2M params) loss+grad, batch 20",
        )
        reg.add_model("resnet18", net18.spec, seed=42)

    # ---- standalone L1 kernels at generic J ---------------------------
    vk = spec([KERNEL_J])
    reg.add(
        "regtopk_score",
        lambda e, g, ap, gp, mp, s: k_regtopk.regtopk_score(
            e, g, ap, gp, mp, s[0], s[1], s[2]
        ),
        [vk, vk, vk, vk, vk, spec([3])],
        2,
        f"fused REGTOP-k score pass, J=2^17={KERNEL_J}",
    )
    reg.add(
        "error_feedback",
        k_ef.error_feedback,
        [vk, vk],
        2,
        f"fused sparsify + error update, J={KERNEL_J}",
    )
    reg.add(
        "sgd_apply",
        lambda w, g, s: k_sgd.sgd_apply(w, g, s[0]),
        [vk, vk, spec([1])],
        1,
        f"fused SGD apply, J={KERNEL_J}",
    )
    reg.add(
        "quantize_sr4",
        lambda x, noise: k_quant.quantize_sr(x, noise, 4),
        [vk, vk],
        1,
        f"4-bit stochastic-rounding quantizer, J={KERNEL_J}",
    )
    reg.add(
        "momentum_apply",
        lambda w, m, g, s: k_sgd.momentum_apply(w, m, g, s[0], s[1]),
        [vk, vk, vk, spec([2])],
        2,
        f"fused momentum apply, J={KERNEL_J}",
    )

    reg.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--full", action="store_true", help="also lower resnet18 (11.2M params)"
    )
    # Legacy single-file interface kept for Makefile compatibility: the
    # stamp target passes --out <dir>/STAMP; we derive the dir from it.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = (
        pathlib.Path(args.out).parent
        if args.out
        else pathlib.Path(args.out_dir)
    )
    build(out_dir, full=args.full)
    if args.out:
        pathlib.Path(args.out).write_text("ok\n")


if __name__ == "__main__":
    main()
