"""Quantization kernel vs oracle + statistical properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as k_quant
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


@settings(**SETTINGS)
@given(
    st.integers(1, 500),
    st.integers(0, 2**31 - 1),
    st.sampled_from([2, 4, 8, 16]),
)
def test_kernel_matches_oracle(j, seed, bits):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(j), jnp.float32)
    noise = jnp.asarray(rng.random(j), jnp.float32)
    got = k_quant.quantize_sr(x, noise, bits, block=128)
    want = ref.quantize_sr(x, noise, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_error_bounded_by_one_level():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 5, jnp.float32)
    noise = jnp.asarray(rng.random(1000), jnp.float32)
    q = np.asarray(ref.quantize_sr(x, noise, 4))
    scale = np.abs(np.asarray(x)).max() / 7.0
    assert np.all(np.abs(q - np.asarray(x)) <= scale * 1.0001)


def test_unbiased_in_expectation():
    rng = np.random.default_rng(1)
    x = jnp.asarray([0.37, 1.0], jnp.float32)  # second entry sets scale
    total = np.zeros(2)
    n = 4000
    for _ in range(n):
        noise = jnp.asarray(rng.random(2), jnp.float32)
        total += np.asarray(ref.quantize_sr(x, noise, 4))
    mean = total / n
    assert abs(mean[0] - 0.37) < 0.02, mean


def test_passthrough_32_bits():
    x = jnp.asarray([0.123, -4.5], jnp.float32)
    noise = jnp.zeros(2, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(k_quant.quantize_sr(x, noise, 32)), np.asarray(x)
    )


def test_zero_vector_stays_zero():
    x = jnp.zeros(64, jnp.float32)
    noise = jnp.full(64, 0.99, jnp.float32)
    q = np.asarray(k_quant.quantize_sr(x, noise, 4, block=32))
    np.testing.assert_array_equal(q, np.zeros(64))
