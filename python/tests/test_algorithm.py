"""Algorithm-level validation of REGTOP-k using the ref oracles.

Re-runs the paper's §1.2 motivational example and a miniature Fig. 2
linear-regression experiment entirely in python — these mirror the rust
integration tests, so a discrepancy between layers localizes fast.
"""

import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import ref


def dist_train(grad_fns, w0, eta, iters, sparsifier, k, omega=None, mu=0.5, q=1.0):
    """Minimal distributed-SGD loop over the ref oracles.

    sparsifier: 'dense' | 'topk' | 'regtopk'.  Returns trajectory of w.
    """
    n = len(grad_fns)
    omega = omega if omega is not None else 1.0 / n
    j = w0.shape[0]
    w = jnp.asarray(w0)
    eps = [jnp.zeros(j) for _ in range(n)]
    acc_prev = [jnp.zeros(j) for _ in range(n)]
    mask_prev = [jnp.zeros(j) for _ in range(n)]
    gagg_prev = jnp.zeros(j)
    traj = [np.asarray(w).copy()]
    for t in range(iters):
        gagg = jnp.zeros(j)
        for i in range(n):
            g = grad_fns[i](w)
            if sparsifier == "dense":
                ghat = g
            elif sparsifier == "topk":
                ghat, eps[i], _, _ = ref.topk_step(eps[i], g, k)
            else:
                if t == 0:
                    # Alg. 1 line 1: plain TOP-k in the initial iteration.
                    acc = ref.accumulate(eps[i], g)
                    mask = ref.topk_mask(acc, k)
                    ghat, eps[i] = ref.error_feedback(acc, mask)
                else:
                    ghat, eps[i], mask, acc, _ = ref.regtopk_step(
                        eps[i], g, acc_prev[i], gagg_prev, mask_prev[i],
                        omega, mu, q, k,
                    )
                if sparsifier == "regtopk":
                    acc_prev[i], mask_prev[i] = acc, mask
            gagg = gagg + omega * ghat
        gagg_prev = gagg
        w = w - eta * gagg
        traj.append(np.asarray(w).copy())
    return np.stack(traj)


def toy_grad_fns():
    """§1.2 toy: two workers, J=2, x1=[100,1], x2=[-100,1], labels +1."""
    x1 = jnp.asarray([[100.0, 1.0]])
    x2 = jnp.asarray([[-100.0, 1.0]])
    y = jnp.asarray([1.0])
    return [
        lambda w: M.logistic_grad(w, x1, y)[1],
        lambda w: M.logistic_grad(w, x2, y)[1],
    ]


def toy_loss(w):
    x1 = jnp.asarray([[100.0, 1.0]])
    x2 = jnp.asarray([[-100.0, 1.0]])
    y = jnp.asarray([1.0])
    return 0.5 * (
        float(M.logistic_loss(w, x1, y)) + float(M.logistic_loss(w, x2, y))
    )


class TestToyExample:
    """The paper's Fig. 1 behaviour, reproduced exactly."""

    def test_top1_stalls_at_w0(self):
        # TOP-1 selects the (cancelling) first entries; the aggregated
        # sparsified gradient is zero, so w stays at w0 for many iters.
        w0 = jnp.asarray([0.0, 1.0])
        traj = dist_train(toy_grad_fns(), w0, 0.9, 40, "topk", k=1)
        # still exactly at w0 after 40 iterations
        np.testing.assert_allclose(traj[40], np.asarray(w0), atol=1e-12)

    def test_dense_descends_immediately(self):
        w0 = jnp.asarray([0.0, 1.0])
        traj = dist_train(toy_grad_fns(), w0, 0.9, 5, "dense", k=2)
        assert toy_loss(jnp.asarray(traj[5])) < toy_loss(w0)

    def test_regtop1_tracks_dense(self):
        # Paper: "REGTOP-1 tracks non-sparsified training consistently."
        w0 = jnp.asarray([0.0, 1.0])
        dense = dist_train(toy_grad_fns(), w0, 0.9, 30, "dense", k=2)
        reg = dist_train(
            toy_grad_fns(), w0, 0.9, 30, "regtopk", k=1, mu=0.5, q=1.0
        )
        top = dist_train(toy_grad_fns(), w0, 0.9, 30, "topk", k=1)
        l_dense = toy_loss(jnp.asarray(dense[30]))
        l_reg = toy_loss(jnp.asarray(reg[30]))
        l_top = toy_loss(jnp.asarray(top[30]))
        # REGTOP-1 ends much closer to dense than TOP-1 does.
        assert l_reg < l_top
        assert (l_reg - l_dense) < 0.3 * (l_top - l_dense)

    def test_learning_rate_scaling_factor(self):
        # §1.2 extension: with loss + G(theta2), TOP-1 stalls ~50 iters
        # then jumps with accumulated magnitude ~ t * |g[1]| — the
        # "learning rate scaling" factor. We verify the stall-then-jump
        # shape: max per-step movement >> first-step dense movement.
        w0 = jnp.asarray([0.0, 1.0])
        fns = toy_grad_fns()
        # add dG/dtheta2 = 1 to worker losses (G'(1)=1 at theta2=1; use
        # constant-derivative G for the whole run, matching the paper's
        # linear-G reading).
        fns_g = [
            (lambda f: (lambda w: f(w) + jnp.asarray([0.0, 1.0])))(f)
            for f in fns
        ]
        traj = dist_train(fns_g, w0, 0.01, 80, "topk", k=1)
        steps = np.linalg.norm(np.diff(traj, axis=0), axis=1)
        stall = steps[:10].max()
        jump = steps.max()
        assert stall < 1e-9  # initial stall: zero aggregate
        # Crossover analysis: entry 0 re-accumulates |a0| = 100*s each
        # iter (sent and cancelled), entry 1 accumulates t*(s+1) where
        # s = sigma(-1) = 0.269; crossover at t* ~= 100*0.269/1.269 ~= 21,
        # so the released step scales the learning rate by ~21x (the
        # paper's "factor 50" uses its 0.736 gradient convention).
        g1 = 1.269
        scaling = jump / (0.01 * g1)
        assert scaling > 15.0


class TestMiniLinreg:
    """Scaled-down Fig. 2: REGTOP-k reaches a smaller optimality gap
    than TOP-k at the same sparsity factor."""

    def _setup(self, seed=0, n=4, d=40, j=20):
        rng = np.random.default_rng(seed)
        xs, ys, fns = [], [], []
        for i in range(n):
            u = rng.normal(0.0, np.sqrt(5.0))
            t = rng.normal(u, 1.0, j)
            x = rng.standard_normal((d, j))
            y = x @ t + rng.normal(0, np.sqrt(0.5), d)
            xs.append(jnp.asarray(x, jnp.float32))
            ys.append(jnp.asarray(y, jnp.float32))
        for x, y in zip(xs, ys):
            fns.append(
                (lambda xx, yy: lambda w: M.linreg_grad(w, xx, yy)[1])(x, y)
            )
        # global LS optimum of the averaged objective
        xall = np.concatenate([np.asarray(x) for x in xs])
        yall = np.concatenate([np.asarray(y) for y in ys])
        wstar = np.linalg.lstsq(xall, yall, rcond=None)[0]
        return fns, jnp.zeros(j), wstar

    def test_regtopk_beats_topk_gap(self):
        fns, w0, wstar = self._setup()
        iters, k = 300, 12  # S = 0.6
        top = dist_train(fns, w0, 0.05, iters, "topk", k=k)
        reg = dist_train(fns, w0, 0.05, iters, "regtopk", k=k, mu=0.5, q=1.0)
        gap_top = np.linalg.norm(top[-1] - wstar)
        gap_reg = np.linalg.norm(reg[-1] - wstar)
        assert gap_reg < gap_top

    def test_dense_converges(self):
        fns, w0, wstar = self._setup()
        dense = dist_train(fns, w0, 0.05, 300, "dense", k=20)
        assert np.linalg.norm(dense[-1] - wstar) < 0.5
