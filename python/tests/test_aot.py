"""AOT artifact sanity: manifest consistency, HLO parse-ability, init files."""

import json
import pathlib

import numpy as np
import pytest

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


def manifest():
    return json.loads((ART / "manifest.json").read_text())


class TestManifest:
    def test_every_artifact_file_exists_and_is_hlo(self):
        m = manifest()
        assert len(m["artifacts"]) >= 10
        for name, a in m["artifacts"].items():
            text = (ART / a["file"]).read_text()
            assert "HloModule" in text, name
            assert "ENTRY" in text, name

    def test_inputs_declared_for_all(self):
        m = manifest()
        for name, a in m["artifacts"].items():
            assert a["outputs"] >= 1, name
            for inp in a["inputs"]:
                assert inp["dtype"] in ("f32", "i32"), name
                assert all(d > 0 for d in inp["shape"]), name

    def test_linreg_grad_signature_matches_fig2_geometry(self):
        a = manifest()["artifacts"]["linreg_grad"]
        shapes = [tuple(i["shape"]) for i in a["inputs"]]
        assert shapes == [(100,), (500, 100), (500,)]

    def test_worker_step_has_fused_inputs(self):
        a = manifest()["artifacts"]["cnn_worker_step_resnet8"]
        # w, eps, acc_prev, gagg_prev, mask_prev, x, y, scal
        assert len(a["inputs"]) == 8
        assert a["outputs"] == 3

    def test_init_files_match_param_counts(self):
        m = manifest()
        for name, mm in m["models"].items():
            raw = (ART / mm["init_file"]).read_bytes()
            assert len(raw) == 4 * mm["param_count"], name
            w = np.frombuffer(raw, "<f4")
            assert np.all(np.isfinite(w)), name

    def test_layer_manifest_covers_flat_vector(self):
        m = manifest()
        for name, mm in m["models"].items():
            layers = mm["layers"]
            end = 0
            for l in layers:
                assert l["offset"] == end
                end += l["size"]
            assert end == mm["param_count"], name

    def test_resnet8_init_reproducible(self):
        # re-derive the seeded init and compare to the artifact
        import sys

        sys.path.insert(0, str(ART.parent / "python"))
        from compile import model as M

        m = manifest()["models"]["resnet8"]
        w_art = np.frombuffer((ART / m["init_file"]).read_bytes(), "<f4")
        w_new = M.resnet8().spec.init(m["init_seed"])
        np.testing.assert_array_equal(w_art, w_new)
