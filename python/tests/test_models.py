"""L2 model graphs: shapes, closed-form gradients, param counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


class TestParamSpec:
    def test_flatten_unflatten_roundtrip(self):
        spec = M.mlp_spec(8, [4], 3)
        w = jnp.arange(spec.total, dtype=jnp.float32)
        p = spec.unflatten(w)
        np.testing.assert_array_equal(np.asarray(spec.flatten(p)), np.asarray(w))

    def test_init_deterministic_and_typed(self):
        spec = M.mlp_spec(8, [4], 3)
        a, b = spec.init(5), spec.init(5)
        np.testing.assert_array_equal(a, b)
        assert a.dtype == np.float32
        # biases are zero
        p = spec.unflatten(jnp.asarray(a))
        np.testing.assert_array_equal(np.asarray(p["fc0.b"]), np.zeros(4))

    def test_manifest_offsets_cover_total(self):
        spec = M.resnet8().spec
        man = spec.manifest()
        assert man[0]["offset"] == 0
        assert man[-1]["offset"] + man[-1]["size"] == spec.total


class TestLinreg:
    def test_grad_closed_form(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((50, 10)), jnp.float32)
        y = jnp.asarray(rng.standard_normal(50), jnp.float32)
        w = jnp.asarray(rng.standard_normal(10), jnp.float32)
        loss, g = M.linreg_grad(w, x, y)
        r = np.asarray(x) @ np.asarray(w) - np.asarray(y)
        np.testing.assert_allclose(float(loss), 0.5 * np.mean(r * r), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(x).T @ r / 50, rtol=1e-4, atol=1e-6
        )


class TestLogistic:
    def test_grad_matches_paper_eq2(self):
        # Paper eq. (2): g = -exp(-<w;x>) x / (1 + exp(-<w;x>)) for label +1.
        x = jnp.asarray([[100.0, 1.0]])
        y = jnp.asarray([1.0])
        w = jnp.asarray([0.0, 1.0])
        _, g = M.logistic_grad(w, x, y)
        z = np.exp(-1.0)  # -<w;x> = -1
        expected = -z / (1 + z) * np.array([100.0, 1.0])
        np.testing.assert_allclose(np.asarray(g), expected, rtol=1e-5)

    def test_toy_gradients_at_w0(self):
        # §1.2: at w0=[0,1], g1 = 0.269*[-100,1]... actually the paper
        # says 0.736[-100,1] using sigmoid(-1)=0.269? Verify numerically:
        # sigma(-<w;x>) with <w0;x1>=1 gives factor exp(-1)/(1+exp(-1))
        # = 0.2689. The paper's 0.736 appears to use a different sign
        # convention; what matters (and what we check) is |g[0]|/|g[1]|
        # = 100 and the two workers' first entries cancel.
        w0 = jnp.asarray([0.0, 1.0])
        _, g1 = M.logistic_grad(w0, jnp.asarray([[100.0, 1.0]]), jnp.asarray([1.0]))
        _, g2 = M.logistic_grad(w0, jnp.asarray([[-100.0, 1.0]]), jnp.asarray([1.0]))
        g1, g2 = np.asarray(g1), np.asarray(g2)
        assert abs(g1[0] / g1[1]) == pytest.approx(100.0)
        assert g1[0] + g2[0] == pytest.approx(0.0, abs=1e-9)
        assert g1[1] + g2[1] != 0.0


class TestMlp:
    def test_grad_shapes_and_descent(self):
        spec = M.mlp_spec(12, [8], 3)
        rng = np.random.default_rng(1)
        w = jnp.asarray(spec.init(1))
        x = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 3, 16), jnp.int32)
        loss, g = M.mlp_grad(spec, w, x, y)
        assert g.shape == (spec.total,)
        loss2, _ = M.mlp_grad(spec, w - 0.1 * g, x, y)
        assert float(loss2) < float(loss)


class TestResNet:
    def test_resnet18_param_count_matches_paper(self):
        # ResNet-18 is ~11.2M params (paper cites ResNet-110 at 1.7M for
        # scale; ResNet-18's canonical count is 11,173,962 for ImageNet;
        # our CIFAR adaptation drops the 7x7 stem for 3x3).
        n = M.resnet18()
        assert 11_000_000 < n.param_count < 11_300_000

    def test_resnet8_forward_shapes(self):
        n = M.resnet8()
        w = jnp.asarray(n.spec.init(0))
        x = jnp.zeros((4, 32, 32, 3), jnp.float32)
        logits = n.logits(w, x)
        assert logits.shape == (4, 10)

    def test_resnet8_grad_descends(self):
        n = M.resnet8()
        rng = np.random.default_rng(2)
        w = jnp.asarray(n.spec.init(2))
        x = jnp.asarray(rng.standard_normal((8, 32, 32, 3)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
        loss, g = n.grad(w, x, y)
        assert np.all(np.isfinite(np.asarray(g)))
        loss2, _ = n.grad(w - 0.05 * g, x, y)
        assert float(loss2) < float(loss)

    def test_stage_downsampling(self):
        # widths double and spatial halves at each stage transition:
        # output of GAP must have the last-stage width.
        n = M.resnet_cifar(1, 4)
        assert n.widths == [4, 8, 16]
        w = jnp.asarray(n.spec.init(0))
        logits = n.logits(w, jnp.zeros((2, 32, 32, 3)))
        assert logits.shape == (2, 10)


class TestWorkerStep:
    def test_fused_step_equals_composition(self):
        rng = np.random.default_rng(3)
        j, d = 10, 20
        x = jnp.asarray(rng.standard_normal((d, j)), jnp.float32)
        y = jnp.asarray(rng.standard_normal(d), jnp.float32)
        w, eps, ap, gp = (
            jnp.asarray(rng.standard_normal(j), jnp.float32) for _ in range(4)
        )
        mp = jnp.asarray(rng.integers(0, 2, j), jnp.float32)
        scal = jnp.asarray([0.05, 0.5, 1.0])
        step = M.worker_step(M.linreg_grad)
        loss, acc, score = step(w, eps, ap, gp, mp, x, y, scal)
        loss_r, g_r = M.linreg_grad(w, x, y)
        from compile.kernels import ref

        acc_r, score_r = ref.regtopk_score(eps, g_r, ap, gp, mp, 0.05, 0.5, 1.0)
        np.testing.assert_allclose(float(loss), float(loss_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_r), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(score), np.asarray(score_r), rtol=1e-4, atol=1e-6
        )
