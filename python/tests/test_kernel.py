"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Every kernel in python/compile/kernels/ is swept against its ref.py
oracle with hypothesis over shapes, block sizes and value regimes
(including the adversarial ones: zeros in the denominator of the
posterior distortion, huge magnitudes, tiny mu).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import error_feedback as k_ef
from compile.kernels import ref
from compile.kernels import regtopk as k_regtopk
from compile.kernels import sgd as k_sgd
from compile.kernels import topk_mask as k_topk

# Hypothesis profile: kernels run under interpret=True (slow), keep the
# example counts moderate but the value space adversarial.
SETTINGS = dict(max_examples=20, deadline=None)


def vec(rng, j, scale=1.0):
    return jnp.asarray(rng.standard_normal(j) * scale, jnp.float32)


@st.composite
def score_case(draw):
    j = draw(st.integers(1, 700))
    block = draw(st.sampled_from([32, 128, 256]))
    seed = draw(st.integers(0, 2**31 - 1))
    omega = draw(st.sampled_from([1.0, 0.5, 0.125, 1 / 20]))
    mu = draw(st.sampled_from([1e-3, 0.1, 0.5, 2.0]))
    q = draw(st.sampled_from([0.0, 0.5, 1.0, 10.0]))
    scale = draw(st.sampled_from([1e-4, 1.0, 1e4]))
    return j, block, seed, omega, mu, q, scale


class TestRegTopKScore:
    @settings(**SETTINGS)
    @given(score_case())
    def test_matches_ref(self, case):
        j, block, seed, omega, mu, q, scale = case
        rng = np.random.default_rng(seed)
        eps, g, ap, gp = (vec(rng, j, scale) for _ in range(4))
        mp = jnp.asarray(rng.integers(0, 2, j), jnp.float32)
        a_ref, s_ref = ref.regtopk_score(eps, g, ap, gp, mp, omega, mu, q)
        a_ker, s_ker = k_regtopk.regtopk_score(
            eps, g, ap, gp, mp, omega, mu, q, block=block
        )
        np.testing.assert_allclose(a_ker, a_ref, rtol=1e-6, atol=0)
        np.testing.assert_allclose(
            s_ker, s_ref, rtol=1e-5, atol=1e-6 * scale
        )

    def test_zero_denominator_entries_are_finite(self):
        # acc = eps + g == 0 at masked positions: distortion guard must
        # kick in; score must be exactly 0 (acc==0) and finite.
        j = 64
        eps = jnp.zeros(j)
        g = jnp.zeros(j)
        ap = jnp.ones(j)
        gp = jnp.ones(j)
        mp = jnp.ones(j)
        acc, score = k_regtopk.regtopk_score(
            eps, g, ap, gp, mp, 0.5, 0.1, 1.0, block=32
        )
        assert np.all(np.isfinite(np.asarray(score)))
        np.testing.assert_array_equal(np.asarray(score), np.zeros(j))

    def test_destructive_cancellation_damps_score(self):
        # Paper §3.2 discussion case (2): entry sent last round whose
        # aggregate came back ~0 has Delta ~= -1 -> tanh(0) ~= 0 -> score
        # damped to ~0 even though |acc| is the largest.
        eps = jnp.zeros(4)
        g = jnp.array([100.0, 1.0, 0.5, 0.1])
        ap = jnp.array([100.0, 0.0, 0.0, 0.0])  # sent entry 0 last round
        gp = jnp.array([0.0, 0.0, 0.0, 0.0])  # ... and it aggregated to 0
        mp = jnp.array([1.0, 0.0, 0.0, 0.0])
        _, score = ref.regtopk_score(eps, g, ap, gp, mp, 1.0, 0.1, 1.0)
        score = np.asarray(score)
        # Entry 0 must lose to entry 1 despite 100x larger magnitude.
        assert abs(score[0]) < abs(score[1])

    def test_mu_to_zero_reduces_to_topk_ordering(self):
        # mu -> 0: tanh(|1+Delta|/mu) -> 1 for any Delta != -1, so the
        # score ordering equals the |acc| ordering (plain TOP-k).
        rng = np.random.default_rng(3)
        j = 128
        eps, g, ap, gp = (vec(rng, j) for _ in range(4))
        mp = jnp.asarray(rng.integers(0, 2, j), jnp.float32)
        acc, score = ref.regtopk_score(eps, g, ap, gp, mp, 0.5, 1e-12, 1.0)
        np.testing.assert_array_equal(
            np.argsort(np.abs(np.asarray(score))),
            np.argsort(np.abs(np.asarray(acc))),
        )


class TestTopKMask:
    @settings(**SETTINGS)
    @given(
        st.integers(1, 500),
        st.integers(0, 2**31 - 1),
        st.integers(0, 600),
    )
    def test_mask_selects_k_largest(self, j, seed, k):
        rng = np.random.default_rng(seed)
        s = vec(rng, j)
        mask = np.asarray(ref.topk_mask(s, k))
        keff = min(k, j)
        assert mask.sum() == keff
        if 0 < keff < j:
            mag = np.abs(np.asarray(s))
            assert mag[mask == 1].min() >= mag[mask == 0].max()

    @settings(**SETTINGS)
    @given(st.integers(1, 600), st.integers(0, 2**31 - 1))
    def test_threshold_kernel_matches_ref(self, j, seed):
        rng = np.random.default_rng(seed)
        s = vec(rng, j)
        tau = float(np.quantile(np.abs(np.asarray(s)), 0.7))
        got = k_topk.threshold_mask(s, tau, block=128)
        want = ref.threshold_mask(s, tau)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(**SETTINGS)
    @given(
        st.integers(1, 600),
        st.integers(0, 2**31 - 1),
        st.sampled_from([32, 64, 128]),
    )
    def test_block_absmax_matches_ref(self, j, seed, block):
        rng = np.random.default_rng(seed)
        s = vec(rng, j)
        got = k_topk.block_absmax(s, block=block)
        want = ref.block_absmax(s, block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_two_phase_equals_exact(self):
        # phase1 absmax + host threshold + phase3 mask == exact top-k
        # when magnitudes are distinct.
        rng = np.random.default_rng(11)
        j, k = 1000, 37
        s = vec(rng, j)
        mag = np.abs(np.asarray(s))
        tau = np.sort(mag)[-k]
        mask2 = np.asarray(k_topk.threshold_mask(s, float(tau), block=128))
        mask_exact = np.asarray(ref.topk_mask(s, k))
        np.testing.assert_array_equal(mask2, mask_exact)


class TestErrorFeedback:
    @settings(**SETTINGS)
    @given(
        st.integers(1, 700),
        st.integers(0, 2**31 - 1),
        st.sampled_from([32, 128, 256]),
    )
    def test_matches_ref_and_conserves(self, j, seed, block):
        rng = np.random.default_rng(seed)
        acc = vec(rng, j, 10.0)
        mask = jnp.asarray(rng.integers(0, 2, j), jnp.float32)
        ghat, eps = k_ef.error_feedback(acc, mask, block=block)
        ghat_r, eps_r = ref.error_feedback(acc, mask)
        np.testing.assert_array_equal(np.asarray(ghat), np.asarray(ghat_r))
        np.testing.assert_array_equal(np.asarray(eps), np.asarray(eps_r))
        # conservation law: acc == ghat + eps' bit-exactly
        np.testing.assert_array_equal(
            np.asarray(ghat) + np.asarray(eps), np.asarray(acc)
        )
        # disjoint support
        assert np.all((np.asarray(ghat) == 0) | (np.asarray(eps) == 0))


class TestSgd:
    @settings(**SETTINGS)
    @given(
        st.integers(1, 700),
        st.integers(0, 2**31 - 1),
        st.sampled_from([1e-4, 0.01, 0.9]),
    )
    def test_sgd_matches_ref(self, j, seed, eta):
        rng = np.random.default_rng(seed)
        w, g = vec(rng, j), vec(rng, j)
        got = k_sgd.sgd_apply(w, g, eta, block=128)
        # 1-ulp difference allowed: the kernel rounds eta to f32 first.
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(ref.sgd_apply(w, g, eta)),
            rtol=1e-5,
            atol=1e-7,
        )

    @settings(**SETTINGS)
    @given(st.integers(1, 700), st.integers(0, 2**31 - 1))
    def test_momentum_matches_ref(self, j, seed):
        rng = np.random.default_rng(seed)
        w, m, g = vec(rng, j), vec(rng, j), vec(rng, j)
        w2, m2 = k_sgd.momentum_apply(w, m, g, 0.01, 0.9, block=128)
        wr, mr = ref.momentum_apply(w, m, g, 0.01, 0.9)
        np.testing.assert_allclose(
            np.asarray(w2), np.asarray(wr), rtol=1e-5, atol=1e-7
        )
        np.testing.assert_allclose(
            np.asarray(m2), np.asarray(mr), rtol=1e-5, atol=1e-7
        )


class TestFullStep:
    @settings(**SETTINGS)
    @given(st.integers(2, 300), st.integers(0, 2**31 - 1))
    def test_regtopk_step_invariants(self, j, seed):
        rng = np.random.default_rng(seed)
        k = max(1, j // 10)
        eps, g, ap, gp = (vec(rng, j) for _ in range(4))
        mp = jnp.asarray(rng.integers(0, 2, j), jnp.float32)
        ghat, eps2, mask, acc, score = ref.regtopk_step(
            eps, g, ap, gp, mp, 1 / 8, 0.5, 1.0, k
        )
        mask = np.asarray(mask)
        assert mask.sum() == k
        np.testing.assert_array_equal(
            np.asarray(ghat) + np.asarray(eps2), np.asarray(acc)
        )
        # selected entries are the k largest |score|
        mag = np.abs(np.asarray(score))
        assert mag[mask == 1].min() >= mag[mask == 0].max() - 1e-12
