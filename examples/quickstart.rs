//! Quickstart: the 60-second tour of the public API.
//!
//! Builds a 4-worker distributed linear-regression problem, trains it
//! three ways (dense, TOP-k, REGTOP-k), and prints optimality gap and
//! communication cost side by side.
//!
//!     cargo run --release --example quickstart

use regtopk::config::TrainConfig;
use regtopk::coordinator::{Server, Trainer, Worker};
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2::opt_gap;
use regtopk::models::LinRegShard;
use regtopk::optim::Sgd;
use regtopk::sparsify::{build, SparsifierKind};

fn main() {
    // 1. A distributed problem: 4 workers, heterogeneous local data.
    let params = LinearParams {
        workers: 4,
        rows_per_worker: 200,
        dim: 50,
        u: 0.0,
        sigma2: 5.0, // worker heterogeneity
        h2: 1.0,
        noise: 0.5,
    };
    let problem = generate(params, /*seed=*/ 1);
    println!("problem: {} workers, J={}, w* known in closed form\n", params.workers, params.dim);

    // 2. Train with three sparsifiers at the same learning rate.
    let k = 15; // transmit 30% of the gradient entries
    let kinds = [
        ("dense  ", SparsifierKind::Dense),
        ("topk   ", SparsifierKind::TopK { k }),
        ("regtopk", SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 }),
    ];
    println!("{:<8} {:>12} {:>14} {:>12}", "algo", "||w-w*||", "upload bytes", "vs dense");
    for (name, kind) in kinds {
        let config = TrainConfig {
            workers: params.workers,
            eta: 0.05,
            sparsifier: kind.clone(),
            ..TrainConfig::default()
        };
        let workers: Vec<Worker> = (0..params.workers)
            .map(|i| {
                Worker::new(
                    i,
                    Box::new(LinRegShard { shard: problem.shards[i].clone() }),
                    build(&kind, params.dim, i),
                )
            })
            .collect();
        let server = Server::new(vec![0.0; params.dim], Box::new(Sgd::new(0.05)));
        let mut trainer = Trainer::new(config, workers, server);
        for _ in 0..500 {
            trainer.round();
        }
        let gap = opt_gap(&trainer.server.w, &problem.w_star);
        let up = trainer.ledger.total_upload_bytes();
        let ratio = trainer.ledger.upload_compression_vs_dense(params.dim, params.workers);
        println!("{name:<8} {gap:>12.6} {up:>14} {ratio:>12.5}");
    }
    println!("\nsame budget for topk/regtopk, ~70% upload savings vs dense.");
    println!("next: examples/toy_logistic.rs (Fig 1), examples/linreg_gap.rs (Fig 2),");
    println!("      examples/cnn_train.rs (Fig 3, end-to-end through PJRT artifacts)");
}
