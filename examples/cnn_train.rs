//! Fig. 3 / END-TO-END DRIVER — trains a CNN through the entire stack
//! on a real (synthetic-CIFAR) workload and logs the loss curve +
//! validation accuracy:
//!
//!   data gen -> shard(8 workers) -> PJRT grad executable (JAX/Pallas
//!   AOT artifact) -> REGTOP-k / TOP-k sparsification -> weighted
//!   aggregation -> SGD -> broadcast -> eval artifact.
//!
//! Python is never on this path; only `artifacts/*.hlo.txt` built once
//! by `make artifacts`.
//!
//!     cargo run --release --example cnn_train -- \
//!         [--iters 300] [--model resnet8|mlp] [--s 0.001] [--dense]
//!
//! The EXPERIMENTS.md §Fig3 record was produced with the defaults.

use regtopk::experiments::fig3::{run, Fig3Config};
use regtopk::runtime::Runtime;
use regtopk::util::cli::Cli;

fn main() {
    let p = Cli::new("Fig 3 end-to-end CNN training")
        .flag("iters", "300", "training iterations")
        .flag("model", "resnet8", "resnet8 | mlp")
        .flag("workers", "8", "workers")
        .flag("s", "0.001", "sparsity factor (paper: 0.001)")
        .flag("eta", "0.01", "learning rate (paper: 0.01)")
        .flag("mu", "0.5", "REGTOP-k temperature")
        .flag("q", "1.0", "REGTOP-k never-sent prior")
        .flag("train-rows", "1600", "synthetic training set size")
        .flag("val-rows", "200", "synthetic validation set size")
        .flag("eval-every", "25", "evaluate accuracy every k iters")
        .flag("seed", "42", "seed (shared init + samplers across algos)")
        .flag("out", "results", "output dir")
        .switch("dense", "also run the dense reference")
        .parse();

    let mut rt = Runtime::open_default().expect("run `make artifacts` first");
    println!("PJRT platform: {}", rt.platform());
    let cfg = Fig3Config {
        workers: p.get_usize("workers"),
        iters: p.get_usize("iters"),
        eta: p.get_f32("eta"),
        s: p.get_f64("s"),
        mu: p.get_f32("mu"),
        q: p.get_f32("q"),
        seed: p.get_usize("seed") as u64,
        train_rows: p.get_usize("train-rows"),
        val_rows: p.get_usize("val-rows"),
        eval_every: p.get_usize("eval-every"),
        ..Fig3Config::default()
    };
    let model = p.get("model").to_string();
    let t0 = std::time::Instant::now();
    let runs = run(&mut rt, &cfg, &model, p.get_bool("dense")).expect("training failed");
    let logs: Vec<_> = runs.into_iter().map(|r| r.log).collect();
    let wall = t0.elapsed().as_secs_f64();

    println!("\n{model}: N={}, S={} (k = S*J), eta={}, {} iters, wall {wall:.1}s", cfg.workers, cfg.s, cfg.eta, cfg.iters);
    println!("\n{:>6} {:>14} {:>14} {:>10} {:>10}", "iter", "loss(topk)", "loss(regtopk)", "acc(topk)", "acc(reg)");
    let step = (cfg.iters / 15).max(1);
    for t in (0..cfg.iters).step_by(step) {
        let a = &logs[0].records()[t];
        let b = &logs[1].records()[t];
        let f = |v: f32| if v.is_nan() { "-".to_string() } else { format!("{v:.3}") };
        println!("{t:>6} {:>14.4} {:>14.4} {:>10} {:>10}", a.loss, b.loss, f(a.accuracy), f(b.accuracy));
    }
    for log in &logs {
        let final_acc = log
            .records()
            .iter()
            .rev()
            .find(|r| !r.accuracy.is_nan())
            .map(|r| r.accuracy)
            .unwrap_or(f32::NAN);
        println!(
            "{:>8}: final loss {:.4}, val acc {:.3}, loss curve {}",
            log.name,
            log.last().unwrap().loss,
            final_acc,
            log.sparkline(|r| r.loss, 40)
        );
        let dir = std::path::PathBuf::from(p.get("out"));
        log.write_csv(&dir.join(format!("cnn_train_{model}_{}.csv", log.name))).unwrap();
    }
    println!("\nwrote CSVs to {}/cnn_train_{model}_*.csv", p.get("out"));
}
