//! Fig. 2 — distributed linear regression optimality gap (§4.1), at
//! the paper's exact parameters by default.
//!
//!     cargo run --release --example linreg_gap -- \
//!         [--iters 2000] [--s 0.4,0.5,0.6] [--seed 42] [--out results]
//!
//! Writes one CSV per (algorithm, S) curve under --out and prints a
//! log-scale summary.  See EXPERIMENTS.md §Fig2 for the reproduction
//! findings at this testbed.

use regtopk::data::linear::LinearParams;
use regtopk::experiments::fig2;
use regtopk::util::cli::Cli;

fn main() {
    let p = Cli::new("Fig 2: optimality gap vs iterations")
        .flag("iters", "2000", "iterations")
        .flag("s", "0.4,0.5,0.6", "sparsity factors")
        .flag("mu", "0.5", "REGTOP-k temperature")
        .flag("q", "1.0", "REGTOP-k never-sent prior")
        .flag("eta", "0.01", "learning rate")
        .flag("seed", "42", "generator seed")
        .flag("out", "results", "output dir")
        .parse();

    let logs = fig2::run(
        LinearParams::fig2(),
        p.get_usize("seed") as u64,
        p.get_usize("iters"),
        &p.get_f64_list("s"),
        p.get_f32("mu"),
        p.get_f32("q"),
        p.get_f32("eta"),
    );
    println!("optimality gap ||w^t - w*|| (log10) at checkpoints:\n");
    print!("{:>14}", "iter");
    let iters = p.get_usize("iters");
    let checkpoints: Vec<usize> =
        [0.05, 0.1, 0.25, 0.5, 0.75, 1.0].iter().map(|f| ((iters as f64 * f) as usize).saturating_sub(1)).collect();
    for c in &checkpoints {
        print!("{c:>10}");
    }
    println!();
    for log in &logs {
        print!("{:>14}", log.name);
        for &c in &checkpoints {
            print!("{:>10.2}", (log.records()[c].opt_gap as f64).max(1e-12).log10());
        }
        println!();
    }
    let dir = std::path::PathBuf::from(p.get("out"));
    for log in &logs {
        let safe = log.name.replace('.', "p");
        log.write_csv(&dir.join(format!("linreg_gap_{safe}.csv"))).unwrap();
    }
    println!("\nwrote CSVs to {}/linreg_gap_*.csv", p.get("out"));
}
