//! Fig. 1 — the paper's motivational toy (§1.2), end to end.
//!
//! Two workers hold single data points x1=[100,1], x2=[-100,1] whose
//! first gradient entries are huge but cancel after aggregation.
//! TOP-1 wastes its budget on them and stalls; REGTOP-1 detects the
//! destructive aggregation through the posterior distortion and moves.
//!
//!     cargo run --release --example toy_logistic -- [--iters 100] [--with-g]

use regtopk::experiments::fig1;
use regtopk::util::cli::Cli;

fn main() {
    let p = Cli::new("Fig 1 toy: dense vs TOP-1 vs REGTOP-1")
        .flag("iters", "100", "iterations")
        .flag("mu", "0.5", "REGTOP-k temperature")
        .flag("q", "1.0", "REGTOP-k never-sent prior")
        .switch("with-g", "run the learning-rate-scaling variant (§1.2 extension)")
        .parse();

    let iters = p.get_usize("iters");
    let logs = fig1::run(iters, p.get_f32("mu"), p.get_f32("q"));
    println!("training loss (empirical risk) per iteration, eta=0.9, w0=[0,1]:\n");
    println!("{:>5} {:>12} {:>12} {:>12}", "iter", "dense", "topk", "regtopk");
    let step = (iters / 20).max(1);
    for t in (0..iters).step_by(step) {
        println!(
            "{t:>5} {:>12.6} {:>12.6} {:>12.6}",
            logs[0].records()[t].loss,
            logs[1].records()[t].loss,
            logs[2].records()[t].loss
        );
    }
    for log in &logs {
        println!("{:>8}: {}", log.name, log.sparkline(|r| r.loss, 50));
    }

    if p.get_bool("with-g") {
        let (steps, factor) = fig1::lr_scaling(iters);
        let stall = steps.iter().take_while(|&&s| s < 1e-9).count();
        println!("\nlearning-rate-scaling variant (loss + G(theta2), G'(1)=1, eta=0.01):");
        println!("  TOP-1 stalls for {stall} iterations, then releases an accumulated");
        println!("  step {factor:.1}x the dense step — the paper's 'factor ~50' effect");
        println!("  (ours is ~21-26x under the sigma(-1)=0.269 gradient convention).");
    }
}
