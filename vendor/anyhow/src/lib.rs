//! Offline stand-in for the `anyhow` crate: the exact API subset this
//! workspace uses (`Error`, `Result`, `anyhow!`, `bail!`, `Context`),
//! with the same observable semantics:
//!
//! - `Display` prints the outermost message; `{:#}` prints the full
//!   context chain joined by ": " (outermost first), matching anyhow's
//!   alternate formatting that `main.rs` relies on for diagnostics.
//! - `Error` deliberately does NOT implement `std::error::Error`, so
//!   the blanket `From<E: std::error::Error>` conversion (what makes
//!   `?` work on io/parse errors) stays coherent.

use std::fmt;

/// Error with a chain of context layers (outermost first) ending at
/// the root message.
pub struct Error {
    /// context layers, outermost first, then the root message last
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // flatten the source chain into the message, innermost last
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_layers_format() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert!(f().is_err());
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/nonexistent/regtopk")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
