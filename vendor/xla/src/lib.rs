//! Offline stub of the `xla` PJRT binding.
//!
//! This container image does not ship the XLA extension shared
//! library, so the binding is replaced by an API-compatible stub whose
//! entry point (`PjRtClient::cpu`) reports the runtime as unavailable.
//! Every caller in the workspace already handles that error by
//! degrading gracefully (`Runtime::open` fails -> artifact-backed
//! tests/examples skip), which keeps the rust-native sparsification
//! stack fully testable without PJRT.
//!
//! To run the artifact-backed paths, replace the `xla` entry in the
//! workspace `Cargo.toml` with the real binding; the API surface here
//! (`Literal`, `PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`,
//! `HloModuleProto`, `XlaComputation`) mirrors it one-to-one.

use std::fmt;
use std::path::Path;

/// Binding-level error.
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA runtime unavailable (offline stub build; see vendor/xla)"
    ))
}

/// Host-side literal (tensor) handle.
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// HLO module in proto form (parsed from text by the real binding).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client; `cpu()` is the only constructor this workspace uses.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
