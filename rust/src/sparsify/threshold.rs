//! Hard-threshold baseline: transmit every accumulated entry with
//! |a| >= tau (variable k per round; error feedback on the rest).

use crate::grad::ErrorFeedback;
use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier};

pub struct Threshold {
    tau: f32,
    ef: ErrorFeedback,
}

impl Threshold {
    pub fn new(dim: usize, tau: f32) -> Self {
        assert!(tau > 0.0, "threshold needs tau > 0");
        Threshold { tau, ef: ErrorFeedback::new(dim) }
    }
}

impl Sparsifier for Threshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn step(&mut self, grad: &[f32], _ctx: &RoundCtx) -> SparseVec {
        self.ef.accumulate(grad);
        let sel: Vec<u32> = self
            .ef
            .acc
            .iter()
            .enumerate()
            .filter(|(_, v)| v.abs() >= self.tau)
            .map(|(i, _)| i as u32)
            .collect();
        self.ef.commit(&sel)
    }

    fn peek_acc(&self, grad: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; grad.len()];
        self.ef.accumulate_into(grad, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_entries_at_or_above_tau() {
        let z = vec![0.0; 4];
        let ctx = RoundCtx { t: 0, gagg_prev: &z, omega: 1.0, genie_acc: None };
        let mut s = Threshold::new(4, 1.0);
        let sv = s.step(&[0.5, -1.0, 2.0, 0.99], &ctx);
        assert_eq!(sv.indices(), &[1, 2]);
    }

    #[test]
    fn sub_threshold_mass_accumulates_until_release() {
        let z = vec![0.0; 1];
        let mut s = Threshold::new(1, 1.0);
        for t in 0..2 {
            let ctx = RoundCtx { t, gagg_prev: &z, omega: 1.0, genie_acc: None };
            assert_eq!(s.step(&[0.4], &ctx).nnz(), 0);
        }
        let ctx = RoundCtx { t: 2, gagg_prev: &z, omega: 1.0, genie_acc: None };
        let sv = s.step(&[0.4], &ctx);
        assert_eq!(sv.nnz(), 1);
        assert!((sv.values()[0] - 1.2).abs() < 1e-6);
    }
}
