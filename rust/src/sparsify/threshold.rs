//! Hard-threshold baseline: transmit every accumulated entry with
//! |a| >= tau (variable k per round; error feedback on the rest).

#![forbid(unsafe_code)]

use crate::grad::ErrorFeedback;
use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};

pub struct Threshold {
    tau: f32,
    ef: ErrorFeedback,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl Threshold {
    pub fn new(dim: usize, tau: f32) -> Self {
        assert!(tau > 0.0, "threshold needs tau > 0");
        Threshold { tau, ef: ErrorFeedback::new(dim), sel: Vec::new() }
    }
}

impl Sparsifier for Threshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], _ctx: &RoundCtx, out: &mut SparseVec) {
        self.ef.accumulate(grad);
        let tau = self.tau;
        self.sel.clear();
        self.sel.extend(
            self.ef
                .acc
                .iter()
                .enumerate()
                .filter(|(_, v)| v.abs() >= tau)
                .map(|(i, _)| i as u32),
        );
        self.ef.commit_into(&self.sel, out);
    }

    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        self.ef.fold_residual(indices, residual);
    }

    fn export_state(&self) -> SparsifierState {
        SparsifierState::Ef(self.ef.snapshot())
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Ef(ef) => self.ef.restore(ef),
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("threshold cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        self.ef.accumulate_into(grad, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_entries_at_or_above_tau() {
        let z = vec![0.0; 4];
        let ctx = RoundCtx { t: 0, gagg_prev: &z, omega: 1.0, genie_acc: None };
        let mut s = Threshold::new(4, 1.0);
        let sv = s.step(&[0.5, -1.0, 2.0, 0.99], &ctx);
        assert_eq!(sv.indices(), &[1, 2]);
    }

    #[test]
    fn sub_threshold_mass_accumulates_until_release() {
        let z = vec![0.0; 1];
        let mut s = Threshold::new(1, 1.0);
        for t in 0..2 {
            let ctx = RoundCtx { t, gagg_prev: &z, omega: 1.0, genie_acc: None };
            assert_eq!(s.step(&[0.4], &ctx).nnz(), 0);
        }
        let ctx = RoundCtx { t: 2, gagg_prev: &z, omega: 1.0, genie_acc: None };
        let sv = s.step(&[0.4], &ctx);
        assert_eq!(sv.nnz(), 1);
        assert!((sv.values()[0] - 1.2).abs() < 1e-6);
    }
}
