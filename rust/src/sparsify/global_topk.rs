//! Global TOP-k — the genie-aided idealization of paper §3.1.
//!
//! Worker n transmits a_n[j] iff j is in the top-k of the TRUE
//! aggregated accumulated gradient sum_n omega_n a_n (which no real
//! worker can know; the trainer computes it through the genie
//! side-channel).  REGTOP-k is the feasible statistical approximation
//! of this scheme, so gtopk's curve is the ceiling REGTOP-k aims for.

#![forbid(unsafe_code)]

use crate::grad::ErrorFeedback;
use crate::sparse::{select_topk, SelectEngine, SparseVec};
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};

pub struct GlobalTopK {
    k: usize,
    ef: ErrorFeedback,
    /// sharded select over the genie channel (None = serial path)
    engine: Option<SelectEngine>,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl GlobalTopK {
    pub fn new(dim: usize, k: usize) -> Self {
        assert!(k > 0, "gtopk needs k >= 1");
        GlobalTopK { k, ef: ErrorFeedback::new(dim), engine: None, sel: Vec::new() }
    }
}

impl Sparsifier for GlobalTopK {
    fn name(&self) -> &'static str {
        "gtopk"
    }

    fn needs_genie(&self) -> bool {
        true
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], ctx: &RoundCtx, out: &mut SparseVec) {
        self.ef.accumulate(grad);
        let genie = ctx
            .genie_acc
            .expect("GlobalTopK requires the genie side-channel (needs_genie)");
        match &mut self.engine {
            Some(eng) => eng.select_into(genie, self.k, &mut self.sel),
            None => {
                self.sel.clear();
                let sel = select_topk(genie, self.k);
                self.sel.extend_from_slice(&sel);
            }
        }
        self.ef.commit_into(&self.sel, out);
    }

    fn set_shards(&mut self, shards: usize) {
        self.engine = if shards > 1 { Some(SelectEngine::new(shards)) } else { None };
    }

    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        self.ef.fold_residual(indices, residual);
    }

    fn export_state(&self) -> SparsifierState {
        SparsifierState::Ef(self.ef.snapshot())
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Ef(ef) => self.ef.restore(ef),
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("gtopk cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        self.ef.accumulate_into(grad, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_by_global_not_local_magnitude() {
        // local gradient favours entry 0, but the genie aggregate says
        // entry 1 is globally dominant -> entry 1 is transmitted.
        let mut s = GlobalTopK::new(2, 1);
        let genie = vec![0.0, 5.0];
        let ctx = RoundCtx { t: 0, gagg_prev: &[0.0; 2], omega: 0.5, genie_acc: Some(&genie) };
        let sv = s.step(&[100.0, 1.0], &ctx);
        assert_eq!(sv.indices(), &[1]);
        assert_eq!(sv.values(), &[1.0]);
    }

    #[test]
    #[should_panic]
    fn panics_without_genie() {
        let mut s = GlobalTopK::new(2, 1);
        let ctx = RoundCtx { t: 0, gagg_prev: &[0.0; 2], omega: 0.5, genie_acc: None };
        s.step(&[1.0, 2.0], &ctx);
    }

    #[test]
    fn toy_cancellation_solved_by_genie() {
        // The §1.2 toy: worker gradients ±100 at entry 0 cancel; the
        // genie aggregate keeps only entry 1, so gtopk transmits entry 1
        // at round 0 (what TOP-k takes ~50 rounds to discover).
        let mut w1 = GlobalTopK::new(2, 1);
        let mut w2 = GlobalTopK::new(2, 1);
        let g1 = [-73.6, 0.736];
        let g2 = [73.6, 0.736];
        let genie: Vec<f32> = (0..2).map(|i| 0.5 * (g1[i] + g2[i])).collect();
        let z = [0.0; 2];
        let c1 = RoundCtx { t: 0, gagg_prev: &z, omega: 0.5, genie_acc: Some(&genie) };
        let sv1 = w1.step(&g1, &c1);
        let sv2 = w2.step(&g2, &c1);
        assert_eq!(sv1.indices(), &[1]);
        assert_eq!(sv2.indices(), &[1]);
        let agg = 0.5 * (sv1.values()[0] + sv2.values()[0]);
        assert!((agg - 0.736).abs() < 1e-6);
    }
}
