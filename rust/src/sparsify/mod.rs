//! The sparsifier family: the paper's REGTOP-k plus every baseline.
//!
//! A [`Sparsifier`] consumes the worker's local gradient for round `t`
//! and emits the sparse update to transmit; all error-feedback state
//! lives inside the sparsifier.  Implementations:
//!
//! | name        | selection rule                                   | paper role |
//! |-------------|--------------------------------------------------|------------|
//! | `dense`     | send everything                                  | upper bound |
//! | `topk`      | k largest |a| (error feedback)                   | baseline (§1.1) |
//! | `regtopk`   | k largest |a . tanh(|1+Delta|/mu)|               | **contribution** (Alg. 1) |
//! | `randk`     | k uniform random entries (error feedback)        | classical baseline |
//! | `threshold` | all entries with |a| >= tau (error feedback)     | classical baseline |
//! | `gtopk`     | k largest |sum_n w_n a_n| (genie, infeasible)    | §3.1 "global TOP-k" |
//! | `dgc`       | TOP-k + momentum correction/masking/clipping      | cited baseline [6,8] |
//! | `adak`      | adaptive budget from the residual ratio           | cited baseline [9,10] |

mod adaptive;
mod dense;
mod dgc;
mod global_topk;
mod randk;
mod regtopk;
mod threshold;
mod topk;

pub use adaptive::AdaK;
pub use dense::Dense;
pub use dgc::Dgc;
pub use global_topk::GlobalTopK;
pub use randk::RandK;
pub use regtopk::RegTopK;
pub use threshold::Threshold;
pub use topk::TopK;

use crate::sparse::SparseVec;

/// Per-round context handed to every sparsifier by the worker loop.
pub struct RoundCtx<'a> {
    /// iteration index t (0-based)
    pub t: usize,
    /// g^{t-1}: aggregated gradient broadcast by the server last round
    /// (zeros at t=0)
    pub gagg_prev: &'a [f32],
    /// omega_n: this worker's aggregation weight
    pub omega: f32,
    /// Genie side-channel: the true aggregated accumulated gradient
    /// sum_n omega_n a_n^t for THIS round.  Only populated when the
    /// sparsifier declares `needs_genie()`; infeasible in practice
    /// (paper §3.1) and used only by the `gtopk` reference bound.
    pub genie_acc: Option<&'a [f32]>,
}

/// A gradient sparsifier with internal error-feedback state.
pub trait Sparsifier: Send {
    /// Short name used in configs, CSV output and plots.
    fn name(&self) -> &'static str;

    /// Process the local gradient for one round; returns the sparse
    /// update to transmit to the server.
    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec;

    /// Whether this sparsifier needs the genie side-channel (only the
    /// idealized global TOP-k does).
    fn needs_genie(&self) -> bool {
        false
    }

    /// The worker's accumulated gradient a_n^t = eps + g for the
    /// CURRENT round, needed by the trainer to build the genie channel.
    /// Sparsifiers without error feedback return the gradient itself.
    fn peek_acc(&self, grad: &[f32]) -> Vec<f32> {
        grad.to_vec()
    }
}

/// Sparsifier configuration — the factory input (see [`build`]).
#[derive(Clone, Debug, PartialEq)]
pub enum SparsifierKind {
    Dense,
    TopK { k: usize },
    RegTopK { k: usize, mu: f32, q: f32 },
    RandK { k: usize, seed: u64 },
    Threshold { tau: f32 },
    GlobalTopK { k: usize },
    Dgc { k: usize, momentum: f32, clip: f32 },
    AdaK { ratio: f32, k_min: usize, k_max: usize },
}

impl SparsifierKind {
    /// Parse "dense" | "topk" | "regtopk" | "randk" | "threshold" | "gtopk"
    /// with parameters supplied separately (CLI layer does this).
    pub fn from_name(
        name: &str,
        k: usize,
        mu: f32,
        q: f32,
        tau: f32,
        seed: u64,
    ) -> Option<Self> {
        Some(match name {
            "dense" => SparsifierKind::Dense,
            "topk" => SparsifierKind::TopK { k },
            "regtopk" => SparsifierKind::RegTopK { k, mu, q },
            "randk" => SparsifierKind::RandK { k, seed },
            "threshold" => SparsifierKind::Threshold { tau },
            "gtopk" => SparsifierKind::GlobalTopK { k },
            "dgc" => SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
            "adak" => SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: k.max(1) },
            _ => return None,
        })
    }
}

/// Instantiate a sparsifier for a worker with gradient dimension `dim`.
/// `worker` diversifies the RandK stream per worker.
pub fn build(kind: &SparsifierKind, dim: usize, worker: usize) -> Box<dyn Sparsifier> {
    match kind {
        SparsifierKind::Dense => Box::new(Dense::new()),
        SparsifierKind::TopK { k } => Box::new(TopK::new(dim, *k)),
        SparsifierKind::RegTopK { k, mu, q } => Box::new(RegTopK::new(dim, *k, *mu, *q)),
        SparsifierKind::RandK { k, seed } => {
            Box::new(RandK::new(dim, *k, seed.wrapping_add(worker as u64)))
        }
        SparsifierKind::Threshold { tau } => Box::new(Threshold::new(dim, *tau)),
        SparsifierKind::GlobalTopK { k } => Box::new(GlobalTopK::new(dim, *k)),
        SparsifierKind::Dgc { k, momentum, clip } => {
            Box::new(Dgc::new(dim, *k, *momentum, *clip))
        }
        SparsifierKind::AdaK { ratio, k_min, k_max } => {
            Box::new(AdaK::new(dim, *ratio, *k_min, (*k_max).min(dim)))
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Drive a sparsifier through `rounds` with a fixed gradient and a
    /// fabricated previous aggregate; returns total transmitted mass.
    pub fn drive(s: &mut dyn Sparsifier, grad: &[f32], rounds: usize) -> f32 {
        let dim = grad.len();
        let mut gagg_prev = vec![0.0; dim];
        let mut total = 0.0;
        for t in 0..rounds {
            let ctx = RoundCtx { t, gagg_prev: &gagg_prev, omega: 1.0, genie_acc: None };
            let sv = s.step(grad, &ctx);
            gagg_prev = sv.to_dense();
            total += sv.values().iter().map(|v| v.abs()).sum::<f32>();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_kind() {
        let kinds = [
            SparsifierKind::Dense,
            SparsifierKind::TopK { k: 2 },
            SparsifierKind::RegTopK { k: 2, mu: 0.5, q: 1.0 },
            SparsifierKind::RandK { k: 2, seed: 1 },
            SparsifierKind::Threshold { tau: 0.1 },
            SparsifierKind::GlobalTopK { k: 2 },
            SparsifierKind::Dgc { k: 2, momentum: 0.9, clip: 0.0 },
            SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 4 },
        ];
        for kind in &kinds {
            let s = build(kind, 10, 0);
            assert!(!s.name().is_empty());
        }
    }

    #[test]
    fn from_name_roundtrip() {
        assert_eq!(
            SparsifierKind::from_name("regtopk", 3, 0.5, 1.0, 0.0, 0),
            Some(SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 })
        );
        assert_eq!(SparsifierKind::from_name("bogus", 1, 0.0, 0.0, 0.0, 0), None);
    }

    #[test]
    fn only_gtopk_needs_genie() {
        assert!(build(&SparsifierKind::GlobalTopK { k: 1 }, 4, 0).needs_genie());
        assert!(!build(&SparsifierKind::TopK { k: 1 }, 4, 0).needs_genie());
        assert!(!build(&SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 }, 4, 0).needs_genie());
    }
}
