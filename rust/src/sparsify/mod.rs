//! The sparsifier family: the paper's REGTOP-k plus every baseline.
//!
//! A [`Sparsifier`] consumes the worker's local gradient for round `t`
//! and emits the sparse update to transmit; all error-feedback state
//! lives inside the sparsifier.  Implementations:
//!
//! | name        | selection rule                                   | paper role |
//! |-------------|--------------------------------------------------|------------|
//! | `dense`     | send everything                                  | upper bound |
//! | `topk`      | k largest |a| (error feedback)                   | baseline (§1.1) |
//! | `regtopk`   | k largest |a . tanh(|1+Delta|/mu)|               | **contribution** (Alg. 1) |
//! | `randk`     | k uniform random entries (error feedback)        | classical baseline |
//! | `threshold` | all entries with |a| >= tau (error feedback)     | classical baseline |
//! | `gtopk`     | k largest |sum_n w_n a_n| (genie, infeasible)    | §3.1 "global TOP-k" |
//! | `dgc`       | TOP-k + momentum correction/masking/clipping      | cited baseline [6,8] |
//! | `adak`      | adaptive budget from the residual ratio           | cited baseline [9,10] |
//!
//! The layer-wise API (journal follow-up, arXiv 2501.05633) layers on
//! top of the family: [`Sparsifier::step_group_into`] consumes a
//! `grad::GradView` and emits a bucketed `comm::SparseUpdate`;
//! [`LayerwiseSparsifier`] wraps any family as one independent child
//! per `grad::GradLayout` group with budgets from a [`BudgetPolicy`].

mod adaptive;
mod dense;
mod dgc;
mod global_topk;
mod layerwise;
mod policy;
mod randk;
mod regtopk;
mod threshold;
mod topk;

pub use adaptive::AdaK;
pub use dense::Dense;
pub use dgc::Dgc;
pub use global_topk::GlobalTopK;
pub use layerwise::{BudgetPolicy, LayerwiseSparsifier};
pub use policy::{glob_match, BitsSpec, GroupPolicy, POLICY_KEYS, PolicyRule, PolicyTable, Schedule};
pub use randk::RandK;
pub use regtopk::RegTopK;
pub use threshold::Threshold;
pub use topk::TopK;

use crate::grad::{EfState, GradView};
use crate::comm::SparseUpdate;
use crate::sparse::SparseVec;

/// The persistent (checkpointable) state a sparsifier carries across
/// rounds.  Scratch buffers (scores, selection lists, engines) are
/// derived and excluded; what is here is exactly what a resumed run
/// needs to continue the trajectory bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub enum SparsifierState {
    /// No state across rounds (dense).
    Stateless,
    /// Error-feedback history (topk / regtopk / threshold / gtopk).
    Ef(EfState),
    /// Error feedback plus the selection RNG stream (randk).
    EfRng { ef: EfState, rng: [u64; 4], gauss_spare: Option<f64> },
    /// DGC velocity + accumulated-velocity stores.
    Dgc { vel: Vec<f32>, acc: Vec<f32> },
    /// Residual store only (adak).
    Residual { eps: Vec<f32> },
    /// One state per child group (the layerwise wrapper).
    Grouped(Vec<SparsifierState>),
    /// A quantizing group's state: the child family's own state plus
    /// the stochastic-rounding stream, so a resumed quantized run
    /// draws exactly the rounding decisions the uninterrupted run
    /// would have (bit-exact resume under a `bits` policy).
    /// `auto_bits` carries the current residual-steered width under a
    /// `bits=auto:LO..HI` policy (None for scheduled widths — the
    /// encoding stays byte-identical to the PR 4 checkpoints).
    Quantized {
        inner: Box<SparsifierState>,
        rng: [u64; 4],
        gauss_spare: Option<f64>,
        auto_bits: Option<usize>,
    },
}

impl SparsifierState {
    /// Short tag for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            SparsifierState::Stateless => "stateless",
            SparsifierState::Ef(_) => "ef",
            SparsifierState::EfRng { .. } => "ef+rng",
            SparsifierState::Dgc { .. } => "dgc",
            SparsifierState::Residual { .. } => "residual",
            SparsifierState::Grouped(_) => "grouped",
            SparsifierState::Quantized { .. } => "quantized",
        }
    }
}

/// Per-round context handed to every sparsifier by the worker loop.
pub struct RoundCtx<'a> {
    /// iteration index t (0-based)
    pub t: usize,
    /// g^{t-1}: aggregated gradient broadcast by the server last round
    /// (zeros at t=0)
    pub gagg_prev: &'a [f32],
    /// omega_n: this worker's aggregation weight
    pub omega: f32,
    /// Genie side-channel: the true aggregated accumulated gradient
    /// sum_n omega_n a_n^t for THIS round.  Only populated when the
    /// sparsifier declares `needs_genie()`; infeasible in practice
    /// (paper §3.1) and used only by the `gtopk` reference bound.
    pub genie_acc: Option<&'a [f32]>,
}

/// A gradient sparsifier with internal error-feedback state.
pub trait Sparsifier: Send {
    /// Short name used in configs, CSV output and plots.
    fn name(&self) -> &'static str;

    /// Process the local gradient for one round; returns the sparse
    /// update to transmit to the server.
    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec;

    /// [`Self::step`] into a recycled [`SparseVec`] — the trainer's
    /// hot path.  Implementations override this to reuse `out`'s
    /// buffers (zero allocation at steady state); the default keeps
    /// correctness for sparsifiers that have not opted in.
    fn step_into(&mut self, grad: &[f32], ctx: &RoundCtx, out: &mut SparseVec) {
        *out = self.step(grad, ctx);
    }

    /// Group-aware entry point of the layer-wise API: sparsify `view`
    /// into the bucketed `out` (one bucket per layout group, indices
    /// local to the group).  The default routes through the flat
    /// [`Self::step_into`] and therefore serves only the degenerate
    /// single-group layout — which makes it bit-identical to the flat
    /// path by construction.  Multi-group layouts are handled by
    /// [`LayerwiseSparsifier`], which overrides this with one child
    /// sparsifier per group.
    fn step_group_into(&mut self, view: &GradView, ctx: &RoundCtx, out: &mut SparseUpdate) {
        let layout = view.layout();
        assert!(
            layout.is_single(),
            "flat sparsifier '{}' cannot serve a {}-group layout; wrap it in LayerwiseSparsifier",
            self.name(),
            layout.num_groups()
        );
        out.conform_to(layout);
        self.step_into(view.flat(), ctx, out.bucket_mut(0));
    }

    /// Number of shards for the in-sparsifier kernels (score/select).
    /// `<= 1` keeps the serial path; selectors with a sharded engine
    /// override this.  The default is a no-op so stateless sparsifiers
    /// need not care.
    fn set_shards(&mut self, _shards: usize) {}

    /// Re-tune the REGTOP-k temperature `mu` / never-sent prior `Q` at
    /// runtime (per-group `Schedule`s drive this once per round).  A
    /// no-op for families without those hyperparameters.
    fn set_temperature(&mut self, _mu: f32, _q: f32) {}

    /// Fold a post-selection residual (e.g. the quantization error on
    /// the transmitted values) back into the error store at `indices`
    /// (which must be the indices of the update just emitted), so the
    /// lossy stage composes with error feedback exactly as the paper
    /// folds sparsification error into eps.  The default is a no-op:
    /// families without a persistent error store (dense) rely on the
    /// stochastic rounding's unbiasedness alone, QSGD-style.
    fn fold_residual(&mut self, _indices: &[u32], _residual: &[f32]) {}

    /// Export the persistent cross-round state for checkpointing.  The
    /// default covers stateless families; everything with history
    /// overrides it so a resumed run continues the trajectory instead
    /// of cold-restarting error feedback (ISSUE 3 resume fix).
    fn export_state(&self) -> SparsifierState {
        SparsifierState::Stateless
    }

    /// Restore a previously exported state.  Errors on a family or
    /// dimension mismatch (the checkpoint belongs to another config).
    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Stateless => Ok(()),
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!(
                "'{}' carries no persistent state, got '{}'",
                self.name(),
                other.kind()
            )),
        }
    }

    /// Family name per parameter group (observability: the CLI prints
    /// this next to the per-group ledger table).  Flat sparsifiers are
    /// one implicit group; the layerwise wrapper reports its children.
    fn group_families(&self) -> Vec<&'static str> {
        vec![self.name()]
    }

    /// Resolved per-group transmission budgets (empty = not a grouped
    /// sparsifier).  Surfaced in the run manifest echo.
    fn group_budgets(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Per-group shard counts as resolved by the last `set_shards`
    /// (empty = not a grouped sparsifier).  Surfaced in the run
    /// manifest echo.
    fn group_shards(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Per-group quantization bit widths at round 0 (32 = passthrough;
    /// empty = not a grouped sparsifier).  Surfaced in the run
    /// manifest echo.
    fn group_value_bits(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Per-group bit widths once any `bits` schedule has settled past
    /// its horizon (== [`Self::group_value_bits`] for constant
    /// widths).  Lets summaries report `8..4` instead of misstating a
    /// decaying schedule as its round-0 value.
    fn group_value_bits_end(&self) -> Vec<usize> {
        self.group_value_bits()
    }

    /// Per-group index-codec names (`packed` unless a policy selects
    /// `raw`/`rice`; empty = not a grouped sparsifier).  Surfaced in
    /// the run manifest echo.
    fn group_index_codecs(&self) -> Vec<&'static str> {
        Vec::new()
    }

    /// Per-group value level-family names (`uniform` unless a policy
    /// selects `nuq`; empty = not a grouped sparsifier).  Surfaced in
    /// the run manifest echo.
    fn group_value_levels(&self) -> Vec<&'static str> {
        Vec::new()
    }

    /// Whether this sparsifier needs the genie side-channel (only the
    /// idealized global TOP-k does).
    fn needs_genie(&self) -> bool {
        false
    }

    /// The worker's accumulated gradient a_n^t = eps + g for the
    /// CURRENT round, needed by the trainer to build the genie channel.
    /// Sparsifiers without error feedback return the gradient itself.
    fn peek_acc(&self, grad: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; grad.len()];
        self.peek_acc_into(grad, &mut out);
        out
    }

    /// [`Self::peek_acc`] into a caller buffer (no allocation).
    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        out.copy_from_slice(grad);
    }
}

/// Sparsifier configuration — the factory input (see [`build`]).
#[derive(Clone, Debug, PartialEq)]
pub enum SparsifierKind {
    Dense,
    TopK { k: usize },
    RegTopK { k: usize, mu: f32, q: f32 },
    RandK { k: usize, seed: u64 },
    Threshold { tau: f32 },
    GlobalTopK { k: usize },
    Dgc { k: usize, momentum: f32, clip: f32 },
    AdaK { ratio: f32, k_min: usize, k_max: usize },
}

/// Full parameter set accepted by [`SparsifierKind::from_params`]:
/// every tunable of every sparsifier, with the family defaults.  The
/// CLI and JSON-config layers fill in whatever the user supplied and
/// leave the rest at `Default`.
#[derive(Clone, Debug)]
pub struct SparsifierParams {
    /// sparsity budget k (topk / regtopk / randk / gtopk / dgc)
    pub k: usize,
    /// REGTOP-k regularization temperature
    pub mu: f32,
    /// REGTOP-k never-sent prior Q
    pub q: f32,
    /// threshold tau
    pub tau: f32,
    /// randk stream seed
    pub seed: u64,
    /// DGC momentum-correction factor
    pub momentum: f32,
    /// DGC local l2 clipping threshold (0 disables)
    pub clip: f32,
    /// AdaK residual-vs-gradient trigger ratio
    pub ratio: f32,
    /// AdaK lower budget bound
    pub k_min: usize,
    /// AdaK upper budget bound (0 = use `k.max(1)`)
    pub k_max: usize,
}

impl Default for SparsifierParams {
    fn default() -> Self {
        SparsifierParams {
            k: 1,
            mu: 0.5,
            q: 1.0,
            tau: 1.0,
            seed: 0,
            momentum: 0.9,
            clip: 0.0,
            ratio: 1.0,
            k_min: 1,
            k_max: 0,
        }
    }
}

impl SparsifierKind {
    /// Short name of this kind — the single source for the name <->
    /// kind mapping (`from_params` accepts exactly these strings; the
    /// config JSON and CLI summaries print them).
    pub fn name(&self) -> &'static str {
        match self {
            SparsifierKind::Dense => "dense",
            SparsifierKind::TopK { .. } => "topk",
            SparsifierKind::RegTopK { .. } => "regtopk",
            SparsifierKind::RandK { .. } => "randk",
            SparsifierKind::Threshold { .. } => "threshold",
            SparsifierKind::GlobalTopK { .. } => "gtopk",
            SparsifierKind::Dgc { .. } => "dgc",
            SparsifierKind::AdaK { .. } => "adak",
        }
    }

    /// Decompose into the full parameter set (fields not used by this
    /// kind keep their family defaults).  Inverse of
    /// [`Self::from_params`] together with [`Self::name`]: override
    /// layers start from these values and overlay what the user set.
    pub fn to_params(&self) -> SparsifierParams {
        let mut p = SparsifierParams::default();
        match self {
            SparsifierKind::Dense => {}
            SparsifierKind::TopK { k } => p.k = *k,
            SparsifierKind::RegTopK { k, mu, q } => {
                p.k = *k;
                p.mu = *mu;
                p.q = *q;
            }
            SparsifierKind::RandK { k, seed } => {
                p.k = *k;
                p.seed = *seed;
            }
            SparsifierKind::Threshold { tau } => p.tau = *tau,
            SparsifierKind::GlobalTopK { k } => p.k = *k,
            SparsifierKind::Dgc { k, momentum, clip } => {
                p.k = *k;
                p.momentum = *momentum;
                p.clip = *clip;
            }
            SparsifierKind::AdaK { ratio, k_min, k_max } => {
                p.ratio = *ratio;
                p.k_min = *k_min;
                p.k_max = *k_max;
            }
        }
        p
    }

    /// Parse "dense" | "topk" | "regtopk" | "randk" | "threshold" |
    /// "gtopk" | "dgc" | "adak" with the legacy positional parameters;
    /// dgc/adak take their family defaults.
    ///
    /// Deprecated shim: every in-tree call site moved to
    /// [`Self::from_params`] (which exposes every tunable); this stays
    /// one release for external callers and is pinned by
    /// `from_name_shim_matches_from_params`.
    #[deprecated(note = "use SparsifierKind::from_params (exposes every tunable)")]
    pub fn from_name(
        name: &str,
        k: usize,
        mu: f32,
        q: f32,
        tau: f32,
        seed: u64,
    ) -> Option<Self> {
        Self::from_params(
            name,
            &SparsifierParams { k, mu, q, tau, seed, ..SparsifierParams::default() },
        )
    }

    /// Build a kind by name from the full parameter set (CLI + JSON
    /// config entry point — nothing is hardcoded here).
    pub fn from_params(name: &str, p: &SparsifierParams) -> Option<Self> {
        Some(match name {
            "dense" => SparsifierKind::Dense,
            "topk" => SparsifierKind::TopK { k: p.k },
            "regtopk" => SparsifierKind::RegTopK { k: p.k, mu: p.mu, q: p.q },
            "randk" => SparsifierKind::RandK { k: p.k, seed: p.seed },
            "threshold" => SparsifierKind::Threshold { tau: p.tau },
            "gtopk" => SparsifierKind::GlobalTopK { k: p.k },
            "dgc" => SparsifierKind::Dgc { k: p.k, momentum: p.momentum, clip: p.clip },
            "adak" => SparsifierKind::AdaK {
                ratio: p.ratio,
                k_min: p.k_min,
                k_max: if p.k_max == 0 { p.k.max(1) } else { p.k_max },
            },
            _ => return None,
        })
    }
}

/// Instantiate a sparsifier for a worker with gradient dimension `dim`.
/// `worker` diversifies the RandK stream per worker.
pub fn build(kind: &SparsifierKind, dim: usize, worker: usize) -> Box<dyn Sparsifier> {
    match kind {
        SparsifierKind::Dense => Box::new(Dense::new()),
        SparsifierKind::TopK { k } => Box::new(TopK::new(dim, *k)),
        SparsifierKind::RegTopK { k, mu, q } => Box::new(RegTopK::new(dim, *k, *mu, *q)),
        SparsifierKind::RandK { k, seed } => {
            Box::new(RandK::new(dim, *k, seed.wrapping_add(worker as u64)))
        }
        SparsifierKind::Threshold { tau } => Box::new(Threshold::new(dim, *tau)),
        SparsifierKind::GlobalTopK { k } => Box::new(GlobalTopK::new(dim, *k)),
        SparsifierKind::Dgc { k, momentum, clip } => {
            Box::new(Dgc::new(dim, *k, *momentum, *clip))
        }
        SparsifierKind::AdaK { ratio, k_min, k_max } => {
            Box::new(AdaK::new(dim, *ratio, *k_min, (*k_max).min(dim)))
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Drive a sparsifier through `rounds` with a fixed gradient and a
    /// fabricated previous aggregate; returns total transmitted mass.
    pub fn drive(s: &mut dyn Sparsifier, grad: &[f32], rounds: usize) -> f32 {
        let dim = grad.len();
        let mut gagg_prev = vec![0.0; dim];
        let mut total = 0.0;
        for t in 0..rounds {
            let ctx = RoundCtx { t, gagg_prev: &gagg_prev, omega: 1.0, genie_acc: None };
            let sv = s.step(grad, &ctx);
            gagg_prev = sv.to_dense();
            total += sv.values().iter().map(|v| v.abs()).sum::<f32>();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_kind() {
        let kinds = [
            SparsifierKind::Dense,
            SparsifierKind::TopK { k: 2 },
            SparsifierKind::RegTopK { k: 2, mu: 0.5, q: 1.0 },
            SparsifierKind::RandK { k: 2, seed: 1 },
            SparsifierKind::Threshold { tau: 0.1 },
            SparsifierKind::GlobalTopK { k: 2 },
            SparsifierKind::Dgc { k: 2, momentum: 0.9, clip: 0.0 },
            SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 4 },
        ];
        for kind in &kinds {
            let s = build(kind, 10, 0);
            assert!(!s.name().is_empty());
        }
    }

    /// The deprecated positional shim must keep delegating to
    /// `from_params` (same kinds, same family defaults) until removal.
    #[test]
    #[allow(deprecated)]
    fn from_name_shim_matches_from_params() {
        assert_eq!(
            SparsifierKind::from_name("regtopk", 3, 0.5, 1.0, 0.0, 0),
            Some(SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 })
        );
        assert_eq!(SparsifierKind::from_name("bogus", 1, 0.0, 0.0, 0.0, 0), None);
        // dgc/adak keep their family defaults under the shim
        assert_eq!(
            SparsifierKind::from_name("dgc", 5, 0.0, 0.0, 0.0, 0),
            Some(SparsifierKind::Dgc { k: 5, momentum: 0.9, clip: 0.0 })
        );
        assert_eq!(
            SparsifierKind::from_name("adak", 5, 0.0, 0.0, 0.0, 0),
            Some(SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 5 })
        );
    }

    #[test]
    fn from_params_exposes_every_tunable() {
        let p = SparsifierParams {
            k: 7,
            momentum: 0.5,
            clip: 2.0,
            ratio: 0.8,
            k_min: 3,
            k_max: 40,
            ..SparsifierParams::default()
        };
        assert_eq!(
            SparsifierKind::from_params("dgc", &p),
            Some(SparsifierKind::Dgc { k: 7, momentum: 0.5, clip: 2.0 })
        );
        assert_eq!(
            SparsifierKind::from_params("adak", &p),
            Some(SparsifierKind::AdaK { ratio: 0.8, k_min: 3, k_max: 40 })
        );
    }

    #[test]
    fn step_into_matches_step_for_every_kind() {
        let kinds = [
            SparsifierKind::Dense,
            SparsifierKind::TopK { k: 3 },
            SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 },
            SparsifierKind::RandK { k: 3, seed: 1 },
            SparsifierKind::Threshold { tau: 0.4 },
            SparsifierKind::Dgc { k: 3, momentum: 0.9, clip: 0.0 },
            SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 6 },
        ];
        let dim = 12;
        for kind in &kinds {
            let mut a = build(kind, dim, 0);
            let mut b = build(kind, dim, 0);
            let mut gagg = vec![0.0f32; dim];
            let mut out = SparseVec::zeros(dim);
            for t in 0..4 {
                let g: Vec<f32> =
                    (0..dim).map(|i| ((i * 7 + t * 13) % 11) as f32 - 5.0).collect();
                let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
                let want = a.step(&g, &ctx);
                b.step_into(&g, &ctx, &mut out);
                assert_eq!(want, out, "{kind:?} t={t}");
                // peek parity as well
                let mut peek = vec![0.0f32; dim];
                a.peek_acc_into(&g, &mut peek);
                assert_eq!(a.peek_acc(&g), peek, "{kind:?} t={t}");
                gagg = want.to_dense();
            }
        }
    }

    #[test]
    fn only_gtopk_needs_genie() {
        assert!(build(&SparsifierKind::GlobalTopK { k: 1 }, 4, 0).needs_genie());
        assert!(!build(&SparsifierKind::TopK { k: 1 }, 4, 0).needs_genie());
        assert!(!build(&SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 }, 4, 0).needs_genie());
    }
}
