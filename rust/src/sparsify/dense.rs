//! No-op sparsifier: transmits the full gradient (the paper's
//! "non-sparsified distributed SGD" upper-bound curve).

use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier};

#[derive(Default)]
pub struct Dense;

impl Dense {
    pub fn new() -> Self {
        Dense
    }
}

impl Sparsifier for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn step(&mut self, grad: &[f32], _ctx: &RoundCtx) -> SparseVec {
        let idx: Vec<u32> = (0..grad.len() as u32).collect();
        SparseVec::new(grad.len(), idx, grad.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmits_everything_unchanged() {
        let mut s = Dense::new();
        let g = vec![1.0, -2.0, 0.0];
        let ctx = RoundCtx { t: 0, gagg_prev: &[0.0; 3], omega: 1.0, genie_acc: None };
        let sv = s.step(&g, &ctx);
        assert_eq!(sv.to_dense(), g);
        assert_eq!(sv.nnz(), 3);
    }
}
