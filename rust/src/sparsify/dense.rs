//! No-op sparsifier: transmits the full gradient (the paper's
//! "non-sparsified distributed SGD" upper-bound curve).
//!
//! Dense carries no error store, so `Sparsifier::fold_residual` keeps
//! its default no-op here: under a `bits` policy a dense group is
//! exactly QSGD — unbiased stochastic quantization with no feedback —
//! which is the correct composition for a memoryless transmitter.

#![forbid(unsafe_code)]

use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier};

#[derive(Default)]
pub struct Dense {
    /// reusable full index list
    idx: Vec<u32>,
}

impl Dense {
    pub fn new() -> Self {
        Dense::default()
    }
}

impl Sparsifier for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], _ctx: &RoundCtx, out: &mut SparseVec) {
        self.idx.clear();
        self.idx.extend(0..grad.len() as u32);
        SparseVec::gather_into(grad, &self.idx, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmits_everything_unchanged() {
        let mut s = Dense::new();
        let g = vec![1.0, -2.0, 0.0];
        let ctx = RoundCtx { t: 0, gagg_prev: &[0.0; 3], omega: 1.0, genie_acc: None };
        let sv = s.step(&g, &ctx);
        assert_eq!(sv.to_dense(), g);
        assert_eq!(sv.nnz(), 3);
    }
}
