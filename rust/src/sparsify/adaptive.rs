//! Adaptive-k sparsification (the paper's [9]/[10] family): the budget
//! k_t is tuned online from feedback instead of fixed.
//!
//! `AdaK` implements the residual-ratio rule of AdaComp (Chen et al.,
//! AAAI'18), simplified to the flat-vector setting: transmit every
//! accumulated entry whose magnitude exceeds `ratio` x (current batch
//! max |g|), bounded to [k_min, k_max].  The effective k thus grows
//! when the residual is large relative to fresh gradients (training
//! plateau, errors piling up) and shrinks when fresh gradients
//! dominate.

#![forbid(unsafe_code)]

use crate::sparse::{select_topk, SelectEngine, SparseVec};
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};

pub struct AdaK {
    /// residual-vs-gradient trigger ratio (AdaComp uses ~1.0)
    ratio: f32,
    k_min: usize,
    k_max: usize,
    eps: Vec<f32>,
    acc: Vec<f32>,
    /// effective k of the last round (observability)
    pub last_k: usize,
    /// sharded select (None = serial path)
    engine: Option<SelectEngine>,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl AdaK {
    pub fn new(dim: usize, ratio: f32, k_min: usize, k_max: usize) -> Self {
        assert!(k_min >= 1 && k_min <= k_max && k_max <= dim);
        AdaK {
            ratio,
            k_min,
            k_max,
            eps: vec![0.0; dim],
            acc: vec![0.0; dim],
            last_k: 0,
            engine: None,
            sel: Vec::new(),
        }
    }
}

impl Sparsifier for AdaK {
    fn name(&self) -> &'static str {
        "adak"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], _ctx: &RoundCtx, out: &mut SparseVec) {
        let gmax = grad.iter().fold(0.0f32, |m, g| m.max(g.abs()));
        for i in 0..grad.len() {
            self.acc[i] = self.eps[i] + grad[i];
        }
        let tau = self.ratio * gmax;
        // candidate count under the adaptive threshold
        let count = self.acc.iter().filter(|a| a.abs() >= tau && tau > 0.0).count();
        let k = count.clamp(self.k_min, self.k_max);
        self.last_k = k;
        // exact top-k at the adapted budget (deterministic; avoids
        // over-shooting k_max on heavy-tailed rounds); the budget is
        // data-dependent, so the selection itself reuses the sharded
        // engine when one is attached
        match &mut self.engine {
            Some(eng) => eng.select_into(&self.acc, k, &mut self.sel),
            None => {
                self.sel.clear();
                let sel = select_topk(&self.acc, k);
                self.sel.extend_from_slice(&sel);
            }
        }
        SparseVec::gather_into(&self.acc, &self.sel, out);
        self.eps.copy_from_slice(&self.acc);
        for &i in &self.sel {
            self.eps[i as usize] = 0.0;
        }
    }

    fn set_shards(&mut self, shards: usize) {
        self.engine = if shards > 1 { Some(SelectEngine::new(shards)) } else { None };
    }

    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        crate::grad::fold_residual_into(&mut self.eps, indices, residual);
    }

    /// AdaK's only cross-round state is the residual store.
    fn export_state(&self) -> SparsifierState {
        SparsifierState::Residual { eps: self.eps.clone() }
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Residual { eps } => {
                if eps.len() != self.eps.len() {
                    return Err(format!(
                        "adak state dim {} != sparsifier dim {}",
                        eps.len(),
                        self.eps.len()
                    ));
                }
                self.eps.copy_from_slice(eps);
                Ok(())
            }
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("adak cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        for ((o, e), g) in out.iter_mut().zip(&self.eps).zip(grad) {
            *o = e + g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(z: &'a [f32]) -> RoundCtx<'a> {
        RoundCtx { t: 0, gagg_prev: z, omega: 1.0, genie_acc: None }
    }

    #[test]
    fn budget_grows_with_residual() {
        let z = vec![0.0; 8];
        let mut s = AdaK::new(8, 1.0, 1, 8);
        // round 1: uniform gradient -> only entries >= max survive
        s.step(&[1.0; 8], &ctx(&z));
        let k1 = s.last_k;
        // rounds 2-4: same gradient; residuals pile up above gmax
        for _ in 0..3 {
            s.step(&[1.0; 8], &ctx(&z));
        }
        assert!(s.last_k >= k1, "{} -> {}", k1, s.last_k);
    }

    #[test]
    fn k_respects_bounds() {
        let z = vec![0.0; 10];
        let mut s = AdaK::new(10, 0.01, 2, 5);
        // tiny ratio: everything qualifies, must clamp to k_max
        let sv = s.step(&[1.0; 10], &ctx(&z));
        assert_eq!(sv.nnz(), 5);
        assert_eq!(s.last_k, 5);
        // huge ratio: nothing qualifies, must clamp to k_min
        let mut s = AdaK::new(10, 100.0, 2, 5);
        let sv = s.step(&[1.0; 10], &ctx(&z));
        assert_eq!(sv.nnz(), 2);
    }

    #[test]
    fn error_feedback_conserves() {
        let z = vec![0.0; 6];
        let mut s = AdaK::new(6, 1.0, 1, 6);
        let g = [3.0, -1.0, 0.5, 2.0, -0.1, 0.0];
        let acc = s.peek_acc(&g);
        let sv = s.step(&g, &ctx(&z));
        let dense = sv.to_dense();
        for i in 0..6 {
            assert_eq!(dense[i] + s.eps[i], acc[i]);
        }
    }
}
