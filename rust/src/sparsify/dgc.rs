//! Deep Gradient Compression (Lin et al., ICLR'18 — the paper's [6]/[8])
//! — the strongest published TOP-k extension, implemented as a
//! comparison baseline.
//!
//! DGC = TOP-k error accumulation + three fixes:
//!   * momentum correction: accumulate *velocity* u = m·u + g instead
//!     of raw gradients, so the error feedback carries momentum;
//!   * momentum factor masking: zero the velocity at transmitted
//!     coordinates (prevents stale momentum from re-releasing);
//!   * local gradient clipping: clip ||g|| to `clip` before
//!     accumulation (DGC clips per-node at 1/N of the global budget).
//!
//! The paper under reproduction claims these extensions "do not revise
//! the derivation of the sparsification mask" and thus inherit TOP-k's
//! learning-rate scaling; this implementation lets the benches test
//! that claim directly.

use crate::sparse::{select_topk, SelectEngine, SparseVec};
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};
use crate::util::pool::SharedSlice;

pub struct Dgc {
    k: usize,
    /// momentum-correction factor m
    momentum: f32,
    /// local l2 clipping threshold (0 disables)
    clip: f32,
    /// velocity u_n
    vel: Vec<f32>,
    /// accumulated velocity v_n (the DGC error store)
    acc: Vec<f32>,
    scratch: Vec<f32>,
    /// sharded fused momentum-update+select (None = serial path)
    engine: Option<SelectEngine>,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl Dgc {
    pub fn new(dim: usize, k: usize, momentum: f32, clip: f32) -> Self {
        assert!(k > 0);
        assert!((0.0..1.0).contains(&momentum));
        Dgc {
            k,
            momentum,
            clip,
            vel: vec![0.0; dim],
            acc: vec![0.0; dim],
            scratch: vec![0.0; dim],
            engine: None,
            sel: Vec::new(),
        }
    }

    /// Clipping scale for this round's gradient (1.0 when disabled).
    fn clip_scale(&self, grad: &[f32]) -> f32 {
        if self.clip > 0.0 {
            let norm = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
            if norm > self.clip {
                self.clip / norm
            } else {
                1.0
            }
        } else {
            1.0
        }
    }
}

impl Sparsifier for Dgc {
    fn name(&self) -> &'static str {
        "dgc"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], _ctx: &RoundCtx, out: &mut SparseVec) {
        // local gradient clipping
        let scale = self.clip_scale(grad);
        let momentum = self.momentum;
        match &mut self.engine {
            // fused sharded path: momentum correction (u <- m*u + g,
            // v <- v + u), scratch copy and |v| histogram in ONE
            // parallel pass per shard.
            Some(eng) => {
                let vel_sh = SharedSlice::new(&mut self.vel);
                let acc_sh = SharedSlice::new(&mut self.acc);
                eng.fused_select_into(
                    &mut self.scratch,
                    |lo, scratch| {
                        let hi = lo + scratch.len();
                        // SAFETY: the engine invokes `fill` once per
                        // shard with the disjoint `[lo, hi)` ranges of
                        // one pool job, and `self.vel` outlives the
                        // enclosing `fused_select_into` call.
                        let vel = unsafe { vel_sh.range(lo, hi) };
                        // SAFETY: same disjoint-shard argument for
                        // `self.acc`, a second slice sharded by the
                        // same ranges.
                        let acc = unsafe { acc_sh.range(lo, hi) };
                        for (i, s) in scratch.iter_mut().enumerate() {
                            vel[i] = momentum * vel[i] + scale * grad[lo + i];
                            acc[i] += vel[i];
                            *s = acc[i];
                        }
                    },
                    self.k,
                    &mut self.sel,
                );
            }
            None => {
                // momentum correction: u <- m*u + g ; v <- v + u
                for i in 0..grad.len() {
                    self.vel[i] = momentum * self.vel[i] + scale * grad[i];
                    self.acc[i] += self.vel[i];
                    self.scratch[i] = self.acc[i];
                }
                self.sel.clear();
                let sel = select_topk(&self.scratch, self.k);
                self.sel.extend_from_slice(&sel);
            }
        }
        SparseVec::gather_into(&self.acc, &self.sel, out);
        // momentum factor masking + error update at transmitted coords
        for &i in &self.sel {
            self.acc[i as usize] = 0.0;
            self.vel[i as usize] = 0.0;
        }
    }

    fn set_shards(&mut self, shards: usize) {
        self.engine = if shards > 1 { Some(SelectEngine::new(shards)) } else { None };
    }

    /// DGC's error store is the accumulated velocity, so that is where
    /// a post-transmission residual folds back (transmitted coords were
    /// just zeroed; the residual is what the wire failed to deliver).
    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        crate::grad::fold_residual_into(&mut self.acc, indices, residual);
    }

    /// DGC's cross-round state is the velocity + accumulated-velocity
    /// pair (its error store), not an `ErrorFeedback`.
    fn export_state(&self) -> SparsifierState {
        SparsifierState::Dgc { vel: self.vel.clone(), acc: self.acc.clone() }
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Dgc { vel, acc } => {
                if vel.len() != self.vel.len() || acc.len() != self.acc.len() {
                    return Err(format!(
                        "dgc state dim {} != sparsifier dim {}",
                        vel.len(),
                        self.vel.len()
                    ));
                }
                self.vel.copy_from_slice(vel);
                self.acc.copy_from_slice(acc);
                Ok(())
            }
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("dgc cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        // accumulated view consistent with one hypothetical step
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.acc[i] + self.momentum * self.vel[i] + grad[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(z: &'a [f32]) -> RoundCtx<'a> {
        RoundCtx { t: 0, gagg_prev: z, omega: 1.0, genie_acc: None }
    }

    #[test]
    fn transmits_k_and_masks_momentum() {
        let z = vec![0.0; 4];
        let mut s = Dgc::new(4, 1, 0.9, 0.0);
        let sv = s.step(&[5.0, 1.0, 0.1, 0.0], &ctx(&z));
        assert_eq!(sv.indices(), &[0]);
        assert_eq!(sv.values(), &[5.0]);
        // transmitted coordinate: both velocity and error cleared
        assert_eq!(s.vel[0], 0.0);
        assert_eq!(s.acc[0], 0.0);
        // untransmitted: velocity carried
        assert!(s.vel[1] > 0.0);
        assert_eq!(s.acc[1], 1.0);
    }

    #[test]
    fn momentum_correction_accelerates_accumulation() {
        // constant gradient on the unselected entry: with momentum m,
        // accumulated error after t rounds grows ~ t/(1-m), i.e. faster
        // than plain TOP-k's t — DGC promotes small entries sooner.
        let z = vec![0.0; 2];
        let mut dgc = Dgc::new(2, 1, 0.5, 0.0);
        let mut topk = crate::sparsify::TopK::new(2, 1);
        let g = [10.0, 1.0];
        let mut dgc_first = None;
        let mut topk_first = None;
        for t in 0..40 {
            let c = RoundCtx { t, gagg_prev: &z, omega: 1.0, genie_acc: None };
            if dgc_first.is_none() && dgc.step(&g, &c).indices() == [1] {
                dgc_first = Some(t);
            }
            let c = RoundCtx { t, gagg_prev: &z, omega: 1.0, genie_acc: None };
            if topk_first.is_none() && topk.step(&g, &c).indices() == [1] {
                topk_first = Some(t);
            }
        }
        assert!(dgc_first.unwrap() < topk_first.unwrap());
    }

    #[test]
    fn clipping_bounds_contribution() {
        let z = vec![0.0; 3];
        let mut s = Dgc::new(3, 3, 0.0, 1.0); // clip ||g|| to 1
        let sv = s.step(&[30.0, 40.0, 0.0], &ctx(&z)); // norm 50 -> x0.02
        let dense = sv.to_dense();
        let norm: f32 = dense.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "{norm}");
    }
}
