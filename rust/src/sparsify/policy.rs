//! Heterogeneous per-group sparsification policies.
//!
//! The journal follow-up ("Regularized Top-k", arXiv 2501.05633) states
//! the posterior statistics, the temperature `mu` and the budget `k`
//! per layer — and nothing forces every layer to run the same *family*:
//! biases are tiny and cheap to send dense, embedding-like blocks want
//! aggressive RegTop-k, everything else can ride plain Top-k.  A
//! [`PolicyTable`] maps parameter-group names (glob patterns, first
//! match wins) to a [`GroupPolicy`]: an optional family override plus
//! any subset of the family hyperparameters, with `mu`/`Q` optionally
//! given as a per-round [`Schedule`] instead of a constant.
//!
//! Spec language (CLI `--policy`, `;`-separated rules):
//!
//! ```text
//! conv*=regtopk:mu=0.3;bias*=dense;*=topk
//! fc*=:mu=0.5..0.1/200          # empty family = inherit, linear mu decay
//! conv*=regtopk:mu=0.3,bits=4;*=topk:bits=8   # quantized transmission
//! fc*=:bits=8..4/100,eta=2.0    # bits tighten over rounds, 2x group lr
//! conv*=:bits=4,idx=rice,levels=nuq  # entropy-coded indices, NUQ levels
//! fc*=:levels=bf16              # true half-width wire values, no bits= key
//! *=topk:bits=auto:4..8         # residual-steered adaptive width
//! ```
//!
//! Each rule is `glob=family[:key=value,...]`; an empty family inherits
//! the run's base sparsifier.  Groups matched by no rule fall back to
//! the shared default (the homogeneous PR 2 path, bit-identical).  The
//! table round-trips through `TrainConfig` JSON, so run manifests and
//! checkpoints echo the full heterogeneous setup.

#![forbid(unsafe_code)]

use crate::comm::codec::{IndexCodec, LevelKind};
use crate::sparsify::{SparsifierKind, SparsifierParams};
use crate::util::json::{obj, Json};

/// The full policy-table keyspace — every key the CLI spec grammar and
/// the JSON round-trip accept.  This is persisted-schema surface
/// (`SCHEMA.lock` pins it): run manifests and checkpoints written
/// today must keep parsing, so keys are append-only and renames are a
/// documented `docs/WIRE.md` schema bump.
pub const POLICY_KEYS: &[&str] = &[
    "match", "family", "k", "mu", "q", "tau", "seed", "momentum", "clip", "ratio", "k_min",
    "k_max", "bits", "idx", "levels", "eta",
];

/// A per-round hyperparameter schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Const(f32),
    /// Linear interpolation from `from` (round 0) to `to` (round
    /// `over`), constant at `to` afterwards.
    Linear { from: f32, to: f32, over: usize },
}

impl Schedule {
    /// Value at round `t`.
    pub fn at(&self, t: usize) -> f32 {
        match self {
            Schedule::Const(v) => *v,
            Schedule::Linear { from, to, over } => {
                if *over == 0 || t >= *over {
                    *to
                } else {
                    from + (to - from) * (t as f32 / *over as f32)
                }
            }
        }
    }

    /// The values the schedule can emit (for range validation).
    pub fn endpoints(&self) -> (f32, f32) {
        match self {
            Schedule::Const(v) => (*v, *v),
            Schedule::Linear { from, to, .. } => (*from, *to),
        }
    }

    /// Parse `"0.3"` (constant) or `"0.5..0.1/200"` (linear over 200
    /// rounds).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        let num = |v: &str| -> Result<f32, String> {
            v.trim()
                .parse::<f32>()
                .map_err(|_| format!("bad schedule value '{v}' in '{s}'"))
        };
        if let Some((range, over)) = s.split_once('/') {
            let (from, to) = range
                .split_once("..")
                .ok_or_else(|| format!("linear schedule '{s}' needs the form FROM..TO/OVER"))?;
            let over: usize = over
                .trim()
                .parse()
                .map_err(|_| format!("bad schedule horizon '{over}' in '{s}'"))?;
            Ok(Schedule::Linear { from: num(from)?, to: num(to)?, over })
        } else if s.contains("..") {
            Err(format!("linear schedule '{s}' needs a /OVER horizon"))
        } else {
            Ok(Schedule::Const(num(s)?))
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Schedule::Const(v) => (*v as f64).into(),
            Schedule::Linear { from, to, over } => obj([
                ("from", (*from as f64).into()),
                ("to", (*to as f64).into()),
                ("over", (*over).into()),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        if let Some(v) = j.as_f64() {
            return Ok(Schedule::Const(v as f32));
        }
        let get = |key: &str| {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("schedule missing '{key}'"))
        };
        Ok(Schedule::Linear {
            from: get("from")? as f32,
            to: get("to")? as f32,
            over: j
                .get("over")
                .and_then(Json::as_usize)
                .ok_or("schedule missing 'over'")?,
        })
    }
}

/// The `bits=` policy value: a per-round width schedule, or the
/// residual-steered adaptive mode (`bits=auto:LO..HI` — the ROADMAP
/// follow-up closing the loop the AdaK family opens for k: the width
/// widens when the observed quantization residual norm says the wire
/// is too lossy and narrows when there is slack).
#[derive(Clone, Debug, PartialEq)]
pub enum BitsSpec {
    /// Fixed or linearly scheduled width (the PR 4 surface).
    Sched(Schedule),
    /// Residual-steered width floating in `[lo, hi]` (both packable,
    /// 2..=16).  Starts at `hi` (conservative) and adapts per round;
    /// the current width is exported in `SparsifierState` so resume
    /// stays bit-exact.
    Auto { lo: usize, hi: usize },
}

impl BitsSpec {
    /// Parse `"8"`, `"8..4/100"` or `"auto:4..8"`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if let Some(range) = s.strip_prefix("auto:") {
            let (lo, hi) = range
                .split_once("..")
                .ok_or_else(|| format!("auto bits '{s}' needs the form auto:LO..HI"))?;
            let num = |v: &str| {
                v.trim()
                    .parse::<usize>()
                    .map_err(|_| format!("bad auto bits bound '{v}' in '{s}'"))
            };
            return Ok(BitsSpec::Auto { lo: num(lo)?, hi: num(hi)? });
        }
        Schedule::parse(s).map(BitsSpec::Sched)
    }

    pub fn to_json(&self) -> Json {
        match self {
            BitsSpec::Sched(s) => s.to_json(),
            BitsSpec::Auto { lo, hi } => {
                obj([("auto", true.into()), ("lo", (*lo).into()), ("hi", (*hi).into())])
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        if j.get("auto").and_then(Json::as_bool).unwrap_or(false) {
            let get = |key: &str| {
                j.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("auto bits missing '{key}'"))
            };
            return Ok(BitsSpec::Auto { lo: get("lo")?, hi: get("hi")? });
        }
        Schedule::from_json(j).map(BitsSpec::Sched)
    }

    fn validate(&self) -> Result<(), String> {
        match self {
            BitsSpec::Sched(bits) => {
                let (a, b) = bits.endpoints();
                for v in [a, b] {
                    if !v.is_finite() || !(2.0..=32.0).contains(&v.round()) {
                        return Err(format!(
                            "bits schedule endpoint {v} outside [2, 32] (32 = passthrough)"
                        ));
                    }
                }
                Ok(())
            }
            BitsSpec::Auto { lo, hi } => {
                if !(2..=16).contains(lo) || !(2..=16).contains(hi) || lo > hi {
                    return Err(format!(
                        "auto bits range {lo}..{hi} must satisfy 2 <= lo <= hi <= 16"
                    ));
                }
                Ok(())
            }
        }
    }
}

/// One group's resolved policy: an optional family override plus any
/// subset of the family hyperparameters.  Unset fields inherit the
/// run's base [`SparsifierKind`]; an unset `k` takes the group's
/// budget-resolved value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupPolicy {
    /// family name override (None = the base sparsifier's family)
    pub family: Option<String>,
    /// explicit budget (overrides the `BudgetPolicy`-resolved k)
    pub k: Option<usize>,
    /// REGTOP-k temperature, possibly scheduled per round
    pub mu: Option<Schedule>,
    /// REGTOP-k never-sent prior Q, possibly scheduled per round
    pub q: Option<Schedule>,
    pub tau: Option<f32>,
    pub seed: Option<u64>,
    pub momentum: Option<f32>,
    pub clip: Option<f32>,
    pub ratio: Option<f32>,
    pub k_min: Option<usize>,
    pub k_max: Option<usize>,
    /// quantized-transmission bit width: a per-round schedule
    /// (`8..4/100` tightens the wire over training; values round to
    /// an integer in [2, 32] at each round) or the residual-steered
    /// `auto:4..8` mode.  Widths 2..=16 engage the packed wire path;
    /// anything above (incl. 32) is raw f32 passthrough for that
    /// round.  Unset = no quantization (the pre-quantization wire
    /// format, bit-identical).
    pub bits: Option<BitsSpec>,
    /// index-codec override (`idx=packed|raw|rice`); unset = the
    /// bit-packed `log J` default, bit-identical to the pre-codec tree
    pub idx: Option<IndexCodec>,
    /// value level-table family (`levels=uniform|nuq|fp16|bf16`).
    /// `uniform`/`nuq` need a `bits=` width (validated); `fp16`/`bf16`
    /// are fixed 16-bit floating grids and reject `bits=`.  Unset =
    /// uniform, the PR 4 offset-binary grid.
    pub levels: Option<LevelKind>,
    /// learning-rate scale for this group's slice of the aggregate
    /// (the §1.2 G-extension applied per layer); the server multiplies
    /// the group's gradient by this factor before the optimizer step.
    /// Unset = 1.0 (bit-identical path).
    pub eta: Option<f32>,
}

impl GroupPolicy {
    /// Whether any mu/Q entry is a non-constant schedule (the layerwise
    /// wrapper only re-tunes children per round when one is).
    pub fn has_schedule(&self) -> bool {
        matches!(self.mu, Some(Schedule::Linear { .. }))
            || matches!(self.q, Some(Schedule::Linear { .. }))
    }

    fn validate(&self) -> Result<(), String> {
        if let Some(f) = &self.family {
            if SparsifierKind::from_params(f, &SparsifierParams::default()).is_none() {
                return Err(format!("policy names unknown family '{f}'"));
            }
        }
        if let Some(mu) = &self.mu {
            let (a, b) = mu.endpoints();
            if !(a.is_finite() && b.is_finite() && a > 0.0 && b > 0.0) {
                return Err(format!("mu schedule endpoints ({a}, {b}) must be positive"));
            }
        }
        if let Some(tau) = self.tau {
            if !(tau.is_finite() && tau > 0.0) {
                return Err(format!("tau {tau} must be positive"));
            }
        }
        if let Some(m) = self.momentum {
            if !(0.0..1.0).contains(&m) {
                return Err(format!("momentum {m} outside [0, 1)"));
            }
        }
        if let Some(s) = self.seed {
            // the config JSON layer stores numbers as f64: larger
            // seeds would silently corrupt on the manifest round trip
            if s > (1u64 << 53) {
                return Err(format!(
                    "seed {s} exceeds 2^53 and cannot round-trip through the config JSON"
                ));
            }
        }
        if let Some(bits) = &self.bits {
            bits.validate()?;
        }
        if let Some(l) = self.levels {
            if l.is_half() {
                if self.bits.is_some() {
                    return Err(format!(
                        "levels={} is fixed at 16 bits on the wire; drop the bits= key",
                        l.name()
                    ));
                }
            } else if self.bits.is_none() {
                return Err(
                    "levels= needs a bits= width (raw f32 values have no level table)"
                        .to_string(),
                );
            }
        }
        if let Some(e) = self.eta {
            if !(e.is_finite() && e > 0.0) {
                return Err(format!("eta scale {e} must be positive and finite"));
            }
        }
        Ok(())
    }

    /// Whether only wire-codec keys (`bits`/`idx`/`levels`) are set.
    /// The downlink policy axis compresses the already-aggregated g^t,
    /// so sparsifier hyperparameters are meaningless there.
    pub fn is_codec_only(&self) -> bool {
        self.family.is_none()
            && self.k.is_none()
            && self.mu.is_none()
            && self.q.is_none()
            && self.tau.is_none()
            && self.seed.is_none()
            && self.momentum.is_none()
            && self.clip.is_none()
            && self.ratio.is_none()
            && self.k_min.is_none()
            && self.k_max.is_none()
            && self.eta.is_none()
    }
}

/// `glob -> GroupPolicy` rule.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyRule {
    pub pattern: String,
    pub policy: GroupPolicy,
}

/// Ordered rule list; [`Self::resolve`] returns the first match.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PolicyTable {
    rules: Vec<PolicyRule>,
}

impl PolicyTable {
    pub fn new(rules: Vec<PolicyRule>) -> Result<Self, String> {
        for r in &rules {
            if r.pattern.is_empty() {
                return Err("policy rule with empty glob pattern".to_string());
            }
            r.policy.validate()?;
        }
        Ok(PolicyTable { rules })
    }

    pub fn rules(&self) -> &[PolicyRule] {
        &self.rules
    }

    /// Validate this table as a DOWNLINK policy: every rule may set
    /// only the wire-codec keys (`bits`/`idx`/`levels`), and `bits`
    /// must be a fixed/scheduled width — the residual-steered `auto`
    /// mode lives in the worker-side sparsifier wrappers and has no
    /// steering state on the server.  A bare `*=` rule is the lossless
    /// sparse broadcast (raw f32 values over the union support).
    pub fn validate_downlink(&self) -> Result<(), String> {
        for r in &self.rules {
            if !r.policy.is_codec_only() {
                return Err(format!(
                    "downlink rule '{}' sets sparsifier keys; only bits=/idx=/levels= apply \
                     to the aggregate broadcast",
                    r.pattern
                ));
            }
            if matches!(r.policy.bits, Some(BitsSpec::Auto { .. })) {
                return Err(format!(
                    "downlink rule '{}': bits=auto is worker-side only; use a fixed or \
                     scheduled width",
                    r.pattern
                ));
            }
        }
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// First rule whose glob matches `group_name` (None = the shared
    /// homogeneous default applies).
    pub fn resolve(&self, group_name: &str) -> Option<&GroupPolicy> {
        self.rules
            .iter()
            .find(|r| glob_match(&r.pattern, group_name))
            .map(|r| &r.policy)
    }

    /// Parse the CLI spec `glob=family[:key=val,...];...` (see module
    /// docs).  An empty family segment inherits the base sparsifier.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut rules = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (pattern, rhs) = part.split_once('=').ok_or_else(|| {
                format!("policy rule '{part}' needs the form glob=family[:key=val,...]")
            })?;
            let pattern = pattern.trim();
            let (family, params) = match rhs.split_once(':') {
                Some((f, p)) => (f.trim(), Some(p)),
                None => (rhs.trim(), None),
            };
            let mut policy = GroupPolicy {
                family: (!family.is_empty()).then(|| family.to_string()),
                ..GroupPolicy::default()
            };
            for kv in params
                .unwrap_or("")
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
            {
                let (key, val) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("policy param '{kv}' needs key=value"))?;
                let val = val.trim();
                let us = |v: &str| {
                    v.parse::<usize>().map_err(|_| format!("bad integer '{v}' for '{key}'"))
                };
                let fl = |v: &str| {
                    v.parse::<f32>().map_err(|_| format!("bad number '{v}' for '{key}'"))
                };
                match key.trim() {
                    "k" => policy.k = Some(us(val)?),
                    "mu" => policy.mu = Some(Schedule::parse(val)?),
                    "q" => policy.q = Some(Schedule::parse(val)?),
                    "tau" => policy.tau = Some(fl(val)?),
                    "seed" => {
                        policy.seed = Some(
                            val.parse::<u64>()
                                .map_err(|_| format!("bad seed '{val}'"))?,
                        )
                    }
                    "momentum" => policy.momentum = Some(fl(val)?),
                    "clip" => policy.clip = Some(fl(val)?),
                    "ratio" => policy.ratio = Some(fl(val)?),
                    "k_min" | "kmin" => policy.k_min = Some(us(val)?),
                    "k_max" | "kmax" => policy.k_max = Some(us(val)?),
                    "bits" => policy.bits = Some(BitsSpec::parse(val)?),
                    "idx" => policy.idx = Some(IndexCodec::parse(val)?),
                    "levels" => policy.levels = Some(LevelKind::parse(val)?),
                    "eta" => policy.eta = Some(fl(val)?),
                    other => return Err(format!("unknown policy param '{other}'")),
                }
            }
            rules.push(PolicyRule { pattern: pattern.to_string(), policy });
        }
        if rules.is_empty() {
            return Err(format!("empty policy spec '{spec}'"));
        }
        Self::new(rules)
    }

    /// Serialize as `[{"match": glob, "family"?: .., "mu"?: .., ...}]`.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rules
                .iter()
                .map(|r| {
                    let mut m = std::collections::BTreeMap::new();
                    m.insert("match".to_string(), r.pattern.as_str().into());
                    let p = &r.policy;
                    if let Some(f) = &p.family {
                        m.insert("family".to_string(), f.as_str().into());
                    }
                    if let Some(k) = p.k {
                        m.insert("k".to_string(), k.into());
                    }
                    if let Some(s) = &p.mu {
                        m.insert("mu".to_string(), s.to_json());
                    }
                    if let Some(s) = &p.q {
                        m.insert("q".to_string(), s.to_json());
                    }
                    if let Some(v) = p.tau {
                        m.insert("tau".to_string(), (v as f64).into());
                    }
                    if let Some(v) = p.seed {
                        m.insert("seed".to_string(), (v as usize).into());
                    }
                    if let Some(v) = p.momentum {
                        m.insert("momentum".to_string(), (v as f64).into());
                    }
                    if let Some(v) = p.clip {
                        m.insert("clip".to_string(), (v as f64).into());
                    }
                    if let Some(v) = p.ratio {
                        m.insert("ratio".to_string(), (v as f64).into());
                    }
                    if let Some(v) = p.k_min {
                        m.insert("k_min".to_string(), v.into());
                    }
                    if let Some(v) = p.k_max {
                        m.insert("k_max".to_string(), v.into());
                    }
                    if let Some(s) = &p.bits {
                        m.insert("bits".to_string(), s.to_json());
                    }
                    if let Some(c) = p.idx {
                        m.insert("idx".to_string(), c.name().into());
                    }
                    if let Some(l) = p.levels {
                        m.insert("levels".to_string(), l.name().into());
                    }
                    if let Some(v) = p.eta {
                        m.insert("eta".to_string(), (v as f64).into());
                    }
                    Json::Obj(m)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let arr = j.as_arr().ok_or("policy must be a JSON array")?;
        let mut rules = Vec::new();
        for (i, entry) in arr.iter().enumerate() {
            // unknown/misspelled keys must fail loudly, exactly like
            // the CLI spec parser — a silently dropped hyperparameter
            // is the state-loss bug class this module exists to fix
            let m = entry
                .as_obj()
                .ok_or_else(|| format!("policy[{i}] must be an object"))?;
            if let Some(bad) = m.keys().find(|k| !POLICY_KEYS.contains(&k.as_str())) {
                return Err(format!("policy[{i}] has unknown key '{bad}'"));
            }
            let pattern = entry
                .get("match")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("policy[{i}] missing 'match'"))?
                .to_string();
            let f32_of = |key: &str| entry.get(key).and_then(Json::as_f64).map(|v| v as f32);
            let sched_of = |key: &str| -> Result<Option<Schedule>, String> {
                entry.get(key).map(Schedule::from_json).transpose()
            };
            let policy = GroupPolicy {
                family: entry.get("family").and_then(Json::as_str).map(str::to_string),
                k: entry.get("k").and_then(Json::as_usize),
                mu: sched_of("mu")?,
                q: sched_of("q")?,
                tau: f32_of("tau"),
                seed: entry.get("seed").and_then(Json::as_f64).map(|v| v as u64),
                momentum: f32_of("momentum"),
                clip: f32_of("clip"),
                ratio: f32_of("ratio"),
                k_min: entry.get("k_min").and_then(Json::as_usize),
                k_max: entry.get("k_max").and_then(Json::as_usize),
                bits: entry.get("bits").map(BitsSpec::from_json).transpose()?,
                idx: entry
                    .get("idx")
                    .map(|j| {
                        j.as_str()
                            .ok_or_else(|| format!("policy[{i}].idx must be a string"))
                            .and_then(IndexCodec::parse)
                    })
                    .transpose()?,
                levels: entry
                    .get("levels")
                    .map(|j| {
                        j.as_str()
                            .ok_or_else(|| format!("policy[{i}].levels must be a string"))
                            .and_then(LevelKind::parse)
                    })
                    .transpose()?,
                eta: f32_of("eta"),
            };
            rules.push(PolicyRule { pattern, policy });
        }
        Self::new(rules)
    }
}

/// `*` (any run) / `?` (any one char) glob match, anchored both ends.
pub fn glob_match(pattern: &str, name: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let n: Vec<char> = name.chars().collect();
    let (mut pi, mut ni) = (0usize, 0usize);
    let mut star: Option<usize> = None;
    let mut mark = 0usize;
    while ni < n.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == n[ni]) {
            pi += 1;
            ni += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some(pi);
            mark = ni;
            pi += 1;
        } else if let Some(s) = star {
            pi = s + 1;
            mark += 1;
            ni = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_matches_star_and_question() {
        assert!(glob_match("conv*", "conv0.w"));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("*.b", "fc0.b"));
        assert!(glob_match("fc?.w", "fc0.w"));
        assert!(glob_match("*conv*", "block1.conv.w"));
        assert!(!glob_match("conv*", "fc0.w"));
        assert!(!glob_match("fc?.w", "fc10.w"));
        assert!(!glob_match("", "x"));
        assert!(glob_match("", ""));
        assert!(glob_match("**", "abc"));
    }

    #[test]
    fn schedule_const_and_linear() {
        let c = Schedule::parse("0.3").unwrap();
        assert_eq!(c, Schedule::Const(0.3));
        assert_eq!(c.at(0), 0.3);
        assert_eq!(c.at(1000), 0.3);
        let l = Schedule::parse("0.5..0.1/4").unwrap();
        assert_eq!(l, Schedule::Linear { from: 0.5, to: 0.1, over: 4 });
        assert_eq!(l.at(0), 0.5);
        assert!((l.at(2) - 0.3).abs() < 1e-6);
        assert_eq!(l.at(4), 0.1);
        assert_eq!(l.at(400), 0.1, "clamped past the horizon");
        assert!(Schedule::parse("0.5..0.1").is_err(), "missing /OVER");
        assert!(Schedule::parse("x").is_err());
        assert!(Schedule::parse("0.5../4").is_err());
    }

    #[test]
    fn schedule_json_roundtrip() {
        for s in [Schedule::Const(0.25), Schedule::Linear { from: 0.5, to: 0.1, over: 200 }] {
            assert_eq!(Schedule::from_json(&s.to_json()).unwrap(), s);
        }
        assert!(Schedule::from_json(&Json::Str("x".into())).is_err());
    }

    #[test]
    fn parse_issue_example() {
        let t = PolicyTable::parse("conv*=regtopk:mu=0.3;bias=dense;*=topk").unwrap();
        assert_eq!(t.rules().len(), 3);
        let conv = t.resolve("conv0.w").unwrap();
        assert_eq!(conv.family.as_deref(), Some("regtopk"));
        assert_eq!(conv.mu, Some(Schedule::Const(0.3)));
        assert_eq!(t.resolve("bias").unwrap().family.as_deref(), Some("dense"));
        assert_eq!(t.resolve("fc.w").unwrap().family.as_deref(), Some("topk"));
    }

    #[test]
    fn first_match_wins_and_inherit_family() {
        let t = PolicyTable::parse("fc*=:mu=0.5..0.1/200;*=dense").unwrap();
        let fc = t.resolve("fc0.w").unwrap();
        assert_eq!(fc.family, None, "empty family segment inherits");
        assert!(fc.has_schedule());
        assert_eq!(t.resolve("conv").unwrap().family.as_deref(), Some("dense"));
        // no rule matches -> shared default
        let t2 = PolicyTable::parse("conv*=dense").unwrap();
        assert!(t2.resolve("fc0.w").is_none());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(PolicyTable::parse("").is_err());
        assert!(PolicyTable::parse("conv*").is_err(), "no '='");
        assert!(PolicyTable::parse("conv*=magic").is_err(), "unknown family");
        assert!(PolicyTable::parse("conv*=topk:bogus=1").is_err(), "unknown param");
        assert!(PolicyTable::parse("conv*=regtopk:mu=-1").is_err(), "mu <= 0");
        assert!(PolicyTable::parse("conv*=regtopk:mu=0..0.5/10").is_err(), "mu endpoint 0");
        assert!(PolicyTable::parse("conv*=threshold:tau=0").is_err(), "tau <= 0");
        assert!(PolicyTable::parse("conv*=dgc:momentum=1.5").is_err(), "momentum >= 1");
        assert!(PolicyTable::parse("conv*=topk:k=x").is_err());
        assert!(PolicyTable::parse("=topk").is_err(), "empty glob");
    }

    #[test]
    fn table_json_roundtrip() {
        let t = PolicyTable::parse(
            "conv*=regtopk:mu=0.5..0.1/200,q=2,k=32;*.b=dense;fc*=adak:ratio=0.8,kmin=2,kmax=40;*=topk:seed=7",
        )
        .unwrap();
        let j = t.to_json();
        let t2 = PolicyTable::from_json(&j).unwrap();
        assert_eq!(t, t2);
        // validation also runs on the JSON path
        assert!(PolicyTable::from_json(&Json::parse(r#"[{"match":"a","family":"magic"}]"#).unwrap()).is_err());
        assert!(PolicyTable::from_json(&Json::parse(r#"[{"family":"topk"}]"#).unwrap()).is_err());
        // unknown/misspelled keys are rejected, not silently dropped
        for bad in [
            r#"[{"match":"a","family":"topk","kmax":40}]"#,
            r#"[{"match":"a","family":"regtopk","Q":2}]"#,
            r#"["not an object"]"#,
        ] {
            assert!(PolicyTable::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn bits_and_eta_parse_validate_and_roundtrip() {
        // the ISSUE 4 spec line
        let t = PolicyTable::parse("conv*=regtopk:mu=0.3,bits=4;*=topk:bits=8").unwrap();
        let conv = t.resolve("conv0.w").unwrap();
        assert_eq!(conv.bits, Some(BitsSpec::Sched(Schedule::Const(4.0))));
        assert_eq!(
            t.resolve("fc.w").unwrap().bits,
            Some(BitsSpec::Sched(Schedule::Const(8.0)))
        );
        // scheduled bits + per-group eta
        let t = PolicyTable::parse("fc*=:bits=8..4/100,eta=2.0;*=dense").unwrap();
        let fc = t.resolve("fc0.w").unwrap();
        assert_eq!(
            fc.bits,
            Some(BitsSpec::Sched(Schedule::Linear { from: 8.0, to: 4.0, over: 100 }))
        );
        assert_eq!(fc.eta, Some(2.0));
        assert_eq!(t.resolve("conv").unwrap().bits, None);
        // JSON round trip keeps both
        let t2 = PolicyTable::from_json(&t.to_json()).unwrap();
        assert_eq!(t, t2);
        // validation: bits outside [2, 32], eta <= 0 rejected on both paths
        assert!(PolicyTable::parse("g=topk:bits=1").is_err());
        assert!(PolicyTable::parse("g=topk:bits=33").is_err());
        assert!(PolicyTable::parse("g=topk:bits=8..1/10").is_err());
        assert!(PolicyTable::parse("g=topk:eta=0").is_err());
        assert!(PolicyTable::parse("g=topk:eta=-1").is_err());
        assert!(PolicyTable::parse("g=topk:bits=32").is_ok(), "32 = explicit passthrough");
        assert!(
            PolicyTable::from_json(&Json::parse(r#"[{"match":"a","bits":1}]"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn codec_keys_parse_validate_and_roundtrip() {
        use crate::comm::codec::{IndexCodec, LevelKind};
        // the ISSUE 5 spec surface: idx / levels / auto bits
        let t = PolicyTable::parse(
            "conv*=regtopk:bits=4,idx=rice,levels=nuq;fc*=:idx=raw;*=topk:bits=auto:4..8",
        )
        .unwrap();
        let conv = t.resolve("conv0.w").unwrap();
        assert_eq!(conv.idx, Some(IndexCodec::Rice));
        assert_eq!(conv.levels, Some(LevelKind::Nuq));
        assert_eq!(t.resolve("fc.w").unwrap().idx, Some(IndexCodec::Raw));
        assert_eq!(
            t.resolve("other").unwrap().bits,
            Some(BitsSpec::Auto { lo: 4, hi: 8 })
        );
        // JSON round trip keeps every codec key
        assert_eq!(PolicyTable::from_json(&t.to_json()).unwrap(), t);
        // validation on both paths
        assert!(PolicyTable::parse("g=topk:idx=huffman").is_err());
        assert!(PolicyTable::parse("g=topk:levels=log").is_err());
        assert!(PolicyTable::parse("g=topk:levels=nuq").is_err(), "levels needs bits");
        assert!(PolicyTable::parse("g=topk:bits=auto:1..8").is_err());
        assert!(PolicyTable::parse("g=topk:bits=auto:8..20").is_err());
        assert!(PolicyTable::parse("g=topk:bits=auto:8..4").is_err(), "lo > hi");
        assert!(PolicyTable::parse("g=topk:bits=auto:4").is_err(), "missing ..HI");
        assert!(
            PolicyTable::from_json(&Json::parse(r#"[{"match":"a","idx":"huffman"}]"#).unwrap())
                .is_err()
        );
        assert!(
            PolicyTable::from_json(
                &Json::parse(r#"[{"match":"a","levels":"nuq"}]"#).unwrap()
            )
            .is_err(),
            "levels without bits rejected on the JSON path too"
        );
        assert!(PolicyTable::from_json(
            &Json::parse(r#"[{"match":"a","bits":{"auto":true,"lo":4,"hi":8}}]"#).unwrap()
        )
        .is_ok());
    }

    #[test]
    fn half_width_levels_parse_without_bits() {
        use crate::comm::codec::LevelKind;
        let t = PolicyTable::parse("fc*=:levels=bf16;conv*=:levels=fp16;*=topk").unwrap();
        assert_eq!(t.resolve("fc0.w").unwrap().levels, Some(LevelKind::Bf16));
        assert_eq!(t.resolve("fc0.w").unwrap().bits, None);
        assert_eq!(t.resolve("conv1.w").unwrap().levels, Some(LevelKind::Fp16));
        // JSON round trip keeps the half kinds
        assert_eq!(PolicyTable::from_json(&t.to_json()).unwrap(), t);
        // half kinds are fixed-width: a bits= key is a contradiction
        assert!(PolicyTable::parse("g=topk:bits=8,levels=fp16").is_err());
        assert!(PolicyTable::parse("g=topk:bits=16,levels=bf16").is_err());
        assert!(PolicyTable::from_json(
            &Json::parse(r#"[{"match":"a","bits":8,"levels":"fp16"}]"#).unwrap()
        )
        .is_err());
        // and they are codec-only keys, so the downlink accepts them
        let d = PolicyTable::parse("*=:levels=fp16").unwrap();
        assert!(d.validate_downlink().is_ok());
    }

    #[test]
    fn downlink_validation_allows_codec_keys_only() {
        // the downlink surface: bare sparse broadcast + codec knobs
        for ok in [
            "*=",
            "*=:bits=8",
            "*=:idx=rice",
            "conv*=:bits=4,idx=rice,levels=nuq;*=:idx=raw",
            "*=:bits=8..4/100",
        ] {
            let t = PolicyTable::parse(ok).unwrap();
            assert!(t.validate_downlink().is_ok(), "{ok}");
            assert!(t.rules()[0].policy.is_codec_only(), "{ok}");
        }
        // sparsifier keys and auto widths have no downlink meaning
        for bad in ["*=topk", "*=:mu=0.3", "*=:eta=2.0", "*=:k=5", "*=:bits=auto:4..8"] {
            let t = PolicyTable::parse(bad).unwrap();
            assert!(t.validate_downlink().is_err(), "{bad}");
        }
    }

    #[test]
    fn huge_seeds_rejected_before_json_corruption() {
        // 2^53 + 3 is not representable as an f64 integer; both spec
        // and JSON paths must refuse it instead of corrupting the
        // stream seed on the manifest round trip
        assert!(PolicyTable::parse("g=randk:seed=9007199254740995").is_err());
        assert!(PolicyTable::parse("g=randk:seed=12345").is_ok());
    }

    #[test]
    fn full_param_surface_parses() {
        let t = PolicyTable::parse(
            "g=dgc:k=5,momentum=0.7,clip=2.5;h=randk:seed=11;i=threshold:tau=0.25",
        )
        .unwrap();
        let g = t.resolve("g").unwrap();
        assert_eq!(g.k, Some(5));
        assert_eq!(g.momentum, Some(0.7));
        assert_eq!(g.clip, Some(2.5));
        assert_eq!(t.resolve("h").unwrap().seed, Some(11));
        assert_eq!(t.resolve("i").unwrap().tau, Some(0.25));
    }
}
