//! REGTOP-k (Algorithm 1) — the paper's contribution.
//!
//! Per round t >= 1 each worker computes
//!
//!   a     = eps + g                                   (line 4)
//!   Delta = s_prev ? (gagg_prev - omega*acc_prev) / (omega*a) : Q   (line 5)
//!   score = a * tanh(|1 + Delta| / mu)                (line 6, eq. 16)
//!   s     = Top_k(score);  ghat = s . a;  eps' = a - ghat  (lines 6-8)
//!
//! Round 0 falls back to plain TOP-k (line 1).  The numerics here match
//! `kernels/ref.py::regtopk_score` to the guard constant (`DIV_EPS`) so
//! the rust-native path and the HLO artifact path agree bit-for-bit in
//! every position that can be selected (cross-checked in
//! rust/tests/hlo_cross_check.rs).

use crate::grad::ErrorFeedback;
use crate::sparse::{select_topk, SelectEngine, SparseVec};
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};

/// Must equal ref.DIV_EPS on the python side.
const DIV_EPS: f32 = 1e-30;

pub struct RegTopK {
    k: usize,
    /// regularization temperature; mu -> 0 recovers plain TOP-k
    mu: f32,
    /// postulated distortion for never-sent entries (Prop. 2's Q)
    q: f32,
    ef: ErrorFeedback,
    /// scratch buffer for scores (avoids per-round allocation)
    score: Vec<f32>,
    /// sharded fused accumulate+score+select (None = serial path)
    engine: Option<SelectEngine>,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl RegTopK {
    pub fn new(dim: usize, k: usize, mu: f32, q: f32) -> Self {
        assert!(k > 0, "regtopk needs k >= 1");
        assert!(mu > 0.0, "mu must be positive (mu -> 0 is TOP-k)");
        RegTopK {
            k,
            mu,
            q,
            ef: ErrorFeedback::new(dim),
            score: vec![0.0; dim],
            engine: None,
            sel: Vec::new(),
        }
    }

    pub fn error(&self) -> &[f32] {
        &self.ef.eps
    }

    /// The regularized score  a * tanh(|1 + Delta|/mu)  (eq. 16).
    /// Exposed for the cross-check tests and the score benches.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_score(
        acc: &[f32],
        acc_prev: &[f32],
        gagg_prev: &[f32],
        mask_prev: &[f32],
        omega: f32,
        mu: f32,
        q: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(acc.len(), out.len());
        let inv_mu = 1.0 / mu;
        for i in 0..acc.len() {
            let denom = omega * acc[i];
            let delta_sent = if denom.abs() > DIV_EPS {
                (gagg_prev[i] - omega * acc_prev[i]) / denom
            } else {
                q
            };
            let delta = mask_prev[i] * delta_sent + q * (1.0 - mask_prev[i]);
            let arg = (1.0 + delta).abs() * inv_mu;
            // Exact-in-f32 saturation shortcut (perf pass): for
            // arg >= 9.2, 1 - tanh(arg) < 2e-8 < half the f32 ulp at
            // 1.0, so f32(tanh(arg)) == 1.0 bit-exactly.  Skipping the
            // transcendental halves the score-pass cost at the plateau
            // where most entries saturate.
            let reg = if arg >= 9.2 { 1.0 } else { arg.tanh() };
            out[i] = acc[i] * reg;
        }
    }

    /// One fused shard pass for the engine: a = eps + g (Alg. 1 line 4)
    /// immediately followed by the eq. 16 score for the same entry —
    /// bit-identical to `accumulate()` then [`Self::compute_score`]
    /// (same operation order, same `DIV_EPS` guard, same saturation
    /// shortcut), but with one loop and one memory traversal.
    #[allow(clippy::too_many_arguments)]
    fn fused_accumulate_score(
        eps: &[f32],
        grad: &[f32],
        acc_out: &mut [f32],
        acc_prev: &[f32],
        gagg_prev: &[f32],
        mask_prev: &[f32],
        omega: f32,
        mu: f32,
        q: f32,
        score_out: &mut [f32],
    ) {
        debug_assert_eq!(acc_out.len(), score_out.len());
        let inv_mu = 1.0 / mu;
        for i in 0..acc_out.len() {
            let a = eps[i] + grad[i];
            acc_out[i] = a;
            let denom = omega * a;
            let delta_sent = if denom.abs() > DIV_EPS {
                (gagg_prev[i] - omega * acc_prev[i]) / denom
            } else {
                q
            };
            let delta = mask_prev[i] * delta_sent + q * (1.0 - mask_prev[i]);
            let arg = (1.0 + delta).abs() * inv_mu;
            let reg = if arg >= 9.2 { 1.0 } else { arg.tanh() };
            score_out[i] = a * reg;
        }
    }
}

impl Sparsifier for RegTopK {
    fn name(&self) -> &'static str {
        "regtopk"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], ctx: &RoundCtx, out: &mut SparseVec) {
        match &mut self.engine {
            // fused sharded path: accumulate + score + histogram in ONE
            // parallel pass, then one parallel collect pass — replacing
            // the serial accumulate/score/select triple.
            Some(eng) => {
                let k = self.k;
                if !self.ef.warm {
                    // Alg. 1 line 1: plain TOP-k on a = eps + g.
                    let eps = &self.ef.eps;
                    eng.fused_select_into(
                        &mut self.ef.acc,
                        |lo, acc| {
                            for ((a, e), g) in
                                acc.iter_mut().zip(&eps[lo..lo + acc.len()]).zip(&grad[lo..])
                            {
                                *a = e + g;
                            }
                        },
                        k,
                        &mut self.sel,
                    );
                } else {
                    let (mu, q) = (self.mu, self.q);
                    let omega = ctx.omega;
                    let gagg = ctx.gagg_prev;
                    let acc_sh = crate::util::pool::SharedSlice::new(&mut self.ef.acc);
                    let eps = &self.ef.eps;
                    let acc_prev = &self.ef.acc_prev;
                    let mask_prev = &self.ef.mask_prev;
                    eng.fused_select_into(
                        &mut self.score,
                        |lo, score| {
                            let hi = lo + score.len();
                            // SAFETY: the engine invokes `fill` once
                            // per shard with the disjoint `[lo, hi)`
                            // ranges of one pool job, and `self.ef.acc`
                            // outlives the enclosing
                            // `fused_select_into` call.
                            let acc = unsafe { acc_sh.range(lo, hi) };
                            Self::fused_accumulate_score(
                                &eps[lo..hi],
                                &grad[lo..hi],
                                acc,
                                &acc_prev[lo..hi],
                                &gagg[lo..hi],
                                &mask_prev[lo..hi],
                                omega,
                                mu,
                                q,
                                score,
                            );
                        },
                        k,
                        &mut self.sel,
                    );
                }
            }
            None => {
                self.ef.accumulate(grad);
                let sel = if !self.ef.warm {
                    // Alg. 1 line 1: plain TOP-k in the initial iteration.
                    select_topk(&self.ef.acc, self.k)
                } else {
                    Self::compute_score(
                        &self.ef.acc,
                        &self.ef.acc_prev,
                        ctx.gagg_prev,
                        &self.ef.mask_prev,
                        ctx.omega,
                        self.mu,
                        self.q,
                        &mut self.score,
                    );
                    select_topk(&self.score, self.k)
                };
                self.sel.clear();
                self.sel.extend_from_slice(&sel);
            }
        }
        self.ef.commit_into(&self.sel, out);
    }

    fn set_shards(&mut self, shards: usize) {
        self.engine = if shards > 1 { Some(SelectEngine::new(shards)) } else { None };
    }

    /// Per-round mu/Q re-tune (layer-wise schedules).  mu is kept
    /// strictly positive — the mu -> 0 limit is plain TOP-k and the
    /// score kernel divides by mu.
    fn set_temperature(&mut self, mu: f32, q: f32) {
        self.mu = mu.max(f32::MIN_POSITIVE);
        self.q = q;
    }

    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        self.ef.fold_residual(indices, residual);
    }

    fn export_state(&self) -> SparsifierState {
        SparsifierState::Ef(self.ef.snapshot())
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Ef(ef) => self.ef.restore(ef),
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("regtopk cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        self.ef.accumulate_into(grad, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::testutil;
    use crate::util::check;

    fn ctx<'a>(t: usize, gagg: &'a [f32]) -> RoundCtx<'a> {
        RoundCtx { t, gagg_prev: gagg, omega: 0.5, genie_acc: None }
    }

    #[test]
    fn round0_is_plain_topk() {
        let mut reg = RegTopK::new(4, 2, 0.5, 1.0);
        let mut top = crate::sparsify::TopK::new(4, 2);
        let z = vec![0.0; 4];
        let g = [3.0, -1.0, 0.5, 2.0];
        assert_eq!(reg.step(&g, &ctx(0, &z)), top.step(&g, &ctx(0, &z)));
    }

    #[test]
    fn destructive_entry_is_damped() {
        // Worker sent entry 0 (huge) in round 0; the server's aggregate
        // came back 0 there (cancelled by another worker).  Delta = -1
        // => tanh(0) => score 0 => round 1 must select a different entry.
        let mut reg = RegTopK::new(3, 1, 0.5, 1.0);
        let z = vec![0.0; 3];
        let g = [100.0, 1.0, 0.5];
        let sv0 = reg.step(&g, &ctx(0, &z));
        assert_eq!(sv0.indices(), &[0]);
        let gagg = vec![0.0, 0.0, 0.0]; // entry 0 cancelled globally
        let sv1 = reg.step(&g, &ctx(1, &gagg));
        assert_eq!(sv1.indices(), &[1], "damped entry 0 must lose");
    }

    #[test]
    fn constructive_entry_is_kept() {
        // If the aggregate equals the worker's own contribution
        // (omega*acc_prev) plus more of the same sign, Delta >= 0 and
        // the large entry keeps winning.
        let mut reg = RegTopK::new(3, 1, 0.5, 1.0);
        let z = vec![0.0; 3];
        let g = [100.0, 1.0, 0.5];
        reg.step(&g, &ctx(0, &z));
        // aggregate reinforces entry 0: g_agg = 2 * omega * 100
        let gagg = vec![100.0, 0.0, 0.0];
        let sv1 = reg.step(&g, &ctx(1, &gagg));
        assert_eq!(sv1.indices(), &[0]);
    }

    #[test]
    fn tiny_mu_matches_topk_trajectory() {
        // mu -> 0: tanh saturates to 1 for any Delta != -1, recovering
        // TOP-k (DESIGN.md invariant 3). Drive both 5 rounds on random
        // grads with a nonzero fabricated aggregate.
        check::forall("regtopk_mu0_is_topk", |rng, _| {
            let n = check::arb_len(rng, 60);
            let k = rng.below(n) + 1;
            let mut reg = RegTopK::new(n, k, 1e-9, 1.0);
            let mut top = crate::sparsify::TopK::new(n, k);
            let mut gagg = vec![0.0; n];
            for t in 0..5 {
                let g = check::arb_vec(rng, n);
                let c = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
                let a = reg.step(&g, &c);
                let b = top.step(&g, &c);
                assert_eq!(a, b, "t={t}");
                gagg = a.to_dense();
            }
        });
    }

    #[test]
    fn conservation_and_mask_size() {
        check::forall("regtopk_conservation", |rng, _| {
            let n = check::arb_len(rng, 80).max(2);
            let k = rng.below(n) + 1;
            let mut reg = RegTopK::new(n, k, 0.5, 1.0);
            let mut gagg = vec![0.0; n];
            for t in 0..4 {
                let g = check::arb_vec(rng, n);
                let acc = reg.peek_acc(&g);
                let c = RoundCtx { t, gagg_prev: &gagg, omega: 0.25, genie_acc: None };
                let sv = reg.step(&g, &c);
                assert_eq!(sv.nnz(), k.min(n));
                let dense = sv.to_dense();
                for i in 0..n {
                    assert_eq!(dense[i] + reg.error()[i], acc[i]);
                }
                gagg = dense;
            }
        });
    }

    #[test]
    fn zero_accumulated_entries_never_panic() {
        let mut reg = RegTopK::new(4, 2, 0.1, 1.0);
        let z = vec![0.0; 4];
        reg.step(&[0.0, 0.0, 0.0, 0.0], &ctx(0, &z));
        let sv = reg.step(&[0.0, 1.0, 0.0, 0.0], &ctx(1, &z));
        assert!(sv.values().iter().all(|v| v.is_finite()));
        let _ = testutil::drive(&mut reg, &[0.0; 4], 3);
    }

    #[test]
    fn score_matches_scalar_formula() {
        // independent recomputation of eq. 16 for a handful of entries
        let acc = [2.0f32, -3.0, 0.5];
        let acc_prev = [1.0f32, 1.0, 1.0];
        let gagg_prev = [0.5f32, -2.0, 0.0];
        let mask_prev = [1.0f32, 0.0, 1.0];
        let (omega, mu, q) = (0.5f32, 0.3f32, 2.0f32);
        let mut out = [0.0f32; 3];
        RegTopK::compute_score(&acc, &acc_prev, &gagg_prev, &mask_prev, omega, mu, q, &mut out);
        for i in 0..3 {
            let delta = if mask_prev[i] == 1.0 {
                (gagg_prev[i] - omega * acc_prev[i]) / (omega * acc[i])
            } else {
                q
            };
            let want = acc[i] * ((1.0f32 + delta).abs() / mu).tanh();
            assert!((out[i] - want).abs() <= 1e-6 * want.abs().max(1.0), "i={i}");
        }
    }
}
