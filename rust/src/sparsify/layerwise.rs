//! Layer-wise sparsification: one independent sparsifier per parameter
//! group, with per-group error feedback and a per-group budget.
//!
//! The journal follow-up to the paper ("Regularized Top-k: A Bayesian
//! Framework for Gradient Sparsification", arXiv 2501.05633) makes the
//! layer-wise formulation explicit: the posterior statistics and the
//! budget k are naturally per-layer.  [`LayerwiseSparsifier`] realizes
//! that here: it owns one child sparsifier (and therefore one
//! error-feedback state, one `SelectEngine`, one scratch arena) per
//! [`GradLayout`] group, carves the incoming gradient / previous
//! aggregate / genie channel into group slices, and emits the bucketed
//! [`SparseUpdate`] wire format.
//!
//! Children need not be homogeneous: [`LayerwiseSparsifier::with_policies`]
//! consumes a `sparsify::PolicyTable` mapping group-name globs to a
//! per-group family + hyperparameters (and mu/Q `Schedule`s re-tuned
//! each round), so biases can ship dense while conv blocks run
//! aggressive RegTop-k.
//!
//! A policy's `bits` override composes QSGD-style stochastic value
//! quantization with the sparsification (rTop-k, arXiv 2005.10941:
//! sparsify-then-quantize beats either alone under a bit budget): the
//! surviving entries of that group's bucket are quantized at the
//! worker boundary, travel as a packed `comm::codec::QuantPayload`,
//! and the rounding residual folds into the child's error store
//! exactly like sparsification error folds into eps.  `bits` accepts
//! the same `FROM..TO/OVER` schedules as mu/Q, plus the
//! residual-steered `auto:LO..HI` mode (the width widens when the
//! observed rounding residual says the wire is too lossy, narrows
//! when there is slack; the current width checkpoints, so resume is
//! bit-exact).
//!
//! The rest of the wire stack is per-group too (ISSUE 5): `levels=`
//! picks the value level family (uniform offset-binary vs NUQSGD-style
//! exponential) and `idx=` the index codec (bit-packed `log J` /
//! raw u32 / delta-sorted Golomb–Rice).  All encode mechanics live in
//! `comm::codec`; this wrapper only owns the per-group schedule/RNG
//! state and applies the stack at the worker boundary.
//!
//! **Equivalence net:** under the degenerate single-group layout the
//! wrapper is a transparent pass-through — one child over the whole
//! vector, built with exactly the flat factory parameters — so its
//! trajectories are bit-identical to the seed flat path for all eight
//! sparsifier families; the same holds for any multi-group layout with
//! an empty or non-matching policy table vs the PR 2 homogeneous path
//! (pinned by `rust/tests/layerwise.rs`).

#![forbid(unsafe_code)]

use crate::comm::codec::{index_bits, IndexCodec, LevelKind, QuantPayload, ValueCodec, WireCost};
use crate::grad::{GradLayout, GradView};
use crate::sparse::engine::MIN_SHARDED_DIM;
use crate::comm::SparseUpdate;
use crate::sparse::SparseVec;
use crate::sparsify::{
    build, BitsSpec, GroupPolicy, PolicyTable, RoundCtx, Schedule, Sparsifier, SparsifierKind,
    SparsifierState,
};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// How the transmission budget is distributed across parameter groups.
///
/// Budgets bind the **fixed-k families** (topk / regtopk / randk /
/// gtopk / dgc): each group's child gets the resolved k.  Families
/// whose transmission rule is not a fixed k keep their own rule per
/// group — `dense` sends everything, `threshold` sends by tau, `adak`
/// adapts within its (per-group-clamped) `[k_min, k_max]` — and the
/// resolved numbers only show up in [`LayerwiseSparsifier::budgets`]
/// observability, not on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum BudgetPolicy {
    /// One whole-model budget k, apportioned across groups
    /// proportionally to group length (largest-remainder rounding).
    Global { k: usize },
    /// Explicit per-group budgets (length must match the group count).
    PerGroup { ks: Vec<usize> },
    /// Per-group k = round(frac * group_len) — the paper's "sparsity
    /// factor S" applied layer-wise.
    Proportional { frac: f64 },
}

impl BudgetPolicy {
    /// Resolve to one budget per group.  Every budget is clamped to
    /// `[1, group_len]`; `Global` may therefore transmit slightly more
    /// than `k` when `k < #groups` (documented floor, matching the
    /// flat selectors' `k >= 1` requirement).
    pub fn resolve(&self, layout: &GradLayout) -> Vec<usize> {
        let clamp = |k: usize, len: usize| k.clamp(1, len);
        match self {
            BudgetPolicy::PerGroup { ks } => {
                assert_eq!(
                    ks.len(),
                    layout.num_groups(),
                    "per-group budget count {} != group count {}",
                    ks.len(),
                    layout.num_groups()
                );
                ks.iter().zip(layout.groups()).map(|(&k, g)| clamp(k, g.len)).collect()
            }
            BudgetPolicy::Proportional { frac } => layout
                .groups()
                .iter()
                .map(|g| clamp((g.len as f64 * frac).round() as usize, g.len))
                .collect(),
            BudgetPolicy::Global { k } => {
                let total = layout.total();
                let k = (*k).min(total);
                // largest-remainder apportionment of k over group lens
                let mut ks: Vec<usize> =
                    layout.groups().iter().map(|g| k * g.len / total).collect();
                let assigned: usize = ks.iter().sum();
                let mut rem: Vec<(usize, usize)> = layout
                    .groups()
                    .iter()
                    .enumerate()
                    .map(|(i, g)| (i, (k * g.len) % total))
                    .collect();
                // biggest fractional part first; ties toward the lower
                // group index (determinism)
                rem.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                for &(i, _) in rem.iter().take(k.saturating_sub(assigned)) {
                    ks[i] += 1;
                }
                ks.iter().zip(layout.groups()).map(|(&kg, g)| clamp(kg, g.len)).collect()
            }
        }
    }

    /// Parse a CLI budget spec: `"global:500"`, `"per:32,8,4"`,
    /// `"prop:0.001"` (also accepts the long policy names).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let (policy, arg) = spec
            .split_once(':')
            .ok_or_else(|| format!("budget spec '{spec}' needs the form policy:value"))?;
        match policy.trim() {
            "global" => arg
                .trim()
                .parse()
                .map(|k| BudgetPolicy::Global { k })
                .map_err(|_| format!("bad global budget '{arg}'")),
            "per" | "per_group" => {
                let ks: Result<Vec<usize>, _> =
                    arg.split(',').map(|s| s.trim().parse()).collect();
                ks.map(|ks| BudgetPolicy::PerGroup { ks })
                    .map_err(|_| format!("bad per-group budget list '{arg}'"))
            }
            "prop" | "proportional" => arg
                .trim()
                .parse()
                .map_err(|_| format!("bad proportional fraction '{arg}'"))
                .and_then(Self::proportional),
            other => Err(format!("unknown budget policy '{other}' (global|per|prop)")),
        }
    }

    /// Validated `Proportional` constructor: the sparsity factor must
    /// be a real fraction in (0, 1] — `prop:10` (a user meaning 10%)
    /// or `prop:nan` must fail loudly, not degenerate to dense/k=1.
    pub fn proportional(frac: f64) -> Result<Self, String> {
        if frac.is_finite() && frac > 0.0 && frac <= 1.0 {
            Ok(BudgetPolicy::Proportional { frac })
        } else {
            Err(format!("proportional fraction {frac} outside (0, 1]"))
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            BudgetPolicy::Global { k } => {
                obj([("policy", "global".into()), ("k", (*k).into())])
            }
            BudgetPolicy::PerGroup { ks } => {
                obj([("policy", "per_group".into()), ("ks", ks.clone().into())])
            }
            BudgetPolicy::Proportional { frac } => {
                obj([("policy", "proportional".into()), ("frac", (*frac).into())])
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let policy = j.get("policy").and_then(Json::as_str).ok_or("budget.policy missing")?;
        match policy {
            "global" => j
                .get("k")
                .and_then(Json::as_usize)
                .map(|k| BudgetPolicy::Global { k })
                .ok_or_else(|| "budget.k missing".to_string()),
            "per_group" => {
                let arr = j
                    .get("ks")
                    .and_then(Json::as_arr)
                    .ok_or("budget.ks missing")?;
                let ks: Option<Vec<usize>> = arr.iter().map(Json::as_usize).collect();
                ks.map(|ks| BudgetPolicy::PerGroup { ks })
                    .ok_or_else(|| "budget.ks must be integers".to_string())
            }
            "proportional" => j
                .get("frac")
                .and_then(Json::as_f64)
                .ok_or_else(|| "budget.frac missing".to_string())
                .and_then(Self::proportional),
            other => Err(format!("unknown budget policy '{other}'")),
        }
    }
}

/// Stream tag separating the quantizer's stochastic-rounding RNG from
/// every other stream in the repo (randk selection, data generators).
const QUANT_STREAM_TAG: u64 = 0x5154_5A51_u64;

/// Residual-steered width thresholds: the relative rounding residual
/// `rho = ||residual|| / ||pre-quantization values||` a round is
/// allowed before the width widens, and the 4x-hysteresis slack below
/// which it narrows (hysteresis keeps the width from oscillating on a
/// noisy trajectory).
const AUTO_WIDEN_RHO: f64 = 0.05;
const AUTO_NARROW_RHO: f64 = AUTO_WIDEN_RHO / 4.0;

/// A quantizing group's width rule.
enum Width {
    /// Fixed or linearly scheduled (the PR 4 path, bit-identical).
    Sched(Schedule),
    /// Residual-steered within `[lo, hi]`; `cur` is the live width —
    /// a pure function of the trajectory, checkpointed for bit-exact
    /// resume.
    Auto { lo: usize, hi: usize, cur: usize },
}

/// One quantizing group's transmission state: the width rule, the
/// level family, the stochastic-rounding stream (checkpointed —
/// resume is bit-exact) and the per-round scratch buffers.
struct GroupQuant {
    width: Width,
    levels: LevelKind,
    rng: Rng,
    residual: Vec<f32>,
    codes: Vec<u32>,
}

impl GroupQuant {
    /// Independent per-(worker, group) rounding stream; the policy's
    /// `seed` override diversifies it exactly like the randk stream.
    fn new(bits: BitsSpec, levels: LevelKind, seed: u64, worker: usize, group: usize) -> Self {
        let width = match bits {
            BitsSpec::Sched(s) => Width::Sched(s),
            // start wide (conservative): narrowing needs evidence
            BitsSpec::Auto { lo, hi } => Width::Auto { lo, hi, cur: hi },
        };
        GroupQuant {
            width,
            levels,
            rng: Rng::seed_from(QUANT_STREAM_TAG ^ seed)
                .derive(((worker as u64) << 32) | group as u64),
            residual: Vec::new(),
            codes: Vec::new(),
        }
    }

    /// Effective bit width at round `t`: a schedule's value rounded
    /// and clamped into [2, 32], or the auto mode's live width.
    /// Packing exists for widths up to 16; anything above is raw-f32
    /// passthrough for the round (so a `32..4/T` schedule stays raw
    /// until it decays into packable territory, and `8..32/T` fades
    /// quantization out).
    fn bits_at(&self, t: usize) -> usize {
        match &self.width {
            Width::Sched(s) => (s.at(t).round() as i64).clamp(2, 32) as usize,
            Width::Auto { cur, .. } => *cur,
        }
    }

    /// Whether `bits` engages the packed path this round.
    fn active_at(bits: usize) -> bool {
        bits <= 16
    }

    /// Settled width once a schedule passes its horizon (auto mode:
    /// the live width).
    fn bits_end(&self) -> usize {
        match &self.width {
            Width::Sched(s) => (s.endpoints().1.round() as i64).clamp(2, 32) as usize,
            Width::Auto { cur, .. } => *cur,
        }
    }

    /// The live auto width (None for scheduled policies) — exported in
    /// `SparsifierState::Quantized` so resume is bit-exact.
    fn auto_bits(&self) -> Option<usize> {
        match &self.width {
            Width::Sched(_) => None,
            Width::Auto { cur, .. } => Some(*cur),
        }
    }

    /// Whether ANY round engages the packed path.  Linear schedules
    /// are monotone between their endpoints, so checking both
    /// suffices; auto widths are capped at 16 and always engage.  A
    /// policy whose width can never drop to 16 or below (e.g. a
    /// constant `bits=32` passthrough) gets no quantizer state at all
    /// — its exports and checkpoints stay interchangeable with a
    /// bits-less policy, matching the bit-identical trajectories.
    fn ever_active(&self) -> bool {
        match &self.width {
            Width::Sched(s) => {
                let (a, b) = s.endpoints();
                let w = |v: f32| (v.round() as i64).clamp(2, 32) as usize;
                Self::active_at(w(a)) || Self::active_at(w(b))
            }
            Width::Auto { .. } => true,
        }
    }

    /// A round where the CURRENT width did not pay on the wire: walk
    /// an auto width one step down if the range's floor width would
    /// pay for this bucket shape.  Without this a group whose `hi`
    /// width never beats raw (tiny nnz: the 4-byte scale header
    /// dominates) could deadlock at `hi` — steering only runs after
    /// an encode, and the encode is gated on the current width
    /// paying.  Pure function of the bucket shape, so resume stays
    /// bit-exact; no-op for scheduled widths.
    fn nudge_down_if_unpaid(&mut self, nnz: usize, ib: usize, raw: usize) {
        let Width::Auto { lo, cur, .. } = &mut self.width else {
            return;
        };
        if nnz > 0 && *cur > *lo && QuantPayload::bytes_for(nnz, *lo, ib) < raw {
            *cur -= 1;
        }
    }

    /// Steer an auto width from the round's observed rounding
    /// residual (`self.residual`, aligned with `decoded`, the lossy
    /// values just written to the bucket).  No-op for scheduled
    /// widths and for rounds that observed nothing.  Deterministic —
    /// a pure function of the trajectory — so resume stays bit-exact
    /// once `cur` travels in the checkpoint.
    fn steer(&mut self, decoded: &[f32]) {
        let Width::Auto { lo, hi, cur } = &mut self.width else {
            return;
        };
        debug_assert_eq!(decoded.len(), self.residual.len());
        let mut r2 = 0.0f64;
        let mut o2 = 0.0f64;
        for (&d, &r) in decoded.iter().zip(&self.residual) {
            r2 += (r as f64) * (r as f64);
            let orig = d as f64 + r as f64;
            o2 += orig * orig;
        }
        if o2 == 0.0 {
            return; // an all-zero bucket says nothing about the width
        }
        let rho = (r2 / o2).sqrt();
        if rho > AUTO_WIDEN_RHO {
            *cur = (*cur + 1).min(*hi);
        } else if rho < AUTO_NARROW_RHO {
            *cur = cur.saturating_sub(1).max(*lo);
        }
    }
}

/// The per-group child configuration: the family's shared parameters
/// with the group's budget and bounds substituted in.  Group 0 of a
/// single-group layout reproduces `kind` exactly (the equivalence
/// net's anchor).
fn child_kind(kind: &SparsifierKind, k: usize, len: usize, group: usize) -> SparsifierKind {
    let k = k.clamp(1, len.max(1));
    match kind {
        SparsifierKind::Dense => SparsifierKind::Dense,
        SparsifierKind::TopK { .. } => SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { mu, q, .. } => {
            SparsifierKind::RegTopK { k, mu: *mu, q: *q }
        }
        SparsifierKind::RandK { seed, .. } => SparsifierKind::RandK {
            k,
            // distinct stream per group; group 0 keeps the flat seed
            seed: seed.wrapping_add((group as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        },
        SparsifierKind::Threshold { tau } => SparsifierKind::Threshold { tau: *tau },
        SparsifierKind::GlobalTopK { .. } => SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { momentum, clip, .. } => {
            SparsifierKind::Dgc { k, momentum: *momentum, clip: *clip }
        }
        SparsifierKind::AdaK { ratio, k_min, k_max } => {
            let k_min = (*k_min).clamp(1, len.max(1));
            SparsifierKind::AdaK {
                ratio: *ratio,
                k_min,
                k_max: (*k_max).clamp(1, len.max(1)).max(k_min),
            }
        }
    }
}

/// Build one group's child from the base family, the group's policy
/// (None = the homogeneous shared default) and the budget-resolved k.
/// Returns the child, the effective k and the group's mu/Q schedule
/// pair (None unless the policy carries a non-constant schedule).
fn build_child(
    base: &SparsifierKind,
    policy: Option<&GroupPolicy>,
    k_budget: usize,
    len: usize,
    group: usize,
    worker: usize,
) -> (Box<dyn Sparsifier>, usize, Option<(Schedule, Schedule)>) {
    let Some(p) = policy else {
        // the PR 2 homogeneous path, byte for byte
        let kind = child_kind(base, k_budget, len, group);
        return (build(&kind, len, worker), k_budget, None);
    };
    let mut params = base.to_params();
    params.k = p.k.unwrap_or(k_budget).clamp(1, len.max(1));
    if let Some(s) = &p.mu {
        params.mu = s.at(0);
    }
    if let Some(s) = &p.q {
        params.q = s.at(0);
    }
    if let Some(v) = p.tau {
        params.tau = v;
    }
    if let Some(v) = p.seed {
        params.seed = v;
    }
    if let Some(v) = p.momentum {
        params.momentum = v;
    }
    if let Some(v) = p.clip {
        params.clip = v;
    }
    if let Some(v) = p.ratio {
        params.ratio = v;
    }
    if let Some(v) = p.k_min {
        params.k_min = v;
    }
    if let Some(v) = p.k_max {
        params.k_max = v;
    }
    let family = p.family.as_deref().unwrap_or_else(|| base.name());
    let kind = SparsifierKind::from_params(family, &params)
        .unwrap_or_else(|| panic!("policy names unknown family '{family}'"));
    // same per-group clamps + stochastic stream diversification as the
    // homogeneous path
    let kind = child_kind(&kind, params.k, len, group);
    let sched = if p.has_schedule() {
        Some((
            p.mu.clone().unwrap_or(Schedule::Const(params.mu)),
            p.q.clone().unwrap_or(Schedule::Const(params.q)),
        ))
    } else {
        None
    };
    (build(&kind, len, worker), params.k, sched)
}

/// One sparsifier per parameter group.  Implements [`Sparsifier`], so
/// workers hold it like any flat sparsifier; the bucketed
/// [`Sparsifier::step_group_into`] entry point is the native path and
/// the flat `step`/`step_into` compatibility path flattens the buckets.
///
/// With a [`PolicyTable`] ([`Self::with_policies`]) the children can be
/// *heterogeneous*: family and hyperparameters per group, with mu/Q
/// re-tuned per round by the group's [`Schedule`]s.  Groups matched by
/// no rule run the shared homogeneous default, so an empty (or
/// non-matching) table is bit-identical to [`Self::new`].
pub struct LayerwiseSparsifier {
    layout: GradLayout,
    children: Vec<Box<dyn Sparsifier>>,
    /// resolved per-group budgets (observability + tests)
    ks: Vec<usize>,
    /// per-group mu/Q schedules; None = fixed hyperparameters (no
    /// per-round re-tune call, preserving the homogeneous bit-identity)
    schedules: Vec<Option<(Schedule, Schedule)>>,
    /// per-group quantized-transmission state; None = raw f32 bucket
    /// (with `bits` unset everywhere this vector is all-None and the
    /// whole path is bit-identical to the pre-quantization tree)
    quants: Vec<Option<GroupQuant>>,
    /// per-group index codec (`idx=` policy key); all-Packed = the
    /// pre-codec accounting, bit-identical
    idx_codecs: Vec<IndexCodec>,
    /// bits an UN-quantized value costs on the wire (the cost model's
    /// `value_bits`; 32 unless the run models half-precision links).
    /// The packing-must-pay guard compares against this so the ledger
    /// can never report a bits policy increasing upload bytes.
    raw_value_bits: usize,
    /// per-child shard counts resolved by [`Sparsifier::set_shards`]
    /// (observability; 1 until the trainer wires shards in)
    child_shards: Vec<usize>,
    /// recycled bucket scratch for the flat compatibility path
    scratch: SparseUpdate,
}

impl LayerwiseSparsifier {
    /// Build one `kind`-family child per `layout` group with budgets
    /// resolved by `budget`.  `worker` diversifies stochastic children
    /// exactly like the flat [`build`] factory.
    pub fn new(
        kind: &SparsifierKind,
        layout: GradLayout,
        budget: &BudgetPolicy,
        worker: usize,
    ) -> Self {
        Self::with_policies(kind, layout, budget, &PolicyTable::default(), worker)
    }

    /// [`Self::new`] with a heterogeneous [`PolicyTable`]: each group
    /// takes the first rule matching its name (family + hyperparameter
    /// overrides + mu/Q schedules); unmatched groups keep the shared
    /// `kind` default.
    pub fn with_policies(
        kind: &SparsifierKind,
        layout: GradLayout,
        budget: &BudgetPolicy,
        policies: &PolicyTable,
        worker: usize,
    ) -> Self {
        let base_ks = budget.resolve(&layout);
        let n = layout.num_groups();
        let mut children = Vec::with_capacity(n);
        let mut ks = Vec::with_capacity(n);
        let mut schedules = Vec::with_capacity(n);
        let mut quants = Vec::with_capacity(n);
        let mut idx_codecs = Vec::with_capacity(n);
        for (g, (spec, &bk)) in layout.groups().iter().zip(&base_ks).enumerate() {
            let pol = policies.resolve(&spec.name);
            let (child, k_eff, sched) = build_child(kind, pol, bk, spec.len, g, worker);
            children.push(child);
            ks.push(k_eff);
            schedules.push(sched);
            quants.push(pol.and_then(|p| {
                // half-width level families need no bits= key: they are
                // fixed 16-bit grids, so a bare `levels=fp16|bf16` rule
                // engages the codec with a synthesized constant width
                let bits = p.bits.clone().or_else(|| {
                    p.levels
                        .filter(LevelKind::is_half)
                        .map(|_| BitsSpec::Sched(Schedule::Const(16.0)))
                })?;
                let gq = GroupQuant::new(
                    bits,
                    p.levels.unwrap_or_default(),
                    p.seed.unwrap_or(0),
                    worker,
                    g,
                );
                gq.ever_active().then_some(gq)
            }));
            idx_codecs.push(pol.and_then(|p| p.idx).unwrap_or_default());
        }
        LayerwiseSparsifier {
            layout,
            children,
            ks,
            schedules,
            quants,
            idx_codecs,
            raw_value_bits: 32,
            child_shards: vec![1; n],
            scratch: SparseUpdate::empty(),
        }
    }

    /// Align the packing-must-pay guard with the run's cost model:
    /// `bits` is what an un-quantized value costs on the wire
    /// (`CostModel::value_bits`).  `TrainConfig::build_sparsifier`
    /// wires this automatically; direct constructions keep the f32
    /// default of 32.
    pub fn set_raw_value_bits(&mut self, bits: usize) {
        assert!(bits > 0, "raw value bits must be positive");
        self.raw_value_bits = bits;
    }

    pub fn layout(&self) -> &GradLayout {
        &self.layout
    }

    /// Resolved per-group budgets.
    pub fn budgets(&self) -> &[usize] {
        &self.ks
    }

    /// Per-child shard counts as resolved by the last `set_shards`
    /// call: children below the engine threshold stay serial instead
    /// of inheriting the model-dim-resolved count (over-sharding fix).
    pub fn child_shards(&self) -> &[usize] {
        &self.child_shards
    }
}

/// Step every child over its group slice of `flat` into the matching
/// bucket of `out`.  Free function so the flat compatibility path can
/// borrow `children`/`layout` disjointly from the scratch buffer.
#[allow(clippy::too_many_arguments)]
fn step_children(
    children: &mut [Box<dyn Sparsifier>],
    layout: &GradLayout,
    schedules: &[Option<(Schedule, Schedule)>],
    quants: &mut [Option<GroupQuant>],
    idx_codecs: &[IndexCodec],
    raw_value_bits: usize,
    flat: &[f32],
    ctx: &RoundCtx,
    out: &mut SparseUpdate,
) {
    assert_eq!(flat.len(), layout.total(), "gradient/layout length mismatch");
    assert_eq!(
        ctx.gagg_prev.len(),
        layout.total(),
        "previous aggregate/layout length mismatch"
    );
    out.conform_to(layout);
    for (g, (child, spec)) in children.iter_mut().zip(layout.groups()).enumerate() {
        if let Some((mu, q)) = &schedules[g] {
            child.set_temperature(mu.at(ctx.t), q.at(ctx.t));
        }
        let (off, len) = (spec.offset, spec.len);
        let gctx = RoundCtx {
            t: ctx.t,
            gagg_prev: &ctx.gagg_prev[off..off + len],
            omega: ctx.omega,
            genie_acc: ctx.genie_acc.map(|ga| &ga[off..off + len]),
        };
        child.step_into(&flat[off..off + len], &gctx, out.bucket_mut(g));
        // Worker-boundary value codec: replace the bucket's values
        // with their packed low-bit decode and fold the rounding error
        // back into the child's error store — the lossy wire composes
        // with error feedback exactly like sparsification does.
        // Packing must PAY against what the bucket would cost raw
        // under the run's cost model (`raw_value_bits`): for tiny
        // buckets the 4-byte scale header exceeds the value-bit
        // saving, so those rounds ship raw (a pure function of
        // nnz/bits, so resume stays bit-exact; the guard compares
        // under packed-log-J indexing regardless of the index codec,
        // which cancels on both sides).
        if let Some(qs) = quants[g].as_mut() {
            let bits = qs.bits_at(ctx.t);
            if GroupQuant::active_at(bits) {
                let (bucket, payload) = out.bucket_quant_mut(g);
                let ib = index_bits(bucket.dim());
                let raw = WireCost::new(raw_value_bits).raw_bucket(bucket.nnz(), bucket.dim());
                let packed = QuantPayload::bytes_for_levels(bucket.nnz(), bits, ib, qs.levels);
                if bucket.nnz() > 0 && packed < raw {
                    ValueCodec { bits, levels: qs.levels }.encode_bucket(
                        bucket,
                        &mut qs.rng,
                        payload,
                        &mut qs.residual,
                        &mut qs.codes,
                    );
                    child.fold_residual(out.bucket(g).indices(), &qs.residual);
                    // residual-steered widths adapt for the NEXT round
                    qs.steer(out.bucket(g).values());
                } else {
                    // the current width did not pay: auto widths walk
                    // toward one that would (no-op for schedules)
                    qs.nudge_down_if_unpaid(bucket.nnz(), ib, raw);
                }
            }
        }
        // Worker-boundary index codec: entropy-code (or re-mark) the
        // bucket's index list; the packed default leaves the slot
        // untouched (bit-identical pre-codec accounting).
        match idx_codecs[g] {
            IndexCodec::Packed => {}
            IndexCodec::Raw => out.payload_mut(g).raw_index = true,
            IndexCodec::Rice => {
                let (bucket, payload) = out.bucket_payload_mut(g);
                payload.rice.encode_into(bucket.indices());
            }
        }
    }
}

impl Sparsifier for LayerwiseSparsifier {
    fn name(&self) -> &'static str {
        "layerwise"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    /// Flat compatibility path: bucketed step, then flatten (bucket
    /// order == ascending global index order, so the wire invariant
    /// holds by construction).
    ///
    /// Under a `bits` policy the VALUES here are identical to the
    /// bucketed path's (quantization runs either way — the two paths
    /// stay bit-identical), but the flat `SparseVec` cannot carry the
    /// packed payload, so a flat caller accounts 32-bit values and
    /// forfeits the wire saving.  Honest quantized byte accounting
    /// needs the bucketed [`Self::step_group_into`] path, which is
    /// what the trainer always drives.
    fn step_into(&mut self, grad: &[f32], ctx: &RoundCtx, out: &mut SparseVec) {
        let mut scratch = std::mem::take(&mut self.scratch);
        step_children(
            &mut self.children,
            &self.layout,
            &self.schedules,
            &mut self.quants,
            &self.idx_codecs,
            self.raw_value_bits,
            grad,
            ctx,
            &mut scratch,
        );
        scratch.flatten_into(out);
        self.scratch = scratch;
    }

    /// The native layer-wise path.
    fn step_group_into(&mut self, view: &GradView, ctx: &RoundCtx, out: &mut SparseUpdate) {
        assert_eq!(
            view.layout(),
            &self.layout,
            "view layout disagrees with the sparsifier's layout"
        );
        step_children(
            &mut self.children,
            &self.layout,
            &self.schedules,
            &mut self.quants,
            &self.idx_codecs,
            self.raw_value_bits,
            view.flat(),
            ctx,
            out,
        );
    }

    /// Fan the model-dim-resolved shard count out to the children, but
    /// clamped per group: a child below [`MIN_SHARDED_DIM`] keeps the
    /// serial path (a sharded engine over a bias vector costs more in
    /// pool handoff than the whole select), and no child gets more
    /// shards than elements.  Results are bit-identical either way —
    /// this is purely the perf fix for tiny groups.
    fn set_shards(&mut self, shards: usize) {
        for ((c, g), cs) in self
            .children
            .iter_mut()
            .zip(self.layout.groups())
            .zip(&mut self.child_shards)
        {
            let s = if g.len < MIN_SHARDED_DIM { 1 } else { shards.max(1).min(g.len) };
            c.set_shards(s);
            *cs = s;
        }
    }

    fn set_temperature(&mut self, mu: f32, q: f32) {
        for c in &mut self.children {
            c.set_temperature(mu, q);
        }
    }

    fn needs_genie(&self) -> bool {
        self.children.iter().any(|c| c.needs_genie())
    }

    /// Route a flat-index residual to the owning children (the flat
    /// compatibility path of external quantizers; internal `bits`
    /// policies fold per group inside the step).
    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        debug_assert_eq!(indices.len(), residual.len());
        let mut i = 0usize;
        for (child, spec) in self.children.iter_mut().zip(self.layout.groups()) {
            let end = (spec.offset + spec.len) as u32;
            let start = i;
            while i < indices.len() && indices[i] < end {
                i += 1;
            }
            if start < i {
                let local: Vec<u32> =
                    indices[start..i].iter().map(|&x| x - spec.offset as u32).collect();
                child.fold_residual(&local, &residual[start..i]);
            }
        }
    }

    /// Per-group child state; quantizing groups additionally wrap
    /// their child in [`SparsifierState::Quantized`] carrying the
    /// rounding stream, so a resumed quantized run draws exactly the
    /// decisions the uninterrupted one would have.  With no `bits`
    /// overrides the export is byte-identical to the pre-quantization
    /// format (old checkpoints keep loading).
    fn export_state(&self) -> SparsifierState {
        SparsifierState::Grouped(
            self.children
                .iter()
                .zip(&self.quants)
                .map(|(c, q)| {
                    let inner = c.export_state();
                    match q {
                        None => inner,
                        Some(gq) => {
                            let (rng, gauss_spare) = gq.rng.state();
                            SparsifierState::Quantized {
                                inner: Box::new(inner),
                                rng,
                                gauss_spare,
                                auto_bits: gq.auto_bits(),
                            }
                        }
                    }
                })
                .collect(),
        )
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Grouped(states) => {
                if states.len() != self.children.len() {
                    return Err(format!(
                        "layerwise state has {} groups, sparsifier has {}",
                        states.len(),
                        self.children.len()
                    ));
                }
                for (g, ((c, q), s)) in self
                    .children
                    .iter_mut()
                    .zip(&mut self.quants)
                    .zip(states)
                    .enumerate()
                {
                    match (q, s) {
                        (
                            Some(gq),
                            SparsifierState::Quantized { inner, rng, gauss_spare, auto_bits },
                        ) => {
                            gq.rng = Rng::from_state(*rng, *gauss_spare);
                            match (&mut gq.width, auto_bits) {
                                (Width::Auto { lo, hi, cur }, Some(b)) => {
                                    if !(*lo..=*hi).contains(b) {
                                        return Err(format!(
                                            "group {g}: checkpointed auto width {b} outside \
                                             the policy's {lo}..{hi} range"
                                        ));
                                    }
                                    *cur = *b;
                                }
                                (Width::Auto { .. }, None) => {
                                    return Err(format!(
                                        "group {g}: bits=auto policy needs the checkpointed \
                                         width (checkpoint belongs to a scheduled-bits policy)"
                                    ));
                                }
                                (Width::Sched(_), Some(_)) => {
                                    return Err(format!(
                                        "group {g}: checkpoint carries an auto width but the \
                                         policy schedules bits"
                                    ));
                                }
                                (Width::Sched(_), None) => {}
                            }
                            c.import_state(inner).map_err(|e| format!("group {g}: {e}"))?;
                        }
                        (Some(_), other) => {
                            return Err(format!(
                                "group {g}: quantizing group needs 'quantized' state, got '{}' \
                                 (checkpoint belongs to a bits-less policy)",
                                other.kind()
                            ));
                        }
                        (None, SparsifierState::Quantized { .. }) => {
                            return Err(format!(
                                "group {g}: checkpoint carries a quantizer stream but the \
                                 policy has no bits override"
                            ));
                        }
                        (None, other) => {
                            c.import_state(other).map_err(|e| format!("group {g}: {e}"))?;
                        }
                    }
                }
                Ok(())
            }
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("layerwise cannot import '{}' state", other.kind())),
        }
    }

    fn group_families(&self) -> Vec<&'static str> {
        self.children.iter().map(|c| c.name()).collect()
    }

    fn group_budgets(&self) -> Vec<usize> {
        self.ks.clone()
    }

    fn group_shards(&self) -> Vec<usize> {
        self.child_shards.clone()
    }

    fn group_value_bits(&self) -> Vec<usize> {
        self.quants
            .iter()
            .map(|q| q.as_ref().map_or(32, |gq| gq.bits_at(0)))
            .collect()
    }

    fn group_value_bits_end(&self) -> Vec<usize> {
        self.quants
            .iter()
            .map(|q| q.as_ref().map_or(32, GroupQuant::bits_end))
            .collect()
    }

    fn group_index_codecs(&self) -> Vec<&'static str> {
        self.idx_codecs.iter().map(IndexCodec::name).collect()
    }

    fn group_value_levels(&self) -> Vec<&'static str> {
        self.quants
            .iter()
            .map(|q| q.as_ref().map_or("f32", |gq| gq.levels.name()))
            .collect()
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        for (child, spec) in self.children.iter().zip(self.layout.groups()) {
            let (off, len) = (spec.offset, spec.len);
            child.peek_acc_into(&grad[off..off + len], &mut out[off..off + len]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_4_6() -> GradLayout {
        GradLayout::from_sizes([("a".to_string(), 4), ("b".to_string(), 6)])
    }

    #[test]
    fn global_budget_apportions_by_len() {
        let layout = layout_4_6();
        assert_eq!(BudgetPolicy::Global { k: 5 }.resolve(&layout), vec![2, 3]);
        // floor at 1 per group even when k is tiny
        assert_eq!(BudgetPolicy::Global { k: 1 }.resolve(&layout), vec![1, 1]);
        // k > total clamps to group lens
        assert_eq!(BudgetPolicy::Global { k: 100 }.resolve(&layout), vec![4, 6]);
    }

    #[test]
    fn proportional_and_per_group_budgets() {
        let layout = layout_4_6();
        assert_eq!(BudgetPolicy::Proportional { frac: 0.5 }.resolve(&layout), vec![2, 3]);
        // rounds to >= 1
        assert_eq!(BudgetPolicy::Proportional { frac: 0.01 }.resolve(&layout), vec![1, 1]);
        assert_eq!(
            BudgetPolicy::PerGroup { ks: vec![3, 9] }.resolve(&layout),
            vec![3, 6],
            "per-group budgets clamp to group length"
        );
    }

    #[test]
    #[should_panic]
    fn per_group_count_mismatch_panics() {
        BudgetPolicy::PerGroup { ks: vec![1] }.resolve(&layout_4_6());
    }

    #[test]
    fn budget_parse_and_json_roundtrip() {
        for (spec, want) in [
            ("global:500", BudgetPolicy::Global { k: 500 }),
            ("per:3,9", BudgetPolicy::PerGroup { ks: vec![3, 9] }),
            ("prop:0.001", BudgetPolicy::Proportional { frac: 0.001 }),
        ] {
            let b = BudgetPolicy::parse(spec).unwrap();
            assert_eq!(b, want, "{spec}");
            assert_eq!(BudgetPolicy::from_json(&b.to_json()).unwrap(), b, "{spec}");
        }
        assert!(BudgetPolicy::parse("nope:1").is_err());
        assert!(BudgetPolicy::parse("global").is_err());
        assert!(BudgetPolicy::parse("per:1,x").is_err());
        // proportional fractions must lie in (0, 1] and be finite
        for bad in ["prop:10", "prop:0", "prop:-0.5", "prop:nan", "prop:inf"] {
            assert!(BudgetPolicy::parse(bad).is_err(), "{bad}");
        }
        assert!(BudgetPolicy::parse("prop:1").is_ok());
        let j = BudgetPolicy::Proportional { frac: 4.0 }.to_json();
        assert!(BudgetPolicy::from_json(&j).is_err(), "json path validates too");
    }

    #[test]
    fn multi_group_emits_per_group_budgets() {
        let layout = layout_4_6();
        let mut lw = LayerwiseSparsifier::new(
            &SparsifierKind::TopK { k: 0 },
            layout.clone(),
            &BudgetPolicy::PerGroup { ks: vec![1, 2] },
            0,
        );
        assert_eq!(lw.budgets(), &[1, 2]);
        let grad: Vec<f32> = (0..10).map(|i| (10 - i) as f32).collect();
        let gagg = vec![0.0f32; 10];
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        assert_eq!(up.bucket(0).nnz(), 1, "group a budget");
        assert_eq!(up.bucket(1).nnz(), 2, "group b budget");
        // group a's largest is its first entry; group b's are its first two
        assert_eq!(up.bucket(0).indices(), &[0]);
        assert_eq!(up.bucket(1).indices(), &[0, 1]);
    }

    #[test]
    fn policy_table_builds_heterogeneous_children() {
        let layout = GradLayout::from_sizes([
            ("conv0.w".to_string(), 8),
            ("conv0.b".to_string(), 2),
            ("fc.w".to_string(), 6),
        ]);
        let table =
            PolicyTable::parse("conv*.b=dense;conv*=regtopk:mu=0.3,k=2;*=topk").unwrap();
        let lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 4 },
            layout,
            &BudgetPolicy::Proportional { frac: 0.5 },
            &table,
            0,
        );
        assert_eq!(lw.group_families(), vec!["regtopk", "dense", "topk"]);
        // conv0.w: policy k=2 overrides the proportional budget of 4
        assert_eq!(lw.budgets(), &[2, 1, 3]);
    }

    #[test]
    fn dense_child_sends_whole_group() {
        let layout = layout_4_6();
        let table = PolicyTable::parse("a=dense").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 0 },
            layout.clone(),
            &BudgetPolicy::PerGroup { ks: vec![1, 2] },
            &table,
            0,
        );
        let grad: Vec<f32> = (0..10).map(|i| (10 - i) as f32).collect();
        let gagg = vec![0.0f32; 10];
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        assert_eq!(up.bucket(0).nnz(), 4, "dense group transmits everything");
        assert_eq!(up.bucket(1).nnz(), 2, "topk group keeps its budget");
    }

    #[test]
    fn constant_schedule_matches_homogeneous_build() {
        // a Linear schedule with from == to is still exercised per
        // round through set_temperature — it must not disturb the
        // trajectory of a plain constant-mu build
        let layout = layout_4_6();
        let kind = SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 };
        let budget = BudgetPolicy::Global { k: 3 };
        let mut plain = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 0);
        let table = PolicyTable::parse("*=regtopk:mu=0.5..0.5/10").unwrap();
        let mut sched =
            LayerwiseSparsifier::with_policies(&kind, layout.clone(), &budget, &table, 0);
        assert!(sched.schedules.iter().all(Option::is_some));
        let mut gagg = vec![0.0f32; 10];
        let mut up_a = SparseUpdate::empty();
        let mut up_b = SparseUpdate::empty();
        for t in 0..6 {
            let g: Vec<f32> = (0..10).map(|i| ((i * 5 + t * 7) % 9) as f32 - 4.0).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
            let view = GradView::new(&layout, &g);
            plain.step_group_into(&view, &ctx, &mut up_a);
            sched.step_group_into(&view, &ctx, &mut up_b);
            assert_eq!(up_a, up_b, "t={t}");
            gagg = up_a.flatten().to_dense();
        }
    }

    #[test]
    fn decaying_mu_schedule_changes_behavior_then_settles() {
        // at t >= over the scheduled stack behaves exactly like a
        // constant-`to` stack with the same error-feedback history
        let sched = Schedule::Linear { from: 4.0, to: 0.1, over: 5 };
        assert_eq!(sched.at(0), 4.0);
        assert_eq!(sched.at(5), 0.1);
        assert_eq!(sched.at(50), 0.1);
        let layout = GradLayout::single(8);
        let kind = SparsifierKind::RegTopK { k: 2, mu: 4.0, q: 1.0 };
        let table = PolicyTable::parse("*=regtopk:mu=4.0..0.1/5").unwrap();
        let lw = LayerwiseSparsifier::with_policies(
            &kind,
            layout,
            &BudgetPolicy::Global { k: 2 },
            &table,
            0,
        );
        assert!(lw.schedules[0].is_some());
        assert_eq!(lw.group_families(), vec!["regtopk"]);
    }

    #[test]
    fn bits_policy_quantizes_bucket_and_folds_residual() {
        let layout = layout_4_6();
        let table = PolicyTable::parse("a=topk:bits=4").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 0 },
            layout.clone(),
            &BudgetPolicy::PerGroup { ks: vec![2, 3] },
            &table,
            0,
        );
        assert_eq!(lw.group_value_bits(), vec![4, 32]);
        let grad: Vec<f32> = (0..10).map(|i| (10 - i) as f32 * 0.37).collect();
        let gagg = vec![0.0f32; 10];
        let acc_before = lw.peek_acc(&grad);
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        // group a carries a packed payload that decodes to its values
        let q = up.quant(0).expect("group a must be quantized");
        assert_eq!(q.bits(), 4);
        assert_eq!(q.decode(), up.bucket(0).values());
        assert!(up.quant(1).is_none(), "group b stays raw f32");
        // conservation THROUGH quantization: what the wire dropped
        // (sparsified + rounding residual) is exactly what the error
        // store carries into the next round
        let transmitted = up.flatten().to_dense();
        let zeros = vec![0.0f32; 10];
        let eps = lw.peek_acc(&zeros);
        for i in 0..10 {
            assert_eq!(eps[i], acc_before[i] - transmitted[i], "i={i}");
        }
    }

    #[test]
    fn half_levels_policy_engages_fixed_sixteen_bit_codec() {
        use crate::comm::codec::LevelKind;
        let layout = layout_4_6();
        // a bare levels= rule, no bits= key: the width is the fixed 16
        let table = PolicyTable::parse("a=topk:levels=fp16;b=:levels=bf16").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 0 },
            layout.clone(),
            &BudgetPolicy::PerGroup { ks: vec![2, 3] },
            &table,
            0,
        );
        assert_eq!(lw.group_value_bits(), vec![16, 16]);
        assert_eq!(lw.group_value_levels(), vec!["fp16", "bf16"]);
        let grad: Vec<f32> = (0..10).map(|i| (10 - i) as f32 * 0.37).collect();
        let gagg = vec![0.0f32; 10];
        let acc_before = lw.peek_acc(&grad);
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        for g in 0..2 {
            let q = up.quant(g).expect("half groups carry a payload");
            assert_eq!(q.bits(), 16);
            assert_eq!(
                q.level_kind(),
                [LevelKind::Fp16, LevelKind::Bf16][g]
            );
            assert_eq!(q.decode(), up.bucket(g).values());
        }
        // conservation through the half-width wire: rounding residual
        // folds into the error store exactly like uniform quantization
        let transmitted = up.flatten().to_dense();
        let zeros = vec![0.0f32; 10];
        let eps = lw.peek_acc(&zeros);
        for i in 0..10 {
            assert_eq!(eps[i], acc_before[i] - transmitted[i], "i={i}");
        }
    }

    #[test]
    fn bits_32_is_explicit_passthrough() {
        // an explicit bits=32 rule exercises the quantization plumbing
        // in its disabled state: no payload, no RNG draws, trajectories
        // bit-identical to the same policy without bits
        let layout = layout_4_6();
        let kind = SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 };
        let budget = BudgetPolicy::Global { k: 3 };
        let with = PolicyTable::parse("*=regtopk:mu=0.5,bits=32").unwrap();
        let without = PolicyTable::parse("*=regtopk:mu=0.5").unwrap();
        let mut a = LayerwiseSparsifier::with_policies(&kind, layout.clone(), &budget, &with, 0);
        let mut b =
            LayerwiseSparsifier::with_policies(&kind, layout.clone(), &budget, &without, 0);
        assert_eq!(a.group_value_bits(), vec![32, 32]);
        let mut gagg = vec![0.0f32; 10];
        let mut up_a = SparseUpdate::empty();
        let mut up_b = SparseUpdate::empty();
        for t in 0..6 {
            let g: Vec<f32> = (0..10).map(|i| ((i * 5 + t * 7) % 9) as f32 - 4.0).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
            let view = GradView::new(&layout, &g);
            a.step_group_into(&view, &ctx, &mut up_a);
            b.step_group_into(&view, &ctx, &mut up_b);
            assert_eq!(up_a, up_b, "t={t}");
            assert!(up_a.quant(0).is_none() && up_a.quant(1).is_none());
            gagg = up_a.flatten().to_dense();
        }
        // a never-active bits policy creates no quantizer state, so
        // its checkpoints stay interchangeable with bits-less ones
        assert_eq!(a.export_state(), b.export_state());
        assert!(b.import_state(&a.export_state()).is_ok());
    }

    #[test]
    fn scheduled_bits_tighten_the_wire_over_rounds() {
        let layout = GradLayout::single(16);
        let table = PolicyTable::parse("*=topk:bits=16..4/4").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 8 },
            layout.clone(),
            &BudgetPolicy::Global { k: 8 },
            &table,
            0,
        );
        assert_eq!(lw.group_value_bits(), vec![16], "schedule reported at t=0");
        let gagg = vec![0.0f32; 16];
        let mut bytes = Vec::new();
        for t in 0..5 {
            let g: Vec<f32> = (0..16).map(|i| (i as f32 + 1.0) * 0.1).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
            let view = GradView::new(&layout, &g);
            let mut up = SparseUpdate::empty();
            lw.step_group_into(&view, &ctx, &mut up);
            assert_eq!(up.quant(0).unwrap().bits(), [16, 13, 10, 7, 4][t]);
            bytes.push(crate::comm::codec::WireCost::paper().update(&up));
        }
        assert!(bytes[4] < bytes[0], "{bytes:?}");
    }

    #[test]
    fn quantized_state_roundtrips_with_rng_stream() {
        let layout = layout_4_6();
        let table = PolicyTable::parse("*=topk:bits=3").unwrap();
        let kind = SparsifierKind::TopK { k: 3 };
        let budget = BudgetPolicy::Global { k: 3 };
        let mk = || {
            LayerwiseSparsifier::with_policies(&kind, layout.clone(), &budget, &table, 0)
        };
        let mut a = mk();
        let mut gagg = vec![0.0f32; 10];
        for t in 0..4 {
            let g: Vec<f32> = (0..10).map(|i| ((i * 3 + t) % 7) as f32 - 3.0).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
            gagg = a.step(&g, &ctx).to_dense();
        }
        let st = a.export_state();
        // quantizing groups wrap their child state
        if let SparsifierState::Grouped(children) = &st {
            assert!(children.iter().all(|c| c.kind() == "quantized"), "{children:?}");
        } else {
            panic!("expected grouped state");
        }
        let mut b = mk();
        b.import_state(&st).unwrap();
        // identical continuation INCLUDING the stochastic rounding
        let g: Vec<f32> = (0..10).map(|i| (i as f32) - 4.5).collect();
        let ctx = RoundCtx { t: 4, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
        assert_eq!(a.step(&g, &ctx), b.step(&g, &ctx));
        // a bits-less build rejects the quantized state and vice versa
        let mut cold = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 0);
        assert!(cold.import_state(&st).is_err());
        let plain = cold.export_state();
        assert!(mk().import_state(&plain).is_err());
    }

    #[test]
    fn rice_policy_encodes_and_shrinks_clustered_buckets() {
        use crate::comm::codec::WireCost;
        // a contiguous dense group: gaps are zero, rice pays ~1
        // bit/index vs the 9-bit packed bound
        let layout = GradLayout::single(512);
        let table = PolicyTable::parse("*=dense:idx=rice").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::Dense,
            layout.clone(),
            &BudgetPolicy::Global { k: 512 },
            &table,
            0,
        );
        assert_eq!(lw.group_index_codecs(), vec!["rice"]);
        let grad: Vec<f32> = (0..512).map(|i| (i % 7) as f32 + 1.0).collect();
        let gagg = vec![0.0f32; 512];
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        let rp = up.rice(0).expect("rice payload must be active");
        assert_eq!(rp.decode(), up.bucket(0).indices(), "lossless index round-trip");
        // values untouched: idx= composes with raw f32 values
        assert!(up.quant(0).is_none());
        let wc = WireCost::paper();
        let riced = wc.update(&up);
        let mut plain = LayerwiseSparsifier::new(
            &SparsifierKind::Dense,
            layout.clone(),
            &BudgetPolicy::Global { k: 512 },
            0,
        );
        let mut up_plain = SparseUpdate::empty();
        plain.step_group_into(&view, &ctx, &mut up_plain);
        assert_eq!(up.bucket(0), up_plain.bucket(0), "values identical under idx=rice");
        assert!(riced < wc.update(&up_plain), "{riced} !< {}", wc.update(&up_plain));
    }

    #[test]
    fn raw_index_policy_marks_buckets_and_costs_more() {
        use crate::comm::codec::WireCost;
        let layout = layout_4_6();
        let table = PolicyTable::parse("a=:idx=raw").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 0 },
            layout.clone(),
            &BudgetPolicy::PerGroup { ks: vec![2, 2] },
            &table,
            0,
        );
        assert_eq!(lw.group_index_codecs(), vec!["raw", "packed"]);
        let grad: Vec<f32> = (0..10).map(|i| (10 - i) as f32).collect();
        let gagg = vec![0.0f32; 10];
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        assert!(up.raw_index(0) && !up.raw_index(1));
        let wc = WireCost::paper();
        // group a pays 32-bit indices: 2 * (32+32) bits = 16 bytes vs
        // the packed 2 * (32+2) -> 9 bytes for the same bucket shape
        assert_eq!(wc.bucket(&up, 0), 16);
        assert_eq!(wc.bucket(&up, 1), (2 * (32 + 3usize)).div_ceil(8));
    }

    #[test]
    fn nuq_levels_ride_the_bits_policy() {
        let layout = layout_4_6();
        let table = PolicyTable::parse("*=:bits=4,levels=nuq").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 0 },
            layout.clone(),
            &BudgetPolicy::PerGroup { ks: vec![2, 3] },
            &table,
            0,
        );
        assert_eq!(lw.group_value_levels(), vec!["nuq", "nuq"]);
        let grad: Vec<f32> = (0..10).map(|i| (10 - i) as f32 * 0.37).collect();
        let gagg = vec![0.0f32; 10];
        let acc_before = lw.peek_acc(&grad);
        let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &grad);
        let mut up = SparseUpdate::empty();
        lw.step_group_into(&view, &ctx, &mut up);
        let q = up.quant(0).expect("group a must be quantized");
        assert_eq!(q.level_kind(), crate::comm::codec::LevelKind::Nuq);
        assert_eq!(q.decode(), up.bucket(0).values(), "payload is the exact decode");
        // conservation through the nonuniform lossy wire
        let transmitted = up.flatten().to_dense();
        let zeros = vec![0.0f32; 10];
        let eps = lw.peek_acc(&zeros);
        for i in 0..10 {
            assert_eq!(eps[i], acc_before[i] - transmitted[i], "i={i}");
        }
    }

    #[test]
    fn auto_bits_start_wide_and_narrow_on_slack() {
        // constant near-binary gradients quantize almost losslessly,
        // so the residual-steered width should walk down toward lo
        let layout = GradLayout::single(8);
        let table = PolicyTable::parse("*=:bits=auto:4..8").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 4 },
            layout.clone(),
            &BudgetPolicy::Global { k: 4 },
            &table,
            0,
        );
        assert_eq!(lw.group_value_bits(), vec![8], "auto starts at hi");
        let gagg = vec![0.0f32; 8];
        let g: Vec<f32> = (0..8).map(|i| if i < 4 { 4.0 } else { 0.5 }).collect();
        let mut widths = Vec::new();
        let mut up = SparseUpdate::empty();
        for t in 0..8 {
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
            let view = GradView::new(&layout, &g);
            lw.step_group_into(&view, &ctx, &mut up);
            widths.push(up.quant(0).map_or(32, |q| q.bits()));
        }
        assert_eq!(widths[0], 8, "first round uses the starting width");
        assert!(widths.iter().all(|&w| (4..=8).contains(&w)), "{widths:?}");
        assert!(*widths.last().unwrap() < 8, "width never narrowed: {widths:?}");
        // the live width is exported for bit-exact resume
        let st = lw.export_state();
        let SparsifierState::Grouped(children) = &st else { panic!("expected grouped") };
        let SparsifierState::Quantized { auto_bits, .. } = &children[0] else {
            panic!("expected quantized state, got {children:?}")
        };
        assert_eq!(*auto_bits, Some(*widths.last().unwrap()));
        // round-trip restores the width; a scheduled-bits build rejects it
        let mk = || {
            LayerwiseSparsifier::with_policies(
                &SparsifierKind::TopK { k: 4 },
                GradLayout::single(8),
                &BudgetPolicy::Global { k: 4 },
                &table,
                0,
            )
        };
        let mut b = mk();
        b.import_state(&st).unwrap();
        assert_eq!(b.group_value_bits(), vec![*widths.last().unwrap()]);
        let sched_table = PolicyTable::parse("*=:bits=6").unwrap();
        let mut sched = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 4 },
            GradLayout::single(8),
            &BudgetPolicy::Global { k: 4 },
            &sched_table,
            0,
        );
        assert!(sched.import_state(&st).is_err(), "auto width into scheduled policy");
        assert!(mk().import_state(&sched.export_state()).is_err(), "and vice versa");
    }

    #[test]
    fn auto_bits_escape_an_unpaying_hi_width() {
        // nnz=2 at 2 index bits: raw = ceil(2*34/8) = 9 B, and 16- or
        // 15-bit packing costs 9 B too (the scale header) — an auto
        // width starting at hi=16 would deadlock without the
        // nudge-down path, never reaching the widths that DO pay
        let layout = GradLayout::single(4);
        let table = PolicyTable::parse("*=:bits=auto:4..16").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 2 },
            layout.clone(),
            &BudgetPolicy::Global { k: 2 },
            &table,
            0,
        );
        let gagg = vec![0.0f32; 4];
        let g = vec![4.0f32, 3.0, 0.1, 0.1];
        let mut up = SparseUpdate::empty();
        let mut engaged = false;
        for t in 0..6 {
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
            let view = GradView::new(&layout, &g);
            lw.step_group_into(&view, &ctx, &mut up);
            engaged |= up.quant(0).is_some();
        }
        assert!(engaged, "auto width never walked down to a paying width");
        assert!(lw.group_value_bits()[0] < 15, "{:?}", lw.group_value_bits());
    }

    #[test]
    fn set_shards_clamps_tiny_groups_to_serial() {
        // a big group takes the resolved count, a bias-sized group
        // stays serial (below MIN_SHARDED_DIM)
        let layout = GradLayout::from_sizes([
            ("big".to_string(), MIN_SHARDED_DIM + 10),
            ("bias".to_string(), 16),
        ]);
        let mut lw = LayerwiseSparsifier::new(
            &SparsifierKind::TopK { k: 8 },
            layout,
            &BudgetPolicy::Global { k: 8 },
            0,
        );
        assert_eq!(lw.child_shards(), &[1, 1], "serial until shards are wired");
        lw.set_shards(8);
        assert_eq!(lw.child_shards(), &[8, 1]);
        lw.set_shards(1);
        assert_eq!(lw.child_shards(), &[1, 1]);
    }

    #[test]
    fn grouped_state_roundtrips_through_export() {
        let layout = layout_4_6();
        let kind = SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 };
        let budget = BudgetPolicy::Global { k: 3 };
        let mut a = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 0);
        let mut gagg = vec![0.0f32; 10];
        for t in 0..4 {
            let g: Vec<f32> = (0..10).map(|i| ((i * 3 + t) % 7) as f32 - 3.0).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
            gagg = a.step(&g, &ctx).to_dense();
        }
        let st = a.export_state();
        assert_eq!(st.kind(), "grouped");
        let mut b = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 0);
        b.import_state(&st).unwrap();
        // both continue identically from the restored history
        let g: Vec<f32> = (0..10).map(|i| (i as f32) - 4.5).collect();
        let ctx = RoundCtx { t: 4, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
        assert_eq!(a.step(&g, &ctx), b.step(&g, &ctx));
        // wrong shape is an error
        let mut c = LayerwiseSparsifier::new(&kind, GradLayout::single(10), &budget, 0);
        assert!(c.import_state(&st).is_err());
    }

    #[test]
    fn flat_path_equals_flattened_buckets() {
        let layout = layout_4_6();
        let mk = || {
            LayerwiseSparsifier::new(
                &SparsifierKind::RegTopK { k: 3, mu: 0.5, q: 1.0 },
                layout.clone(),
                &BudgetPolicy::Global { k: 3 },
                0,
            )
        };
        let mut a = mk();
        let mut b = mk();
        let mut gagg = vec![0.0f32; 10];
        for t in 0..5 {
            let grad: Vec<f32> = (0..10).map(|i| ((i * 7 + t * 3) % 5) as f32 - 2.0).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
            let flat = a.step(&grad, &ctx);
            let view = GradView::new(&layout, &grad);
            let mut up = SparseUpdate::empty();
            b.step_group_into(&view, &ctx, &mut up);
            assert_eq!(flat, up.flatten(), "t={t}");
            gagg = flat.to_dense();
        }
    }
}
