//! Classical TOP-k with error accumulation (paper §1.1) — the baseline
//! the contribution is measured against.

#![forbid(unsafe_code)]

use crate::grad::ErrorFeedback;
use crate::sparse::{select_topk, SelectEngine, SparseVec};
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};

pub struct TopK {
    k: usize,
    ef: ErrorFeedback,
    /// sharded fused accumulate+select (None = serial path)
    engine: Option<SelectEngine>,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl TopK {
    pub fn new(dim: usize, k: usize) -> Self {
        assert!(k > 0, "topk needs k >= 1");
        TopK { k, ef: ErrorFeedback::new(dim), engine: None, sel: Vec::new() }
    }

    pub fn error(&self) -> &[f32] {
        &self.ef.eps
    }
}

impl Sparsifier for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], _ctx: &RoundCtx, out: &mut SparseVec) {
        match &mut self.engine {
            // fused path: one parallel pass computes a = eps + g AND
            // histograms |a|; selection needs no extra full scan
            Some(eng) => {
                let eps = &self.ef.eps;
                eng.fused_select_into(
                    &mut self.ef.acc,
                    |lo, acc| {
                        for ((a, e), g) in
                            acc.iter_mut().zip(&eps[lo..lo + acc.len()]).zip(&grad[lo..])
                        {
                            *a = e + g;
                        }
                    },
                    self.k,
                    &mut self.sel,
                );
            }
            None => {
                self.ef.accumulate(grad);
                self.sel.clear();
                let sel = select_topk(&self.ef.acc, self.k);
                self.sel.extend_from_slice(&sel);
            }
        }
        self.ef.commit_into(&self.sel, out);
    }

    fn set_shards(&mut self, shards: usize) {
        self.engine = if shards > 1 { Some(SelectEngine::new(shards)) } else { None };
    }

    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        self.ef.fold_residual(indices, residual);
    }

    fn export_state(&self) -> SparsifierState {
        SparsifierState::Ef(self.ef.snapshot())
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::Ef(ef) => self.ef.restore(ef),
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("topk cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        self.ef.accumulate_into(grad, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn ctx<'a>(t: usize, gagg: &'a [f32]) -> RoundCtx<'a> {
        RoundCtx { t, gagg_prev: gagg, omega: 1.0, genie_acc: None }
    }

    #[test]
    fn selects_largest_magnitudes() {
        let mut s = TopK::new(4, 2);
        let z = vec![0.0; 4];
        let sv = s.step(&[1.0, -5.0, 3.0, 0.1], &ctx(0, &z));
        assert_eq!(sv.indices(), &[1, 2]);
        assert_eq!(sv.values(), &[-5.0, 3.0]);
    }

    #[test]
    fn error_accumulation_promotes_small_entries() {
        // The §1.1 mechanism: entry 1 (always 1.0) is never selected
        // against entry 0 (always 10.0) until its accumulated error
        // overtakes; with k=1 that happens at t where t*1.0 > 10.
        let mut s = TopK::new(2, 1);
        let z = vec![0.0; 2];
        let mut first_sel_of_1 = None;
        for t in 0..15 {
            let sv = s.step(&[10.0, 1.0], &ctx(t, &z));
            if sv.indices() == [1] {
                first_sel_of_1 = Some(t);
                // released value = accumulated error = (t+1) * 1.0
                assert_eq!(sv.values()[0], (t + 1) as f32);
                break;
            }
        }
        assert_eq!(first_sel_of_1, Some(10));
    }

    #[test]
    fn transmitted_plus_error_equals_accumulated() {
        check::forall("topk_conservation", |rng, _| {
            let n = check::arb_len(rng, 100);
            let k = rng.below(n) + 1;
            let mut s = TopK::new(n, k);
            let z = vec![0.0; n];
            for t in 0..3 {
                let g = check::arb_vec(rng, n);
                let acc = s.peek_acc(&g);
                let sv = s.step(&g, &ctx(t, &z));
                let dense = sv.to_dense();
                for i in 0..n {
                    assert_eq!(dense[i] + s.error()[i], acc[i]);
                }
            }
        });
    }
}
