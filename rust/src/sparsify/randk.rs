//! RAND-k baseline: k uniformly random entries with error feedback.
//! Unbiased in expectation (after 1/p scaling variants; we transmit raw
//! accumulated values like TOP-k so comparisons stay apples-to-apples).

#![forbid(unsafe_code)]

use crate::grad::ErrorFeedback;
use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierState};
use crate::util::rng::Rng;

pub struct RandK {
    k: usize,
    ef: ErrorFeedback,
    rng: Rng,
    /// reusable selection buffer
    sel: Vec<u32>,
}

impl RandK {
    pub fn new(dim: usize, k: usize, seed: u64) -> Self {
        assert!(k > 0, "randk needs k >= 1");
        RandK { k, ef: ErrorFeedback::new(dim), rng: Rng::seed_from(seed), sel: Vec::new() }
    }
}

impl Sparsifier for RandK {
    fn name(&self) -> &'static str {
        "randk"
    }

    fn step(&mut self, grad: &[f32], ctx: &RoundCtx) -> SparseVec {
        let mut out = SparseVec::zeros(grad.len());
        self.step_into(grad, ctx, &mut out);
        out
    }

    fn step_into(&mut self, grad: &[f32], _ctx: &RoundCtx, out: &mut SparseVec) {
        self.ef.accumulate(grad);
        let dim = grad.len();
        let mut sampled: Vec<usize> = self.rng.sample_indices(dim, self.k.min(dim));
        sampled.sort_unstable();
        self.sel.clear();
        self.sel.extend(sampled.into_iter().map(|i| i as u32));
        self.ef.commit_into(&self.sel, out);
    }

    fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        self.ef.fold_residual(indices, residual);
    }

    /// Error feedback AND the selection stream: a resumed randk run
    /// re-draws exactly the indices the uninterrupted run would have.
    fn export_state(&self) -> SparsifierState {
        let (rng, gauss_spare) = self.rng.state();
        SparsifierState::EfRng { ef: self.ef.snapshot(), rng, gauss_spare }
    }

    fn import_state(&mut self, st: &SparsifierState) -> Result<(), String> {
        match st {
            SparsifierState::EfRng { ef, rng, gauss_spare } => {
                self.ef.restore(ef)?;
                self.rng = Rng::from_state(*rng, *gauss_spare);
                Ok(())
            }
            // foreign-family states must error: repro-lint: allow(wildcard)
            other => Err(format!("randk cannot import '{}' state", other.kind())),
        }
    }

    fn peek_acc_into(&self, grad: &[f32], out: &mut [f32]) {
        self.ef.accumulate_into(grad, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(gagg: &'a [f32]) -> RoundCtx<'a> {
        RoundCtx { t: 0, gagg_prev: gagg, omega: 1.0, genie_acc: None }
    }

    #[test]
    fn transmits_exactly_k_random_entries() {
        let z = vec![0.0; 20];
        let mut s = RandK::new(20, 5, 9);
        let g: Vec<f32> = (1..=20).map(|i| i as f32).collect();
        let sv = s.step(&g, &ctx(&z));
        assert_eq!(sv.nnz(), 5);
    }

    #[test]
    fn eventually_covers_all_entries() {
        let z = vec![0.0; 10];
        let mut s = RandK::new(10, 2, 1);
        let g = vec![1.0; 10];
        let mut seen = [false; 10];
        for _ in 0..200 {
            for &i in s.step(&g, &ctx(&z)).indices() {
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn error_feedback_preserves_mass() {
        // unselected mass accumulates: after T rounds of constant grad,
        // transmitted + residual error == T * grad (per entry).
        let z = vec![0.0; 6];
        let mut s = RandK::new(6, 2, 3);
        let g = vec![1.0; 6];
        let mut transmitted = vec![0.0f32; 6];
        let rounds = 50;
        for _ in 0..rounds {
            s.step(&g, &ctx(&z)).axpy_into(1.0, &mut transmitted);
        }
        for i in 0..6 {
            assert!((transmitted[i] + s.ef.eps[i] - rounds as f32).abs() < 1e-3);
        }
    }
}
