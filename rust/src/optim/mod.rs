//! Server-side optimizers and learning-rate schedules.
//!
//! The paper trains with plain SGD at fixed eta; we additionally ship
//! heavy-ball momentum and the standard schedule family so the
//! framework covers the "extensions to various optimizers" the related
//! work (DGC, Adacomp) targets.

#![forbid(unsafe_code)]

/// Learning-rate schedule evaluated per iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Const { eta: f32 },
    /// eta * gamma^(t / step_every)
    Step { eta: f32, gamma: f32, step_every: usize },
    /// linear warmup to eta over `warmup` iters, then cosine decay to
    /// `eta_min` at `horizon`
    WarmupCosine { eta: f32, eta_min: f32, warmup: usize, horizon: usize },
}

impl Schedule {
    pub fn at(&self, t: usize) -> f32 {
        match *self {
            Schedule::Const { eta } => eta,
            Schedule::Step { eta, gamma, step_every } => {
                eta * gamma.powi((t / step_every.max(1)) as i32)
            }
            Schedule::WarmupCosine { eta, eta_min, warmup, horizon } => {
                if t < warmup {
                    eta * (t as f32 + 1.0) / warmup as f32
                } else {
                    let p = ((t - warmup) as f32
                        / (horizon.saturating_sub(warmup).max(1)) as f32)
                        .min(1.0);
                    eta_min + 0.5 * (eta - eta_min) * (1.0 + (std::f32::consts::PI * p).cos())
                }
            }
        }
    }
}

use crate::comm::SparseUpdate;

/// A gradient-descent optimizer applied to the flat parameter vector.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;
    /// In-place update of `w` with aggregated gradient `g` at iter `t`.
    fn step(&mut self, w: &mut [f32], g: &[f32], t: usize);
    /// Current learning rate (for logging / gradient recovery).
    fn lr(&self, t: usize) -> f32;

    /// Whether [`Self::step_sparse`] over only the aggregate's touched
    /// entries is bit-identical to [`Self::step`] over the full dense
    /// vector.  True only for per-coordinate *stateless* rules where an
    /// exactly-zero gradient entry leaves the weight bit-unchanged
    /// (plain SGD).  Momentum/Adam keep per-coordinate state that
    /// decays even where g is zero, so they return false and the server
    /// falls back to the dense O(J) step.
    fn sparse_step_exact(&self) -> bool {
        false
    }

    /// Step only on the entries present in `up` (global index = bucket
    /// offset + local index).  Callers must gate on
    /// [`Self::sparse_step_exact`]; the default is unreachable.
    fn step_sparse(&mut self, _w: &mut [f32], _up: &SparseUpdate, _t: usize) {
        unreachable!("{}: no exact sparse step; gate on sparse_step_exact()", self.name())
    }
}

/// Plain SGD:  w <- w - eta_t * g   (the paper's optimizer).
pub struct Sgd {
    pub schedule: Schedule,
}

impl Sgd {
    pub fn new(eta: f32) -> Self {
        Sgd { schedule: Schedule::Const { eta } }
    }
    pub fn with_schedule(schedule: Schedule) -> Self {
        Sgd { schedule }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }
    fn step(&mut self, w: &mut [f32], g: &[f32], t: usize) {
        let eta = self.schedule.at(t);
        debug_assert_eq!(w.len(), g.len());
        for (wi, gi) in w.iter_mut().zip(g) {
            *wi -= eta * gi;
        }
    }
    fn lr(&self, t: usize) -> f32 {
        self.schedule.at(t)
    }

    fn sparse_step_exact(&self) -> bool {
        // w - eta*(+0.0) == w bitwise for every w (eta >= 0), so
        // skipping untouched coordinates reproduces the dense step.
        true
    }

    fn step_sparse(&mut self, w: &mut [f32], up: &SparseUpdate, t: usize) {
        let eta = self.schedule.at(t);
        for g in 0..up.num_buckets() {
            let off = up.offset(g);
            let b = up.bucket(g);
            for (&i, &v) in b.indices().iter().zip(b.values()) {
                w[off + i as usize] -= eta * v;
            }
        }
    }
}

/// Heavy-ball momentum:  m <- beta*m + g ;  w <- w - eta_t * m.
pub struct SgdMomentum {
    pub schedule: Schedule,
    pub beta: f32,
    m: Vec<f32>,
}

impl SgdMomentum {
    pub fn new(dim: usize, eta: f32, beta: f32) -> Self {
        SgdMomentum { schedule: Schedule::Const { eta }, beta, m: vec![0.0; dim] }
    }
}

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgd+momentum"
    }
    fn step(&mut self, w: &mut [f32], g: &[f32], t: usize) {
        let eta = self.schedule.at(t);
        for i in 0..w.len() {
            self.m[i] = self.beta * self.m[i] + g[i];
            w[i] -= eta * self.m[i];
        }
    }
    fn lr(&self, t: usize) -> f32 {
        self.schedule.at(t)
    }
}

/// Adam (Kingma & Ba) on the aggregated sparse-sum gradient — the
/// "various optimizers" extension the related work (DGC, Adacomp)
/// targets; bias-corrected, eps inside the sqrt denominator.
pub struct Adam {
    pub schedule: Schedule,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: usize,
}

impl Adam {
    pub fn new(dim: usize, eta: f32) -> Self {
        Adam {
            schedule: Schedule::Const { eta },
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }
    fn step(&mut self, w: &mut [f32], g: &[f32], t: usize) {
        self.t += 1;
        let eta = self.schedule.at(t);
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..w.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            w[i] -= eta * mhat / (vhat.sqrt() + self.eps);
        }
    }
    fn lr(&self, t: usize) -> f32 {
        self.schedule.at(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_formula() {
        let mut o = Sgd::new(0.1);
        let mut w = vec![1.0, 2.0];
        o.step(&mut w, &[10.0, -10.0], 0);
        assert_eq!(w, vec![0.0, 3.0]);
    }

    #[test]
    fn sgd_sparse_step_matches_dense_bitwise() {
        use crate::grad::GradLayout;
        let layout =
            GradLayout::from_sizes([("a".to_string(), 3), ("b".to_string(), 4)]);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(1, 0.125);
        up.bucket_mut(1).push(0, -3.5);
        up.bucket_mut(1).push(3, 0.0); // touched-but-zero entry
        let g = up.to_dense();
        let w0 = vec![0.1f32, -0.0, 7.25, 0.3, 1e-8, -2.0, 0.5];
        let mut dense = Sgd::new(0.07);
        let mut sparse = Sgd::new(0.07);
        let (mut wd, mut ws) = (w0.clone(), w0);
        dense.step(&mut wd, &g, 3);
        assert!(sparse.sparse_step_exact());
        sparse.step_sparse(&mut ws, &up, 3);
        assert_eq!(
            wd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            ws.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "sparse SGD step must be bit-identical to the dense step"
        );
    }

    #[test]
    fn stateful_optimizers_decline_sparse_step() {
        assert!(!SgdMomentum::new(4, 0.1, 0.9).sparse_step_exact());
        assert!(!Adam::new(4, 0.1).sparse_step_exact());
    }

    #[test]
    fn momentum_accumulates() {
        let mut o = SgdMomentum::new(1, 1.0, 0.5);
        let mut w = vec![0.0];
        o.step(&mut w, &[1.0], 0); // m=1, w=-1
        o.step(&mut w, &[1.0], 1); // m=1.5, w=-2.5
        assert_eq!(w, vec![-2.5]);
    }

    #[test]
    fn step_schedule_decays() {
        let s = Schedule::Step { eta: 1.0, gamma: 0.1, step_every: 10 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(10) - 0.1).abs() < 1e-7);
        assert!((s.at(25) - 0.01).abs() < 1e-8);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = Schedule::WarmupCosine { eta: 1.0, eta_min: 0.1, warmup: 10, horizon: 110 };
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(60) < 1.0 && s.at(60) > 0.1);
        assert!((s.at(1000) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_eta_sized() {
        // bias correction makes the first update ~eta * sign(g)
        let mut o = Adam::new(2, 0.1);
        let mut w = vec![0.0, 0.0];
        o.step(&mut w, &[3.0, -0.5], 0);
        assert!((w[0] + 0.1).abs() < 1e-3, "{w:?}");
        assert!((w[1] - 0.1).abs() < 1e-3, "{w:?}");
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut o = Adam::new(1, 0.3);
        let mut w = vec![8.0];
        for t in 0..200 {
            let g = vec![w[0]];
            o.step(&mut w, &g, t);
        }
        assert!(w[0].abs() < 0.05, "{w:?}");
    }

    #[test]
    fn sgd_descends_quadratic() {
        // f(w) = 0.5 w^2, grad = w: converges geometrically
        let mut o = Sgd::new(0.5);
        let mut w = vec![8.0];
        for t in 0..20 {
            let g = vec![w[0]];
            o.step(&mut w, &g, t);
        }
        assert!(w[0].abs() < 1e-4);
    }
}
