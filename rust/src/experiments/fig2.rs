//! Fig. 2 — distributed linear regression (§4.1): N=20 workers, D=500
//! points each, J=100, full-batch GD, eta=1e-2, omega=1/N; generator
//! U=0, sigma^2=5, h^2=1, epsilon=0.5.  Plots optimality gap
//! delta^t = ||w^t - w*|| (log scale) for S in {0.4, 0.5, 0.6} under
//! Dense / TOP-k / REGTOP-k.
//!
//! Expected shape (paper): REGTOP-k starts tracking the dense curve at
//! S=0.6 while TOP-k plateaus at a fixed gap (oscillation around the
//! optimum driven by learning-rate scaling of late-released entries).

use crate::config::TrainConfig;
use crate::coordinator::{Server, Trainer, Worker};
use crate::data::linear::{generate, LinearParams, LinearProblem};
use crate::metrics::{IterRecord, RunLog};
use crate::models::LinRegShard;
use crate::optim::Sgd;
use crate::sparsify::SparsifierKind;

pub const ETA: f32 = 0.01;

/// Build a trainer over a generated problem for one sparsifier kind.
pub fn trainer_for(problem: &LinearProblem, kind: SparsifierKind, eta: f32) -> Trainer {
    trainer_sharded(problem, kind, eta, 1)
}

/// [`trainer_for`] with an explicit sparsification-engine shard count
/// (1 = serial seed path, 0 = auto; see `TrainConfig::shards`).
pub fn trainer_sharded(
    problem: &LinearProblem,
    kind: SparsifierKind,
    eta: f32,
    shards: usize,
) -> Trainer {
    let config = TrainConfig {
        workers: problem.params.workers,
        eta,
        sparsifier: kind,
        eval_every: 1,
        shards,
        ..TrainConfig::default()
    };
    trainer_from_config(&config, problem)
}

/// The config-driven constructor behind every fig2-testbed trainer:
/// honors the full [`TrainConfig`] surface including the layer-wise
/// `groups`/`budget` pair (each worker gets the config's layout and a
/// per-group sparsifier stack when groups are set; the flat default is
/// bit-identical to the seed constructor).
pub fn trainer_from_config(config: &TrainConfig, problem: &LinearProblem) -> Trainer {
    let n = problem.params.workers;
    assert_eq!(config.workers, n, "config.workers != problem workers");
    let workers = (0..n).map(|i| worker_from_config(config, problem, i)).collect();
    let dim = problem.params.dim;
    let server = Server::new(vec![0.0; dim], Box::new(Sgd::new(config.eta)));
    Trainer::new(config.clone(), workers, server)
}

/// Build worker `i` of a config's testbed run, exactly as
/// [`trainer_from_config`] would — including the engine shard count
/// `Trainer::new` normally wires in.  This is the constructor a
/// standalone worker *process* (`repro worker --connect`) uses: the
/// problem generator is seeded, so every process derives the same
/// shards and the networked trajectory matches the in-process one
/// bit-for-bit.
pub fn worker_from_config(config: &TrainConfig, problem: &LinearProblem, i: usize) -> Worker {
    let dim = problem.params.dim;
    let mut w = Worker::with_layout(
        i,
        Box::new(LinRegShard { shard: problem.shards[i].clone() }),
        config.build_sparsifier(dim, i),
        config.layout_for(dim),
    );
    w.set_shards(config.effective_shards(dim));
    w
}

/// ||w - w*||
pub fn opt_gap(w: &[f32], w_star: &[f32]) -> f32 {
    w.iter()
        .zip(w_star)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
}

/// One (sparsity, algorithm) curve.
pub fn run_curve(
    problem: &LinearProblem,
    kind: SparsifierKind,
    name: &str,
    iters: usize,
    eta: f32,
) -> RunLog {
    run_curve_sharded(problem, kind, name, iters, eta, 1)
}

/// [`run_curve`] with an explicit engine shard count (bit-identical
/// output for every value; see `rust/tests/sharded_select.rs`).
pub fn run_curve_sharded(
    problem: &LinearProblem,
    kind: SparsifierKind,
    name: &str,
    iters: usize,
    eta: f32,
    shards: usize,
) -> RunLog {
    let mut tr = trainer_sharded(problem, kind, eta, shards);
    run_curve_with(&mut tr, problem, name, iters)
}

/// Drive `iters` rounds of an already-built trainer, logging the
/// standard fig2 record shape (loss, opt gap, upload bytes, sim
/// time).  Shared by every curve runner and `repro train`, which
/// keeps the trainer afterwards to read the per-group ledger.
pub fn run_curve_with(
    tr: &mut Trainer,
    problem: &LinearProblem,
    name: &str,
    iters: usize,
) -> RunLog {
    // the echo carries the per-group resolution (family/k/shards/bits)
    // for grouped runs, so written manifests are self-describing
    let mut log = RunLog::new(name, tr.config_echo());
    for t in 0..iters {
        let rr = tr.round();
        let mut rec = IterRecord::new(t);
        rec.loss = rr.mean_loss;
        rec.opt_gap = opt_gap(&tr.server.w, &problem.w_star);
        rec.upload_bytes = rr.upload_bytes;
        rec.sim_time_s = tr.ledger.rounds().last().unwrap().sim_time_s;
        log.push(rec);
    }
    log
}

/// The full figure: for each S in `sparsities`, run dense / topk /
/// regtopk.  Run names are "{alg}-S{S}".
pub fn run(
    params: LinearParams,
    seed: u64,
    iters: usize,
    sparsities: &[f64],
    mu: f32,
    q: f32,
    eta: f32,
) -> Vec<RunLog> {
    let problem = generate(params, seed);
    let j = params.dim;
    let mut logs = Vec::new();
    // dense reference is sparsity-independent; run it once
    logs.push(run_curve(&problem, SparsifierKind::Dense, "dense", iters, eta));
    for &s in sparsities {
        let k = ((s * j as f64).round() as usize).clamp(1, j);
        logs.push(run_curve(
            &problem,
            SparsifierKind::TopK { k },
            &format!("topk-S{s}"),
            iters,
            eta,
        ));
        logs.push(run_curve(
            &problem,
            SparsifierKind::RegTopK { k, mu, q },
            &format!("regtopk-S{s}"),
            iters,
            eta,
        ));
    }
    logs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LinearParams {
        // scaled-down geometry, same generator statistics
        LinearParams { workers: 6, rows_per_worker: 120, dim: 30, u: 0.0, sigma2: 5.0, h2: 1.0, noise: 0.5 }
    }

    #[test]
    fn dense_gap_decreases_monotonically_late() {
        let p = generate(small(), 3);
        let log = run_curve(&p, SparsifierKind::Dense, "dense", 200, ETA);
        let g50 = log.records()[50].opt_gap;
        let g199 = log.records()[199].opt_gap;
        assert!(g199 < g50, "{g199} !< {g50}");
    }

    #[test]
    fn regtopk_parity_with_topk_at_same_sparsity() {
        // Reproduction finding (see rust/tests/fig2_linreg.rs and
        // EXPERIMENTS.md §Fig2): on the isotropic LS testbed REGTOP-k
        // is at PARITY with TOP-k — this fixed-seed check pins the
        // transient-phase gap within a tight band of TOP-k's, and the
        // deterministic run keeps it stable.
        let p = generate(small(), 3);
        let k = 18; // S = 0.6
        let top = run_curve(&p, SparsifierKind::TopK { k }, "t", 400, 0.05);
        let reg = run_curve(
            &p,
            SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
            "r",
            400,
            0.05,
        );
        let gap_top = top.records().last().unwrap().opt_gap;
        let gap_reg = reg.records().last().unwrap().opt_gap;
        assert!(
            gap_reg < 1.5 * gap_top && gap_reg > 0.2 * gap_top,
            "regtopk {gap_reg} vs topk {gap_top}"
        );
    }

    #[test]
    fn higher_sparsity_budget_helps_topk() {
        let p = generate(small(), 7);
        let lo = run_curve(&p, SparsifierKind::TopK { k: 6 }, "lo", 300, 0.05);
        let hi = run_curve(&p, SparsifierKind::TopK { k: 24 }, "hi", 300, 0.05);
        assert!(
            hi.records().last().unwrap().opt_gap < lo.records().last().unwrap().opt_gap
        );
    }
}
