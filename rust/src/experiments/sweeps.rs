//! Ablation sweeps (DESIGN.md Abl 1-4): mu, Q, worker count, and
//! approximate-selection recall.  All run on the Fig. 2 testbed at a
//! reduced geometry so a full sweep finishes in seconds.

use crate::data::linear::{generate, LinearParams};
use crate::experiments::fig2;
use crate::sparse::{approx, select_topk};
use crate::sparsify::SparsifierKind;
use crate::util::rng::Rng;

/// Reduced Fig. 2 geometry for sweeps.
pub fn sweep_params(workers: usize) -> LinearParams {
    LinearParams { workers, rows_per_worker: 200, dim: 60, u: 0.0, sigma2: 5.0, h2: 1.0, noise: 0.5 }
}

/// Abl 1 — mu sweep: final optimality gap of REGTOP-k per mu, plus the
/// TOP-k reference at the same k.  mu -> 0 must converge to TOP-k.
pub fn mu_sweep(mus: &[f64], s: f64, iters: usize, seed: u64) -> Vec<(String, f32)> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    let mut out = Vec::new();
    let top = fig2::run_curve(&problem, SparsifierKind::TopK { k }, "topk", iters, 0.02);
    out.push(("topk".to_string(), top.records().last().unwrap().opt_gap));
    for &mu in mus {
        let log = fig2::run_curve(
            &problem,
            SparsifierKind::RegTopK { k, mu: mu as f32, q: 1.0 },
            &format!("mu={mu}"),
            iters,
            0.02,
        );
        out.push((format!("mu={mu}"), log.records().last().unwrap().opt_gap));
    }
    out
}

/// Abl 2 — Q sweep at fixed mu.
pub fn q_sweep(qs: &[f64], s: f64, iters: usize, seed: u64) -> Vec<(String, f32)> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    qs.iter()
        .map(|&q| {
            let log = fig2::run_curve(
                &problem,
                SparsifierKind::RegTopK { k, mu: 0.5, q: q as f32 },
                &format!("q={q}"),
                iters,
                0.02,
            );
            (format!("q={q}"), log.records().last().unwrap().opt_gap)
        })
        .collect()
}

/// Abl 3 — worker-count scaling: (N, topk gap, regtopk gap).
pub fn worker_sweep(ns: &[usize], s: f64, iters: usize, seed: u64) -> Vec<(usize, f32, f32)> {
    ns.iter()
        .map(|&n| {
            let problem = generate(sweep_params(n), seed);
            let k = ((s * 60.0).round() as usize).max(1);
            let top = fig2::run_curve(&problem, SparsifierKind::TopK { k }, "t", iters, 0.02);
            let reg = fig2::run_curve(
                &problem,
                SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
                "r",
                iters,
                0.02,
            );
            (
                n,
                top.records().last().unwrap().opt_gap,
                reg.records().last().unwrap().opt_gap,
            )
        })
        .collect()
}

/// Abl 4 — approximate top-k: (oversample, mean recall) over random
/// Gaussian vectors at the Fig. 3 scale.
pub fn approx_recall_sweep(oversamples: &[usize], j: usize, k: usize, trials: usize) -> Vec<(usize, f64)> {
    oversamples
        .iter()
        .map(|&ov| {
            let mut total = 0.0;
            for t in 0..trials {
                let mut rng = Rng::seed_from(1000 + t as u64);
                let x = rng.gaussian_vec(j, 1.0);
                let exact = select_topk(&x, k);
                let ap = approx::select_topk_sampled(&x, k, ov, &mut rng);
                total += approx::recall(&exact, &ap);
            }
            (ov, total / trials as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_sweep_small_mu_matches_topk() {
        let rows = mu_sweep(&[1e-6, 0.5], 0.5, 150, 5);
        let topk_gap = rows[0].1;
        let mu_tiny_gap = rows[1].1;
        assert!(
            (mu_tiny_gap - topk_gap).abs() < 0.05 * topk_gap.max(0.1),
            "mu->0 {mu_tiny_gap} vs topk {topk_gap}"
        );
    }

    #[test]
    fn recall_improves_with_oversampling() {
        let rows = approx_recall_sweep(&[2, 16], 20_000, 200, 5);
        // the threshold estimator is stochastic; require high recall at
        // large oversampling and no collapse at small
        assert!(rows[1].1 > 0.9, "{rows:?}");
        assert!(rows[0].1 > 0.7, "{rows:?}");
    }
}
