//! Ablation sweeps (DESIGN.md Abl 1-4): mu, Q, worker count, and
//! approximate-selection recall.  All run on the Fig. 2 testbed at a
//! reduced geometry so a full sweep finishes in seconds.

use crate::config::TrainConfig;
use crate::data::linear::{generate, LinearParams, LinearProblem};
use crate::experiments::fig2;
use crate::grad::GradLayout;
use crate::sparse::{approx, select_topk};
use crate::sparsify::{BudgetPolicy, PolicyTable, SparsifierKind};
use crate::util::rng::Rng;

/// Reduced Fig. 2 geometry for sweeps.
pub fn sweep_params(workers: usize) -> LinearParams {
    LinearParams { workers, rows_per_worker: 200, dim: 60, u: 0.0, sigma2: 5.0, h2: 1.0, noise: 0.5 }
}

/// Abl 1 — mu sweep: final optimality gap of REGTOP-k per mu, plus the
/// TOP-k reference at the same k.  mu -> 0 must converge to TOP-k.
pub fn mu_sweep(mus: &[f64], s: f64, iters: usize, seed: u64) -> Vec<(String, f32)> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    let mut out = Vec::new();
    let top = fig2::run_curve(&problem, SparsifierKind::TopK { k }, "topk", iters, 0.02);
    out.push(("topk".to_string(), top.records().last().unwrap().opt_gap));
    for &mu in mus {
        let log = fig2::run_curve(
            &problem,
            SparsifierKind::RegTopK { k, mu: mu as f32, q: 1.0 },
            &format!("mu={mu}"),
            iters,
            0.02,
        );
        out.push((format!("mu={mu}"), log.records().last().unwrap().opt_gap));
    }
    out
}

/// Abl 2 — Q sweep at fixed mu.
pub fn q_sweep(qs: &[f64], s: f64, iters: usize, seed: u64) -> Vec<(String, f32)> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    qs.iter()
        .map(|&q| {
            let log = fig2::run_curve(
                &problem,
                SparsifierKind::RegTopK { k, mu: 0.5, q: q as f32 },
                &format!("q={q}"),
                iters,
                0.02,
            );
            (format!("q={q}"), log.records().last().unwrap().opt_gap)
        })
        .collect()
}

/// Abl 3 — worker-count scaling: (N, topk gap, regtopk gap).
pub fn worker_sweep(ns: &[usize], s: f64, iters: usize, seed: u64) -> Vec<(usize, f32, f32)> {
    ns.iter()
        .map(|&n| {
            let problem = generate(sweep_params(n), seed);
            let k = ((s * 60.0).round() as usize).max(1);
            let top = fig2::run_curve(&problem, SparsifierKind::TopK { k }, "t", iters, 0.02);
            let reg = fig2::run_curve(
                &problem,
                SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
                "r",
                iters,
                0.02,
            );
            (
                n,
                top.records().last().unwrap().opt_gap,
                reg.records().last().unwrap().opt_gap,
            )
        })
        .collect()
}

/// One row of the flat / layer-wise / heterogeneous comparison.
#[derive(Clone, Debug)]
pub struct HeteroRow {
    pub name: String,
    pub final_gap: f32,
    pub bytes_per_round: usize,
    pub entries_per_round: usize,
}

/// The sweep's 4-layer testbed layout (dim 60, CNN-shaped: two weight
/// blocks with a tiny bias each).
pub fn hetero_layout() -> GradLayout {
    GradLayout::from_sizes([
        ("fc0.w".to_string(), 24),
        ("fc0.b".to_string(), 6),
        ("fc1.w".to_string(), 24),
        ("fc1.b".to_string(), 6),
    ])
}

/// Run one config on the shared testbed problem and collapse it to a
/// comparison row — the row constructor every sweep table shares.
fn sweep_row(name: &str, cfg: &TrainConfig, problem: &LinearProblem, iters: usize) -> HeteroRow {
    let mut tr = fig2::trainer_from_config(cfg, problem);
    let log = fig2::run_curve_with(&mut tr, problem, name, iters);
    HeteroRow {
        name: name.to_string(),
        final_gap: log.last().unwrap().opt_gap,
        bytes_per_round: tr.ledger.total_upload_bytes() / iters.max(1),
        entries_per_round: tr
            .ledger
            .rounds()
            .iter()
            .map(|r| r.upload_entries)
            .sum::<usize>()
            / iters.max(1),
    }
}

/// ISSUE 3 protocol — flat vs layer-wise vs heterogeneous RegTop-k on
/// the linreg testbed (EXPERIMENTS.md §Heterogeneous): identical data,
/// seed and total budget k = round(S*J); the heterogeneous row ships
/// biases dense, keeps RegTop-k on the weight blocks with a linear mu
/// decay, and re-apportions the remaining budget.
pub fn hetero_sweep(s: f64, iters: usize, seed: u64) -> Vec<HeteroRow> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    let kind = SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 };
    let layout = hetero_layout();
    let mut rows = Vec::new();
    let mut run =
        |name: &str, cfg: &TrainConfig| rows.push(sweep_row(name, cfg, &problem, iters));
    let base = TrainConfig {
        workers: params.workers,
        eta: 0.02,
        sparsifier: kind,
        eval_every: 1,
        ..TrainConfig::default()
    };
    // flat: the seed path, one global top-k pool
    run("flat/regtopk", &base);
    // layer-wise homogeneous: same family, budget apportioned per layer
    let mut lw = base.clone();
    lw.groups = Some(layout.clone());
    lw.budget = Some(BudgetPolicy::Global { k });
    run("layered/regtopk", &lw);
    // heterogeneous: dense biases + decaying-mu RegTop-k weights
    let mut het = lw.clone();
    het.policy = Some(
        PolicyTable::parse(&format!("*.b=dense;*.w=regtopk:mu=0.5..0.1/{iters}"))
            .expect("hetero policy spec"),
    );
    run("hetero/regtopk+dense", &het);
    rows
}

/// ISSUE 4 protocol — accuracy vs wire bytes under quantized
/// transmission (EXPERIMENTS.md §Quantization): the layer-wise
/// RegTop-k stack at one budget, sweeping the per-group value width
/// `bits` in {32 (off), 16, 8, 4, 2}.  Same data, seed and budget per
/// row; the rounding residual folds into error feedback, so accuracy
/// should degrade gracefully while upload bytes drop ~linearly in
/// `bits`.
pub fn bits_sweep(s: f64, iters: usize, seed: u64) -> Vec<HeteroRow> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    let layout = hetero_layout();
    let base = TrainConfig {
        workers: params.workers,
        eta: 0.02,
        sparsifier: SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        eval_every: 1,
        groups: Some(layout),
        budget: Some(BudgetPolicy::Global { k }),
        ..TrainConfig::default()
    };
    let mut rows = Vec::new();
    for bits in [32usize, 16, 8, 4, 2] {
        let mut cfg = base.clone();
        let name = if bits == 32 {
            "bits=32 (off)".to_string()
        } else {
            cfg.policy = Some(
                PolicyTable::parse(&format!("*=:bits={bits}")).expect("bits policy spec"),
            );
            format!("bits={bits}")
        };
        rows.push(sweep_row(&name, &cfg, &problem, iters));
    }
    rows
}

/// ISSUE 5 protocol — accuracy vs TRUE wire bytes across the codec
/// matrix (EXPERIMENTS.md §Compression): the layer-wise RegTop-k stack
/// at one budget, sweeping the index codec (packed `log J` / raw u32 /
/// Golomb–Rice) against the value codec (raw f32 / uniform@4 / nuq@4)
/// plus the residual-steered `auto:4..8` width.  Same data, seed and
/// budget per row; byte columns come from the ledger, which charges
/// whatever each codec actually put on the wire.
pub fn codec_sweep(s: f64, iters: usize, seed: u64) -> Vec<HeteroRow> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    // one bucket over the whole testbed: per-bucket codec headers
    // (Rice parameter, quantizer scale) amortize over all k entries,
    // so the bound-vs-code gap stays visible at the testbed's size —
    // on the 4-layer layout the 6-element bias buckets would drown
    // the entropy code in headers (an honest but uninteresting row)
    let base = TrainConfig {
        workers: params.workers,
        eta: 0.02,
        sparsifier: SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        eval_every: 1,
        groups: Some(GradLayout::single(params.dim)),
        budget: Some(BudgetPolicy::Global { k }),
        ..TrainConfig::default()
    };
    let variants: [(&str, &str); 8] = [
        ("packed/f32", ""),
        ("raw/f32", "*=:idx=raw"),
        ("rice/f32", "*=:idx=rice"),
        ("packed/uniform@4", "*=:bits=4"),
        ("rice/uniform@4", "*=:bits=4,idx=rice"),
        ("packed/nuq@4", "*=:bits=4,levels=nuq"),
        ("rice/nuq@4", "*=:bits=4,idx=rice,levels=nuq"),
        ("auto:4..8", "*=:bits=auto:4..8"),
    ];
    variants
        .iter()
        .map(|(name, spec)| {
            let mut cfg = base.clone();
            if !spec.is_empty() {
                cfg.policy = Some(PolicyTable::parse(spec).expect("codec policy spec"));
            }
            sweep_row(name, &cfg, &problem, iters)
        })
        .collect()
}

/// One row of the downlink sweep: convergence plus BOTH link
/// directions from the ledger (the dense row's download is the
/// analytic `32J x workers` broadcast; sparse rows are charged at
/// whatever their codec actually put on the wire).
#[derive(Clone, Debug)]
pub struct DownlinkRow {
    pub name: String,
    pub final_gap: f32,
    pub up_bytes_per_round: usize,
    pub down_bytes_per_round: usize,
}

/// PR 6 protocol — dense vs sparse-broadcast downlink across the codec
/// matrix (EXPERIMENTS.md §Downlink protocol): flat RegTop-k at one
/// budget, sweeping the downlink policy from off (dense 32J broadcast)
/// through the lossless sparse broadcast to quantized/entropy-coded
/// variants.  Same data, seed, budget and uplink per row; lossless
/// rows reproduce the dense row's trajectory bit-for-bit, so their
/// `final_gap` columns must match exactly.
pub fn downlink_sweep(s: f64, iters: usize, seed: u64) -> Vec<DownlinkRow> {
    let params = sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    let base = TrainConfig {
        workers: params.workers,
        eta: 0.02,
        sparsifier: SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        eval_every: 1,
        ..TrainConfig::default()
    };
    let variants: [(&str, &str); 5] = [
        ("dense", ""),
        ("sparse/f32", "*="),
        ("sparse/rice", "*=:idx=rice"),
        ("sparse/u8", "*=:bits=8"),
        ("sparse/rice+nuq@8", "*=:bits=8,idx=rice,levels=nuq"),
    ];
    variants
        .iter()
        .map(|(name, spec)| {
            let mut cfg = base.clone();
            if !spec.is_empty() {
                cfg.downlink = Some(PolicyTable::parse(spec).expect("downlink policy spec"));
            }
            let mut tr = fig2::trainer_from_config(&cfg, &problem);
            let log = fig2::run_curve_with(&mut tr, &problem, name, iters);
            DownlinkRow {
                name: name.to_string(),
                final_gap: log.last().unwrap().opt_gap,
                up_bytes_per_round: tr.ledger.total_upload_bytes() / iters.max(1),
                down_bytes_per_round: tr.ledger.total_download_bytes() / iters.max(1),
            }
        })
        .collect()
}

/// Abl 4 — approximate top-k: (oversample, mean recall) over random
/// Gaussian vectors at the Fig. 3 scale.
pub fn approx_recall_sweep(oversamples: &[usize], j: usize, k: usize, trials: usize) -> Vec<(usize, f64)> {
    oversamples
        .iter()
        .map(|&ov| {
            let mut total = 0.0;
            for t in 0..trials {
                let mut rng = Rng::seed_from(1000 + t as u64);
                let x = rng.gaussian_vec(j, 1.0);
                let exact = select_topk(&x, k);
                let ap = approx::select_topk_sampled(&x, k, ov, &mut rng);
                total += approx::recall(&exact, &ap);
            }
            (ov, total / trials as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_sweep_small_mu_matches_topk() {
        let rows = mu_sweep(&[1e-6, 0.5], 0.5, 150, 5);
        let topk_gap = rows[0].1;
        let mu_tiny_gap = rows[1].1;
        assert!(
            (mu_tiny_gap - topk_gap).abs() < 0.05 * topk_gap.max(0.1),
            "mu->0 {mu_tiny_gap} vs topk {topk_gap}"
        );
    }

    #[test]
    fn hetero_sweep_three_rows_converge() {
        let rows = hetero_sweep(0.2, 120, 7);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].name, "flat/regtopk");
        for r in &rows {
            assert!(r.final_gap.is_finite() && r.final_gap >= 0.0, "{r:?}");
            assert!(r.bytes_per_round > 0, "{r:?}");
        }
        // dense biases push the heterogeneous row's entry count above
        // the budgeted homogeneous rows
        assert!(rows[2].entries_per_round > rows[1].entries_per_round, "{rows:?}");
    }

    #[test]
    fn bits_sweep_trades_bytes_for_accuracy() {
        let rows = bits_sweep(0.2, 120, 7);
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].name, "bits=32 (off)");
        for r in &rows {
            assert!(r.final_gap.is_finite() && r.final_gap >= 0.0, "{r:?}");
            assert!(r.bytes_per_round > 0, "{r:?}");
        }
        // fewer value bits, fewer wire bytes — strictly down the sweep
        for w in rows.windows(2) {
            assert!(w[1].bytes_per_round < w[0].bytes_per_round, "{rows:?}");
        }
        // same budget every row: the entry counts match exactly
        assert!(rows.iter().all(|r| r.entries_per_round == rows[0].entries_per_round));
        // error feedback keeps even 4-bit training in a sane band
        let off = rows[0].final_gap;
        let q4 = rows.iter().find(|r| r.name == "bits=4").unwrap().final_gap;
        assert!(q4 < 6.0 * off.max(0.05), "q4 {q4} vs off {off}");
    }

    #[test]
    fn codec_sweep_orders_wire_bytes() {
        let rows = codec_sweep(0.2, 120, 7);
        assert_eq!(rows.len(), 8);
        let by = |name: &str| rows.iter().find(|r| r.name == name).unwrap();
        for r in &rows {
            assert!(r.final_gap.is_finite() && r.final_gap >= 0.0, "{r:?}");
            assert!(r.bytes_per_round > 0, "{r:?}");
        }
        // index axis at fixed values: raw u32 > packed log J > rice
        assert!(by("raw/f32").bytes_per_round > by("packed/f32").bytes_per_round);
        assert!(by("rice/f32").bytes_per_round < by("packed/f32").bytes_per_round);
        // value axis at fixed index codec: 4-bit packing shrinks the
        // wire, and nuq packs the same widths as uniform (same bytes)
        assert!(by("packed/uniform@4").bytes_per_round < by("packed/f32").bytes_per_round);
        assert_eq!(
            by("packed/nuq@4").bytes_per_round,
            by("packed/uniform@4").bytes_per_round
        );
        // the axes compose: rice beats packed at 4-bit values too, for
        // either level family
        assert!(by("rice/uniform@4").bytes_per_round < by("packed/uniform@4").bytes_per_round);
        assert!(by("rice/nuq@4").bytes_per_round < by("packed/nuq@4").bytes_per_round);
        // the residual-steered width stays well under the raw wire
        assert!(by("auto:4..8").bytes_per_round < by("packed/f32").bytes_per_round);
        // every codec path still converges near the baseline
        let base = by("packed/f32").final_gap;
        for r in &rows {
            assert!(r.final_gap < 6.0 * base.max(0.05), "{r:?} vs base {base}");
        }
        // identical budgets: entry counts match across the matrix
        assert!(rows.iter().all(|r| r.entries_per_round == rows[0].entries_per_round));
    }

    #[test]
    fn downlink_sweep_cuts_broadcast_bytes() {
        let rows = downlink_sweep(0.05, 120, 7);
        assert_eq!(rows.len(), 5);
        let by = |name: &str| rows.iter().find(|r| r.name == name).unwrap();
        let dense = by("dense");
        for r in &rows {
            assert!(r.final_gap.is_finite() && r.final_gap >= 0.0, "{r:?}");
            // the downlink never touches the uplink: same budget, same
            // (or bit-identical) trajectory, same upload bytes
            assert_eq!(r.up_bytes_per_round, dense.up_bytes_per_round, "{r:?}");
        }
        // lossless sparse broadcasts reproduce the dense trajectory
        // bit-for-bit — the gap columns match EXACTLY
        assert_eq!(by("sparse/f32").final_gap, dense.final_gap);
        assert_eq!(by("sparse/rice").final_gap, dense.final_gap);
        // byte ordering: every sparse row beats the dense 32J
        // broadcast; rice beats packed indices; 8-bit values beat f32
        for r in &rows {
            if r.name != "dense" {
                assert!(r.down_bytes_per_round < dense.down_bytes_per_round, "{r:?}");
            }
        }
        assert!(by("sparse/rice").down_bytes_per_round < by("sparse/f32").down_bytes_per_round);
        assert!(by("sparse/u8").down_bytes_per_round < by("sparse/f32").down_bytes_per_round);
        // quantized downlink only perturbs the posterior statistic
        // (the server still steps on the exact aggregate), so the gap
        // stays in a tight band around the dense run
        assert!(by("sparse/u8").final_gap < 6.0 * dense.final_gap.max(0.05), "{rows:?}");
    }

    #[test]
    fn recall_improves_with_oversampling() {
        let rows = approx_recall_sweep(&[2, 16], 20_000, 200, 5);
        // the threshold estimator is stochastic; require high recall at
        // large oversampling and no collapse at small
        assert!(rows[1].1 > 0.9, "{rows:?}");
        assert!(rows[0].1 > 0.7, "{rows:?}");
    }
}
