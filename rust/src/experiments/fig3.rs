//! Fig. 3 — CNN on CIFAR-like data (§4.2 substitute; DESIGN.md §3):
//! N=8 workers, mini-batch 20/worker, eta=0.01, S=0.001 (k = max(1,
//! round(S*J))), validation accuracy vs iteration, TOP-k vs REGTOP-k
//! with identical init and identical batch samplers.
//!
//! The model is the artifact-backed ResNet-8 (`cnn_grad_resnet8` /
//! `cnn_eval_resnet8` HLO executables through PJRT) — python never
//! runs here.  With `--model mlp` the MLP artifacts are used instead
//! (faster; same J-scale sparsification dynamics).
//!
//! With `layerwise` set, the artifact model's REAL per-layer
//! [`FlatLayout`] (from `artifacts/manifest.json`) is adopted as the
//! run's `GradLayout` via [`GradLayout::from_flat`]: workers carve
//! their gradients per layer, updates travel bucketed, the ledger
//! accounts bytes/entries per layer, and an optional heterogeneous
//! `PolicyTable` assigns families/hyperparameters per layer-name glob.
//! When the PJRT binding is the offline stub (no artifacts), the
//! degraded path ([`run_degraded`]) exercises the identical layer-wise
//! protocol on the linreg testbed with a synthetic CNN-shaped layout.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::{Server, Trainer, Worker};
use crate::data::cifar_like;
use crate::data::linear::{generate, LinearParams};
use crate::experiments::fig2;
use crate::grad::GradLayout;
use crate::metrics::{IterRecord, RunLog};
use crate::models::artifact::{CnnEval, CnnModel, MlpModel};
use crate::optim::Sgd;
use crate::runtime::Runtime;
use crate::sparsify::{BudgetPolicy, PolicyTable, SparsifierKind};

#[derive(Clone, Debug)]
pub struct Fig3Config {
    pub workers: usize,
    pub iters: usize,
    pub eta: f32,
    /// sparsity factor S; k = max(1, round(S * J))
    pub s: f64,
    pub mu: f32,
    pub q: f32,
    pub seed: u64,
    pub train_rows: usize,
    pub val_rows: usize,
    pub eval_every: usize,
    /// adopt the artifact model's per-layer layout (bucketed path)
    pub layerwise: bool,
    /// heterogeneous per-layer policies (implies `layerwise`)
    pub policy: Option<PolicyTable>,
    /// per-layer budget policy (default: `Global{k}`, the same total
    /// budget as the flat run, apportioned by layer size)
    pub budget: Option<BudgetPolicy>,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config {
            workers: 8,
            iters: 300,
            eta: 0.01,
            s: 0.001,
            mu: 0.5,
            q: 1.0,
            seed: 42,
            train_rows: 1600,
            val_rows: 200,
            eval_every: 25,
            layerwise: false,
            policy: None,
            budget: None,
        }
    }
}

/// One Fig. 3 run: the metric log plus — on the layer-wise path — the
/// per-layer ledger table `(layer, family, upload bytes, entries)`.
pub struct Fig3Run {
    pub log: RunLog,
    pub groups: Vec<(String, String, usize, usize)>,
}

impl Fig3Config {
    fn wants_layerwise(&self) -> bool {
        self.layerwise || self.policy.is_some()
    }

    /// The trainer-level config for one sparsifier kind over `layout`.
    fn train_config(&self, kind: SparsifierKind, k: usize, layout: &GradLayout) -> TrainConfig {
        let layerwise = self.wants_layerwise();
        TrainConfig {
            workers: self.workers,
            eta: self.eta,
            sparsifier: kind,
            eval_every: self.eval_every,
            seed: self.seed,
            groups: layerwise.then(|| layout.clone()),
            budget: layerwise
                .then(|| self.budget.clone().unwrap_or(BudgetPolicy::Global { k })),
            policy: if layerwise { self.policy.clone() } else { None },
            ..TrainConfig::default()
        }
    }
}

/// Drain the per-layer ledger table out of a finished trainer.
fn group_table(tr: &Trainer) -> Vec<(String, String, usize, usize)> {
    let totals = tr.ledger.group_upload_totals();
    if totals.len() <= 1 {
        return Vec::new();
    }
    let entries = tr.ledger.group_upload_entries();
    let families = tr.workers[0].sparsifier.group_families();
    totals
        .into_iter()
        .zip(entries)
        .enumerate()
        .map(|(g, ((name, bytes), (_, n)))| {
            let fam = families.get(g).copied().unwrap_or("?").to_string();
            (name, fam, bytes, n)
        })
        .collect()
}

/// Build a trainer for one sparsifier over shared data/artifacts.
fn build_trainer(
    rt: &mut Runtime,
    cfg: &Fig3Config,
    kind: SparsifierKind,
    k: usize,
    model: &str,
    layout: &GradLayout,
    train: &cifar_like::ImageSet,
) -> Result<Trainer> {
    let grad_name = match model {
        "mlp" => "mlp_grad".to_string(),
        m => format!("cnn_grad_{m}"),
    };
    let model_key = if model == "mlp" { "mlp" } else { model };
    let exe = rt.load(&grad_name)?;
    let w0 = rt.load_init(model_key)?;
    let dim = w0.len();
    let config = cfg.train_config(kind, k, layout);
    let shards = train.shard(cfg.workers);
    let workers: Vec<Worker> = shards
        .into_iter()
        .enumerate()
        .map(|(i, shard)| {
            // identical batch-sampler seeds across algorithms (§4.2)
            let seed = cfg.seed.wrapping_mul(1000).wrapping_add(i as u64);
            let boxed: Box<dyn crate::models::GradModel> = if model == "mlp" {
                Box::new(MlpModel::new(exe.clone(), shard, seed))
            } else {
                Box::new(CnnModel::new(exe.clone(), shard, seed))
            };
            Worker::with_layout(i, boxed, config.build_sparsifier(dim, i), layout.clone())
        })
        .collect();
    let server = Server::new(w0, Box::new(Sgd::new(cfg.eta)));
    Ok(Trainer::new(config, workers, server))
}

/// The sparsifier lineup of the figure at budget `k`.
///
/// When a policy table pins an explicit family for EVERY layer, the
/// base family of a lineup entry never reaches any child, so running
/// topk-lw AND regtopk-lw would train (near-)identical stacks under
/// misleading labels.  In that case the lineup collapses to one
/// `policy-lw` run with the RegTop-k base, so `cfg.mu`/`cfg.q` still
/// flow into regtopk-family rules that leave mu/Q unset.
fn lineup(
    cfg: &Fig3Config,
    k: usize,
    layout: &GradLayout,
    with_dense: bool,
) -> Vec<(String, SparsifierKind)> {
    let suffix = if cfg.wants_layerwise() { "-lw" } else { "" };
    if let Some(p) = &cfg.policy {
        let fully_pinned = layout
            .groups()
            .iter()
            .all(|g| p.resolve(&g.name).is_some_and(|r| r.family.is_some()));
        if fully_pinned {
            return vec![(
                "policy-lw".to_string(),
                SparsifierKind::RegTopK { k, mu: cfg.mu, q: cfg.q },
            )];
        }
    }
    let mut kinds = vec![
        (format!("topk{suffix}"), SparsifierKind::TopK { k }),
        (
            format!("regtopk{suffix}"),
            SparsifierKind::RegTopK { k, mu: cfg.mu, q: cfg.q },
        ),
    ];
    if with_dense {
        kinds.push((format!("dense{suffix}"), SparsifierKind::Dense));
    }
    kinds
}

/// Run the figure: accuracy curves for TOP-k and REGTOP-k (and dense
/// when `with_dense`).  `model` is "resnet8" (default) or "mlp".
pub fn run(
    rt: &mut Runtime,
    cfg: &Fig3Config,
    model: &str,
    with_dense: bool,
) -> Result<Vec<Fig3Run>> {
    let train = cifar_like::generate(cfg.train_rows, 0.15, cfg.seed);
    let val = cifar_like::generate(cfg.val_rows, 0.15, cfg.seed ^ 0xEEEE);
    let eval_exe = if model == "mlp" {
        None // MLP eval via grad artifact loss only
    } else {
        Some(CnnEval::new(rt.load(&format!("cnn_eval_{model}"))?, val))
    };

    let model_key = if model == "mlp" { "mlp" } else { model };
    let dim = rt.load_init(model_key)?.len();
    let k = ((cfg.s * dim as f64).round() as usize).max(1);
    let layout = if cfg.wants_layerwise() {
        rt.manifest
            .models
            .get(model_key)
            .ok_or_else(|| anyhow::anyhow!("model '{model_key}' not in manifest"))?
            .grad_layout()
            .map_err(|e| e.context(model_key.to_string()))?
    } else {
        GradLayout::single(dim)
    };

    let mut runs = Vec::new();
    for (name, kind) in lineup(cfg, k, &layout, with_dense) {
        let mut tr = build_trainer(rt, cfg, kind, k, model, &layout, &train)?;
        let mut log = RunLog::new(name, tr.config_echo());
        for t in 0..cfg.iters {
            // wall_time_s is a reported metric, never an input to the
            // trajectory — repro-lint: allow(wall-clock)
            let t0 = std::time::Instant::now();
            let rr = tr.round();
            let mut rec = IterRecord::new(t);
            rec.loss = rr.mean_loss;
            rec.upload_bytes = rr.upload_bytes;
            rec.wall_time_s = t0.elapsed().as_secs_f64();
            if cfg.eval_every > 0 && (t % cfg.eval_every == 0 || t + 1 == cfg.iters) {
                if let Some(ev) = &eval_exe {
                    rec.accuracy = ev.accuracy(&tr.server.w);
                }
            }
            log.push(rec);
        }
        let groups = group_table(&tr);
        runs.push(Fig3Run { log, groups });
    }
    Ok(runs)
}

/// A synthetic CNN-shaped layout for the artifact-free degraded path:
/// the real manifest layouts alternate big kernel blocks with tiny
/// bias vectors, which is exactly the shape that exercises per-group
/// budgets, index widths and heterogeneous policies.
pub fn degraded_layout(model: &str) -> GradLayout {
    let sizes: &[(&str, usize)] = if model == "mlp" {
        &[("fc0.w", 192), ("fc0.b", 16), ("fc1.w", 160), ("fc1.b", 10)]
    } else {
        &[
            ("conv0.w", 216),
            ("conv0.b", 8),
            ("block1.conv.w", 576),
            ("block1.conv.b", 8),
            ("fc.w", 80),
            ("fc.b", 10),
        ]
    };
    GradLayout::from_sizes(sizes.iter().map(|(n, l)| (n.to_string(), *l)))
}

/// Artifact-free degraded path: the same sparsifier lineup, layout
/// semantics, budgets and policies as the artifact run, driven on the
/// linreg testbed with [`degraded_layout`] standing in for the
/// manifest's `FlatLayout`.  Keeps `repro fig3 --layerwise` exercising
/// the full bucketed/heterogeneous stack on hosts without the PJRT
/// binding (the run is labeled degraded by the caller).
pub fn run_degraded(cfg: &Fig3Config, model: &str, with_dense: bool) -> Vec<Fig3Run> {
    let layout = degraded_layout(model);
    let dim = layout.total();
    let params = LinearParams {
        workers: cfg.workers,
        rows_per_worker: 64,
        dim,
        ..LinearParams::fig2()
    };
    let problem = generate(params, cfg.seed);
    let k = ((cfg.s * dim as f64).round() as usize).max(1);
    let mut runs = Vec::new();
    for (name, kind) in lineup(cfg, k, &layout, with_dense) {
        let config = cfg.train_config(kind, k, &layout);
        let mut tr = fig2::trainer_from_config(&config, &problem);
        let log = fig2::run_curve_with(&mut tr, &problem, &name, cfg.iters);
        runs.push(Fig3Run { log, groups: group_table(&tr) });
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degraded_layerwise_run_reports_per_layer_tables() {
        let cfg = Fig3Config {
            workers: 2,
            iters: 4,
            s: 0.01,
            train_rows: 64,
            val_rows: 16,
            eval_every: 0,
            layerwise: true,
            ..Fig3Config::default()
        };
        let runs = run_degraded(&cfg, "mlp", false);
        assert_eq!(runs.len(), 2);
        for r in &runs {
            assert_eq!(r.log.records().len(), 4);
            assert!(r.log.last().unwrap().loss.is_finite());
            assert_eq!(r.groups.len(), 4, "one table row per mlp layer");
            let total: usize = r.groups.iter().map(|(_, _, b, _)| b).sum();
            assert!(total > 0);
        }
    }

    #[test]
    fn degraded_heterogeneous_policy_changes_entry_split() {
        let mut cfg = Fig3Config {
            workers: 2,
            iters: 3,
            s: 0.01,
            eval_every: 0,
            layerwise: true,
            ..Fig3Config::default()
        };
        cfg.policy = Some(PolicyTable::parse("*.b=dense;*=regtopk").unwrap());
        let runs = run_degraded(&cfg, "resnet8", false);
        // every layer's family is pinned by the policy, so the
        // topk/regtopk lineup collapses to one labeled policy run
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].log.name, "policy-lw");
        // bias layers ship dense: entries per bias row = len * workers * iters
        let bias = runs[0]
            .groups
            .iter()
            .find(|(n, _, _, _)| n == "conv0.b")
            .expect("conv0.b row");
        assert_eq!(bias.1, "dense");
        assert_eq!(bias.3, 8 * 2 * 3);
    }

    #[test]
    fn partial_policy_keeps_the_comparison_lineup() {
        // only biases are pinned: the topk-vs-regtopk comparison is
        // still meaningful and must keep both runs
        let mut cfg = Fig3Config {
            workers: 2,
            iters: 2,
            s: 0.01,
            eval_every: 0,
            layerwise: true,
            ..Fig3Config::default()
        };
        cfg.policy = Some(PolicyTable::parse("*.b=dense").unwrap());
        let runs = run_degraded(&cfg, "mlp", false);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].log.name, "topk-lw");
        assert_eq!(runs[1].log.name, "regtopk-lw");
    }

    #[test]
    fn flat_config_stays_single_group() {
        let cfg = Fig3Config { workers: 2, iters: 2, eval_every: 0, ..Fig3Config::default() };
        assert!(!cfg.wants_layerwise());
        let runs = run_degraded(&cfg, "mlp", false);
        assert!(runs[0].groups.is_empty(), "no per-layer table on the flat path");
    }
}
