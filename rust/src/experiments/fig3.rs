//! Fig. 3 — CNN on CIFAR-like data (§4.2 substitute; DESIGN.md §3):
//! N=8 workers, mini-batch 20/worker, eta=0.01, S=0.001 (k = max(1,
//! round(S*J))), validation accuracy vs iteration, TOP-k vs REGTOP-k
//! with identical init and identical batch samplers.
//!
//! The model is the artifact-backed ResNet-8 (`cnn_grad_resnet8` /
//! `cnn_eval_resnet8` HLO executables through PJRT) — python never
//! runs here.  With `--model mlp` the MLP artifacts are used instead
//! (faster; same J-scale sparsification dynamics).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::{Server, Trainer, Worker};
use crate::data::cifar_like;
use crate::metrics::{IterRecord, RunLog};
use crate::models::artifact::{CnnEval, CnnModel, MlpModel};
use crate::optim::Sgd;
use crate::runtime::Runtime;
use crate::sparsify::{build, SparsifierKind};

#[derive(Clone, Copy, Debug)]
pub struct Fig3Config {
    pub workers: usize,
    pub iters: usize,
    pub eta: f32,
    /// sparsity factor S; k = max(1, round(S * J))
    pub s: f64,
    pub mu: f32,
    pub q: f32,
    pub seed: u64,
    pub train_rows: usize,
    pub val_rows: usize,
    pub eval_every: usize,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config {
            workers: 8,
            iters: 300,
            eta: 0.01,
            s: 0.001,
            mu: 0.5,
            q: 1.0,
            seed: 42,
            train_rows: 1600,
            val_rows: 200,
            eval_every: 25,
        }
    }
}

/// Build a trainer for one sparsifier over shared data/artifacts.
fn build_trainer(
    rt: &mut Runtime,
    cfg: &Fig3Config,
    kind: SparsifierKind,
    model: &str,
    train: &cifar_like::ImageSet,
) -> Result<Trainer> {
    let grad_name = match model {
        "mlp" => "mlp_grad".to_string(),
        m => format!("cnn_grad_{m}"),
    };
    let exe = rt.load(&grad_name)?;
    let w0 = rt.load_init(if model == "mlp" { "mlp" } else { model })?;
    let dim = w0.len();
    let shards = train.shard(cfg.workers);
    let workers: Vec<Worker> = shards
        .into_iter()
        .enumerate()
        .map(|(i, shard)| {
            // identical batch-sampler seeds across algorithms (§4.2)
            let seed = cfg.seed.wrapping_mul(1000).wrapping_add(i as u64);
            let boxed: Box<dyn crate::models::GradModel> = if model == "mlp" {
                Box::new(MlpModel::new(exe.clone(), shard, seed))
            } else {
                Box::new(CnnModel::new(exe.clone(), shard, seed))
            };
            Worker::new(i, boxed, build(&kind, dim, i))
        })
        .collect();
    let config = TrainConfig {
        workers: cfg.workers,
        eta: cfg.eta,
        sparsifier: kind,
        eval_every: cfg.eval_every,
        seed: cfg.seed,
        ..TrainConfig::default()
    };
    let server = Server::new(w0, Box::new(Sgd::new(cfg.eta)));
    Ok(Trainer::new(config, workers, server))
}

/// Run the figure: accuracy curves for TOP-k and REGTOP-k (and dense
/// when `with_dense`).  `model` is "resnet8" (default) or "mlp".
pub fn run(
    rt: &mut Runtime,
    cfg: Fig3Config,
    model: &str,
    with_dense: bool,
) -> Result<Vec<RunLog>> {
    let train = cifar_like::generate(cfg.train_rows, 0.15, cfg.seed);
    let val = cifar_like::generate(cfg.val_rows, 0.15, cfg.seed ^ 0xEEEE);
    let eval_exe = if model == "mlp" {
        None // MLP eval via grad artifact loss only
    } else {
        Some(CnnEval::new(rt.load(&format!("cnn_eval_{model}"))?, val))
    };

    let dim = rt.load_init(if model == "mlp" { "mlp" } else { model })?.len();
    let k = ((cfg.s * dim as f64).round() as usize).max(1);
    let mut kinds = vec![
        ("topk".to_string(), SparsifierKind::TopK { k }),
        ("regtopk".to_string(), SparsifierKind::RegTopK { k, mu: cfg.mu, q: cfg.q }),
    ];
    if with_dense {
        kinds.push(("dense".to_string(), SparsifierKind::Dense));
    }

    let mut logs = Vec::new();
    for (name, kind) in kinds {
        let mut tr = build_trainer(rt, &cfg, kind, model, &train)?;
        let mut log = RunLog::new(name.clone(), tr.config.to_json());
        for t in 0..cfg.iters {
            let t0 = std::time::Instant::now();
            let rr = tr.round();
            let mut rec = IterRecord::new(t);
            rec.loss = rr.mean_loss;
            rec.upload_bytes = rr.upload_bytes;
            rec.wall_time_s = t0.elapsed().as_secs_f64();
            if cfg.eval_every > 0 && (t % cfg.eval_every == 0 || t + 1 == cfg.iters) {
                if let Some(ev) = &eval_exe {
                    rec.accuracy = ev.accuracy(&tr.server.w);
                }
            }
            log.push(rec);
        }
        logs.push(log);
    }
    Ok(logs)
}
