//! Experiment harnesses: one module per paper figure/table plus the
//! ablation sweeps (DESIGN.md §5 experiment index).
//!
//! Every harness returns [`crate::metrics::RunLog`]s so the CLI,
//! examples, integration tests and benches all regenerate the same
//! series the paper reports; EXPERIMENTS.md records the outputs.

#![forbid(unsafe_code)]

pub mod baselines;
pub mod comm_table;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod sweeps;
