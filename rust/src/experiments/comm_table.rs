//! Tab A — communication-volume accounting (the paper's motivating
//! arithmetic, §1: "for ResNet-110, J ~= 1.7e6 ... the network
//! exchanges 1.7e9 symbols per epoch per worker" at 1000 minibatches).
//!
//! Produces (a) the analytic symbols/epoch table for representative
//! model sizes and sparsities and (b) measured bytes/round from a live
//! ledger on the Fig. 2 testbed.

use crate::comm::CostModel;
use crate::data::linear::generate;
use crate::experiments::{fig2, sweeps};
use crate::sparsify::SparsifierKind;

/// One analytic row: model, J, S, symbols/epoch/worker, bytes/epoch,
/// compression vs dense.
#[derive(Clone, Debug)]
pub struct CommRow {
    pub model: String,
    pub dim: usize,
    pub s: f64,
    pub symbols_per_epoch: f64,
    pub bytes_per_epoch: f64,
    pub compression: f64,
}

/// Analytic table (batches/epoch = 1000 as in §1).
pub fn analytic(sparsities: &[f64]) -> Vec<CommRow> {
    let models: [(&str, usize); 3] =
        [("resnet110", 1_700_000), ("resnet18", 11_173_962), ("resnet8", 19_858)];
    let cm = CostModel::default();
    let batches = 1000.0;
    let mut rows = Vec::new();
    for (name, j) in models {
        // dense reference row (S = 1, no index overhead)
        rows.push(CommRow {
            model: name.to_string(),
            dim: j,
            s: 1.0,
            symbols_per_epoch: j as f64 * batches,
            bytes_per_epoch: cm.broadcast_bytes(j) as f64 * batches,
            compression: 1.0,
        });
        for &s in sparsities {
            let k = ((s * j as f64).round()).max(1.0);
            let index_bits = (usize::BITS - (j - 1).leading_zeros()) as f64;
            let bytes = k * (32.0 + index_bits) / 8.0 * batches;
            rows.push(CommRow {
                model: name.to_string(),
                dim: j,
                s,
                symbols_per_epoch: k * batches,
                bytes_per_epoch: bytes,
                compression: bytes / (cm.broadcast_bytes(j) as f64 * batches),
            });
        }
    }
    rows
}

/// Measured bytes/round per sparsifier on the (reduced) Fig. 2 testbed.
pub fn measured(s: f64, iters: usize, seed: u64) -> Vec<(String, usize, f64)> {
    let params = sweeps::sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    [
        ("dense".to_string(), SparsifierKind::Dense),
        ("topk".to_string(), SparsifierKind::TopK { k }),
        ("regtopk".to_string(), SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 }),
        ("randk".to_string(), SparsifierKind::RandK { k, seed: 7 }),
    ]
    .into_iter()
    .map(|(name, kind)| {
        let mut tr = fig2::trainer_for(&problem, kind, 0.02);
        for _ in 0..iters {
            tr.round();
        }
        let per_round = tr.ledger.total_upload_bytes() / iters;
        let sim = tr.ledger.total_sim_time() / iters as f64;
        (name, per_round, sim)
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_reproduces_paper_motivating_number() {
        // §1: ResNet-110, 1000 minibatches -> 1.7e9 symbols/epoch/worker
        let rows = analytic(&[0.001]);
        let dense110 = rows.iter().find(|r| r.model == "resnet110" && r.s == 1.0).unwrap();
        assert!((dense110.symbols_per_epoch - 1.7e9).abs() < 1e7);
        // 0.1% sparsification cuts symbols by ~1000x
        let sp = rows.iter().find(|r| r.model == "resnet110" && r.s == 0.001).unwrap();
        assert!(sp.symbols_per_epoch < 2e6);
        assert!(sp.compression < 0.003, "{}", sp.compression);
    }

    #[test]
    fn measured_sparsifiers_transmit_less_than_dense() {
        let rows = measured(0.1, 5, 3);
        let dense = rows.iter().find(|r| r.0 == "dense").unwrap().1;
        for (name, bytes, _) in &rows {
            if name != "dense" {
                assert!(*bytes < dense / 5, "{name}: {bytes} vs dense {dense}");
            }
        }
        // topk and regtopk budgets identical
        let t = rows.iter().find(|r| r.0 == "topk").unwrap().1;
        let r = rows.iter().find(|r| r.0 == "regtopk").unwrap().1;
        assert_eq!(t, r);
    }
}
