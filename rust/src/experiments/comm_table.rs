//! Tab A — communication-volume accounting (the paper's motivating
//! arithmetic, §1: "for ResNet-110, J ~= 1.7e6 ... the network
//! exchanges 1.7e9 symbols per epoch per worker" at 1000 minibatches).
//!
//! Produces (a) the analytic symbols/epoch table for representative
//! model sizes and sparsities — now with the measured Golomb–Rice
//! index cost next to the paper's `log J` bound per sparsity point
//! (the bound-vs-code gap, ISSUE 5) — and (b) measured bytes/round
//! from a live ledger on the Fig. 2 testbed.

use crate::comm::codec::{index_bits, RicePayload};
use crate::comm::CostModel;
use crate::config::TrainConfig;
use crate::data::linear::generate;
use crate::experiments::{fig2, sweeps};
use crate::sparsify::{PolicyTable, SparsifierKind};
use crate::util::rng::Rng;

/// One analytic row: model, J, S, symbols/epoch/worker, bytes/epoch,
/// compression vs dense, plus the index-cost pair — the paper's
/// `ceil(log2 J)` bound and the measured Golomb–Rice bits/index on a
/// sampled k-of-J index set (both 0 for the dense row: no indices).
#[derive(Clone, Debug)]
pub struct CommRow {
    pub model: String,
    pub dim: usize,
    pub s: f64,
    pub symbols_per_epoch: f64,
    pub bytes_per_epoch: f64,
    pub compression: f64,
    /// the paper's per-index bound: `ceil(log2 J)` bits
    pub idx_bound_bits: f64,
    /// measured Golomb–Rice bits/index (uniform k-of-J sample,
    /// header included — the honest wire cost of `idx=rice`)
    pub rice_bits: f64,
}

/// Measured Golomb–Rice bits/index for a uniform k-of-J sample
/// (seeded: the table is reproducible).  Uniform sampling is the
/// WORST case for the entropy code — real top-k sets cluster — so the
/// table's bound-vs-code gap is a conservative floor.
fn rice_bits_per_index(j: usize, k: usize, rng: &mut Rng) -> f64 {
    // cap the sample: the code rate depends on the gap statistics,
    // i.e. on the ratio J/k, so a proportionally scaled subsample
    // measures the same bits/index.  BOTH axes are bounded — the
    // sampler materializes an O(j_s) permutation, so j_s must shrink
    // with k_s or the 11M-parameter rows would allocate ~85 MB per
    // call (the ratio is preserved by scaling k_s down first).
    const J_CAP: usize = 1 << 20;
    let k_s = k
        .clamp(1, 1 << 16)
        .min(((k as u128 * J_CAP as u128 / j.max(1) as u128) as usize).max(1));
    let j_s = ((j as u128 * k_s as u128 / k as u128) as usize).clamp(k_s, J_CAP);
    let mut idx: Vec<u32> =
        rng.sample_indices(j_s, k_s).into_iter().map(|i| i as u32).collect();
    idx.sort_unstable();
    let mut p = RicePayload::default();
    p.encode_into(&idx);
    debug_assert_eq!(p.decode(), idx, "rice round-trip must be lossless");
    p.wire_bytes() as f64 * 8.0 / k_s as f64
}

/// Analytic table (batches/epoch = 1000 as in §1).
pub fn analytic(sparsities: &[f64]) -> Vec<CommRow> {
    let models: [(&str, usize); 3] =
        [("resnet110", 1_700_000), ("resnet18", 11_173_962), ("resnet8", 19_858)];
    let cm = CostModel::default();
    let batches = 1000.0;
    let mut rng = Rng::seed_from(0x51CE);
    let mut rows = Vec::new();
    for (name, j) in models {
        // dense reference row (S = 1, no index overhead)
        rows.push(CommRow {
            model: name.to_string(),
            dim: j,
            s: 1.0,
            symbols_per_epoch: j as f64 * batches,
            bytes_per_epoch: cm.broadcast_bytes(j) as f64 * batches,
            compression: 1.0,
            idx_bound_bits: 0.0,
            rice_bits: 0.0,
        });
        for &s in sparsities {
            let k = ((s * j as f64).round()).max(1.0);
            let ib = index_bits(j) as f64;
            let bytes = k * (32.0 + ib) / 8.0 * batches;
            rows.push(CommRow {
                model: name.to_string(),
                dim: j,
                s,
                symbols_per_epoch: k * batches,
                bytes_per_epoch: bytes,
                compression: bytes / (cm.broadcast_bytes(j) as f64 * batches),
                idx_bound_bits: ib,
                rice_bits: rice_bits_per_index(j, k as usize, &mut rng),
            });
        }
    }
    rows
}

/// One measured row from a live ledger: bytes/round in BOTH link
/// directions (the pre-PR 6 table printed only uploads and implied
/// the analytic `32J` broadcast; these are the bytes the ledger
/// actually charged, so downlink-compressed rows show their real
/// broadcast cost).
#[derive(Clone, Debug)]
pub struct MeasuredRow {
    pub name: String,
    /// sum over workers, per round
    pub up_bytes: usize,
    /// broadcast cost x workers, per round
    pub down_bytes: usize,
    pub sim_s: f64,
    /// socket-measured charged bytes/round from a loopback-TCP replay
    /// of the same run (worker->server frames); equal to `up_bytes`
    /// by construction — the frames carry exactly the charged bytes —
    /// and asserted so every round by `Trainer::run_transport`
    pub sock_up_bytes: usize,
    /// socket-measured charged broadcast bytes/round of the replay
    pub sock_down_bytes: usize,
}

/// Measured bytes/round per sparsifier on the (reduced) Fig. 2
/// testbed, including downlink-compressed RegTop-k variants (`dl`
/// rows: lossless sparse broadcast, and 8-bit Rice-indexed).  Each
/// row is measured twice: the deterministic driver fills the ledger
/// columns, and a loopback-TCP replay fills the socket columns from
/// real framed traffic.
pub fn measured(s: f64, iters: usize, seed: u64) -> Vec<MeasuredRow> {
    let params = sweeps::sweep_params(8);
    let problem = generate(params, seed);
    let k = ((s * params.dim as f64).round() as usize).max(1);
    let reg = SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 };
    [
        ("dense".to_string(), SparsifierKind::Dense, None),
        ("topk".to_string(), SparsifierKind::TopK { k }, None),
        ("regtopk".to_string(), reg.clone(), None),
        ("randk".to_string(), SparsifierKind::RandK { k, seed: 7 }, None),
        ("regtopk+dl".to_string(), reg.clone(), Some("*=")),
        ("regtopk+dl8".to_string(), reg, Some("*=:bits=8,idx=rice")),
    ]
    .into_iter()
    .map(|(name, kind, downlink)| {
        let config = TrainConfig {
            workers: params.workers,
            eta: 0.02,
            sparsifier: kind,
            eval_every: 1,
            downlink: downlink.map(|d| PolicyTable::parse(d).unwrap()),
            ..TrainConfig::default()
        };
        let mut tr = fig2::trainer_from_config(&config, &problem);
        for _ in 0..iters {
            tr.round();
        }
        // loopback-TCP replay: the same trajectory over real sockets,
        // counted at the server's connections
        let mut tcp = fig2::trainer_from_config(&config, &problem);
        let (_, sock) = tcp.run_tcp_loopback_counted(iters);
        MeasuredRow {
            name,
            up_bytes: tr.ledger.total_upload_bytes() / iters,
            down_bytes: tr.ledger.total_download_bytes() / iters,
            sim_s: tr.ledger.total_sim_time() / iters as f64,
            sock_up_bytes: sock.recv_wire as usize / iters,
            sock_down_bytes: sock.sent_wire as usize / iters,
        }
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_reproduces_paper_motivating_number() {
        // §1: ResNet-110, 1000 minibatches -> 1.7e9 symbols/epoch/worker
        let rows = analytic(&[0.001]);
        let dense110 = rows.iter().find(|r| r.model == "resnet110" && r.s == 1.0).unwrap();
        assert!((dense110.symbols_per_epoch - 1.7e9).abs() < 1e7);
        // 0.1% sparsification cuts symbols by ~1000x
        let sp = rows.iter().find(|r| r.model == "resnet110" && r.s == 0.001).unwrap();
        assert!(sp.symbols_per_epoch < 2e6);
        assert!(sp.compression < 0.003, "{}", sp.compression);
    }

    #[test]
    fn rice_column_beats_the_log_j_bound() {
        // at the paper's 0.1% regime index bits dominate the payload;
        // the measured entropy code must come in under the bound on
        // every sparse row, and the dense rows carry no index cost
        let rows = analytic(&[0.1, 0.001]);
        for r in &rows {
            if r.s >= 1.0 {
                assert_eq!(r.idx_bound_bits, 0.0);
                assert_eq!(r.rice_bits, 0.0);
            } else {
                assert!(r.idx_bound_bits >= 14.0, "{r:?}");
                assert!(r.rice_bits > 0.0, "{r:?}");
                assert!(r.rice_bits < r.idx_bound_bits, "{r:?}");
            }
        }
        // denser selections have smaller gaps and cheaper indices
        let r110: Vec<&CommRow> =
            rows.iter().filter(|r| r.model == "resnet110" && r.s < 1.0).collect();
        assert!(r110[0].rice_bits < r110[1].rice_bits, "{:?}", r110);
    }

    #[test]
    fn measured_sparsifiers_transmit_less_than_dense() {
        let rows = measured(0.1, 5, 3);
        let dense = rows.iter().find(|r| r.name == "dense").unwrap().up_bytes;
        for r in &rows {
            if r.name != "dense" {
                assert!(r.up_bytes < dense / 5, "{}: {} vs dense {dense}", r.name, r.up_bytes);
            }
        }
        // topk and regtopk budgets identical
        let t = rows.iter().find(|r| r.name == "topk").unwrap().up_bytes;
        let r = rows.iter().find(|r| r.name == "regtopk").unwrap().up_bytes;
        assert_eq!(t, r);
    }

    #[test]
    fn socket_columns_equal_ledger_columns() {
        // the tentpole acceptance in table form: bytes measured at the
        // server's sockets == bytes the ledger charged, both directions
        let rows = measured(0.1, 4, 5);
        for r in &rows {
            assert_eq!(r.sock_up_bytes, r.up_bytes, "{}: socket uplink", r.name);
            assert_eq!(r.sock_down_bytes, r.down_bytes, "{}: socket downlink", r.name);
        }
    }

    #[test]
    fn measured_downlink_rows_beat_the_dense_broadcast() {
        // at 1% sparsity the 8-worker union support is far below J, so
        // the sparse broadcast must be charged under the dense 32J
        // formula — and 8-bit values + Rice indices under that again
        let rows = measured(0.01, 5, 3);
        let row = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        let dense_down = row("dense").down_bytes;
        assert_eq!(row("regtopk").down_bytes, dense_down, "uncompressed downlink is dense");
        let dl = row("regtopk+dl");
        let dl8 = row("regtopk+dl8");
        assert!(dl.down_bytes < dense_down, "{} vs {dense_down}", dl.down_bytes);
        assert!(dl8.down_bytes < dl.down_bytes, "{} vs {}", dl8.down_bytes, dl.down_bytes);
        // the lossless sparse broadcast does not change the uplink
        assert_eq!(dl.up_bytes, row("regtopk").up_bytes);
    }
}
