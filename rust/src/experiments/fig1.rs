//! Fig. 1 — the §1.2 motivational toy: logistic regression, J=2, N=2,
//! x1=[100,1], x2=[-100,1], w0=[0,1], eta=0.9; training loss for
//! non-sparsified GD, TOP-1 and REGTOP-1.
//!
//! Expected shape (paper): TOP-1 is flat at the initial loss for ~50+
//! iterations (its selected first entries cancel after averaging);
//! REGTOP-1 tracks the dense curve closely.

use crate::config::TrainConfig;
use crate::coordinator::{Server, Trainer, Worker};
use crate::metrics::RunLog;
use crate::models::logistic::Logistic;
use crate::optim::Sgd;
use crate::sparsify::{build, SparsifierKind};

pub const ETA: f32 = 0.9;
pub const W0: [f32; 2] = [0.0, 1.0];

/// The empirical risk F(w) = (F_1 + F_2)/2 of the toy problem.
pub fn risk(w: &[f32]) -> f32 {
    let m1 = Logistic::toy_worker(vec![100.0, 1.0]);
    let m2 = Logistic::toy_worker(vec![-100.0, 1.0]);
    0.5 * (m1.loss(w) + m2.loss(w))
}

/// Build the two-worker toy trainer for a sparsifier.
/// `with_g` adds the §1.2 extension loss G(theta_2) with G'(1)=1
/// (implemented as a constant +1 gradient offset on theta_2).
pub fn toy_trainer(kind: SparsifierKind, eta: f32, with_g: bool) -> Trainer {
    let config = TrainConfig {
        workers: 2,
        eta,
        sparsifier: kind.clone(),
        eval_every: 1,
        ..TrainConfig::default()
    };
    let mk = |x: Vec<f32>| {
        let mut m = Logistic::toy_worker(x);
        if with_g {
            m.grad_offset = vec![0.0, 1.0];
        }
        Box::new(m)
    };
    let workers = vec![
        Worker::new(0, mk(vec![100.0, 1.0]), build(&kind, 2, 0)),
        Worker::new(1, mk(vec![-100.0, 1.0]), build(&kind, 2, 1)),
    ];
    let server = Server::new(W0.to_vec(), Box::new(Sgd::new(eta)));
    Trainer::new(config, workers, server)
}

/// Run the three curves for `iters` iterations.  Returns logs named
/// dense / topk / regtopk whose `loss` field is the empirical risk at
/// the *post-update* model (the quantity Fig. 1 plots).
pub fn run(iters: usize, mu: f32, q: f32) -> Vec<RunLog> {
    let kinds = [
        ("dense", SparsifierKind::Dense),
        ("topk", SparsifierKind::TopK { k: 1 }),
        ("regtopk", SparsifierKind::RegTopK { k: 1, mu, q }),
    ];
    kinds
        .iter()
        .map(|(name, kind)| {
            let mut tr = toy_trainer(kind.clone(), ETA, false);
            let mut log = RunLog::new(*name, tr.config.to_json());
            for t in 0..iters {
                tr.round();
                let mut rec = crate::metrics::IterRecord::new(t);
                rec.loss = risk(&tr.server.w);
                rec.upload_bytes = tr.ledger.rounds().last().unwrap().upload_bytes;
                log.push(rec);
            }
            log
        })
        .collect()
}

/// The learning-rate-scaling diagnostic (§1.2 extension): returns the
/// per-iteration step norms under TOP-1 with the G-extended loss, plus
/// the implied scaling factor (max step / first dense-equivalent step).
pub fn lr_scaling(iters: usize) -> (Vec<f32>, f32) {
    let mut tr = toy_trainer(SparsifierKind::TopK { k: 1 }, 0.01, true);
    let mut prev = tr.server.w.clone();
    let mut steps = Vec::with_capacity(iters);
    for _ in 0..iters {
        tr.round();
        let d: f32 = tr
            .server
            .w
            .iter()
            .zip(&prev)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        steps.push(d);
        prev = tr.server.w.clone();
    }
    // dense-equivalent first step: eta * |g[1] of combined loss| =
    // eta * (sigma(-1) + 1)
    let sigma = 1.0 / (1.0 + 1f32.exp());
    let dense_step = 0.01 * (sigma + 1.0);
    let max_step = steps.iter().cloned().fold(0.0f32, f32::max);
    (steps, max_step / dense_step)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_matches_paper() {
        let logs = run(60, 0.5, 1.0);
        let f = |name: &str| logs.iter().find(|l| l.name == name).unwrap();
        let loss0 = risk(&W0);
        // TOP-1 flat at the initial risk for at least 40 iters
        let top = f("topk");
        assert!((top.records()[40].loss - loss0).abs() < 1e-6);
        // dense descends immediately
        let dense = f("dense");
        assert!(dense.records()[5].loss < loss0);
        // REGTOP-1 tracks dense: much closer to dense than TOP-1 at t=30
        let reg = f("regtopk");
        let gap_reg = (reg.records()[30].loss - dense.records()[30].loss).abs();
        let gap_top = (top.records()[30].loss - dense.records()[30].loss).abs();
        assert!(gap_reg < 0.2 * gap_top, "reg {gap_reg} vs top {gap_top}");
    }

    #[test]
    fn lr_scaling_shows_stall_then_jump() {
        let (steps, factor) = lr_scaling(80);
        assert!(steps[..10].iter().all(|&s| s < 1e-9), "must stall first");
        // crossover analysis (see python test): factor ~= 21 with the
        // sigmoid convention here; assert the qualitative regime
        assert!(factor > 10.0, "scaling factor {factor}");
    }

    #[test]
    fn regtopk_transmits_same_budget_as_topk() {
        let logs = run(20, 0.5, 1.0);
        let f = |name: &str| logs.iter().find(|l| l.name == name).unwrap();
        assert_eq!(
            f("topk").records()[5].upload_bytes,
            f("regtopk").records()[5].upload_bytes
        );
        assert!(f("dense").records()[5].upload_bytes > f("topk").records()[5].upload_bytes);
    }
}
