//! Baseline shoot-out: every sparsifier in the framework on the Fig. 2
//! testbed at one sparsity budget — the comparison table the paper's
//! §1.3 discusses qualitatively ("these approaches perform identically
//! to TOP-k with respect to learning-rate scaling").
//!
//! Also exercises the quantization axis: `topk+q4` transmits the same
//! k entries at 4-bit values, with the quantization residual folded
//! back into the error accumulator (unbiased end-to-end).

use crate::comm::{CostModel, Quantizer};
use crate::data::linear::{generate, LinearParams, LinearProblem};
use crate::experiments::fig2;
use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier, SparsifierKind, TopK};
use crate::util::rng::Rng;

/// Row of the comparison table.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub name: String,
    pub final_gap: f32,
    pub bytes_per_round: usize,
    pub mean_k: f32,
}

/// Run all baselines at sparsity `s` for `iters` rounds.
pub fn run(params: LinearParams, s: f64, iters: usize, seed: u64) -> Vec<BaselineRow> {
    let problem = generate(params, seed);
    let j = params.dim;
    let k = ((s * j as f64).round() as usize).max(1);
    let kinds: Vec<(String, SparsifierKind)> = vec![
        ("dense".into(), SparsifierKind::Dense),
        ("topk".into(), SparsifierKind::TopK { k }),
        ("regtopk".into(), SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 }),
        ("gtopk".into(), SparsifierKind::GlobalTopK { k }),
        ("randk".into(), SparsifierKind::RandK { k, seed: 11 }),
        ("dgc".into(), SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 }),
        ("adak".into(), SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k }),
    ];
    let mut rows = Vec::new();
    for (name, kind) in kinds {
        let mut tr = fig2::trainer_for(&problem, kind, 0.02);
        for _ in 0..iters {
            tr.round();
        }
        let gap = fig2::opt_gap(&tr.server.w, &problem.w_star);
        let bytes = tr.ledger.total_upload_bytes() / iters;
        let entries = tr.ledger.rounds().iter().map(|r| r.upload_entries).sum::<usize>();
        rows.push(BaselineRow {
            name,
            final_gap: gap,
            bytes_per_round: bytes,
            mean_k: entries as f32 / (iters * params.workers) as f32,
        });
    }
    // quantized TOP-k (manual loop: quantization sits between
    // sparsifier and transport, residual folds into error feedback)
    rows.push(run_quantized_topk(&problem, k, iters, 4));
    rows
}

fn run_quantized_topk(
    problem: &LinearProblem,
    k: usize,
    iters: usize,
    bits: usize,
) -> BaselineRow {
    use crate::data::linear::ls_gradient;
    let n = problem.params.workers;
    let j = problem.params.dim;
    let omega = 1.0 / n as f32;
    let quant = Quantizer::new(bits);
    let cost = CostModel { value_bits: bits, ..CostModel::default() };
    let mut rng = Rng::seed_from(99);
    let mut sparsifiers: Vec<TopK> = (0..n).map(|_| TopK::new(j, k)).collect();
    let mut w = vec![0.0f32; j];
    let mut grad = vec![0.0f32; j];
    let mut gagg_prev = vec![0.0f32; j];
    let mut bytes_total = 0usize;
    let mut entries = 0usize;
    for t in 0..iters {
        let mut gagg = vec![0.0f32; j];
        for (i, sp) in sparsifiers.iter_mut().enumerate() {
            ls_gradient(&problem.shards[i], &w, &mut grad);
            let ctx = RoundCtx { t, gagg_prev: &gagg_prev, omega, genie_acc: None };
            let sv = sp.step(&grad, &ctx);
            let (qsv, residual) = quant.quantize_update(&sv, &mut rng);
            // fold the quantization error back into the accumulator
            sp.fold_residual(qsv.indices(), &residual);
            bytes_total += cost.update_bytes(&qsv);
            entries += qsv.nnz();
            qsv.axpy_into(omega, &mut gagg);
        }
        for i in 0..j {
            w[i] -= 0.02 * gagg[i];
        }
        gagg_prev = gagg;
    }
    BaselineRow {
        name: format!("topk+q{bits}"),
        final_gap: fig2::opt_gap(&w, &problem.w_star),
        bytes_per_round: bytes_total / iters,
        mean_k: entries as f32 / (iters * n) as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sweeps;

    #[test]
    fn table_has_all_rows_and_sane_ordering() {
        let rows = run(sweeps::sweep_params(6), 0.3, 250, 5);
        assert_eq!(rows.len(), 8);
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        // dense is the floor; randk the worst selector
        assert!(get("dense").final_gap < get("randk").final_gap);
        assert!(get("gtopk").final_gap <= get("topk").final_gap * 1.2);
        // budgets: fixed-k rows transmit k entries on average
        assert!((get("topk").mean_k - 18.0).abs() < 0.5);
        // quantized topk transmits the same entries in fewer bytes
        assert!(get("topk+q4").bytes_per_round < get("topk").bytes_per_round);
        // ... and still converges to a reasonable gap (unbiased EF)
        assert!(get("topk+q4").final_gap < 4.0 * get("topk").final_gap);
        // adak adapts within bounds
        let a = get("adak");
        assert!(a.mean_k >= 1.0 && a.mean_k <= 36.0);
    }
}
