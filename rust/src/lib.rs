//! # regtopk — REGTOP-k gradient sparsification, reproduced end-to-end
//!
//! Production-quality reproduction of *"Novel Gradient Sparsification
//! Algorithm via Bayesian Inference"* (Bereyhi, Liang, Boudreau, Afana,
//! 2024): a distributed-SGD coordinator in rust whose model gradients
//! are AOT-compiled JAX/Pallas artifacts executed through PJRT, and
//! whose communication layer sparsifies gradients with the paper's
//! REGTOP-k algorithm (plus the TOP-k family of baselines).
//!
//! Layer map (see DESIGN.md):
//! - **L3 (this crate)** — coordination: [`coordinator`] drives the
//!   synchronous rounds; [`sparsify`] implements the paper's Alg. 1 and
//!   baselines plus the layer-wise API (`GradLayout` parameter groups,
//!   bucketed `SparseUpdate` wire format, per-group budgets); [`comm`]
//!   simulates the transport with exact byte accounting (per group);
//!   [`data`], [`models`], [`optim`], [`metrics`], [`config`],
//!   [`util`] are the substrates.
//! - **L2/L1 (python, build-time only)** — JAX model graphs + Pallas
//!   kernels, lowered once to `artifacts/*.hlo.txt`; [`runtime`] loads
//!   and executes them via the PJRT CPU client.
//!
//! Soundness tooling (README §Static analysis & soundness): [`analysis`]
//! is the repo-invariant analyzer behind `repro lint`; the `unsafe`
//! surface is confined to the allowlist in `analysis::rules`, every
//! `unsafe` operation sits in an explicit block (`unsafe_op_in_unsafe_fn`
//! is denied crate-wide), and debug builds run the `SharedSlice` borrow
//! auditor (see [`util::pool`]).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod grad;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod runtime;
pub mod sparse;
pub mod sparsify;
pub mod util;
