//! Checkpointing: save/restore the global model and training cursor so
//! long runs (Fig. 3 at full scale) survive restarts.
//!
//! Format: a JSON header (config echo, iteration, dims, crc) followed
//! by the raw little-endian f32 model vector in a sidecar `.w` file —
//! human-inspectable metadata, zero-parse bulk data.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};

/// A saved training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub iter: usize,
    pub w: Vec<f32>,
    pub config: Json,
}

fn crc32(data: &[u8]) -> u32 {
    // small table-free CRC-32 (IEEE), fine for checkpoint integrity
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Checkpoint {
    pub fn new(iter: usize, w: Vec<f32>, config: Json) -> Self {
        Checkpoint { iter, w, config }
    }

    fn weight_path(path: &Path) -> PathBuf {
        path.with_extension("w")
    }

    /// Write `<path>` (JSON header) and `<path minus ext>.w` (weights).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let raw: Vec<u8> = self.w.iter().flat_map(|v| v.to_le_bytes()).collect();
        let header = obj([
            ("iter", Json::from(self.iter)),
            ("dim", Json::from(self.w.len())),
            ("crc32", Json::from(crc32(&raw) as usize)),
            ("config", self.config.clone()),
        ]);
        std::fs::write(path, header.dump())?;
        std::fs::write(Self::weight_path(path), raw)?;
        Ok(())
    }

    /// Load and verify a checkpoint pair.
    pub fn load(path: &Path) -> Result<Self> {
        let header = Json::parse(
            &std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;
        let dim = header
            .get("dim")
            .and_then(Json::as_usize)
            .context("header missing dim")?;
        let iter = header
            .get("iter")
            .and_then(Json::as_usize)
            .context("header missing iter")?;
        let want_crc = header
            .get("crc32")
            .and_then(Json::as_usize)
            .context("header missing crc32")? as u32;
        let raw = std::fs::read(Self::weight_path(path))?;
        if raw.len() != 4 * dim {
            bail!("weight file size {} != 4*{}", raw.len(), dim);
        }
        if crc32(&raw) != want_crc {
            bail!("checkpoint crc mismatch (corrupt or truncated)");
        }
        let w = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Checkpoint {
            iter,
            w,
            config: header.get("config").cloned().unwrap_or(Json::Null),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("regtopk_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let path = tmp("rt.json");
        let ck = Checkpoint::new(
            123,
            vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
            obj([("eta", Json::from(0.01))]),
        );
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(re, ck);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(path.with_extension("w")).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let path = tmp("bad.json");
        let ck = Checkpoint::new(1, vec![1.0; 16], Json::Null);
        ck.save(&path).unwrap();
        // flip a byte in the weight file
        let wpath = path.with_extension("w");
        let mut raw = std::fs::read(&wpath).unwrap();
        raw[5] ^= 0xFF;
        std::fs::write(&wpath, raw).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&wpath).ok();
    }

    #[test]
    fn truncation_is_detected() {
        let path = tmp("trunc.json");
        let ck = Checkpoint::new(1, vec![1.0; 16], Json::Null);
        ck.save(&path).unwrap();
        let wpath = path.with_extension("w");
        let raw = std::fs::read(&wpath).unwrap();
        std::fs::write(&wpath, &raw[..raw.len() - 4]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&wpath).ok();
    }

    #[test]
    fn crc_reference_value() {
        // "123456789" -> 0xCBF43926 (IEEE CRC-32 check value)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
