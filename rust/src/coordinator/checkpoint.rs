//! Checkpointing: save/restore the global model and training cursor so
//! long runs (Fig. 3 at full scale) survive restarts.
//!
//! Format: a JSON header (config echo, iteration, dims, crcs) followed
//! by the raw little-endian f32 model vector in a sidecar `.w` file —
//! human-inspectable metadata, zero-parse bulk data.  When the trainer
//! provides resume state (the previous aggregate `g^{t-1}` plus every
//! worker's sparsifier history), it travels in a second binary sidecar
//! `.ef`: without it a resumed RegTop-k run silently cold-restarts its
//! Bayesian history and degrades to plain Top-k (the ISSUE 3 bug);
//! with it the resumed trajectory is bit-identical to an uninterrupted
//! one (pinned by `rust/tests/resume.rs`).  Legacy model-only
//! checkpoints (no `.ef`) still load and restore cold.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::grad::EfState;
use crate::sparsify::SparsifierState;
use crate::util::json::{obj, Json};

/// The trainer-level resume state persisted next to the model.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// g^{t-1}: the aggregate broadcast in the last completed round
    /// (always stored dense; a sparse-broadcast run densifies via its
    /// mirror, which is exact — see `coordinator::GaggMirror`)
    pub gagg_prev: Vec<f32>,
    /// one sparsifier state per worker, in worker-id order
    pub workers: Vec<SparsifierState>,
    /// downlink codec state (PR 6); None when the run broadcasts dense,
    /// and absent entirely from pre-PR 6 sidecars — the section is
    /// additive, so old `.ef` files encode/decode byte-identically
    pub downlink: Option<DownlinkState>,
}

/// Resume state for the server's downlink codec: just its stochastic-
/// rounding stream.  The aggregate support need not be saved — after a
/// restore the server's sparse mirror starts empty (consistent with
/// its zeroed dense mirror) and `gagg_prev` is rebuilt from the dense
/// snapshot above, so the next round proceeds bit-identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DownlinkState {
    pub rng: [u64; 4],
    pub gauss_spare: Option<f64>,
}

/// A saved training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub iter: usize,
    pub w: Vec<f32>,
    pub config: Json,
    /// sparsifier/aggregate resume state (None = legacy model-only
    /// checkpoint; restore falls back to the cold error-feedback start)
    pub state: Option<TrainState>,
}

fn crc32(data: &[u8]) -> u32 {
    // small table-free CRC-32 (IEEE), fine for checkpoint integrity
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Checkpoint {
    pub fn new(iter: usize, w: Vec<f32>, config: Json) -> Self {
        Checkpoint { iter, w, config, state: None }
    }

    /// [`Self::new`] with the full resume state attached.
    pub fn with_state(iter: usize, w: Vec<f32>, config: Json, state: TrainState) -> Self {
        Checkpoint { iter, w, config, state: Some(state) }
    }

    fn weight_path(path: &Path) -> PathBuf {
        path.with_extension("w")
    }

    fn state_path(path: &Path) -> PathBuf {
        path.with_extension("ef")
    }

    /// Write `<path>` (JSON header), `<path minus ext>.w` (weights) and
    /// — when resume state is attached — `<path minus ext>.ef`.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let raw: Vec<u8> = self.w.iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut header = obj([
            ("iter", Json::from(self.iter)),
            ("dim", Json::from(self.w.len())),
            ("crc32", Json::from(crc32(&raw) as usize)),
            ("config", self.config.clone()),
        ]);
        if let Some(state) = &self.state {
            let sbytes = encode_train_state(state);
            if let Json::Obj(m) = &mut header {
                m.insert("state_crc32".to_string(), Json::from(crc32(&sbytes) as usize));
            }
            std::fs::write(Self::state_path(path), sbytes)?;
        }
        std::fs::write(path, header.dump())?;
        std::fs::write(Self::weight_path(path), raw)?;
        Ok(())
    }

    /// Load and verify a checkpoint (pair or triple).
    pub fn load(path: &Path) -> Result<Self> {
        let header = Json::parse(
            &std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;
        let dim = header
            .get("dim")
            .and_then(Json::as_usize)
            .context("header missing dim")?;
        let iter = header
            .get("iter")
            .and_then(Json::as_usize)
            .context("header missing iter")?;
        let want_crc = header
            .get("crc32")
            .and_then(Json::as_usize)
            .context("header missing crc32")? as u32;
        let raw = std::fs::read(Self::weight_path(path))?;
        if raw.len() != 4 * dim {
            bail!("weight file size {} != 4*{}", raw.len(), dim);
        }
        if crc32(&raw) != want_crc {
            bail!("checkpoint crc mismatch (corrupt or truncated)");
        }
        let w = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let state = match header.get("state_crc32").and_then(Json::as_usize) {
            None => None,
            Some(want) => {
                let spath = Self::state_path(path);
                let sbytes = std::fs::read(&spath)
                    .with_context(|| format!("reading resume state {spath:?}"))?;
                if crc32(&sbytes) != want as u32 {
                    bail!("resume-state crc mismatch (corrupt or truncated)");
                }
                Some(decode_train_state(&sbytes)?)
            }
        };
        Ok(Checkpoint {
            iter,
            w,
            config: header.get("config").cloned().unwrap_or(Json::Null),
            state,
        })
    }
}

// ---- binary codec for the `.ef` sidecar (all little-endian) ---------

// Persisted schema surface: section magics and state tags, extracted
// into `SCHEMA.lock` by `repro lint --schema`.  Tags are append-only —
// renumbering or reusing a retired number breaks old checkpoints and
// is rejected outright by the schema gate (`schema-tag-reuse`).
const EF_MAGIC: &[u8; 4] = b"RTKS";
const DLNK_MAGIC: &[u8; 4] = b"DLNK";
const STATE_TAG_STATELESS: u8 = 0;
const STATE_TAG_EF: u8 = 1;
const STATE_TAG_GROUPED: u8 = 2;
const STATE_TAG_DGC: u8 = 3;
const STATE_TAG_RESIDUAL: u8 = 4;
const STATE_TAG_EF_RNG: u8 = 5;
const STATE_TAG_QUANTIZED: u8 = 6;
const STATE_TAG_QUANTIZED_AUTO: u8 = 7;

fn put_u32(out: &mut Vec<u8>, v: usize) {
    out.extend_from_slice(&u32::try_from(v).expect("state section too large").to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn encode_ef(out: &mut Vec<u8>, ef: &EfState) {
    out.push(ef.warm as u8);
    put_f32s(out, &ef.eps);
    put_f32s(out, &ef.acc_prev);
    put_f32s(out, &ef.mask_prev);
}

fn encode_state(out: &mut Vec<u8>, st: &SparsifierState) {
    match st {
        SparsifierState::Stateless => out.push(STATE_TAG_STATELESS),
        SparsifierState::Ef(ef) => {
            out.push(STATE_TAG_EF);
            encode_ef(out, ef);
        }
        SparsifierState::Grouped(children) => {
            out.push(STATE_TAG_GROUPED);
            put_u32(out, children.len());
            for c in children {
                encode_state(out, c);
            }
        }
        SparsifierState::Dgc { vel, acc } => {
            out.push(STATE_TAG_DGC);
            put_f32s(out, vel);
            put_f32s(out, acc);
        }
        SparsifierState::Residual { eps } => {
            out.push(STATE_TAG_RESIDUAL);
            put_f32s(out, eps);
        }
        SparsifierState::EfRng { ef, rng, gauss_spare } => {
            out.push(STATE_TAG_EF_RNG);
            encode_ef(out, ef);
            for word in rng {
                out.extend_from_slice(&word.to_le_bytes());
            }
            out.push(gauss_spare.is_some() as u8);
            out.extend_from_slice(&gauss_spare.unwrap_or(0.0).to_le_bytes());
        }
        SparsifierState::Quantized { inner, rng, gauss_spare, auto_bits } => {
            // tag 6 = scheduled width (byte-identical to the PR 4
            // format, so old checkpoints keep loading); tag 7 adds the
            // residual-steered live width (`bits=auto`)
            out.push(match auto_bits {
                Some(_) => STATE_TAG_QUANTIZED_AUTO,
                None => STATE_TAG_QUANTIZED,
            });
            encode_state(out, inner);
            for word in rng {
                out.extend_from_slice(&word.to_le_bytes());
            }
            out.push(gauss_spare.is_some() as u8);
            out.extend_from_slice(&gauss_spare.unwrap_or(0.0).to_le_bytes());
            if let Some(b) = auto_bits {
                put_u32(out, *b);
            }
        }
    }
}

fn encode_train_state(st: &TrainState) -> Vec<u8> {
    let mut out = EF_MAGIC.to_vec();
    put_f32s(&mut out, &st.gagg_prev);
    put_u32(&mut out, st.workers.len());
    for w in &st.workers {
        encode_state(&mut out, w);
    }
    // additive downlink section (PR 6): written only when present, so
    // downlink-free runs produce byte-identical sidecars to PR 5
    if let Some(dl) = &st.downlink {
        out.extend_from_slice(DLNK_MAGIC);
        for word in dl.rng {
            out.extend_from_slice(&word.to_le_bytes());
        }
        out.push(dl.gauss_spare.is_some() as u8);
        out.extend_from_slice(&dl.gauss_spare.unwrap_or(0.0).to_le_bytes());
    }
    out
}

/// Byte cursor over the `.ef` sidecar.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("resume state truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<usize> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()?;
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn ef(&mut self) -> Result<EfState> {
        let warm = self.u8()? != 0;
        Ok(EfState { warm, eps: self.f32s()?, acc_prev: self.f32s()?, mask_prev: self.f32s()? })
    }

    fn state(&mut self, depth: usize) -> Result<SparsifierState> {
        Ok(match self.u8()? {
            STATE_TAG_STATELESS => SparsifierState::Stateless,
            STATE_TAG_EF => SparsifierState::Ef(self.ef()?),
            STATE_TAG_GROUPED => {
                if depth > 1 {
                    bail!("resume state nests groups deeper than the sparsifier stack");
                }
                let n = self.u32()?;
                let mut children = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    children.push(self.state(depth + 1)?);
                }
                SparsifierState::Grouped(children)
            }
            STATE_TAG_DGC => SparsifierState::Dgc { vel: self.f32s()?, acc: self.f32s()? },
            STATE_TAG_RESIDUAL => SparsifierState::Residual { eps: self.f32s()? },
            STATE_TAG_EF_RNG => {
                let ef = self.ef()?;
                let rng = [self.u64()?, self.u64()?, self.u64()?, self.u64()?];
                let has_spare = self.u8()? != 0;
                let spare = self.f64()?;
                SparsifierState::EfRng { ef, rng, gauss_spare: has_spare.then_some(spare) }
            }
            t @ (STATE_TAG_QUANTIZED | STATE_TAG_QUANTIZED_AUTO) => {
                // a quantizing group wraps exactly one leaf family
                // state; deeper nesting means a corrupt stream
                if depth > 2 {
                    bail!("resume state nests quantizers deeper than the sparsifier stack");
                }
                let inner = Box::new(self.state(depth + 1)?);
                if matches!(
                    *inner,
                    SparsifierState::Grouped(_) | SparsifierState::Quantized { .. }
                ) {
                    bail!("quantized resume state must wrap a leaf family state");
                }
                let rng = [self.u64()?, self.u64()?, self.u64()?, self.u64()?];
                let has_spare = self.u8()? != 0;
                let spare = self.f64()?;
                let auto_bits =
                    if t == STATE_TAG_QUANTIZED_AUTO { Some(self.u32()?) } else { None };
                SparsifierState::Quantized {
                    inner,
                    rng,
                    gauss_spare: has_spare.then_some(spare),
                    auto_bits,
                }
            }
            // a future tag must fail the load with a message, not be
            // silently misdecoded: repro-lint: allow(wildcard)
            t => bail!("unknown resume-state tag {t}"),
        })
    }
}

fn decode_train_state(bytes: &[u8]) -> Result<TrainState> {
    let mut c = Cur { b: bytes, i: 0 };
    if c.take(4)? != EF_MAGIC {
        bail!("bad resume-state magic");
    }
    let gagg_prev = c.f32s()?;
    let n = c.u32()?;
    let mut workers = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        workers.push(c.state(0)?);
    }
    let downlink = if c.i == bytes.len() {
        None // pre-PR 6 sidecar: no downlink section
    } else {
        if c.take(4)? != DLNK_MAGIC {
            bail!("bad downlink-state magic");
        }
        let rng = [c.u64()?, c.u64()?, c.u64()?, c.u64()?];
        let has_spare = c.u8()? != 0;
        let spare = c.f64()?;
        Some(DownlinkState { rng, gauss_spare: has_spare.then_some(spare) })
    };
    if c.i != bytes.len() {
        bail!("trailing bytes in resume state");
    }
    Ok(TrainState { gagg_prev, workers, downlink })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("regtopk_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let path = tmp("rt.json");
        let ck = Checkpoint::new(
            123,
            vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
            obj([("eta", Json::from(0.01))]),
        );
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(re, ck);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(path.with_extension("w")).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let path = tmp("bad.json");
        let ck = Checkpoint::new(1, vec![1.0; 16], Json::Null);
        ck.save(&path).unwrap();
        // flip a byte in the weight file
        let wpath = path.with_extension("w");
        let mut raw = std::fs::read(&wpath).unwrap();
        raw[5] ^= 0xFF;
        std::fs::write(&wpath, raw).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&wpath).ok();
    }

    #[test]
    fn truncation_is_detected() {
        let path = tmp("trunc.json");
        let ck = Checkpoint::new(1, vec![1.0; 16], Json::Null);
        ck.save(&path).unwrap();
        let wpath = path.with_extension("w");
        let raw = std::fs::read(&wpath).unwrap();
        std::fs::write(&wpath, &raw[..raw.len() - 4]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&wpath).ok();
    }

    #[test]
    fn state_sidecar_roundtrips_every_variant() {
        let ef = EfState {
            eps: vec![1.0, -2.5],
            acc_prev: vec![0.5, 0.0],
            mask_prev: vec![1.0, 0.0],
            warm: true,
        };
        let state = TrainState {
            gagg_prev: vec![0.25, -0.125, 3.0],
            workers: vec![
                SparsifierState::Stateless,
                SparsifierState::Ef(ef.clone()),
                SparsifierState::EfRng {
                    ef: ef.clone(),
                    rng: [1, u64::MAX, 3, 4],
                    gauss_spare: Some(-0.75),
                },
                SparsifierState::EfRng { ef: ef.clone(), rng: [9, 8, 7, 6], gauss_spare: None },
                SparsifierState::Dgc { vel: vec![1.0], acc: vec![-1.0] },
                SparsifierState::Residual { eps: vec![0.0, 4.0] },
                SparsifierState::Grouped(vec![
                    SparsifierState::Ef(ef.clone()),
                    SparsifierState::Stateless,
                ]),
                // quantizing groups (ISSUE 4): child state + rounding
                // stream, nested inside a grouped worker
                SparsifierState::Grouped(vec![SparsifierState::Quantized {
                    inner: Box::new(SparsifierState::Ef(ef.clone())),
                    rng: [2, 4, 6, 8],
                    gauss_spare: None,
                    auto_bits: None,
                }]),
                SparsifierState::Quantized {
                    inner: Box::new(SparsifierState::Dgc { vel: vec![0.5], acc: vec![1.5] }),
                    rng: [u64::MAX, 0, 1, 2],
                    gauss_spare: Some(0.25),
                    auto_bits: None,
                },
                // residual-steered width (ISSUE 5): the live auto
                // width rides tag 7
                SparsifierState::Quantized {
                    inner: Box::new(SparsifierState::Ef(ef.clone())),
                    rng: [3, 5, 7, 9],
                    gauss_spare: None,
                    auto_bits: Some(5),
                },
            ],
            downlink: None,
        };
        let bytes = encode_train_state(&state);
        assert_eq!(decode_train_state(&bytes).unwrap(), state);
        // truncation and garbage are errors, not panics
        assert!(decode_train_state(&bytes[..bytes.len() - 1]).is_err());
        assert!(decode_train_state(b"XXXX").is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(decode_train_state(&extra).is_err(), "trailing bytes");
        // downlink codec state (PR 6) rides an additive trailing section
        for spare in [Some(-1.25), None] {
            let with_dl = TrainState {
                downlink: Some(DownlinkState { rng: [11, 13, 17, 19], gauss_spare: spare }),
                ..state.clone()
            };
            let dl_bytes = encode_train_state(&with_dl);
            assert_eq!(decode_train_state(&dl_bytes).unwrap(), with_dl);
            assert_eq!(&dl_bytes[..bytes.len()], &bytes[..], "section is purely additive");
            assert!(decode_train_state(&dl_bytes[..dl_bytes.len() - 1]).is_err());
        }
    }

    #[test]
    fn downlink_free_sidecar_keeps_the_legacy_byte_format() {
        // a run without a downlink codec must write exactly the PR 5
        // bytes: magic + gagg_prev + worker count + worker states,
        // nothing after
        let state = TrainState {
            gagg_prev: vec![1.0, -2.0],
            workers: vec![SparsifierState::Stateless],
            downlink: None,
        };
        let bytes = encode_train_state(&state);
        let mut want = b"RTKS".to_vec();
        put_f32s(&mut want, &[1.0, -2.0]);
        put_u32(&mut want, 1);
        want.push(0); // Stateless tag
        assert_eq!(bytes, want);
    }

    #[test]
    fn checkpoint_with_state_roundtrips_on_disk() {
        let path = tmp("state.json");
        let state = TrainState {
            gagg_prev: vec![1.0, 2.0],
            workers: vec![SparsifierState::Ef(EfState {
                eps: vec![0.5, -0.5],
                acc_prev: vec![1.5, 2.5],
                mask_prev: vec![0.0, 1.0],
                warm: true,
            })],
            downlink: Some(DownlinkState { rng: [1, 2, 3, 4], gauss_spare: None }),
        };
        let ck = Checkpoint::with_state(7, vec![1.0, -1.0], Json::Null, state);
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(re, ck);
        // corrupt the state sidecar: load must fail loudly
        let spath = path.with_extension("ef");
        let mut raw = std::fs::read(&spath).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xFF;
        std::fs::write(&spath, &raw).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // missing sidecar while the header promises one: also an error
        std::fs::remove_file(&spath).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(path.with_extension("w")).ok();
    }

    #[test]
    fn legacy_checkpoint_without_state_still_loads() {
        let path = tmp("legacy.json");
        let ck = Checkpoint::new(3, vec![2.0; 4], Json::Null);
        ck.save(&path).unwrap();
        assert!(!path.with_extension("ef").exists(), "no sidecar for model-only saves");
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(re.state, None);
        assert_eq!(re, ck);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(path.with_extension("w")).ok();
    }

    #[test]
    fn crc_reference_value() {
        // "123456789" -> 0xCBF43926 (IEEE CRC-32 check value)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
