//! Worker-side state: model + sparsifier + gradient buffer + layout.

use crate::grad::{GradLayout, GradView};
use crate::models::GradModel;
use crate::comm::SparseUpdate;
use crate::sparse::SparseVec;
use crate::sparsify::{RoundCtx, Sparsifier};

/// One worker: computes the local gradient with its [`GradModel`] and
/// sparsifies it with its [`Sparsifier`].  The [`GradLayout`] carves
/// the flat gradient into parameter groups for the bucketed
/// [`Self::sparsify_into`] path; [`Worker::new`] installs the
/// degenerate single-group layout (the seed flat path, bit-identical).
pub struct Worker {
    pub id: usize,
    pub model: Box<dyn GradModel>,
    pub sparsifier: Box<dyn Sparsifier>,
    layout: GradLayout,
    grad: Vec<f32>,
    last_loss: f32,
}

impl Worker {
    pub fn new(id: usize, model: Box<dyn GradModel>, sparsifier: Box<dyn Sparsifier>) -> Self {
        let layout = GradLayout::single(model.dim());
        Self::with_layout(id, model, sparsifier, layout)
    }

    /// [`Self::new`] with an explicit parameter-group layout (must
    /// cover the model's full dimension).
    pub fn with_layout(
        id: usize,
        model: Box<dyn GradModel>,
        sparsifier: Box<dyn Sparsifier>,
        layout: GradLayout,
    ) -> Self {
        let dim = model.dim();
        assert_eq!(layout.total(), dim, "worker {id}: layout total != model dim");
        Worker { id, model, sparsifier, layout, grad: vec![0.0; dim], last_loss: f32::NAN }
    }

    pub fn dim(&self) -> usize {
        self.grad.len()
    }

    pub fn layout(&self) -> &GradLayout {
        &self.layout
    }

    pub fn last_loss(&self) -> f32 {
        self.last_loss
    }

    /// Phase 1: local gradient at the current global model.
    pub fn compute_grad(&mut self, w: &[f32]) -> f32 {
        self.last_loss = self.model.loss_grad(w, &mut self.grad);
        self.last_loss
    }

    /// Accumulated gradient a_n^t for the genie channel (gtopk only).
    pub fn peek_acc(&self) -> Vec<f32> {
        self.sparsifier.peek_acc(&self.grad)
    }

    /// [`Self::peek_acc`] into a caller buffer (no allocation).
    pub fn peek_acc_into(&self, out: &mut [f32]) {
        self.sparsifier.peek_acc_into(&self.grad, out);
    }

    /// Phase 2 (flat compatibility): sparsify the gradient computed in
    /// phase 1 into a flat [`SparseVec`].
    pub fn sparsify(&mut self, ctx: &RoundCtx) -> SparseVec {
        self.sparsifier.step(&self.grad, ctx)
    }

    /// Phase 2: sparsify into a recycled bucketed update (the
    /// trainer's zero-allocation round path).  One bucket per layout
    /// group; the single-group layout reproduces the flat wire format.
    pub fn sparsify_into(&mut self, ctx: &RoundCtx, out: &mut SparseUpdate) {
        let view = GradView::new(&self.layout, &self.grad);
        self.sparsifier.step_group_into(&view, ctx, out);
    }

    /// Allocating variant of [`Self::sparsify_into`] (threaded driver).
    pub fn sparsify_update(&mut self, ctx: &RoundCtx) -> SparseUpdate {
        let mut out = SparseUpdate::empty();
        self.sparsify_into(ctx, &mut out);
        out
    }

    /// Shard count for the sparsifier's internal kernels.
    pub fn set_shards(&mut self, shards: usize) {
        self.sparsifier.set_shards(shards);
    }

    /// Persistent sparsifier state for checkpointing.
    pub fn export_state(&self) -> crate::sparsify::SparsifierState {
        self.sparsifier.export_state()
    }

    /// Restore a previously exported sparsifier state (resume path).
    pub fn import_state(&mut self, st: &crate::sparsify::SparsifierState) -> Result<(), String> {
        self.sparsifier.import_state(st)
    }

    pub fn needs_genie(&self) -> bool {
        self.sparsifier.needs_genie()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::logistic::Logistic;
    use crate::sparsify::{build, SparsifierKind};

    #[test]
    fn grad_then_sparsify_roundtrip() {
        let model = Box::new(Logistic::toy_worker(vec![100.0, 1.0]));
        let sp = build(&SparsifierKind::TopK { k: 1 }, 2, 0);
        let mut w = Worker::new(0, model, sp);
        let loss = w.compute_grad(&[0.0, 1.0]);
        assert!(loss.is_finite() && loss > 0.0);
        let z = vec![0.0; 2];
        let ctx = RoundCtx { t: 0, gagg_prev: &z, omega: 0.5, genie_acc: None };
        let sv = w.sparsify(&ctx);
        assert_eq!(sv.nnz(), 1);
        assert_eq!(sv.indices(), &[0]); // |g[0]| = 100x |g[1]|
    }

    #[test]
    fn bucketed_sparsify_matches_flat_on_single_group() {
        let mk = || {
            Worker::new(
                0,
                Box::new(Logistic::toy_worker(vec![100.0, 1.0])),
                build(&SparsifierKind::TopK { k: 1 }, 2, 0),
            )
        };
        let mut flat = mk();
        let mut grouped = mk();
        flat.compute_grad(&[0.0, 1.0]);
        grouped.compute_grad(&[0.0, 1.0]);
        let z = vec![0.0; 2];
        let ctx = RoundCtx { t: 0, gagg_prev: &z, omega: 0.5, genie_acc: None };
        let sv = flat.sparsify(&ctx);
        let up = grouped.sparsify_update(&ctx);
        assert_eq!(up.num_buckets(), 1);
        assert_eq!(up.flatten(), sv);
    }
}
