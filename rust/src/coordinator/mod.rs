//! The L3 coordination layer: the paper's distributed-SGD system.
//!
//! Topology is a parameter-server star (paper §1): N workers compute
//! local gradients, sparsify (TOP-k / REGTOP-k / baselines), and send
//! sparse updates; the server aggregates g^t = sum_n omega_n ghat_n^t,
//! applies the optimizer to the global model, and broadcasts g^t back
//! (workers need g^{t-1} for the REGTOP-k posterior distortion — the
//! paper's footnote 1: broadcasting w^{t+1} is equivalent since
//! g^t = (w^t - w^{t+1}) / eta^t).
//!
//! Three drivers over the same [`Worker`]/[`Server`] state:
//! - [`Trainer::run`]           — deterministic single-threaded rounds
//!   (reference semantics; all experiments and tests use this).
//! - [`Trainer::run_threaded`]  — per-worker lanes fanned out on the
//!   persistent pool's executors over the in-process
//!   [`crate::comm::InProc`] star (no `thread::spawn` per run).
//! - [`Trainer::run_transport`] — server loop over any
//!   [`crate::comm::Transport`]; with the [`crate::comm::Tcp`]
//!   backend each worker runs [`serve_worker`] behind a framed
//!   socket, as a loopback thread or a separate OS process
//!   (`repro worker --connect`).
//!
//! All three are bit-identical (verified in tests) because gathers
//! are ordered by worker id and the aggregation path is shared.

#![forbid(unsafe_code)]

mod checkpoint;
mod downlink;
mod server;
mod trainer;
mod worker;

pub use checkpoint::{Checkpoint, DownlinkState, TrainState};
pub use downlink::{DownlinkCodec, GaggMirror};
pub use server::{merge_updates, Server};
pub use trainer::{serve_worker, EvalFn, RoundResult, Trainer};
pub use worker::Worker;
