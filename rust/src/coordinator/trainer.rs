//! The synchronous-round driver tying workers, server, transport and
//! metrics together.

use std::time::Instant;

use crate::comm::{InProc, Ledger, Msg, SocketCounters, Tcp, TcpLink, Transport, WorkerLink};
use crate::config::TrainConfig;
use crate::coordinator::{DownlinkCodec, GaggMirror, Server, Worker};
use crate::metrics::{IterRecord, RunLog};
use crate::comm::SparseUpdate;
use crate::sparsify::RoundCtx;

/// Optional per-evaluation callback: `(iter, w, record)` — fills
/// opt_gap / accuracy on the record (e.g. ||w - w*|| for Fig. 2, val
/// accuracy via the PJRT eval artifact for Fig. 3).
pub type EvalFn<'a> = dyn FnMut(usize, &[f32], &mut IterRecord) + 'a;

/// Result of one synchronous round.
#[derive(Clone, Copy, Debug)]
pub struct RoundResult {
    pub t: usize,
    pub mean_loss: f32,
    pub upload_bytes: usize,
}

/// Synchronous distributed-SGD trainer.
pub struct Trainer {
    pub config: TrainConfig,
    pub workers: Vec<Worker>,
    pub server: Server,
    pub ledger: Ledger,
    /// g^{t-1} broadcast to workers (zeros before the first round)
    gagg_prev: Vec<f32>,
    /// per-worker bucketed update buffers, recycled every round (zero
    /// steady-state allocation on the sparsify path)
    updates: Vec<SparseUpdate>,
    /// genie-channel scratch (allocated lazily, only for gtopk runs)
    genie_buf: Vec<f32>,
    peek_buf: Vec<f32>,
    /// per-group learning-rate scales from the policy table (None =
    /// the exact pre-scaling server path)
    eta_scales: Option<Vec<(usize, usize, f32)>>,
    /// downlink codec from `config.downlink` (None = dense broadcast,
    /// bit-identical to the pre-PR 6 path)
    downlink: Option<DownlinkCodec>,
    t: usize,
}

impl Trainer {
    pub fn new(config: TrainConfig, mut workers: Vec<Worker>, server: Server) -> Self {
        assert_eq!(config.workers, workers.len(), "config.workers mismatch");
        let dim = server.dim();
        for w in &workers {
            assert_eq!(w.dim(), dim, "worker {} dim mismatch", w.id);
        }
        // wire the configured shard count into every sparsifier; small
        // models and shards=1 keep the seed's serial path
        let shards = config.effective_shards(dim);
        for w in &mut workers {
            w.set_shards(shards);
        }
        let mut ledger = Ledger::new(config.cost);
        // per-group upload accounting follows the workers' layout
        if let Some(w0) = workers.first() {
            ledger.set_layout(w0.layout());
        }
        let updates = (0..workers.len()).map(|_| SparseUpdate::empty()).collect();
        let eta_scales = config.eta_scales(dim);
        let downlink = config.downlink.as_ref().map(|table| {
            assert!(
                !server.force_dense,
                "downlink compression needs the sparse aggregation path \
                 (server.force_dense must stay false)"
            );
            let layout = workers
                .first()
                .map(|w| w.layout().clone())
                .unwrap_or_else(|| crate::grad::GradLayout::single(dim));
            DownlinkCodec::new(table, &layout, config.seed)
        });
        Trainer {
            config,
            workers,
            server,
            ledger,
            gagg_prev: vec![0.0; dim],
            updates,
            genie_buf: Vec::new(),
            peek_buf: Vec::new(),
            eta_scales,
            downlink,
            t: 0,
        }
    }

    /// Post-aggregate bookkeeping shared by both drivers: encode the
    /// downlink broadcast when configured (AFTER the optimizer step,
    /// so the model always steps on the exact aggregate), refresh
    /// `gagg_prev` with exactly what workers will decode, and close
    /// the ledger round under the matching byte accounting.
    fn finish_round(&mut self, t: usize, dim: usize, n: usize) {
        match &mut self.downlink {
            None => {
                self.gagg_prev.copy_from_slice(&self.server.gagg);
                self.ledger.close_round(t, dim, n);
            }
            Some(dl) => {
                // encode mutates the sparse aggregate into its decoded
                // form and re-scatters it into the dense mirror, so the
                // copy below IS the decoded broadcast
                self.server.encode_gagg_with(|up| dl.encode(up, t));
                self.ledger.close_round_sparse(t, self.server.gagg_sparse(), n);
                self.gagg_prev.copy_from_slice(&self.server.gagg);
            }
        }
    }

    pub fn iter(&self) -> usize {
        self.t
    }

    /// The config echo written into every run manifest: the config's
    /// JSON plus — for grouped runs — a `"resolved"` array surfacing
    /// what each group ACTUALLY runs after policy/budget/shard
    /// resolution: family, budget k, engine shards, value bits and
    /// the learning-rate scale (ROADMAP follow-up: manifests must not
    /// make the reader re-derive the heterogeneous setup).
    pub fn config_echo(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let mut j = self.config.to_json();
        let Some(w0) = self.workers.first() else {
            return j;
        };
        let sp = &w0.sparsifier;
        let budgets = sp.group_budgets();
        if budgets.is_empty() {
            return j; // flat run: nothing grouped to resolve
        }
        let families = sp.group_families();
        let shards = sp.group_shards();
        let bits = sp.group_value_bits();
        let bits_end = sp.group_value_bits_end();
        let idx_codecs = sp.group_index_codecs();
        let levels = sp.group_value_levels();
        let layout = w0.layout();
        let resolved: Vec<Json> = layout
            .groups()
            .iter()
            .enumerate()
            .map(|(g, spec)| {
                let eta = self
                    .eta_scales
                    .as_ref()
                    .and_then(|sc| sc.get(g))
                    .map_or(1.0, |&(_, _, s)| s);
                let b0 = bits.get(g).copied().unwrap_or(32);
                let b1 = bits_end.get(g).copied().unwrap_or(32);
                let mut o = obj([
                    ("name", spec.name.as_str().into()),
                    ("family", families.get(g).copied().unwrap_or("?").into()),
                    ("k", budgets.get(g).copied().unwrap_or(0).into()),
                    ("shards", shards.get(g).copied().unwrap_or(1).into()),
                    ("bits", b0.into()),
                    ("idx", idx_codecs.get(g).copied().unwrap_or("packed").into()),
                    ("levels", levels.get(g).copied().unwrap_or("f32").into()),
                    ("eta_scale", (eta as f64).into()),
                ]);
                // scheduled widths: also echo where the schedule lands
                if b1 != b0 {
                    if let Json::Obj(m) = &mut o {
                        m.insert("bits_end".to_string(), b1.into());
                    }
                }
                o
            })
            .collect();
        if let Json::Obj(m) = &mut j {
            m.insert("resolved".to_string(), Json::Arr(resolved));
        }
        j
    }

    /// Snapshot the current training state: model + cursor + the full
    /// resume state (previous aggregate and every worker's sparsifier
    /// history), so a restored run continues the trajectory instead of
    /// cold-restarting error feedback.
    pub fn checkpoint(&self) -> crate::coordinator::Checkpoint {
        let state = crate::coordinator::TrainState {
            gagg_prev: self.gagg_prev.clone(),
            workers: self.workers.iter().map(Worker::export_state).collect(),
            downlink: self.downlink.as_ref().map(|d| {
                let (rng, gauss_spare) = d.rng_state();
                crate::coordinator::DownlinkState { rng, gauss_spare }
            }),
        };
        crate::coordinator::Checkpoint::with_state(
            self.t,
            self.server.w.clone(),
            self.config.to_json(),
            state,
        )
    }

    /// Restore model + cursor from a checkpoint.  When the checkpoint
    /// carries resume state (every checkpoint this trainer writes
    /// does), `g^{t-1}` and each worker's error-feedback/sparsifier
    /// history are restored too, making the resumed trajectory
    /// bit-identical to an uninterrupted run; a legacy model-only
    /// checkpoint restores cold as before.
    pub fn restore(&mut self, ck: &crate::coordinator::Checkpoint) {
        assert_eq!(ck.w.len(), self.server.dim(), "checkpoint dim mismatch");
        self.server.w.copy_from_slice(&ck.w);
        self.t = ck.iter;
        if let Some(st) = &ck.state {
            assert_eq!(
                st.gagg_prev.len(),
                self.server.dim(),
                "resume-state aggregate dim mismatch"
            );
            assert_eq!(
                st.workers.len(),
                self.workers.len(),
                "resume-state worker count mismatch"
            );
            self.gagg_prev.copy_from_slice(&st.gagg_prev);
            for (w, s) in self.workers.iter_mut().zip(&st.workers) {
                let id = w.id;
                w.import_state(s)
                    .unwrap_or_else(|e| panic!("restoring worker {id}: {e}"));
            }
            match (&mut self.downlink, &st.downlink) {
                (Some(d), Some(s)) => d.restore_rng(s.rng, s.gauss_spare),
                (None, Some(_)) => panic!(
                    "checkpoint carries downlink codec state but this run has no downlink table"
                ),
                // checkpoint from a downlink-free (or pre-PR 6) run:
                // the rounding stream restarts cold, like the legacy
                // model-only restore
                _ => {}
            }
        }
    }

    /// One synchronous round (deterministic reference driver).
    pub fn round(&mut self) -> RoundResult {
        let t = self.t;
        let n = self.workers.len();
        let dim = self.server.dim();
        // Phase 1: local gradients at the current global model.
        // Fanned out over the persistent pool when the model is heavy
        // enough to amortize the handoff (perf pass, EXPERIMENTS.md
        // §Perf) — the pool replaces the seed's per-round
        // `thread::scope`, so no OS threads are created per round;
        // results are per-worker so the aggregate stays bit-identical
        // to the sequential order.
        let mut loss_sum = 0.0f64;
        if n > 1 && dim >= 4096 {
            let w_ref = &self.server.w;
            let losses: Vec<f32> =
                crate::util::pool::global().map_mut(&mut self.workers, |_, w| {
                    w.compute_grad(w_ref)
                });
            loss_sum = losses.iter().map(|&l| l as f64).sum();
        } else {
            for w in &mut self.workers {
                loss_sum += w.compute_grad(&self.server.w) as f64;
            }
        }
        // Genie side-channel for gtopk: true aggregated accumulated
        // gradient sum_n omega_n a_n^t (infeasible in practice, §3.1).
        // Buffers are lazily sized and reused across rounds.
        let genie: Option<&[f32]> = if self.workers.iter().any(Worker::needs_genie) {
            self.genie_buf.resize(dim, 0.0);
            self.peek_buf.resize(dim, 0.0);
            self.genie_buf.fill(0.0);
            for (i, w) in self.workers.iter().enumerate() {
                let omega = self.config.omega(i);
                w.peek_acc_into(&mut self.peek_buf);
                for (a, &v) in self.genie_buf.iter_mut().zip(&self.peek_buf) {
                    *a += omega * v;
                }
            }
            Some(&self.genie_buf)
        } else {
            None
        };
        // Phase 2: sparsify + "transmit" (ledger accounting), each
        // worker writing into its recycled bucketed update buffer.
        for (i, w) in self.workers.iter_mut().enumerate() {
            let ctx = RoundCtx {
                t,
                gagg_prev: &self.gagg_prev,
                omega: self.config.omega(i),
                genie_acc: genie,
            };
            w.sparsify_into(&ctx, &mut self.updates[i]);
            self.ledger.record_update(&self.updates[i]);
        }
        // Phase 3: aggregate, step, broadcast.
        let weighted: Vec<(f32, &SparseUpdate)> = self
            .updates
            .iter()
            .enumerate()
            .map(|(i, up)| (self.config.omega(i), up))
            .collect();
        self.server.aggregate_and_step_scaled(&weighted, t, self.eta_scales.as_deref());
        self.finish_round(t, dim, n);
        self.t += 1;
        RoundResult {
            t,
            mean_loss: (loss_sum / n as f64) as f32,
            upload_bytes: self.ledger.rounds().last().unwrap().upload_bytes,
        }
    }

    /// Run `iters` rounds, logging per-round records and evaluating
    /// every `config.eval_every` rounds (and at the final round).
    pub fn run(&mut self, iters: usize, mut eval: Option<&mut EvalFn>) -> RunLog {
        let mut log = RunLog::new(
            format!("{}-{}", self.workers[0].sparsifier.name(), self.config.seed),
            self.config_echo(),
        );
        for i in 0..iters {
            // wall_time_s is a reported metric, never an input to the
            // trajectory — repro-lint: allow(wall-clock)
            let t0 = Instant::now();
            let rr = self.round();
            let mut rec = IterRecord::new(rr.t);
            rec.loss = rr.mean_loss;
            rec.upload_bytes = rr.upload_bytes;
            rec.sim_time_s = self.ledger.rounds().last().unwrap().sim_time_s;
            rec.wall_time_s = t0.elapsed().as_secs_f64();
            let is_eval = self.config.eval_every > 0
                && (rr.t % self.config.eval_every == 0 || i + 1 == iters);
            if is_eval {
                if let Some(f) = eval.as_deref_mut() {
                    f(rr.t, &self.server.w, &mut rec);
                }
            }
            log.push(rec);
        }
        log
    }

    /// Threaded driver: workers exchange [`Msg`]s with the server over
    /// the in-process star [`InProc`], with the per-worker round body
    /// fanned out on the persistent pool's executors (no
    /// `thread::spawn` per run — the seed spawned one OS thread per
    /// worker per call).  Each lane owns its [`WorkerLink`] and
    /// model/aggregate buffers across rounds, so the message protocol
    /// is identical to a long-lived worker thread's.  Produces a
    /// bit-identical model trajectory to [`Trainer::run`] because the
    /// gather orders updates by worker id.  Genie sparsifiers are not
    /// supported here (they need a global side-channel).
    pub fn run_threaded(&mut self, iters: usize) -> RunLog {
        assert!(
            !self.workers.iter().any(Worker::needs_genie),
            "gtopk requires the deterministic driver"
        );
        let n = self.workers.len();
        let dim = self.server.dim();
        let mut net = InProc::star(n);
        let mut log = RunLog::new(
            format!("{}-threaded", self.workers[0].sparsifier.name()),
            self.config_echo(),
        );
        /// Per-worker execution lane: everything one pooled task needs.
        struct Lane {
            worker: Worker,
            link: crate::comm::InProcLink,
            w_model: Vec<f32>,
            /// dense g^{t-1}, reconstructed from whichever broadcast
            /// form the server sent
            mirror: GaggMirror,
            omega: f32,
        }
        let omegas: Vec<f32> = (0..n).map(|i| self.config.omega(i)).collect();
        let mut lanes: Vec<Lane> = self
            .workers
            .drain(..)
            .enumerate()
            .map(|(i, worker)| Lane {
                link: net.link(i),
                w_model: vec![0.0f32; dim],
                mirror: GaggMirror::new(dim),
                omega: omegas[i],
                worker,
            })
            .collect();
        let mut bcast = vec![0.0f32; 2 * dim];
        for t in 0..iters {
            if self.downlink.is_none() || t == 0 {
                // dense broadcast, layout [w | gagg_prev].  The first
                // round is dense even under a downlink codec: after a
                // resume the restored g^{t-1} exists only densely, and
                // on a cold start it is all zeros either way.
                bcast[..dim].copy_from_slice(&self.server.w);
                bcast[dim..].copy_from_slice(&self.gagg_prev);
                net.broadcast(&Msg::Broadcast { round: t, gagg: bcast.clone() });
            } else {
                net.broadcast(&Msg::SparseBroadcast {
                    round: t,
                    w: self.server.w.clone(),
                    gagg: self.server.gagg_sparse().clone(),
                });
            }
            // worker phase on the pool: each lane drains its own link
            // (the broadcast is already queued, so no task blocks on
            // another), computes, sparsifies, sends up
            crate::util::pool::global().map_mut(&mut lanes, |i, lane| {
                match lane.link.recv().expect("server gone") {
                    Msg::Broadcast { round, gagg } => {
                        assert_eq!(round, t);
                        lane.w_model.copy_from_slice(&gagg[..dim]);
                        lane.mirror.copy_dense(&gagg[dim..]);
                    }
                    Msg::SparseBroadcast { round, w, gagg } => {
                        assert_eq!(round, t);
                        lane.w_model.copy_from_slice(&w);
                        lane.mirror.apply(&gagg);
                    }
                    m @ Msg::Update { .. } => panic!("worker {i}: unexpected {m:?}"),
                }
                let loss = lane.worker.compute_grad(&lane.w_model);
                let ctx = RoundCtx {
                    t,
                    gagg_prev: lane.mirror.dense(),
                    omega: lane.omega,
                    genie_acc: None,
                };
                let up = lane.worker.sparsify_update(&ctx);
                lane.link.send(&Msg::Update { worker: i, round: t, update: up, loss });
            });
            // server phase: gather (ordered by worker id), aggregate
            let msgs = net.gather_round(n, t);
            let mut updates = Vec::with_capacity(n);
            let mut loss_sum = 0.0f64;
            for m in msgs {
                if let Msg::Update { update, loss, .. } = m {
                    loss_sum += loss as f64;
                    self.ledger.record_update(&update);
                    updates.push(update);
                }
            }
            let weighted: Vec<(f32, &SparseUpdate)> =
                updates.iter().enumerate().map(|(i, up)| (omegas[i], up)).collect();
            self.server.aggregate_and_step_scaled(&weighted, t, self.eta_scales.as_deref());
            self.finish_round(t, dim, n);
            let mut rec = IterRecord::new(t);
            rec.loss = (loss_sum / n as f64) as f32;
            rec.upload_bytes = self.ledger.rounds().last().unwrap().upload_bytes;
            rec.sim_time_s = self.ledger.rounds().last().unwrap().sim_time_s;
            log.push(rec);
        }
        // reclaim workers (lanes preserve id order)
        self.workers = lanes.into_iter().map(|l| l.worker).collect();
        self.t += iters;
        log
    }

    /// Server loop over any [`Transport`]: broadcast the bootstrap
    /// state (round 0, always dense), then per round gather →
    /// aggregate → step → broadcast the next round's state.  Workers
    /// live on the far side of the transport running [`serve_worker`]
    /// — pool lanes over an in-process star, or threads/OS processes
    /// over framed sockets — so `self.workers` is unused (and may be
    /// drained) for the duration.  The trajectory is bit-identical to
    /// [`Trainer::run`] / [`Trainer::run_threaded`] because gathers
    /// are ordered by worker id and the aggregation path is shared.
    ///
    /// On byte-moving transports ([`Transport::counters`] is `Some`)
    /// every round asserts the socket wire-byte deltas equal the
    /// ledger's charged bytes — measured traffic IS the accounted
    /// traffic — whenever the link model uses the paper's 32-bit
    /// value format (other widths model hypothetical links narrower
    /// than the real f32 frames, so only the ledger scales).
    pub fn run_transport(&mut self, net: &mut dyn Transport, iters: usize) -> RunLog {
        let n = self.config.workers;
        let dim = self.server.dim();
        let mut log = RunLog::new(
            format!("{}-transport", self.config.sparsifier.name()),
            self.config_echo(),
        );
        let mut bcast = vec![0.0f32; 2 * dim];
        let mut dense_bcast = |server: &Server, gagg_prev: &[f32], round: usize| {
            bcast[..dim].copy_from_slice(&server.w);
            bcast[dim..].copy_from_slice(gagg_prev);
            Msg::Broadcast { round, gagg: bcast.clone() }
        };
        // bootstrap broadcast b(0): always dense (g^{-1} exists only
        // densely — zeros cold, restored state after a resume); the
        // ledger never charges it, so the counters exclude it and
        // cover exactly the charged span
        net.broadcast(&dense_bcast(&self.server, &self.gagg_prev, 0));
        net.reset_counters();
        let mut wire_prev = net.counters();
        for t in 0..iters {
            let msgs = net.gather_round(n, t);
            let mut updates = Vec::with_capacity(n);
            let mut loss_sum = 0.0f64;
            for m in msgs {
                if let Msg::Update { update, loss, .. } = m {
                    loss_sum += loss as f64;
                    self.ledger.record_update(&update);
                    updates.push(update);
                }
            }
            let weighted: Vec<(f32, &SparseUpdate)> = updates
                .iter()
                .enumerate()
                .map(|(i, up)| (self.config.omega(i), up))
                .collect();
            self.server.aggregate_and_step_scaled(&weighted, t, self.eta_scales.as_deref());
            self.finish_round(t, dim, n);
            // b(t+1) carries the state round t produced — the ledger
            // charged it to round t, so the socket comparison below
            // includes this send
            if self.downlink.is_none() {
                net.broadcast(&dense_bcast(&self.server, &self.gagg_prev, t + 1));
            } else {
                net.broadcast(&Msg::SparseBroadcast {
                    round: t + 1,
                    w: self.server.w.clone(),
                    gagg: self.server.gagg_sparse().clone(),
                });
            }
            let rt = *self.ledger.rounds().last().unwrap();
            if let (Some(prev), Some(now)) = (wire_prev, net.counters()) {
                if self.ledger.cost.value_bits == 32 {
                    assert_eq!(
                        (now.recv_wire - prev.recv_wire) as usize,
                        rt.upload_bytes,
                        "round {t}: socket upload bytes != ledger-charged bytes"
                    );
                    assert_eq!(
                        (now.sent_wire - prev.sent_wire) as usize,
                        rt.download_bytes,
                        "round {t}: socket download bytes != ledger-charged bytes"
                    );
                }
                wire_prev = Some(now);
            }
            let mut rec = IterRecord::new(t);
            rec.loss = (loss_sum / n as f64) as f32;
            rec.upload_bytes = rt.upload_bytes;
            rec.sim_time_s = rt.sim_time_s;
            log.push(rec);
        }
        self.t += iters;
        log
    }

    /// Networked driver, loopback form: bind a TCP star, run every
    /// worker as a [`serve_worker`] loop on its own OS thread behind
    /// a [`TcpLink`], and drive the server with
    /// [`Trainer::run_transport`].  Every message crosses a real
    /// socket as framed bytes — the same path `repro train
    /// --transport tcp` exercises with worker *processes* — and the
    /// trajectory stays bit-identical to the in-process drivers.
    pub fn run_tcp_loopback(&mut self, iters: usize) -> RunLog {
        self.run_tcp_loopback_counted(iters).0
    }

    /// [`Trainer::run_tcp_loopback`] plus the server-side
    /// [`SocketCounters`], for callers that report measured socket
    /// traffic next to the ledger's charged bytes (`repro comm`).
    pub fn run_tcp_loopback_counted(&mut self, iters: usize) -> (RunLog, SocketCounters) {
        assert!(
            !self.workers.iter().any(Worker::needs_genie),
            "gtopk requires the deterministic driver"
        );
        let mut net = Tcp::bind().expect("tcp bind");
        let addr = net.addr().to_string();
        let omegas: Vec<f32> = (0..self.workers.len()).map(|i| self.config.omega(i)).collect();
        // long-lived per-worker loops can't run on the pool (its
        // executors must stay available to other callers), so this is
        // genuinely a thread-per-worker driver
        // repro-lint: allow(spawn-outside-pool)
        let handles: Vec<_> = self
            .workers
            .drain(..)
            .zip(omegas)
            .map(|(worker, omega)| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let id = worker.id;
                    let mut link = TcpLink::connect(&addr, id).expect("worker connect");
                    serve_worker(worker, &mut link, omega, iters)
                })
            })
            .collect();
        net.accept(handles.len()).expect("tcp accept");
        let log = self.run_transport(&mut net, iters);
        let counters = net.counters().expect("tcp counts bytes");
        // reclaim workers in id order (threads were spawned in order)
        self.workers = handles
            .into_iter()
            .map(|h| h.join().expect("worker thread"))
            .collect();
        (log, counters)
    }
}

/// The worker side of a transport-driven run: consume `rounds`
/// broadcasts over `link`, answering each with a sparsified update.
/// This is the loop a separate worker *process* runs (`repro worker
/// --connect`), and what [`Trainer::run_tcp_loopback`] runs per
/// thread; the message protocol — and therefore the trajectory — is
/// identical to [`Trainer::run_threaded`]'s pooled lanes.  Returns
/// the worker (with its accumulated sparsifier state) so loopback
/// callers can reclaim it.
pub fn serve_worker(
    mut worker: Worker,
    link: &mut dyn WorkerLink,
    omega: f32,
    rounds: usize,
) -> Worker {
    let dim = worker.dim();
    let mut w_model = vec![0.0f32; dim];
    let mut mirror = GaggMirror::new(dim);
    let id = worker.id;
    for t in 0..rounds {
        match link.recv().expect("server gone") {
            Msg::Broadcast { round, gagg } => {
                assert_eq!(round, t, "worker {id}: broadcast out of order");
                w_model.copy_from_slice(&gagg[..dim]);
                mirror.copy_dense(&gagg[dim..]);
            }
            Msg::SparseBroadcast { round, w, gagg } => {
                assert_eq!(round, t, "worker {id}: broadcast out of order");
                w_model.copy_from_slice(&w);
                mirror.apply(&gagg);
            }
            m @ Msg::Update { .. } => panic!("worker {id}: unexpected {m:?}"),
        }
        let loss = worker.compute_grad(&w_model);
        let ctx = RoundCtx { t, gagg_prev: mirror.dense(), omega, genie_acc: None };
        let up = worker.sparsify_update(&ctx);
        link.send(&Msg::Update { worker: id, round: t, update: up, loss });
    }
    // the server closes every round with a broadcast; consume the
    // final one so its socket write can't race our disconnect
    if let Some(m) = link.recv() {
        match m {
            Msg::Broadcast { round, .. } | Msg::SparseBroadcast { round, .. } => {
                assert_eq!(round, rounds, "worker {id}: trailing broadcast out of order");
            }
            m @ Msg::Update { .. } => panic!("worker {id}: unexpected {m:?}"),
        }
    }
    worker
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::logistic::Logistic;
    use crate::optim::Sgd;
    use crate::sparsify::{build, SparsifierKind};

    fn toy_trainer(kind: SparsifierKind, eta: f32) -> Trainer {
        toy_trainer_with_downlink(kind, eta, None)
    }

    fn toy_trainer_with_downlink(
        kind: SparsifierKind,
        eta: f32,
        downlink: Option<&str>,
    ) -> Trainer {
        let config = TrainConfig {
            workers: 2,
            iters: 0,
            eta,
            sparsifier: kind.clone(),
            omega_uniform: true,
            seed: 0,
            eval_every: 0,
            downlink: downlink.map(|s| crate::sparsify::PolicyTable::parse(s).unwrap()),
            ..TrainConfig::default()
        };
        let workers = vec![
            Worker::new(0, Box::new(Logistic::toy_worker(vec![100.0, 1.0])), build(&kind, 2, 0)),
            Worker::new(1, Box::new(Logistic::toy_worker(vec![-100.0, 1.0])), build(&kind, 2, 1)),
        ];
        let server = Server::new(vec![0.0, 1.0], Box::new(Sgd::new(eta)));
        Trainer::new(config, workers, server)
    }

    #[test]
    fn toy_top1_stalls_regtop1_moves() {
        let mut top = toy_trainer(SparsifierKind::TopK { k: 1 }, 0.9);
        for _ in 0..20 {
            top.round();
        }
        assert_eq!(top.server.w, vec![0.0, 1.0], "TOP-1 must stall at w0");

        let mut reg = toy_trainer(SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 }, 0.9);
        for _ in 0..20 {
            reg.round();
        }
        assert!(reg.server.w[1] > 1.0, "REGTOP-1 must move theta_2: {:?}", reg.server.w);
    }

    #[test]
    fn dense_matches_manual_gd() {
        let mut tr = toy_trainer(SparsifierKind::Dense, 0.9);
        let rr = tr.round();
        assert!(rr.mean_loss > 0.0);
        // manual: g = 0.5(g1+g2); first entries cancel; second entries
        // equal -sigma(-1) each
        let s = 1.0 / (1.0 + 1f64.exp());
        let expect_w1 = 1.0 + 0.9 * s as f32;
        assert!((tr.server.w[1] - expect_w1).abs() < 1e-6);
        assert_eq!(tr.server.w[0], 0.0);
    }

    #[test]
    fn ledger_counts_rounds_and_bytes() {
        let mut tr = toy_trainer(SparsifierKind::TopK { k: 1 }, 0.9);
        tr.round();
        tr.round();
        assert_eq!(tr.ledger.rounds().len(), 2);
        // 2 workers x 1 entry x (32+1 index bits for J=2)/8 -> 5 bytes each
        assert_eq!(tr.ledger.rounds()[0].upload_entries, 2);
        assert!(tr.ledger.rounds()[0].upload_bytes > 0);
        // single-group layout: one "all" group carries everything
        let groups = tr.ledger.group_upload_totals();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].0, "all");
        assert_eq!(groups[0].1, tr.ledger.total_upload_bytes());
    }

    #[test]
    fn run_produces_log_with_eval() {
        let mut tr = toy_trainer(SparsifierKind::Dense, 0.5);
        tr.config.eval_every = 2;
        let mut eval_calls = 0;
        let mut eval = |_t: usize, w: &[f32], rec: &mut IterRecord| {
            eval_calls += 1;
            rec.opt_gap = w[1];
        };
        let log = tr.run(5, Some(&mut eval));
        assert_eq!(log.records().len(), 5);
        assert!(eval_calls >= 2);
        assert!(log.records()[0].loss.is_finite());
    }

    #[test]
    fn config_echo_resolves_groups_when_grouped() {
        use crate::grad::GradLayout;
        let flat = toy_trainer(SparsifierKind::TopK { k: 1 }, 0.9);
        assert!(flat.config_echo().get("resolved").is_none(), "flat run has no resolution");
        // grouped trainer: two one-element groups over the toy model
        let kind = SparsifierKind::TopK { k: 1 };
        let layout =
            GradLayout::from_sizes([("w".to_string(), 1), ("b".to_string(), 1)]);
        let config = TrainConfig {
            workers: 2,
            eta: 0.9,
            sparsifier: kind.clone(),
            eval_every: 0,
            groups: Some(layout.clone()),
            policy: Some(crate::sparsify::PolicyTable::parse("b=dense:eta=2.0").unwrap()),
            ..TrainConfig::default()
        };
        let workers = vec![
            crate::coordinator::Worker::with_layout(
                0,
                Box::new(Logistic::toy_worker(vec![100.0, 1.0])),
                config.build_sparsifier(2, 0),
                layout.clone(),
            ),
            crate::coordinator::Worker::with_layout(
                1,
                Box::new(Logistic::toy_worker(vec![-100.0, 1.0])),
                config.build_sparsifier(2, 1),
                layout.clone(),
            ),
        ];
        let server = Server::new(vec![0.0, 1.0], Box::new(Sgd::new(0.9)));
        let tr = Trainer::new(config, workers, server);
        let echo = tr.config_echo();
        let resolved = echo.get("resolved").and_then(|r| r.as_arr().map(<[_]>::to_vec));
        let resolved = resolved.expect("grouped run must echo a resolution");
        assert_eq!(resolved.len(), 2);
        assert_eq!(resolved[0].get("family").and_then(|j| j.as_str()), Some("topk"));
        assert_eq!(resolved[1].get("family").and_then(|j| j.as_str()), Some("dense"));
        assert_eq!(resolved[1].get("eta_scale").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(resolved[0].get("bits").and_then(|j| j.as_usize()), Some(32));
    }

    #[test]
    fn threaded_driver_matches_deterministic() {
        for kind in [
            SparsifierKind::TopK { k: 1 },
            SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 },
            SparsifierKind::Dense,
        ] {
            let mut a = toy_trainer(kind.clone(), 0.9);
            for _ in 0..15 {
                a.round();
            }
            let mut b = toy_trainer(kind.clone(), 0.9);
            b.run_threaded(15);
            assert_eq!(a.server.w, b.server.w, "{kind:?}");
            assert_eq!(
                a.ledger.total_upload_bytes(),
                b.ledger.total_upload_bytes(),
                "{kind:?}"
            );
        }
    }

    #[test]
    fn lossless_downlink_preserves_the_trajectory_bitwise() {
        // downlink "*=" reindexes the exact aggregate: every worker
        // decodes bit-identical g^{t-1}, so the whole trajectory
        // matches the dense-broadcast run — only the ledger's download
        // accounting changes (sparse wire cost vs dense 32J formula)
        let kind = SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 };
        let mut dense = toy_trainer(kind.clone(), 0.9);
        let mut sparse = toy_trainer_with_downlink(kind, 0.9, Some("*="));
        for _ in 0..12 {
            dense.round();
            sparse.round();
        }
        assert_eq!(dense.server.w, sparse.server.w);
        assert_eq!(dense.ledger.total_upload_bytes(), sparse.ledger.total_upload_bytes());
        assert_ne!(
            dense.ledger.total_download_bytes(),
            sparse.ledger.total_download_bytes(),
            "downlink rounds must be charged at sparse wire cost"
        );
    }

    #[test]
    fn threaded_driver_matches_deterministic_with_downlink() {
        for spec in ["*=", "*=:idx=rice", "*=:bits=8"] {
            let kind = SparsifierKind::TopK { k: 1 };
            let mut a = toy_trainer_with_downlink(kind.clone(), 0.9, Some(spec));
            for _ in 0..15 {
                a.round();
            }
            let mut b = toy_trainer_with_downlink(kind, 0.9, Some(spec));
            b.run_threaded(15);
            assert_eq!(a.server.w, b.server.w, "downlink {spec}");
            assert_eq!(a.gagg_prev, b.gagg_prev, "downlink {spec}");
            assert_eq!(
                a.ledger.total_download_bytes(),
                b.ledger.total_download_bytes(),
                "downlink {spec}"
            );
        }
    }

    #[test]
    fn tcp_loopback_driver_matches_deterministic() {
        // framed sockets end-to-end: same trajectory, same ledger, and
        // run_transport's per-round socket==ledger asserts all hold
        let kind = SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 };
        let mut a = toy_trainer(kind.clone(), 0.9);
        for _ in 0..10 {
            a.round();
        }
        let mut b = toy_trainer(kind, 0.9);
        let log = b.run_tcp_loopback(10);
        assert_eq!(a.server.w, b.server.w);
        assert_eq!(a.ledger.total_upload_bytes(), b.ledger.total_upload_bytes());
        assert_eq!(a.ledger.total_download_bytes(), b.ledger.total_download_bytes());
        assert_eq!(log.records().len(), 10);
        // workers reclaimed in id order, cursor advanced
        assert_eq!(b.workers.len(), 2);
        assert_eq!(b.workers[0].id, 0);
        assert_eq!(b.iter(), 10);
    }

    #[test]
    fn tcp_loopback_driver_matches_deterministic_with_downlink() {
        // sparse broadcasts cross the socket too (frame kind 2), and
        // the download side of the socket==ledger assert covers them
        for spec in ["*=", "*=:bits=8"] {
            let kind = SparsifierKind::TopK { k: 1 };
            let mut a = toy_trainer_with_downlink(kind.clone(), 0.9, Some(spec));
            for _ in 0..10 {
                a.round();
            }
            let mut b = toy_trainer_with_downlink(kind, 0.9, Some(spec));
            b.run_tcp_loopback(10);
            assert_eq!(a.server.w, b.server.w, "downlink {spec}");
            assert_eq!(a.gagg_prev, b.gagg_prev, "downlink {spec}");
            assert_eq!(
                a.ledger.total_download_bytes(),
                b.ledger.total_download_bytes(),
                "downlink {spec}"
            );
        }
    }

    #[test]
    fn threaded_driver_reclaims_workers_for_reuse() {
        // back-to-back run_threaded calls must keep working (workers
        // are drained into lanes and reclaimed in id order)
        let mut tr = toy_trainer(SparsifierKind::TopK { k: 1 }, 0.9);
        tr.run_threaded(3);
        assert_eq!(tr.workers.len(), 2);
        assert_eq!(tr.workers[0].id, 0);
        assert_eq!(tr.workers[1].id, 1);
        tr.run_threaded(2);
        assert_eq!(tr.iter(), 5);
    }
}
