//! Downlink codec: compress the sparse aggregate g^t for the
//! server -> worker broadcast (PR 6 tentpole).
//!
//! The same per-group `comm::codec` stack that encodes worker uploads
//! applies symmetrically to the downlink via the `downlink` policy
//! axis (`bits=`/`idx=`/`levels=` rules over group-name globs; a bare
//! `*=` rule is the lossless sparse broadcast — raw f32 values over
//! the union support).  The codec runs AFTER the optimizer step, so
//! the model always steps on the exact aggregate; workers — and the
//! trainer's own `gagg_prev` — see the decoded broadcast, identically
//! on both drivers.  When the value codec is lossless, RegTop-k's
//! posterior statistics see the identical aggregate.
//!
//! Two deliberate asymmetries vs the uplink stack:
//! - no error feedback: the quantization residual is discarded (the
//!   aggregate is re-derived each round; a server-side EF loop would
//!   change the algorithm, not just the wire),
//! - no `bits=auto`: the residual-steered width lives in the
//!   worker-side sparsifier wrappers ([`PolicyTable::validate_downlink`]
//!   rejects it).

use crate::comm::codec::{IndexCodec, LevelKind, ValueCodec};
use crate::grad::GradLayout;
use crate::comm::SparseUpdate;
use crate::sparsify::{BitsSpec, PolicyTable, Schedule};
use crate::util::rng::Rng;

/// Stream tag for the downlink stochastic-rounding RNG, derived from
/// the run seed (disjoint from the worker/data streams by the
/// `Rng::derive` construction).
const DOWNLINK_STREAM: u64 = 0x646f_776e_6c6b;

/// One group's resolved downlink stack.
struct DownGroup {
    /// value width schedule (None = raw f32 values)
    bits: Option<Schedule>,
    levels: LevelKind,
    idx: IndexCodec,
}

/// Server-side downlink encoder: resolves the codec-only policy table
/// against the run's layout once, then encodes the aggregate in place
/// each round.
pub struct DownlinkCodec {
    groups: Vec<DownGroup>,
    rng: Rng,
    /// scratch the value codec writes its (discarded) residual into
    residual: Vec<f32>,
    codes: Vec<u32>,
}

impl DownlinkCodec {
    /// Resolve `table` against `layout` (first matching rule per
    /// group; unmatched groups broadcast raw).  Panics on a table that
    /// fails [`PolicyTable::validate_downlink`] — config loading and
    /// the CLI validate earlier, so this guards programmatic misuse.
    pub fn new(table: &PolicyTable, layout: &GradLayout, seed: u64) -> Self {
        table.validate_downlink().expect("invalid downlink policy");
        let groups = layout
            .groups()
            .iter()
            .map(|g| match table.resolve(&g.name) {
                Some(p) => DownGroup {
                    bits: match &p.bits {
                        Some(BitsSpec::Sched(s)) => Some(s.clone()),
                        // rejected by validate_downlink above
                        Some(BitsSpec::Auto { .. }) => unreachable!(),
                        // a bare levels=fp16|bf16 rule engages the
                        // fixed 16-bit half-width codec (no bits= key)
                        None => p
                            .levels
                            .filter(LevelKind::is_half)
                            .map(|_| Schedule::Const(16.0)),
                    },
                    levels: p.levels.unwrap_or_default(),
                    idx: p.idx.unwrap_or_default(),
                },
                None => DownGroup {
                    bits: None,
                    levels: LevelKind::default(),
                    idx: IndexCodec::default(),
                },
            })
            .collect();
        DownlinkCodec {
            groups,
            rng: Rng::seed_from(seed).derive(DOWNLINK_STREAM),
            residual: Vec::new(),
            codes: Vec::new(),
        }
    }

    /// Encode the aggregate in place for round `t`: values are
    /// stochastically rounded onto the configured grid (the bucket
    /// ends up holding the exact decode), index payloads are attached
    /// for `idx=rice`/`idx=raw` groups.  Empty buckets are skipped
    /// entirely — they cost nothing on the wire and (like all-zero
    /// buckets inside the value codec) consume nothing from the
    /// rounding stream, so checkpoint resume stays bit-exact.
    pub fn encode(&mut self, up: &mut SparseUpdate, t: usize) {
        assert_eq!(
            up.num_buckets(),
            self.groups.len(),
            "aggregate bucketing does not match the downlink layout"
        );
        for g in 0..up.num_buckets() {
            if up.bucket(g).nnz() == 0 {
                continue;
            }
            let gr = &self.groups[g];
            if let Some(sched) = &gr.bits {
                let bits = sched.at(t).round() as i64;
                // widths outside the packable range are raw passthrough
                // for the round (same contract as the uplink stack)
                if (2..=16).contains(&bits) {
                    let vc = ValueCodec { bits: bits as usize, levels: gr.levels };
                    let (bucket, payload) = up.bucket_payload_mut(g);
                    vc.encode_bucket(
                        bucket,
                        &mut self.rng,
                        &mut payload.value,
                        &mut self.residual,
                        &mut self.codes,
                    );
                }
            }
            match gr.idx {
                IndexCodec::Packed => {}
                IndexCodec::Raw => up.payload_mut(g).raw_index = true,
                IndexCodec::Rice => {
                    let (bucket, payload) = up.bucket_payload_mut(g);
                    payload.rice.encode_into(bucket.indices());
                }
            }
        }
    }

    /// Whether any group quantizes values (false = the broadcast is a
    /// lossless re-indexing of the exact aggregate).
    pub fn is_lossless(&self) -> bool {
        self.groups.iter().all(|g| g.bits.is_none())
    }

    /// Snapshot the rounding stream for checkpointing.
    pub fn rng_state(&self) -> ([u64; 4], Option<f64>) {
        self.rng.state()
    }

    /// Restore the rounding stream from a checkpoint snapshot.
    pub fn restore_rng(&mut self, s: [u64; 4], gauss_spare: Option<f64>) {
        self.rng = Rng::from_state(s, gauss_spare);
    }
}

/// Worker-side reconstruction of dense `gagg_prev` from the sparse
/// broadcast: clear the previous round's support to +0.0, scatter the
/// new values, remember the new support.  Because union-merge sums
/// starting from +0.0 never produce -0.0, the result is bit-identical
/// to densifying the aggregate into a fresh zero vector every round —
/// at O(k·n) cost instead of O(J).
pub struct GaggMirror {
    dense: Vec<f32>,
    /// global indices written last round (what to clear next round)
    support: Vec<usize>,
}

impl GaggMirror {
    pub fn new(dim: usize) -> Self {
        GaggMirror { dense: vec![0.0; dim], support: Vec::new() }
    }

    /// The reconstructed dense aggregate.
    pub fn dense(&self) -> &[f32] {
        &self.dense
    }

    /// Indices holding a (possibly zero) broadcast value.
    pub fn support(&self) -> &[usize] {
        &self.support
    }

    /// Apply one round's sparse broadcast.
    pub fn apply(&mut self, up: &SparseUpdate) {
        for &i in &self.support {
            self.dense[i] = 0.0;
        }
        self.support.clear();
        for g in 0..up.num_buckets() {
            let off = up.offset(g);
            let b = up.bucket(g);
            for (&i, &v) in b.indices().iter().zip(b.values()) {
                let gi = off + i as usize;
                self.dense[gi] = v;
                self.support.push(gi);
            }
        }
    }

    /// Dense broadcast: plain copy, with the nonzero entries recorded
    /// as support so a later [`Self::apply`] clears them correctly
    /// (the threaded driver's first round after a resume is dense —
    /// the restored `g^{t-1}` has no sparse form).
    pub fn copy_dense(&mut self, src: &[f32]) {
        self.dense.copy_from_slice(src);
        self.support.clear();
        for (i, &v) in src.iter().enumerate() {
            if v != 0.0 {
                self.support.push(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;

    fn table(spec: &str) -> PolicyTable {
        PolicyTable::parse(spec).unwrap()
    }

    #[test]
    fn lossless_encode_keeps_values_bit_exact() {
        let layout = GradLayout::single(16);
        let mut dl = DownlinkCodec::new(&table("*="), &layout, 7);
        assert!(dl.is_lossless());
        let sv = SparseVec::new(16, vec![1, 5, 9], vec![0.5, -0.25, 3.0]);
        let mut up = SparseUpdate::single(sv.clone());
        let before = dl.rng_state();
        dl.encode(&mut up, 0);
        assert_eq!(up.bucket(0), &sv, "bare sparse broadcast is lossless");
        assert_eq!(dl.rng_state(), before, "lossless encode draws nothing");
        // rice attaches an index payload but leaves values alone
        let mut dl = DownlinkCodec::new(&table("*=:idx=rice"), &layout, 7);
        let mut up = SparseUpdate::single(sv.clone());
        dl.encode(&mut up, 0);
        assert_eq!(up.bucket(0).values(), sv.values());
        assert!(up.rice(0).is_some());
    }

    #[test]
    fn quantized_encode_leaves_exact_decode_in_bucket() {
        let layout = GradLayout::single(32);
        let mut dl = DownlinkCodec::new(&table("*=:bits=4"), &layout, 3);
        assert!(!dl.is_lossless());
        let mut up = SparseUpdate::single(SparseVec::new(
            32,
            vec![0, 7, 20],
            vec![1.0, -0.4, 0.03],
        ));
        dl.encode(&mut up, 0);
        let q = up.quant(0).expect("value payload active");
        for (i, &v) in up.bucket(0).values().iter().enumerate() {
            assert_eq!(q.decode_value(i), v, "bucket holds the payload's exact decode");
        }
    }

    #[test]
    fn half_levels_downlink_is_deterministic_sixteen_bit() {
        let layout = GradLayout::single(32);
        let mut dl = DownlinkCodec::new(&table("*=:levels=bf16"), &layout, 5);
        assert!(!dl.is_lossless(), "half-width rounding is lossy");
        let mut up = SparseUpdate::single(SparseVec::new(
            32,
            vec![0, 7, 20],
            vec![1.0, -0.4, 0.03],
        ));
        let before = dl.rng_state();
        dl.encode(&mut up, 0);
        let q = up.quant(0).expect("half payload active");
        assert_eq!(q.bits(), 16);
        assert_eq!(q.level_kind(), LevelKind::Bf16);
        for (i, &v) in up.bucket(0).values().iter().enumerate() {
            assert_eq!(q.decode_value(i), v, "bucket holds the payload's exact decode");
        }
        // RNE rounding is deterministic: the stream is untouched
        assert_eq!(dl.rng_state(), before, "half encode draws nothing");
    }

    #[test]
    fn empty_buckets_cost_nothing_and_draw_nothing() {
        let layout =
            GradLayout::from_sizes([("a".to_string(), 8), ("b".to_string(), 8)]);
        let mut dl = DownlinkCodec::new(&table("*=:bits=4,idx=rice"), &layout, 3);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(1).push(2, 1.5);
        let before = dl.rng_state();
        dl.encode(&mut up, 0);
        assert!(up.quant(0).is_none() && up.rice(0).is_none(), "empty bucket skipped");
        assert!(up.quant(1).is_some() && up.rice(1).is_some());
        assert_ne!(dl.rng_state(), before, "nonzero bucket consumed the stream");
    }

    #[test]
    fn rng_state_roundtrips() {
        let layout = GradLayout::single(8);
        let mut a = DownlinkCodec::new(&table("*=:bits=4"), &layout, 11);
        let mut b = DownlinkCodec::new(&table("*=:bits=4"), &layout, 11);
        let up0 = SparseUpdate::single(SparseVec::new(8, vec![0, 3], vec![1.0, -2.0]));
        let mut ua = up0.clone();
        a.encode(&mut ua, 0);
        let (s, spare) = a.rng_state();
        b.restore_rng(s, spare);
        let mut x = up0.clone();
        let mut y = up0.clone();
        a.encode(&mut x, 1);
        b.encode(&mut y, 1);
        assert_eq!(x, y, "restored stream continues identically");
    }

    #[test]
    fn mirror_reconstructs_dense_broadcast() {
        let layout =
            GradLayout::from_sizes([("a".to_string(), 4), ("b".to_string(), 4)]);
        let mut m = GaggMirror::new(8);
        let mut u1 = SparseUpdate::zeros(&layout);
        u1.bucket_mut(0).push(1, 2.0);
        u1.bucket_mut(1).push(3, -1.0);
        m.apply(&u1);
        assert_eq!(m.dense(), &[0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, -1.0]);
        assert_eq!(m.support(), &[1, 7]);
        // next round: old support cleared, new values scattered
        let mut u2 = SparseUpdate::zeros(&layout);
        u2.bucket_mut(0).push(0, 5.0);
        m.apply(&u2);
        assert_eq!(m.dense(), &[5.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(m.dense(), u2.to_dense().as_slice());
        // dense init (resumed g^{t-1}) followed by a sparse round:
        // copy_dense leaves a clearable support
        m.copy_dense(&[1.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0, -2.0]);
        assert_eq!(m.support(), &[0, 2, 7]);
        m.apply(&u2);
        assert_eq!(m.dense(), u2.to_dense().as_slice());
    }

    #[test]
    #[should_panic]
    fn constructor_rejects_sparsifier_keys() {
        DownlinkCodec::new(&table("*=topk"), &GradLayout::single(4), 0);
    }
}
