//! Server-side state: aggregation + model update + broadcast value.

use crate::optim::Optimizer;
use crate::sparse::SparseUpdate;

/// The parameter server: owns the global model w and the optimizer.
pub struct Server {
    pub w: Vec<f32>,
    pub optimizer: Box<dyn Optimizer>,
    /// g^t of the last completed round (what gets broadcast)
    pub gagg: Vec<f32>,
    agg_buf: Vec<f32>,
}

impl Server {
    pub fn new(w0: Vec<f32>, optimizer: Box<dyn Optimizer>) -> Self {
        let dim = w0.len();
        Server { w: w0, optimizer, gagg: vec![0.0; dim], agg_buf: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Aggregate bucketed updates with weights omega and update the
    /// model:  g^t = sum_n omega_n ghat_n ;  w <- optimizer(w, g^t).
    /// Updates MUST be ordered by worker id, and each update's buckets
    /// apply in offset order — so the float-add sequence (and thus the
    /// aggregate) is bit-identical to the seed's flat path.
    pub fn aggregate_and_step(&mut self, updates: &[(f32, &SparseUpdate)], t: usize) -> &[f32] {
        self.aggregate_and_step_scaled(updates, t, None)
    }

    /// [`Self::aggregate_and_step`] with optional per-group
    /// learning-rate scales `(offset, len, scale)` — the §1.2
    /// G-extension applied per layer.  The optimizer steps on the
    /// scaled gradient, but the broadcast value g^t stays UNSCALED:
    /// eta scaling is a server-side optimizer detail, and the
    /// RegTop-k Delta statistic keeps seeing the true aggregate.
    /// `None` (or all-unit scales from the caller) takes the exact
    /// pre-scaling code path, bit for bit.
    pub fn aggregate_and_step_scaled(
        &mut self,
        updates: &[(f32, &SparseUpdate)],
        t: usize,
        scales: Option<&[(usize, usize, f32)]>,
    ) -> &[f32] {
        self.agg_buf.iter_mut().for_each(|v| *v = 0.0);
        for (omega, up) in updates {
            up.axpy_into(*omega, &mut self.agg_buf);
        }
        std::mem::swap(&mut self.gagg, &mut self.agg_buf);
        match scales {
            None => self.optimizer.step(&mut self.w, &self.gagg, t),
            Some(sc) => {
                // agg_buf (last round's gagg) is free scratch here
                self.agg_buf.copy_from_slice(&self.gagg);
                for &(off, len, s) in sc {
                    if s != 1.0 {
                        for v in &mut self.agg_buf[off..off + len] {
                            *v *= s;
                        }
                    }
                }
                self.optimizer.step(&mut self.w, &self.agg_buf, t);
            }
        }
        &self.gagg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::GradLayout;
    use crate::optim::Sgd;
    use crate::sparse::SparseVec;

    #[test]
    fn weighted_aggregation_and_sgd_step() {
        let mut s = Server::new(vec![1.0, 1.0, 1.0], Box::new(Sgd::new(0.5)));
        let a = SparseUpdate::single(SparseVec::new(3, vec![0], vec![2.0]));
        let b = SparseUpdate::single(SparseVec::new(3, vec![0, 2], vec![-2.0, 4.0]));
        s.aggregate_and_step(&[(0.5, &a), (0.5, &b)], 0);
        // g = [0.5*2 + 0.5*(-2), 0, 0.5*4] = [0, 0, 2]
        assert_eq!(s.gagg, vec![0.0, 0.0, 2.0]);
        assert_eq!(s.w, vec![1.0, 1.0, 0.0]);
    }

    #[test]
    fn cancellation_yields_zero_step() {
        // the §1.2 toy's first-entry cancellation
        let mut s = Server::new(vec![0.0, 1.0], Box::new(Sgd::new(0.9)));
        let a = SparseUpdate::single(SparseVec::new(2, vec![0], vec![-73.6]));
        let b = SparseUpdate::single(SparseVec::new(2, vec![0], vec![73.6]));
        s.aggregate_and_step(&[(0.5, &a), (0.5, &b)], 0);
        assert_eq!(s.gagg, vec![0.0, 0.0]);
        assert_eq!(s.w, vec![0.0, 1.0]); // model did not move
    }

    #[test]
    fn eta_scales_step_but_not_broadcast() {
        let mk = || Server::new(vec![0.0; 4], Box::new(Sgd::new(1.0)));
        let layout = GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 2)]);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(0, 2.0);
        up.bucket_mut(1).push(1, 4.0);
        // group b steps at 3x; broadcast g^t stays unscaled
        let mut s = mk();
        let g = s.aggregate_and_step_scaled(&[(1.0, &up)], 0, Some(&[(0, 2, 1.0), (2, 2, 3.0)]));
        assert_eq!(g, &[2.0, 0.0, 0.0, 4.0]);
        assert_eq!(s.w, vec![-2.0, 0.0, 0.0, -12.0]);
        // all-unit scales match the unscaled path exactly
        let mut a = mk();
        let mut b = mk();
        a.aggregate_and_step(&[(1.0, &up)], 0);
        b.aggregate_and_step_scaled(&[(1.0, &up)], 0, Some(&[(0, 2, 1.0), (2, 2, 1.0)]));
        assert_eq!(a.w, b.w);
        assert_eq!(a.gagg, b.gagg);
    }

    #[test]
    fn bucketed_update_aggregates_with_offsets() {
        let layout =
            GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 2)]);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(1, 4.0);
        up.bucket_mut(1).push(0, -2.0);
        let mut s = Server::new(vec![0.0; 4], Box::new(Sgd::new(1.0)));
        s.aggregate_and_step(&[(0.5, &up)], 0);
        assert_eq!(s.gagg, vec![0.0, 2.0, -1.0, 0.0]);
        assert_eq!(s.w, vec![0.0, -2.0, 1.0, 0.0]);
    }
}
