//! Server-side state: aggregation + model update + broadcast value.

use crate::optim::Optimizer;
use crate::sparse::SparseUpdate;

/// The parameter server: owns the global model w and the optimizer.
pub struct Server {
    pub w: Vec<f32>,
    pub optimizer: Box<dyn Optimizer>,
    /// g^t of the last completed round (what gets broadcast)
    pub gagg: Vec<f32>,
    agg_buf: Vec<f32>,
}

impl Server {
    pub fn new(w0: Vec<f32>, optimizer: Box<dyn Optimizer>) -> Self {
        let dim = w0.len();
        Server { w: w0, optimizer, gagg: vec![0.0; dim], agg_buf: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Aggregate bucketed updates with weights omega and update the
    /// model:  g^t = sum_n omega_n ghat_n ;  w <- optimizer(w, g^t).
    /// Updates MUST be ordered by worker id, and each update's buckets
    /// apply in offset order — so the float-add sequence (and thus the
    /// aggregate) is bit-identical to the seed's flat path.
    pub fn aggregate_and_step(&mut self, updates: &[(f32, &SparseUpdate)], t: usize) -> &[f32] {
        self.agg_buf.iter_mut().for_each(|v| *v = 0.0);
        for (omega, up) in updates {
            up.axpy_into(*omega, &mut self.agg_buf);
        }
        std::mem::swap(&mut self.gagg, &mut self.agg_buf);
        self.optimizer.step(&mut self.w, &self.gagg, t);
        &self.gagg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::GradLayout;
    use crate::optim::Sgd;
    use crate::sparse::SparseVec;

    #[test]
    fn weighted_aggregation_and_sgd_step() {
        let mut s = Server::new(vec![1.0, 1.0, 1.0], Box::new(Sgd::new(0.5)));
        let a = SparseUpdate::single(SparseVec::new(3, vec![0], vec![2.0]));
        let b = SparseUpdate::single(SparseVec::new(3, vec![0, 2], vec![-2.0, 4.0]));
        s.aggregate_and_step(&[(0.5, &a), (0.5, &b)], 0);
        // g = [0.5*2 + 0.5*(-2), 0, 0.5*4] = [0, 0, 2]
        assert_eq!(s.gagg, vec![0.0, 0.0, 2.0]);
        assert_eq!(s.w, vec![1.0, 1.0, 0.0]);
    }

    #[test]
    fn cancellation_yields_zero_step() {
        // the §1.2 toy's first-entry cancellation
        let mut s = Server::new(vec![0.0, 1.0], Box::new(Sgd::new(0.9)));
        let a = SparseUpdate::single(SparseVec::new(2, vec![0], vec![-73.6]));
        let b = SparseUpdate::single(SparseVec::new(2, vec![0], vec![73.6]));
        s.aggregate_and_step(&[(0.5, &a), (0.5, &b)], 0);
        assert_eq!(s.gagg, vec![0.0, 0.0]);
        assert_eq!(s.w, vec![0.0, 1.0]); // model did not move
    }

    #[test]
    fn bucketed_update_aggregates_with_offsets() {
        let layout =
            GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 2)]);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(1, 4.0);
        up.bucket_mut(1).push(0, -2.0);
        let mut s = Server::new(vec![0.0; 4], Box::new(Sgd::new(1.0)));
        s.aggregate_and_step(&[(0.5, &up)], 0);
        assert_eq!(s.gagg, vec![0.0, 2.0, -1.0, 0.0]);
        assert_eq!(s.w, vec![0.0, -2.0, 1.0, 0.0]);
    }
}
