//! Server-side state: sparse-domain aggregation + model update +
//! broadcast value.
//!
//! PR 6 replaces the dense densify-then-step loop (an O(J) zero-fill
//! plus O(J·n) adds per round) with an O(k·n) cursor merge over the
//! union support: "Understanding Top-k Sparsification" (PAPERS.md)
//! shows the union of n worker top-k supports stays sparse, so the
//! aggregate g^t is itself a bucketed [`SparseUpdate`].  The merge is
//! EXACT — for every union index the contributions accumulate in
//! ascending worker order starting from +0.0, the same float-add
//! sequence as the dense `axpy_into` loop — so the sparse path is
//! bit-identical to the dense reference (kept behind
//! [`Server::force_dense`] for the equivalence tests and benches).

use crate::optim::Optimizer;
use crate::comm::SparseUpdate;
use crate::sparse::SparseVec;
use crate::util::{kernels, pool};

/// Below this many total transmitted entries in a bucket the serial
/// merge wins; above it the union merge shards over `util::pool`
/// index ranges (disjoint writes concatenated in shard order, so the
/// result is identical to the serial merge).
const MIN_SHARDED_MERGE_NNZ: usize = 1 << 14;

/// Merge weighted worker updates over the union support:
/// `out = sum_n omega_n * ghat_n` as a bucketed sparse update shaped
/// like the inputs.  Updates MUST be ordered by worker id and share
/// one bucket structure; the per-index accumulation order (ascending
/// worker id onto a +0.0 accumulator) reproduces the dense aggregate
/// bit for bit.
pub fn merge_updates(updates: &[(f32, &SparseUpdate)], out: &mut SparseUpdate) {
    let Some((_, first)) = updates.first() else {
        out.conform_like(&SparseUpdate::empty());
        return;
    };
    out.conform_like(first);
    let mut cursors = vec![0usize; updates.len()];
    for g in 0..first.num_buckets() {
        let dim = first.bucket(g).dim();
        debug_assert!(updates.iter().all(|(_, u)| {
            u.num_buckets() == first.num_buckets()
                && u.bucket(g).dim() == dim
                && u.offset(g) == first.offset(g)
        }));
        let nnz: usize = updates.iter().map(|(_, u)| u.bucket(g).nnz()).sum();
        if nnz >= MIN_SHARDED_MERGE_NNZ && pool::global().parallelism() > 1 {
            merge_bucket_sharded(updates, g, dim, out.bucket_mut(g));
        } else {
            cursors.fill(0);
            merge_bucket_range(updates, g, dim as u32, &mut cursors, out.bucket_mut(g));
        }
    }
}

/// Cursor merge of bucket `g` over local indices in `[cursor start,
/// hi)`.  `cursors[n]` must point at worker n's first entry inside the
/// range (0 for a full-bucket merge).
fn merge_bucket_range(
    updates: &[(f32, &SparseUpdate)],
    g: usize,
    hi: u32,
    cursors: &mut [usize],
    out: &mut SparseVec,
) {
    loop {
        let mut min = hi;
        for ((_, u), c) in updates.iter().zip(cursors.iter()) {
            let idx = u.bucket(g).indices();
            if *c < idx.len() && idx[*c] < min {
                min = idx[*c];
            }
        }
        if min >= hi {
            return;
        }
        let mut acc = 0.0f32;
        for ((omega, u), c) in updates.iter().zip(cursors.iter_mut()) {
            let b = u.bucket(g);
            if *c < b.nnz() && b.indices()[*c] == min {
                acc += *omega * b.values()[*c];
                *c += 1;
            }
        }
        out.push(min, acc);
    }
}

/// Pool-sharded variant: each shard merges a disjoint index range of
/// the bucket into its own scratch vec (cursor starts found by binary
/// search), and the shards concatenate in range order — identical
/// output to the serial merge by construction.
fn merge_bucket_sharded(
    updates: &[(f32, &SparseUpdate)],
    g: usize,
    dim: usize,
    out: &mut SparseVec,
) {
    let pool = pool::global();
    let shards = pool.parallelism();
    let mut parts: Vec<SparseVec> = (0..shards).map(|_| SparseVec::zeros(dim)).collect();
    pool.map_mut(&mut parts, |s, part| {
        let (lo, hi) = pool::shard_range(dim, shards, s);
        let mut cursors: Vec<usize> = updates
            .iter()
            .map(|(_, u)| u.bucket(g).indices().partition_point(|&i| (i as usize) < lo))
            .collect();
        merge_bucket_range(updates, g, hi as u32, &mut cursors, part);
    });
    for part in &parts {
        out.append_tail(part.indices(), part.values());
    }
}

/// The parameter server: owns the global model w and the optimizer.
pub struct Server {
    pub w: Vec<f32>,
    pub optimizer: Box<dyn Optimizer>,
    /// dense mirror of g^t of the last completed round (what dense
    /// consumers — `gagg_prev`, the dense `Msg::Broadcast` — read);
    /// maintained incrementally from the sparse aggregate
    pub gagg: Vec<f32>,
    /// dense scratch: the optimizer fallback and eta-scaled dense step
    agg_buf: Vec<f32>,
    /// g^t over the union support (empty before the first round)
    gagg_sparse: SparseUpdate,
    /// scratch the next round's merge builds into (swapped in)
    merge_next: SparseUpdate,
    /// scratch for the eta-scaled sparse step
    scaled_buf: SparseUpdate,
    /// Take the dense O(J·n) reference aggregation path instead of the
    /// union merge (equivalence tests and the `aggregate` bench).  Set
    /// at construction time only — toggling mid-run desyncs the
    /// mirrors — and incompatible with a downlink codec (the sparse
    /// aggregate stays empty on this path).
    pub force_dense: bool,
}

impl Server {
    pub fn new(w0: Vec<f32>, optimizer: Box<dyn Optimizer>) -> Self {
        let dim = w0.len();
        Server {
            w: w0,
            optimizer,
            gagg: vec![0.0; dim],
            agg_buf: vec![0.0; dim],
            gagg_sparse: SparseUpdate::empty(),
            merge_next: SparseUpdate::empty(),
            scaled_buf: SparseUpdate::empty(),
            force_dense: false,
        }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// g^t over the union support — what a downlink codec compresses
    /// and what [`crate::comm::Ledger::close_round_sparse`] charges.
    pub fn gagg_sparse(&self) -> &SparseUpdate {
        &self.gagg_sparse
    }

    /// Run a downlink encoder over the sparse aggregate (AFTER the
    /// optimizer has stepped on the exact values), then refresh the
    /// dense mirror so every dense consumer sees exactly the decoded
    /// broadcast.  Value codecs rewrite values in place but never the
    /// support, so no mirror clearing is needed here.
    pub fn encode_gagg_with(&mut self, f: impl FnOnce(&mut SparseUpdate)) {
        assert!(!self.force_dense, "downlink encoding needs the sparse aggregation path");
        f(&mut self.gagg_sparse);
        for g in 0..self.gagg_sparse.num_buckets() {
            let off = self.gagg_sparse.offset(g);
            let b = self.gagg_sparse.bucket(g);
            kernels::scatter_assign(&mut self.gagg[off..], b.indices(), b.values());
        }
    }

    /// Aggregate bucketed updates with weights omega and update the
    /// model:  g^t = sum_n omega_n ghat_n ;  w <- optimizer(w, g^t).
    /// Updates MUST be ordered by worker id, and each update's buckets
    /// apply in offset order — so the float-add sequence (and thus the
    /// aggregate) is bit-identical to the seed's flat path.
    pub fn aggregate_and_step(&mut self, updates: &[(f32, &SparseUpdate)], t: usize) -> &[f32] {
        self.aggregate_and_step_scaled(updates, t, None)
    }

    /// [`Self::aggregate_and_step`] with optional per-group
    /// learning-rate scales `(offset, len, scale)` — the §1.2
    /// G-extension applied per layer.  The optimizer steps on the
    /// scaled gradient, but the broadcast value g^t stays UNSCALED:
    /// eta scaling is a server-side optimizer detail, and the
    /// RegTop-k Delta statistic keeps seeing the true aggregate.
    /// `None` (or all-unit scales from the caller) takes the exact
    /// pre-scaling code path, bit for bit.
    pub fn aggregate_and_step_scaled(
        &mut self,
        updates: &[(f32, &SparseUpdate)],
        t: usize,
        scales: Option<&[(usize, usize, f32)]>,
    ) -> &[f32] {
        if self.force_dense {
            // PR 5 reference path: zero-fill + densify every update
            self.agg_buf.iter_mut().for_each(|v| *v = 0.0);
            for (omega, up) in updates {
                up.axpy_into(*omega, &mut self.agg_buf);
            }
            std::mem::swap(&mut self.gagg, &mut self.agg_buf);
            self.step_dense(t, scales);
            return &self.gagg;
        }
        // O(k·n) union merge, then an incremental dense-mirror update:
        // clearing last round's support to +0.0 and scattering the new
        // values leaves exactly the vector a fresh zero-fill + axpy
        // pass would build (union sums starting from +0.0 cannot
        // produce -0.0, so no sign-of-zero drift accumulates).
        merge_updates(updates, &mut self.merge_next);
        for g in 0..self.gagg_sparse.num_buckets() {
            let off = self.gagg_sparse.offset(g);
            for &i in self.gagg_sparse.bucket(g).indices() {
                self.gagg[off + i as usize] = 0.0;
            }
        }
        std::mem::swap(&mut self.gagg_sparse, &mut self.merge_next);
        for g in 0..self.gagg_sparse.num_buckets() {
            let off = self.gagg_sparse.offset(g);
            let b = self.gagg_sparse.bucket(g);
            kernels::scatter_assign(&mut self.gagg[off..], b.indices(), b.values());
        }
        if self.optimizer.sparse_step_exact() {
            match scales {
                None => self.optimizer.step_sparse(&mut self.w, &self.gagg_sparse, t),
                Some(sc) => {
                    // scale a sparse copy per group (buckets align 1:1
                    // with the layout-derived scale tuples), broadcast
                    // value stays unscaled
                    debug_assert_eq!(sc.len(), self.gagg_sparse.num_buckets());
                    self.scaled_buf.conform_like(&self.gagg_sparse);
                    for g in 0..self.gagg_sparse.num_buckets() {
                        debug_assert_eq!(sc[g].0, self.gagg_sparse.offset(g));
                        let s = sc[g].2;
                        let src = self.gagg_sparse.bucket(g);
                        let dst = self.scaled_buf.bucket_mut(g);
                        for (&i, &v) in src.indices().iter().zip(src.values()) {
                            dst.push(i, if s != 1.0 { v * s } else { v });
                        }
                    }
                    self.optimizer.step_sparse(&mut self.w, &self.scaled_buf, t);
                }
            }
        } else {
            // stateful optimizers (momentum, Adam) need the full-J
            // gradient: step on the dense mirror exactly as before
            self.step_dense(t, scales);
        }
        &self.gagg
    }

    /// Dense optimizer step on the mirror, with optional per-group eta
    /// scaling applied in `agg_buf` scratch (the pre-PR 6 code path).
    fn step_dense(&mut self, t: usize, scales: Option<&[(usize, usize, f32)]>) {
        match scales {
            None => self.optimizer.step(&mut self.w, &self.gagg, t),
            Some(sc) => {
                self.agg_buf.copy_from_slice(&self.gagg);
                for &(off, len, s) in sc {
                    if s != 1.0 {
                        for v in &mut self.agg_buf[off..off + len] {
                            *v *= s;
                        }
                    }
                }
                self.optimizer.step(&mut self.w, &self.agg_buf, t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::GradLayout;
    use crate::optim::{Sgd, SgdMomentum};
    use crate::sparse::SparseVec;

    #[test]
    fn weighted_aggregation_and_sgd_step() {
        let mut s = Server::new(vec![1.0, 1.0, 1.0], Box::new(Sgd::new(0.5)));
        let a = SparseUpdate::single(SparseVec::new(3, vec![0], vec![2.0]));
        let b = SparseUpdate::single(SparseVec::new(3, vec![0, 2], vec![-2.0, 4.0]));
        s.aggregate_and_step(&[(0.5, &a), (0.5, &b)], 0);
        // g = [0.5*2 + 0.5*(-2), 0, 0.5*4] = [0, 0, 2]
        assert_eq!(s.gagg, vec![0.0, 0.0, 2.0]);
        assert_eq!(s.w, vec![1.0, 1.0, 0.0]);
        // the sparse aggregate carries the union support, zeros kept
        assert_eq!(s.gagg_sparse().nnz(), 2);
    }

    #[test]
    fn cancellation_yields_zero_step() {
        // the §1.2 toy's first-entry cancellation
        let mut s = Server::new(vec![0.0, 1.0], Box::new(Sgd::new(0.9)));
        let a = SparseUpdate::single(SparseVec::new(2, vec![0], vec![-73.6]));
        let b = SparseUpdate::single(SparseVec::new(2, vec![0], vec![73.6]));
        s.aggregate_and_step(&[(0.5, &a), (0.5, &b)], 0);
        assert_eq!(s.gagg, vec![0.0, 0.0]);
        assert_eq!(s.w, vec![0.0, 1.0]); // model did not move
    }

    #[test]
    fn eta_scales_step_but_not_broadcast() {
        let mk = || Server::new(vec![0.0; 4], Box::new(Sgd::new(1.0)));
        let layout = GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 2)]);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(0, 2.0);
        up.bucket_mut(1).push(1, 4.0);
        // group b steps at 3x; broadcast g^t stays unscaled
        let mut s = mk();
        let g = s.aggregate_and_step_scaled(&[(1.0, &up)], 0, Some(&[(0, 2, 1.0), (2, 2, 3.0)]));
        assert_eq!(g, &[2.0, 0.0, 0.0, 4.0]);
        assert_eq!(s.w, vec![-2.0, 0.0, 0.0, -12.0]);
        // all-unit scales match the unscaled path exactly
        let mut a = mk();
        let mut b = mk();
        a.aggregate_and_step(&[(1.0, &up)], 0);
        b.aggregate_and_step_scaled(&[(1.0, &up)], 0, Some(&[(0, 2, 1.0), (2, 2, 1.0)]));
        assert_eq!(a.w, b.w);
        assert_eq!(a.gagg, b.gagg);
    }

    #[test]
    fn bucketed_update_aggregates_with_offsets() {
        let layout = GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 2)]);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(1, 4.0);
        up.bucket_mut(1).push(0, -2.0);
        let mut s = Server::new(vec![0.0; 4], Box::new(Sgd::new(1.0)));
        s.aggregate_and_step(&[(0.5, &up)], 0);
        assert_eq!(s.gagg, vec![0.0, 2.0, -1.0, 0.0]);
        assert_eq!(s.w, vec![0.0, -2.0, 1.0, 0.0]);
    }

    fn overlapping_updates(layout: &GradLayout, round: usize) -> Vec<SparseUpdate> {
        // three workers with overlapping, shifting supports and values
        // chosen to exercise accumulation order (non-associative adds)
        (0..3)
            .map(|n| {
                let mut u = SparseUpdate::zeros(layout);
                for g in 0..u.num_buckets() {
                    let dim = u.bucket(g).dim() as u32;
                    let mut i = ((n + g + round) % 3) as u32;
                    let mut v = 0.1 + n as f32 * 0.7 - g as f32 * 1.3;
                    while i < dim {
                        u.bucket_mut(g).push(i, v);
                        v = -v * 1.37 + 0.011;
                        i += 1 + (n as u32 + round as u32) % 3;
                    }
                }
                u
            })
            .collect()
    }

    #[test]
    fn sparse_merge_is_bit_identical_to_dense_reference() {
        let layout = GradLayout::from_sizes([("a".to_string(), 5), ("b".to_string(), 9)]);
        let mut sparse = Server::new(vec![0.2; 14], Box::new(Sgd::new(0.3)));
        let mut dense = Server::new(vec![0.2; 14], Box::new(Sgd::new(0.3)));
        dense.force_dense = true;
        let omegas = [0.5f32, 0.25, 0.25];
        for t in 0..4 {
            let ups = overlapping_updates(&layout, t);
            let weighted: Vec<(f32, &SparseUpdate)> =
                omegas.iter().copied().zip(ups.iter()).collect();
            sparse.aggregate_and_step(&weighted, t);
            dense.aggregate_and_step(&weighted, t);
            assert_eq!(
                dense.gagg.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                sparse.gagg.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "round {t}: dense mirror diverged"
            );
            assert_eq!(
                dense.w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                sparse.w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "round {t}: model diverged"
            );
            assert_eq!(sparse.gagg_sparse().to_dense(), sparse.gagg);
        }
    }

    #[test]
    fn sparse_merge_matches_dense_with_eta_scales_and_momentum() {
        let layout = GradLayout::from_sizes([("a".to_string(), 5), ("b".to_string(), 9)]);
        let sc = [(0usize, 5usize, 1.0f32), (5, 9, 2.5)];
        // SGD with per-group eta scales: sparse scaled step
        let mut a = Server::new(vec![0.1; 14], Box::new(Sgd::new(0.2)));
        let mut b = Server::new(vec![0.1; 14], Box::new(Sgd::new(0.2)));
        b.force_dense = true;
        // momentum: sparse_step_exact() is false, dense fallback steps
        let mut c = Server::new(vec![0.1; 14], Box::new(SgdMomentum::new(14, 0.2, 0.9)));
        let mut d = Server::new(vec![0.1; 14], Box::new(SgdMomentum::new(14, 0.2, 0.9)));
        d.force_dense = true;
        for t in 0..3 {
            let ups = overlapping_updates(&layout, t);
            let weighted: Vec<(f32, &SparseUpdate)> =
                [0.4f32, 0.3, 0.3].iter().copied().zip(ups.iter()).collect();
            a.aggregate_and_step_scaled(&weighted, t, Some(&sc));
            b.aggregate_and_step_scaled(&weighted, t, Some(&sc));
            c.aggregate_and_step(&weighted, t);
            d.aggregate_and_step(&weighted, t);
        }
        assert_eq!(a.w, b.w, "eta-scaled sparse step diverged from dense");
        assert_eq!(a.gagg, b.gagg);
        assert_eq!(c.w, d.w, "momentum dense fallback diverged");
        assert_eq!(c.gagg, d.gagg);
    }

    #[test]
    fn merge_updates_unions_and_weights() {
        let a = SparseUpdate::single(SparseVec::new(6, vec![1, 4], vec![2.0, 8.0]));
        let b = SparseUpdate::single(SparseVec::new(6, vec![1, 5], vec![-2.0, 4.0]));
        let mut out = SparseUpdate::empty();
        merge_updates(&[(0.5, &a), (0.5, &b)], &mut out);
        assert_eq!(out.bucket(0).indices(), &[1, 4, 5]);
        assert_eq!(out.bucket(0).values(), &[0.0, 4.0, 2.0]);
        // empty input conforms to nothing
        merge_updates(&[], &mut out);
        assert_eq!(out.num_buckets(), 0);
    }

    #[test]
    fn encode_gagg_with_refreshes_dense_mirror() {
        let mut s = Server::new(vec![0.0; 3], Box::new(Sgd::new(0.0)));
        let up = SparseUpdate::single(SparseVec::new(3, vec![0, 2], vec![1.0, -4.0]));
        s.aggregate_and_step(&[(1.0, &up)], 0);
        s.encode_gagg_with(|g| {
            for v in g.bucket_mut(0).values_mut() {
                *v *= 0.5; // a "lossy codec"
            }
        });
        assert_eq!(s.gagg, vec![0.5, 0.0, -2.0]);
        assert_eq!(s.gagg_sparse().bucket(0).values(), &[0.5, -2.0]);
    }
}
