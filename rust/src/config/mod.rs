//! Experiment configuration: a typed config with JSON file loading and
//! CLI overrides.  Every `repro` subcommand and example builds one of
//! these; the config is echoed into each run's JSON output so results
//! are self-describing.

#![forbid(unsafe_code)]

use std::path::Path;

use crate::comm::{CostModel, TransportKind};
use crate::grad::GradLayout;
use crate::sparsify::{
    BudgetPolicy, LayerwiseSparsifier, PolicyTable, Sparsifier, SparsifierKind,
    SparsifierParams,
};
use crate::util::json::{obj, Json};

/// Top-level experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// number of workers N
    pub workers: usize,
    /// synchronous rounds T
    pub iters: usize,
    /// learning rate eta (constant schedule unless overridden)
    pub eta: f32,
    /// sparsifier + parameters
    pub sparsifier: SparsifierKind,
    /// aggregation weights: uniform 1/N (the paper's arithmetic mean)
    pub omega_uniform: bool,
    /// RNG seed for data, init and samplers
    pub seed: u64,
    /// evaluate validation metrics every `eval_every` rounds (0 = never)
    pub eval_every: usize,
    /// communication cost model
    pub cost: CostModel,
    /// shard count for the sparsification engine: 1 = serial (the seed
    /// path), 0 = auto (sized to the persistent pool), N = fixed.
    /// Small models fall back to serial regardless (see
    /// [`Self::effective_shards`]).
    pub shards: usize,
    /// parameter-group layout for the layer-wise API (None = the seed's
    /// flat single-group path; totals must match the model dimension)
    pub groups: Option<GradLayout>,
    /// per-group budget policy; only consulted when `groups` is set
    /// (None = `Global{k}` from the sparsifier's own budget)
    pub budget: Option<BudgetPolicy>,
    /// heterogeneous per-group policy table (family + hyperparameters
    /// per group-name glob); only consulted when `groups` is set.
    /// None/empty = the homogeneous layer-wise path.
    pub policy: Option<PolicyTable>,
    /// downlink (server -> worker) codec policy over the sparse
    /// aggregate g^t: codec-only rules (`bits=`/`idx=`/`levels=` per
    /// group glob; a bare `*=` is the lossless sparse broadcast).
    /// Applies to flat runs too (single `all` group).  None = the
    /// dense 32·J-bit broadcast, bit-identical to the pre-PR 6 tree.
    pub downlink: Option<PolicyTable>,
    /// which transport backend `repro train` drives: the in-process
    /// star (default, bit-identical to the seed) or framed bytes over
    /// sockets with workers as separate OS processes.  The trajectory
    /// is identical either way; only the message path changes.
    pub transport: TransportKind,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            workers: 8,
            iters: 100,
            eta: 0.01,
            sparsifier: SparsifierKind::TopK { k: 1 },
            omega_uniform: true,
            seed: 42,
            eval_every: 10,
            cost: CostModel::default(),
            shards: 1,
            groups: None,
            budget: None,
            policy: None,
            downlink: None,
            transport: TransportKind::InProc,
        }
    }
}

impl TrainConfig {
    /// omega_n for worker n (uniform only; the hook exists for
    /// D_n-proportional weights).
    pub fn omega(&self, _worker: usize) -> f32 {
        1.0 / self.workers as f32
    }

    /// Short name of the configured sparsifier (for summaries).
    pub fn sparsifier_name(&self) -> &'static str {
        self.sparsifier.name()
    }

    /// Resolve the configured shard count for a model of dimension
    /// `dim`: `0` means "one shard per pool executor"; dimensions
    /// below the engine threshold always run serial (a parallel pass
    /// over a few thousand elements costs more in handoff than it
    /// saves).  Results are bit-identical across all shard counts, so
    /// this is purely a performance decision.
    pub fn effective_shards(&self, dim: usize) -> usize {
        if dim < crate::sparse::engine::MIN_SHARDED_DIM {
            return 1;
        }
        match self.shards {
            0 => crate::util::pool::global().parallelism(),
            s => s,
        }
    }

    /// The effective parameter-group layout for a model of dimension
    /// `dim`: the configured groups (validated against `dim`) or the
    /// degenerate flat single-group layout.
    pub fn layout_for(&self, dim: usize) -> GradLayout {
        match &self.groups {
            Some(l) => {
                assert_eq!(
                    l.total(),
                    dim,
                    "configured groups total {} != model dim {dim}",
                    l.total()
                );
                l.clone()
            }
            None => GradLayout::single(dim),
        }
    }

    /// The effective budget policy when groups are configured: the
    /// explicit policy, or `Global{k}` derived from the sparsifier's
    /// own budget.
    pub fn effective_budget(&self) -> BudgetPolicy {
        self.budget
            .clone()
            .unwrap_or(BudgetPolicy::Global { k: self.sparsifier.to_params().k })
    }

    /// Per-group learning-rate scales `(offset, len, scale)` resolved
    /// from the policy table (the §1.2 G-extension applied per layer).
    /// `None` unless groups + a policy are configured AND some
    /// matching rule carries a non-unit `eta` — so the common case
    /// takes the exact pre-scaling server path.
    pub fn eta_scales(&self, dim: usize) -> Option<Vec<(usize, usize, f32)>> {
        let (Some(_), Some(policy)) = (&self.groups, &self.policy) else {
            return None;
        };
        let layout = self.layout_for(dim);
        let scales: Vec<(usize, usize, f32)> = layout
            .groups()
            .iter()
            .map(|g| {
                let s = policy.resolve(&g.name).and_then(|p| p.eta).unwrap_or(1.0);
                (g.offset, g.len, s)
            })
            .collect();
        scales.iter().any(|&(_, _, s)| s != 1.0).then_some(scales)
    }

    /// Instantiate this config's sparsifier for one worker.  Without
    /// `groups` this is exactly the seed factory call (flat path,
    /// bit-identical); with `groups` it wraps the configured family in
    /// a [`LayerwiseSparsifier`] with per-group budgets, heterogeneous
    /// per the optional policy table.
    pub fn build_sparsifier(&self, dim: usize, worker: usize) -> Box<dyn Sparsifier> {
        match &self.groups {
            None => crate::sparsify::build(&self.sparsifier, dim, worker),
            Some(_) => {
                let empty = PolicyTable::default();
                let mut lw = LayerwiseSparsifier::with_policies(
                    &self.sparsifier,
                    self.layout_for(dim),
                    &self.effective_budget(),
                    self.policy.as_ref().unwrap_or(&empty),
                    worker,
                );
                // the packing-must-pay guard compares against what a
                // raw value costs on THIS run's simulated link
                lw.set_raw_value_bits(self.cost.value_bits);
                Box::new(lw)
            }
        }
    }

    /// Serialize for run manifests.
    pub fn to_json(&self) -> Json {
        let sp = match &self.sparsifier {
            SparsifierKind::Dense => obj([("name", "dense".into())]),
            SparsifierKind::TopK { k } => obj([("name", "topk".into()), ("k", (*k).into())]),
            SparsifierKind::RegTopK { k, mu, q } => obj([
                ("name", "regtopk".into()),
                ("k", (*k).into()),
                ("mu", (*mu as f64).into()),
                ("q", (*q as f64).into()),
            ]),
            SparsifierKind::RandK { k, seed } => obj([
                ("name", "randk".into()),
                ("k", (*k).into()),
                ("seed", (*seed as usize).into()),
            ]),
            SparsifierKind::Threshold { tau } => {
                obj([("name", "threshold".into()), ("tau", (*tau as f64).into())])
            }
            SparsifierKind::GlobalTopK { k } => {
                obj([("name", "gtopk".into()), ("k", (*k).into())])
            }
            SparsifierKind::Dgc { k, momentum, clip } => obj([
                ("name", "dgc".into()),
                ("k", (*k).into()),
                ("momentum", (*momentum as f64).into()),
                ("clip", (*clip as f64).into()),
            ]),
            SparsifierKind::AdaK { ratio, k_min, k_max } => obj([
                ("name", "adak".into()),
                ("ratio", (*ratio as f64).into()),
                ("k_min", (*k_min).into()),
                ("k_max", (*k_max).into()),
            ]),
        };
        let mut j = obj([
            ("workers", self.workers.into()),
            ("iters", self.iters.into()),
            ("eta", (self.eta as f64).into()),
            ("sparsifier", sp),
            ("omega_uniform", self.omega_uniform.into()),
            ("seed", (self.seed as usize).into()),
            ("eval_every", self.eval_every.into()),
            ("cost", self.cost.to_json()),
            ("shards", self.shards.into()),
            ("transport", self.transport.name().into()),
        ]);
        if let Json::Obj(m) = &mut j {
            // budget/policy are only consulted on the grouped path, so
            // they are only echoed alongside groups — a manifest must
            // never claim a policy the run did not apply
            if let Some(l) = &self.groups {
                m.insert("groups".to_string(), l.to_json());
                if let Some(b) = &self.budget {
                    m.insert("budget".to_string(), b.to_json());
                }
                if let Some(p) = &self.policy {
                    m.insert("policy".to_string(), p.to_json());
                }
            }
            // the downlink codec compresses the aggregate broadcast,
            // which every run has — flat runs included — so it is
            // echoed unconditionally
            if let Some(d) = &self.downlink {
                m.insert("downlink".to_string(), d.to_json());
            }
        }
        j
    }

    /// Load from a JSON config file; missing keys keep defaults.
    pub fn from_json_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut c = TrainConfig::default();
        if let Some(v) = j.get("workers").and_then(Json::as_usize) {
            c.workers = v;
        }
        if let Some(v) = j.get("iters").and_then(Json::as_usize) {
            c.iters = v;
        }
        if let Some(v) = j.get("eta").and_then(Json::as_f64) {
            c.eta = v as f32;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("eval_every").and_then(Json::as_usize) {
            c.eval_every = v;
        }
        if let Some(v) = j.get("omega_uniform").and_then(Json::as_bool) {
            c.omega_uniform = v;
        }
        if let Some(cm) = j.get("cost") {
            c.cost = CostModel::from_json(cm)?;
        }
        if let Some(v) = j.get("shards").and_then(Json::as_usize) {
            c.shards = v;
        }
        if let Some(v) = j.get("transport").and_then(Json::as_str) {
            c.transport = TransportKind::parse(v)?;
        }
        if let Some(g) = j.get("groups") {
            c.groups = Some(GradLayout::from_json(g)?);
        }
        if let Some(b) = j.get("budget") {
            c.budget = Some(BudgetPolicy::from_json(b)?);
        }
        if let Some(p) = j.get("policy") {
            c.policy = Some(PolicyTable::from_json(p)?);
        }
        if let Some(d) = j.get("downlink") {
            let t = PolicyTable::from_json(d)?;
            t.validate_downlink()?;
            c.downlink = Some(t);
        }
        if let Some(sp) = j.get("sparsifier") {
            let name = sp.get("name").and_then(Json::as_str).ok_or("sparsifier.name missing")?;
            let d = SparsifierParams::default();
            let p = SparsifierParams {
                k: sp.get("k").and_then(Json::as_usize).unwrap_or(d.k),
                mu: sp.get("mu").and_then(Json::as_f64).map(|v| v as f32).unwrap_or(d.mu),
                q: sp.get("q").and_then(Json::as_f64).map(|v| v as f32).unwrap_or(d.q),
                tau: sp.get("tau").and_then(Json::as_f64).map(|v| v as f32).unwrap_or(d.tau),
                seed: sp.get("seed").and_then(Json::as_f64).map(|v| v as u64).unwrap_or(d.seed),
                momentum: sp
                    .get("momentum")
                    .and_then(Json::as_f64)
                    .map(|v| v as f32)
                    .unwrap_or(d.momentum),
                clip: sp.get("clip").and_then(Json::as_f64).map(|v| v as f32).unwrap_or(d.clip),
                ratio: sp.get("ratio").and_then(Json::as_f64).map(|v| v as f32).unwrap_or(d.ratio),
                k_min: sp.get("k_min").and_then(Json::as_usize).unwrap_or(d.k_min),
                k_max: sp.get("k_max").and_then(Json::as_usize).unwrap_or(d.k_max),
            };
            c.sparsifier = SparsifierKind::from_params(name, &p)
                .ok_or_else(|| format!("unknown sparsifier '{name}'"))?;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = TrainConfig::default();
        c.sparsifier = SparsifierKind::RegTopK { k: 7, mu: 0.25, q: 2.0 };
        c.workers = 20;
        let j = c.to_json();
        let c2 = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c2.workers, 20);
        assert_eq!(c2.sparsifier, c.sparsifier);
    }

    /// The ISSUE 3 state-loss regression: EVERY field — including the
    /// formerly dropped `cost` and `omega_uniform` — survives the
    /// to_json/from_json round trip, so replaying a run from its own
    /// manifest reproduces the exact configuration.
    #[test]
    fn full_field_roundtrip_drops_nothing() {
        let c = TrainConfig {
            workers: 11,
            iters: 321,
            eta: 0.037,
            sparsifier: SparsifierKind::RegTopK { k: 13, mu: 0.125, q: 2.5 },
            omega_uniform: false,
            seed: 987654321,
            eval_every: 17,
            cost: crate::comm::CostModel {
                latency_s: 3.5e-4,
                bandwidth_bps: 2.5e8,
                value_bits: 16,
            },
            shards: 6,
            groups: Some(GradLayout::from_sizes([
                ("conv0.w".to_string(), 70),
                ("conv0.b".to_string(), 10),
                ("fc.w".to_string(), 20),
            ])),
            budget: Some(BudgetPolicy::PerGroup { ks: vec![7, 1, 2] }),
            policy: Some(
                PolicyTable::parse("conv*=regtopk:mu=0.5..0.1/100;*.b=dense;*=topk")
                    .unwrap(),
            ),
            downlink: Some(PolicyTable::parse("conv*=:bits=8,idx=rice;*=").unwrap()),
            transport: TransportKind::Tcp,
        };
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c, "a config field was dropped by the JSON round trip");
        // and the default config round-trips to itself as well
        let d = TrainConfig::default();
        assert_eq!(TrainConfig::from_json(&d.to_json()).unwrap(), d);
    }

    #[test]
    fn groupless_config_never_echoes_budget_or_policy() {
        // budget/policy without groups are never applied, so the
        // manifest echo must not claim them (the CLI rejects the
        // combination outright; a programmatic config just drops them)
        let mut c = TrainConfig::default();
        c.budget = Some(BudgetPolicy::Global { k: 5 });
        c.policy = Some(PolicyTable::parse("*=dense").unwrap());
        let j = c.to_json();
        assert!(j.get("budget").is_none());
        assert!(j.get("policy").is_none());
        let c2 = TrainConfig::from_json(&j).unwrap();
        assert!(c2.budget.is_none() && c2.policy.is_none());
    }

    #[test]
    fn downlink_roundtrips_flat_and_rejects_sparsifier_keys() {
        // downlink applies to flat runs too, so it is echoed without
        // groups — unlike budget/policy
        let mut c = TrainConfig::default();
        c.downlink = Some(PolicyTable::parse("*=:bits=8").unwrap());
        let j = c.to_json();
        assert!(j.get("downlink").is_some());
        let c2 = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c2.downlink, c.downlink);
        // the JSON path enforces codec-only downlink rules
        let bad = Json::parse(
            r#"{"downlink": [{"match": "*", "family": "topk"}]}"#,
        )
        .unwrap();
        assert!(TrainConfig::from_json(&bad).is_err());
        let auto = Json::parse(
            r#"{"downlink": [{"match": "*", "bits": {"auto": true, "lo": 4, "hi": 8}}]}"#,
        )
        .unwrap();
        assert!(TrainConfig::from_json(&auto).is_err(), "auto bits are worker-side only");
    }

    #[test]
    fn cost_model_previously_lost_in_roundtrip() {
        // the exact failure mode: a non-default link silently reverted
        let mut c = TrainConfig::default();
        c.cost.bandwidth_bps = 1e6;
        c.omega_uniform = false;
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cost.bandwidth_bps, 1e6);
        assert!(!c2.omega_uniform);
    }

    #[test]
    fn missing_keys_keep_defaults() {
        let j = Json::parse(r#"{"iters": 7}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.iters, 7);
        assert_eq!(c.workers, TrainConfig::default().workers);
        assert_eq!(c.shards, 1, "serial engine by default");
    }

    #[test]
    fn dgc_and_adak_params_roundtrip() {
        let mut c = TrainConfig::default();
        c.sparsifier = SparsifierKind::Dgc { k: 9, momentum: 0.7, clip: 3.0 };
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sparsifier, c.sparsifier);
        c.sparsifier = SparsifierKind::AdaK { ratio: 0.4, k_min: 2, k_max: 17 };
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sparsifier, c.sparsifier);
    }

    #[test]
    fn shards_roundtrip_and_effective_fallback() {
        let mut c = TrainConfig::default();
        c.shards = 8;
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.shards, 8);
        // below the engine threshold: always serial
        assert_eq!(c2.effective_shards(100), 1);
        // above it: the configured count
        assert_eq!(c2.effective_shards(1 << 20), 8);
        // auto resolves to the pool size (>= 1)
        c.shards = 0;
        assert!(c.effective_shards(1 << 20) >= 1);
    }

    #[test]
    fn groups_and_budget_roundtrip() {
        let mut c = TrainConfig::default();
        c.sparsifier = SparsifierKind::RegTopK { k: 10, mu: 0.5, q: 1.0 };
        c.groups = Some(GradLayout::from_sizes([
            ("conv".to_string(), 60),
            ("fc".to_string(), 40),
        ]));
        c.budget = Some(BudgetPolicy::Proportional { frac: 0.1 });
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.groups, c.groups);
        assert_eq!(c2.budget, c.budget);
        // layout_for validates the dimension
        assert_eq!(c2.layout_for(100).num_groups(), 2);
        // default (flat) config round-trips to no groups
        let flat = TrainConfig::from_json(&TrainConfig::default().to_json()).unwrap();
        assert!(flat.groups.is_none());
        assert!(flat.budget.is_none());
        assert!(flat.layout_for(7).is_single());
    }

    #[test]
    #[should_panic]
    fn layout_for_rejects_dim_mismatch() {
        let mut c = TrainConfig::default();
        c.groups = Some(GradLayout::single(10));
        c.layout_for(11);
    }

    #[test]
    fn build_sparsifier_flat_vs_grouped() {
        let mut c = TrainConfig::default();
        c.sparsifier = SparsifierKind::TopK { k: 4 };
        // flat: the family's own name
        assert_eq!(c.build_sparsifier(20, 0).name(), "topk");
        // grouped: the layerwise wrapper
        c.groups = Some(GradLayout::from_sizes([
            ("a".to_string(), 12),
            ("b".to_string(), 8),
        ]));
        assert_eq!(c.build_sparsifier(20, 0).name(), "layerwise");
        // default budget is Global{k from the sparsifier}
        assert_eq!(c.effective_budget(), BudgetPolicy::Global { k: 4 });
    }

    #[test]
    fn eta_scales_resolve_only_when_non_unit() {
        let mut c = TrainConfig::default();
        c.groups = Some(GradLayout::from_sizes([
            ("w".to_string(), 12),
            ("b".to_string(), 8),
        ]));
        assert!(c.eta_scales(20).is_none(), "no policy, no scales");
        c.policy = Some(PolicyTable::parse("b=dense").unwrap());
        assert!(c.eta_scales(20).is_none(), "policy without eta, no scales");
        c.policy = Some(PolicyTable::parse("b=dense:eta=2.5").unwrap());
        assert_eq!(
            c.eta_scales(20),
            Some(vec![(0, 12, 1.0), (12, 8, 2.5)]),
            "unmatched groups scale at 1.0"
        );
    }

    #[test]
    fn build_sparsifier_heterogeneous_policy() {
        let mut c = TrainConfig::default();
        c.sparsifier = SparsifierKind::TopK { k: 4 };
        c.groups = Some(GradLayout::from_sizes([
            ("w".to_string(), 12),
            ("b".to_string(), 8),
        ]));
        c.policy = Some(PolicyTable::parse("b=dense").unwrap());
        let sp = c.build_sparsifier(20, 0);
        assert_eq!(sp.name(), "layerwise");
        assert_eq!(sp.group_families(), vec!["topk", "dense"]);
        // a flat build reports its own single family
        c.groups = None;
        c.policy = None;
        assert_eq!(c.build_sparsifier(20, 0).group_families(), vec!["topk"]);
    }

    #[test]
    fn transport_roundtrips_and_rejects_unknown() {
        let mut c = TrainConfig::default();
        assert_eq!(c.transport, TransportKind::InProc, "seed-identical default");
        c.transport = TransportKind::Tcp;
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.transport, TransportKind::Tcp);
        let bad = Json::parse(r#"{"transport": "smoke-signals"}"#).unwrap();
        assert!(TrainConfig::from_json(&bad).is_err());
    }

    #[test]
    fn unknown_sparsifier_rejected() {
        let j = Json::parse(r#"{"sparsifier": {"name": "magic"}}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
    }

    #[test]
    fn uniform_omega_sums_to_one() {
        let c = TrainConfig { workers: 8, ..TrainConfig::default() };
        let total: f32 = (0..8).map(|n| c.omega(n)).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }
}
