//! `QuantPayload`: the packed low-bit value payload of one quantized
//! bucket — what actually crosses the wire when a group's policy sets
//! a `bits` override.
//!
//! Codes are offset-binary: a stochastic-rounding level `q` in
//! `[-L, +L]` (with `L = 2^(bits-1) - 1`) is stored as `q + L`, which
//! spans `[0, 2L]` and always fits in `bits` bits (2 <= bits <= 16).
//! Codes are bit-packed LSB-first into `u32` words; the shared `f32`
//! scale travels once per bucket.  Dequantization is exact and
//! deterministic — `(code - L) * scale` reproduces the worker-side
//! lossy values bit-for-bit, so the server can aggregate from the
//! packed payload alone (pinned by `rust/tests/quantized.rs`).
//!
//! The *wire accounting* is the single source of truth for the ledger:
//! [`QuantPayload::wire_bytes`] = `ceil(n*(bits + index_bits)/8)` plus
//! the 4-byte scale header, mirroring the paper's §2 cost model with
//! `bits` in place of the 32-bit value width.

/// Packed quantized values for one bucket.  `bits == 0` means the slot
/// is inactive (the bucket travels as raw f32, the pre-quantization
/// wire format).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantPayload {
    bits: usize,
    scale: f32,
    len: usize,
    words: Vec<u32>,
}

/// Quantization levels per side for a bit width: `2^(bits-1) - 1`.
pub fn quant_levels(bits: usize) -> i64 {
    debug_assert!((2..=16).contains(&bits));
    (1i64 << (bits - 1)) - 1
}

impl QuantPayload {
    /// Whether this slot carries a packed payload.
    pub fn is_active(&self) -> bool {
        self.bits != 0
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Number of packed codes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Deactivate, keeping the word buffer's capacity (per-round
    /// recycling in the trainer's update buffers).
    pub fn clear(&mut self) {
        self.bits = 0;
        self.scale = 0.0;
        self.len = 0;
        self.words.clear();
    }

    /// Pack `codes` at `bits` per code with the shared `scale`,
    /// recycling the word buffer.  Every code must fit in `bits` bits.
    pub fn encode_into(&mut self, bits: usize, scale: f32, codes: &[u32]) {
        assert!((2..=16).contains(&bits), "packable bit width is 2..=16, got {bits}");
        let mask = (1u32 << bits) - 1;
        self.bits = bits;
        self.scale = scale;
        self.len = codes.len();
        self.words.clear();
        self.words.resize((codes.len() * bits).div_ceil(32), 0);
        for (i, &code) in codes.iter().enumerate() {
            debug_assert_eq!(code & mask, code, "code {code} exceeds {bits} bits");
            let bitpos = i * bits;
            let (w, off) = (bitpos / 32, bitpos % 32);
            self.words[w] |= code << off;
            if off + bits > 32 {
                self.words[w + 1] |= code >> (32 - off);
            }
        }
    }

    /// Extract code `i`.
    pub fn code(&self, i: usize) -> u32 {
        assert!(i < self.len, "code index {i} out of {}", self.len);
        let mask = (1u32 << self.bits) - 1;
        let bitpos = i * self.bits;
        let (w, off) = (bitpos / 32, bitpos % 32);
        let mut code = self.words[w] >> off;
        if off + self.bits > 32 {
            code |= self.words[w + 1] << (32 - off);
        }
        code & mask
    }

    /// Dequantize code `i`: `(code - L) * scale`.  This is exactly the
    /// f32 the worker wrote into the bucket, so server-side decode
    /// reproduces the transmitted values bit-for-bit.
    pub fn decode_value(&self, i: usize) -> f32 {
        (self.code(i) as i64 - quant_levels(self.bits)) as f32 * self.scale
    }

    /// Dequantize the whole payload into a fresh vector.
    pub fn decode(&self) -> Vec<f32> {
        (0..self.len).map(|i| self.decode_value(i)).collect()
    }

    /// Wire bytes of `len` entries packed at `bits` per value with
    /// `index_bits` per index, plus the 4-byte scale header (empty
    /// payloads cost nothing).  Exposed as an associated fn so the
    /// worker can decide BEFORE packing whether quantization pays for
    /// a bucket at all (for tiny buckets the scale header can exceed
    /// the value-bit saving).
    pub fn bytes_for(len: usize, bits: usize, index_bits: usize) -> usize {
        if len == 0 {
            return 0;
        }
        (len * (bits + index_bits)).div_ceil(8) + 4
    }

    /// Wire bytes of this payload for a bucket whose index costs
    /// `index_bits` bits per entry.
    pub fn wire_bytes(&self, index_bits: usize) -> usize {
        Self::bytes_for(self.len, self.bits, index_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn pack_unpack_roundtrips_across_widths() {
        check::forall("quant_pack_roundtrip", |rng, _| {
            let bits = 2 + rng.below(15); // 2..=16
            let n = check::arb_len(rng, 200);
            let max_code = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max_code as usize + 1) as u32).collect();
            let mut p = QuantPayload::default();
            p.encode_into(bits, 0.5, &codes);
            assert_eq!(p.len(), n);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(p.code(i), c, "bits={bits} i={i}");
            }
        });
    }

    #[test]
    fn decode_is_offset_binary() {
        let mut p = QuantPayload::default();
        // bits=4 -> L=7; codes 0, 7, 14 -> -7, 0, +7 levels
        p.encode_into(4, 0.25, &[0, 7, 14]);
        assert_eq!(p.decode(), vec![-7.0 * 0.25, 0.0, 7.0 * 0.25]);
    }

    #[test]
    fn clear_deactivates_and_recycles() {
        let mut p = QuantPayload::default();
        assert!(!p.is_active());
        p.encode_into(8, 1.0, &[1, 2, 3]);
        assert!(p.is_active());
        let cap = p.words.capacity();
        p.clear();
        assert!(!p.is_active());
        assert_eq!(p.len(), 0);
        assert_eq!(p.words.capacity(), cap, "buffer capacity survives clear");
    }

    #[test]
    fn wire_bytes_packs_tight() {
        let mut p = QuantPayload::default();
        // 10 codes at 4 bits + 10 index bits each = 140 bits -> 18 B + 4 B scale
        p.encode_into(4, 1.0, &[0; 10]);
        assert_eq!(p.wire_bytes(10), 22);
        // empty payload: nothing on the wire
        p.encode_into(4, 1.0, &[]);
        assert_eq!(p.wire_bytes(10), 0);
    }

    #[test]
    fn levels_per_width() {
        assert_eq!(quant_levels(2), 1);
        assert_eq!(quant_levels(4), 7);
        assert_eq!(quant_levels(8), 127);
        assert_eq!(quant_levels(16), 32767);
    }

    #[test]
    fn codes_straddling_word_boundaries() {
        // 7-bit codes hit every 32-bit boundary misalignment
        let codes: Vec<u32> = (0..64).map(|i| (i * 2 + 1) % 128).collect();
        let mut p = QuantPayload::default();
        p.encode_into(7, 2.0, &codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(p.code(i), c, "i={i}");
        }
    }
}
