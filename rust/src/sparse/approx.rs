//! Approximate top-k via sampled thresholding (DESIGN.md ablation 4).
//!
//! For very large J, exact selection costs O(J) with a large constant
//! (full pass + partition).  The sampled-threshold scheme estimates the
//! k-th magnitude from a random subsample, then collects entries above
//! the estimated threshold in a single pass:
//!
//!   1. sample m = min(J, oversample * k) entries uniformly
//!   2. tau_hat = (k * m / J)-th largest magnitude of the sample
//!   3. emit entries with |x| >= tau_hat, clipped/padded to ~k
//!
//! Recall is tunable via `oversample`; the `approx_topk_recall` test
//! and the `topk_select` bench quantify the accuracy/latency trade-off.

#![forbid(unsafe_code)]

use crate::sparse::topk::select_topk;
use crate::util::rng::Rng;

/// Approximate top-k selection. Returns ascending indices; the result
/// has between ~0.5k and ~2k entries depending on threshold accuracy
/// (callers that need exactly k entries re-trim with `select_topk`).
pub fn select_topk_sampled(x: &[f32], k: usize, oversample: usize, rng: &mut Rng) -> Vec<u32> {
    let j = x.len();
    let k = k.min(j);
    if k == 0 {
        return Vec::new();
    }
    let m = (oversample.max(2) * k).min(j);
    if m >= j / 2 {
        // sampling would touch most of the vector anyway: do it exactly
        return select_topk(x, k);
    }
    // 1-2. sample magnitudes and take the proportional rank
    let sample_idx = rng.sample_indices(j, m);
    let sample: Vec<f32> = sample_idx.iter().map(|&i| x[i]).collect();
    // Proportional rank, biased 25% conservative (lower threshold):
    // over-collecting a few entries is cheap, missing true top-k
    // entries is what hurts recall.
    let rank = ((k as f64) * (m as f64) / (j as f64) * 1.25).ceil() as usize;
    let rank = rank.clamp(1, m);
    let thresh_idx = select_topk(&sample, rank);
    let tau = thresh_idx
        .iter()
        .map(|&i| sample[i as usize].abs())
        .fold(f32::INFINITY, f32::min);
    // 3. single pass collect
    let mut out: Vec<u32> = Vec::with_capacity(2 * k);
    for (i, &v) in x.iter().enumerate() {
        if v.abs() >= tau {
            out.push(i as u32);
        }
    }
    // keep the result bounded: if the threshold was too low, exact-trim
    if out.len() > 4 * k {
        let vals: Vec<f32> = out.iter().map(|&i| x[i as usize]).collect();
        let keep = select_topk(&vals, k);
        out = keep.iter().map(|&i| out[i as usize]).collect();
        out.sort_unstable();
    }
    out
}

/// Recall of an approximate selection vs the exact top-k set.
pub fn recall(exact: &[u32], approx: &[u32]) -> f64 {
    if exact.is_empty() {
        return 1.0;
    }
    let mut hit = 0usize;
    let mut ai = 0usize;
    for &e in exact {
        while ai < approx.len() && approx[ai] < e {
            ai += 1;
        }
        if ai < approx.len() && approx[ai] == e {
            hit += 1;
        }
    }
    hit as f64 / exact.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn high_recall_on_gaussian_vectors() {
        let mut rng = Rng::seed_from(42);
        let j = 50_000;
        let x = rng.gaussian_vec(j, 1.0);
        let k = 500;
        let exact = select_topk(&x, k);
        let approx = select_topk_sampled(&x, k, 8, &mut rng);
        let r = recall(&exact, &approx);
        assert!(r > 0.8, "recall {r}");
    }

    #[test]
    fn small_inputs_fall_back_to_exact() {
        check::forall("approx_small_exact", |rng, _| {
            let n = check::arb_len(rng, 64);
            let x = check::arb_vec(rng, n);
            let k = rng.below(n) + 1;
            let approx = select_topk_sampled(&x, k, 8, rng);
            assert_eq!(approx, select_topk(&x, k));
        });
    }

    #[test]
    fn result_is_sorted_and_bounded() {
        let mut rng = Rng::seed_from(7);
        let x = rng.gaussian_vec(20_000, 1.0);
        let k = 100;
        let sel = select_topk_sampled(&x, k, 4, &mut rng);
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
        assert!(sel.len() <= 4 * k, "len={}", sel.len());
    }

    #[test]
    fn recall_metric_sanity() {
        assert_eq!(recall(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(recall(&[1, 2, 3, 4], &[1, 3]), 0.5);
        assert_eq!(recall(&[], &[1]), 1.0);
        assert_eq!(recall(&[5], &[]), 0.0);
    }
}
