//! Sparse-vector substrate: representation, exact and approximate
//! top-k selection, and sparse aggregation.
//!
//! Everything the sparsifiers and the server's aggregation path need:
//!
//! - [`SparseVec`] — index+value pairs (indices strictly increasing),
//!   the wire format of a sparsified gradient.
//! - [`topk`] — exact k-largest-|x| selection (quickselect-based,
//!   O(J) average) with stable low-index tie-breaking that matches
//!   `ref.topk_mask` / `lax.top_k` on the python side.
//! - [`approx`] — sampled-threshold approximate selection for very
//!   large J (ablation 4 in DESIGN.md).
//! - [`engine`] — the sharded zero-allocation engine: fused
//!   score+select over the persistent thread pool, bit-identical to
//!   the serial selectors for every shard count.
//!
//! The bucketed wire format built on top of `SparseVec`
//! (`comm::SparseUpdate`, one bucket per parameter group) and all
//! encoding/byte accounting live one layer up in `comm` — this module
//! is the substrate below the wire and imports nothing from it.

pub mod approx;
pub mod engine;
pub mod topk;
mod vec;

pub use engine::SelectEngine;
pub use topk::{select_topk, topk_threshold};
pub use vec::SparseVec;
