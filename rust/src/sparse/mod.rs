//! Sparse-vector substrate: representation, exact and approximate
//! top-k selection, and sparse aggregation.
//!
//! Everything the sparsifiers and the server's aggregation path need:
//!
//! - [`SparseVec`] — index+value pairs (indices strictly increasing),
//!   the wire format of a sparsified gradient.
//! - [`topk`] — exact k-largest-|x| selection (quickselect-based,
//!   O(J) average) with stable low-index tie-breaking that matches
//!   `ref.topk_mask` / `lax.top_k` on the python side.
//! - [`approx`] — sampled-threshold approximate selection for very
//!   large J (ablation 4 in DESIGN.md).
//! - [`engine`] — the sharded zero-allocation engine: fused
//!   score+select over the persistent thread pool, bit-identical to
//!   the serial selectors for every shard count.
//! - [`SparseUpdate`] — the bucketed wire format of the layer-wise
//!   API: one `SparseVec` per parameter group with group-local
//!   indices (cheaper index bits per entry).
//!
//! Encoding a bucket into bytes — packed low-bit values, entropy-coded
//! indices, and ALL byte accounting — lives in `comm::codec` (the
//! pluggable wire-codec stack); buckets here only carry the codec
//! slots (`comm::codec::WirePayload`) the encoders write into.

pub mod approx;
pub mod engine;
pub mod topk;
mod update;
mod vec;

pub use engine::SelectEngine;
pub use topk::{select_topk, topk_threshold};
pub use update::SparseUpdate;
pub use vec::SparseVec;
