//! Sparse-vector substrate: representation, exact and approximate
//! top-k selection, and sparse aggregation.
//!
//! Everything the sparsifiers and the server's aggregation path need:
//!
//! - [`SparseVec`] — index+value pairs (indices strictly increasing),
//!   the wire format of a sparsified gradient.
//! - [`topk`] — exact k-largest-|x| selection (quickselect-based,
//!   O(J) average) with stable low-index tie-breaking that matches
//!   `ref.topk_mask` / `lax.top_k` on the python side.
//! - [`approx`] — sampled-threshold approximate selection for very
//!   large J (ablation 4 in DESIGN.md).
//! - [`engine`] — the sharded zero-allocation engine: fused
//!   score+select over the persistent thread pool, bit-identical to
//!   the serial selectors for every shard count.
//! - [`SparseUpdate`] — the bucketed wire format of the layer-wise
//!   API: one `SparseVec` per parameter group with group-local
//!   indices (cheaper index bits per entry).
//! - [`QuantPayload`] — packed low-bit value codes for quantized
//!   buckets (per-group `bits` policies): `bits` value bits per entry
//!   instead of 32, plus one shared f32 scale per bucket.

pub mod approx;
pub mod engine;
mod packed;
pub mod topk;
mod update;
mod vec;

pub use engine::SelectEngine;
pub use packed::{quant_levels, QuantPayload};
pub use topk::{select_topk, topk_threshold};
pub use update::SparseUpdate;
pub use vec::SparseVec;

/// Per-entry index cost in bits: `ceil(log2 dim)` with the `dim >= 2`
/// clamp (paper §2: "the index can be losslessly represented by log J
/// bits").  The single source for every place the cost model meets
/// the wire — `SparseVec::wire_bytes`, the bucketed update, and both
/// `CostModel` byte accountants.
pub fn index_bits(dim: usize) -> usize {
    (usize::BITS - (dim.max(2) - 1).leading_zeros()) as usize
}
