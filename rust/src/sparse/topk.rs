//! Exact top-k selection over |x| — the Top_k(.) selector of eq. (5).
//!
//! Selection is by magnitude with ties broken toward the LOWER index
//! (stable), matching `lax.top_k` / `ref.topk_mask` on the python side
//! so the two implementations are bit-compatible (integration test
//! `rust/tests/hlo_cross_check.rs`).
//!
//! Algorithm: quickselect over (|x|, index) keys, O(J) average, then an
//! O(k log k) sort of the selected prefix to emit sorted indices.  For
//! k >= J it degenerates to "select all".

#![forbid(unsafe_code)]

/// Composite ordering key: larger |v| wins; on exact magnitude ties the
/// lower index wins.
#[inline]
fn better(a_mag: f32, a_idx: u32, b_mag: f32, b_idx: u32) -> bool {
    a_mag > b_mag || (a_mag == b_mag && a_idx < b_idx)
}

/// Indices of the k largest-|x| entries, sorted ascending.
/// NaNs are treated as magnitude 0 (never preferred).
///
/// Dispatch (perf pass, EXPERIMENTS.md §Perf): for k << J the
/// radix-bucket path ([`select_topk_radix`]) does two sequential O(J)
/// passes with a 256-bucket histogram — ~6x faster than quickselect at
/// J=1e6, S=0.1% because it never materializes the (mag, idx) key
/// array.  Larger k falls back to quickselect.
pub fn select_topk(x: &[f32], k: usize) -> Vec<u32> {
    let j = x.len();
    let k_eff = k.min(j);
    if k_eff > 0 && k_eff < j && j >= 4096 && k_eff <= j / 8 {
        return select_topk_radix(x, k_eff);
    }
    select_topk_quick(x, k)
}

// The order-preserving magnitude-bits map lives in the kernel layer
// (PR 10) so the serial radix path, the sharded engine and the chunked
// kernels all bucket through literally the same function; re-exported
// here because this module owns the selection semantics built on it.
pub(crate) use crate::util::kernels::mag_bits;

/// Walk 256-bucket magnitude counts from the top until the cumulative
/// count reaches `k`: returns `(boundary_bucket, entries_above)` where
/// `entries_above` counts buckets strictly above the boundary.  The
/// single boundary rule shared by [`select_topk_radix`] and the
/// sharded engine ([`crate::sparse::engine`]) — the bit-identity
/// contract between the two paths hinges on this staying one function.
pub(crate) fn boundary_bucket(counts: &[usize; 256], k: usize) -> (usize, usize) {
    let mut above = 0usize;
    let mut b = 255usize;
    loop {
        if above + counts[b] >= k || b == 0 {
            break;
        }
        above += counts[b];
        b -= 1;
    }
    (b, above)
}

/// Radix-bucket top-k for k << J: histogram the top byte of the
/// magnitude bits, locate the boundary bucket, take everything above
/// it, and exact-select the remainder inside the boundary bucket
/// (expected J/256 candidates).  Tie-breaking matches quickselect:
/// equal magnitudes prefer the lower index, because the boundary-bucket
/// candidates are collected in ascending index order.
pub fn select_topk_radix(x: &[f32], k: usize) -> Vec<u32> {
    let j = x.len();
    debug_assert!(k > 0 && k < j);
    // pass 1: 256-bucket histogram of the high byte
    let mut counts = [0usize; 256];
    for &v in x {
        counts[(mag_bits(v) >> 24) as usize] += 1;
    }
    let (b, above) = boundary_bucket(&counts, k);
    let need = k - above; // how many to take from bucket b
    // pass 2: collect winners from above-buckets and candidates at b
    let mut out: Vec<u32> = Vec::with_capacity(k);
    let mut cand_idx: Vec<u32> = Vec::with_capacity(counts[b].min(j));
    let mut cand_val: Vec<f32> = Vec::with_capacity(counts[b].min(j));
    // u64 floor avoids overflow when the boundary bucket is 255
    // (infinities / values >= 2^128 land there).
    let hi_floor: u64 = ((b as u64) + 1) << 24;
    for (i, &v) in x.iter().enumerate() {
        let m = mag_bits(v);
        if (m as u64) >= hi_floor {
            out.push(i as u32);
        } else if (m >> 24) as usize == b {
            cand_idx.push(i as u32);
            cand_val.push(v);
        }
    }
    // exact select among the boundary candidates (index order preserved
    // => quickselect's positional tie-break equals global index order)
    if need > 0 {
        let chosen = select_topk_quick(&cand_val, need);
        out.extend(chosen.into_iter().map(|c| cand_idx[c as usize]));
    }
    out.sort_unstable();
    debug_assert_eq!(out.len(), k);
    out
}

/// Quickselect top-k (the general-k path; also the exact selector the
/// radix path uses inside the boundary bucket).
pub fn select_topk_quick(x: &[f32], k: usize) -> Vec<u32> {
    let j = x.len();
    let k = k.min(j);
    if k == 0 {
        return Vec::new();
    }
    if k == j {
        return (0..j as u32).collect();
    }
    let mut keys: Vec<(f32, u32)> = x
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let m = v.abs();
            (if m.is_nan() { 0.0 } else { m }, i as u32)
        })
        .collect();
    quickselect_keys(&mut keys, k);
    let mut out: Vec<u32> = keys[..k].iter().map(|&(_, i)| i).collect();
    out.sort_unstable();
    out
}

/// Partially order `keys` so `keys[..k]` hold the k best `(mag, idx)`
/// entries under [`better`] (in arbitrary order).  The exact-select
/// kernel behind [`select_topk_quick`] and the boundary-bucket step of
/// the sharded engine; both therefore share one tie-break definition.
///
/// Deterministic LCG pivots avoid adversarial quadratic behaviour on
/// sorted inputs without an RNG dependency; the pivot sequence depends
/// only on (len, k), never on addresses or threads.
pub(crate) fn quickselect_keys(keys: &mut [(f32, u32)], k: usize) {
    let j = keys.len();
    debug_assert!(k <= j);
    if k == 0 || k >= j {
        return;
    }
    let mut lo = 0usize;
    let mut hi = j;
    let mut state: u64 = 0x2545F4914F6CDD1D;
    while hi - lo > 1 {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let pivot_at = lo + (state >> 33) as usize % (hi - lo);
        keys.swap(lo, pivot_at);
        let (pm, pi) = keys[lo];
        // Lomuto-style partition: entries better than the pivot move to
        // the front; the pivot ends at index `p` with exactly `p`
        // better entries before it.
        let mut i = lo + 1;
        for scan in lo + 1..hi {
            let (m, ix) = keys[scan];
            if better(m, ix, pm, pi) {
                keys.swap(i, scan);
                i += 1;
            }
        }
        keys.swap(lo, i - 1);
        let p = i - 1;
        if p == k {
            break; // keys[..k] are exactly the k best
        } else if p > k {
            hi = p;
        } else {
            lo = p + 1;
        }
    }
}

/// The k-th largest magnitude (the selection threshold tau), used by
/// the two-phase HLO path: phase-2 of DESIGN.md §Hardware-Adaptation.
/// Returns 0.0 for k == 0 and the min magnitude for k >= J.
pub fn topk_threshold(x: &[f32], k: usize) -> f32 {
    if k == 0 {
        return f32::INFINITY;
    }
    let idx = select_topk(x, k);
    idx.iter()
        .map(|&i| x[i as usize].abs())
        .fold(f32::INFINITY, f32::min)
}

/// Reference O(J log J) implementation (full sort) — used by tests and
/// as the fallback oracle for the property suite.
pub fn select_topk_sort(x: &[f32], k: usize) -> Vec<u32> {
    let j = x.len();
    let k = k.min(j);
    let mut order: Vec<u32> = (0..j as u32).collect();
    order.sort_by(|&a, &b| {
        let ma = x[a as usize].abs();
        let mb = x[b as usize].abs();
        let ma = if ma.is_nan() { 0.0 } else { ma };
        let mb = if mb.is_nan() { 0.0 } else { mb };
        mb.partial_cmp(&ma).unwrap().then(a.cmp(&b))
    });
    let mut out = order[..k].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn matches_sort_oracle_on_random_inputs() {
        check::forall("topk_vs_sort", |rng, _| {
            let n = check::arb_len(rng, 300);
            let x = check::arb_vec(rng, n);
            let k = rng.below(n + 2);
            assert_eq!(select_topk(&x, k), select_topk_sort(&x, k), "n={n} k={k}");
        });
    }

    #[test]
    fn selects_k_largest_magnitudes() {
        check::forall("topk_magnitudes", |rng, _| {
            let n = check::arb_len(rng, 300);
            let x = check::arb_vec(rng, n);
            let k = rng.below(n) + 1;
            let sel = select_topk(&x, k);
            assert_eq!(sel.len(), k.min(n));
            let selected: Vec<bool> = {
                let mut b = vec![false; n];
                for &i in &sel {
                    b[i as usize] = true;
                }
                b
            };
            let min_in = sel.iter().map(|&i| x[i as usize].abs()).fold(f32::INFINITY, f32::min);
            for i in 0..n {
                if !selected[i] {
                    assert!(
                        x[i].abs() <= min_in,
                        "unselected {} > min selected {}",
                        x[i].abs(),
                        min_in
                    );
                }
            }
        });
    }

    #[test]
    fn radix_matches_sort_oracle_small_k() {
        check::forall("radix_vs_sort", |rng, _| {
            let n = 4096 + rng.below(4096);
            let x = check::arb_vec(rng, n);
            let k = rng.below(n / 8) + 1;
            assert_eq!(
                select_topk_radix(&x, k),
                select_topk_sort(&x, k),
                "n={n} k={k}"
            );
        });
    }

    #[test]
    fn radix_top_bucket_boundary_no_overflow() {
        // infinities and huge values live in bucket 255; the boundary
        // floor must not overflow u32
        let mut x = vec![0.5f32; 8192];
        x[7] = f32::INFINITY;
        x[9] = f32::MAX;
        x[11] = 3.0e38;
        assert_eq!(select_topk_radix(&x, 2), vec![7, 9]);
        assert_eq!(select_topk_radix(&x, 3), vec![7, 9, 11]);
        assert_eq!(select_topk_radix(&x, 4), select_topk_sort(&x, 4));
    }

    #[test]
    fn radix_handles_nan_and_duplicates() {
        let mut x = vec![1.0f32; 8192];
        x[0] = f32::NAN;
        x[100] = 7.0;
        x[4000] = -7.0;
        let sel = select_topk_radix(&x, 3);
        assert_eq!(sel, vec![1, 100, 4000]); // 7s first, then lowest-index 1.0... 
    }

    #[test]
    fn tie_break_prefers_lower_index() {
        let x = vec![1.0, -1.0, 1.0, 0.5];
        assert_eq!(select_topk(&x, 2), vec![0, 1]);
        assert_eq!(select_topk(&x, 3), vec![0, 1, 2]);
    }

    #[test]
    fn edge_cases() {
        assert!(select_topk(&[], 3).is_empty());
        assert!(select_topk(&[1.0, 2.0], 0).is_empty());
        assert_eq!(select_topk(&[1.0, 2.0], 5), vec![0, 1]);
        assert_eq!(select_topk(&[0.0, 0.0, 0.0], 2), vec![0, 1]);
    }

    #[test]
    fn nan_never_selected_over_finite() {
        let x = vec![f32::NAN, 1.0, 0.5];
        assert_eq!(select_topk(&x, 1), vec![1]);
        assert_eq!(select_topk(&x, 2), vec![1, 2]);
    }

    #[test]
    fn threshold_is_kth_magnitude() {
        let x = vec![5.0, -3.0, 1.0, -4.0, 2.0];
        assert_eq!(topk_threshold(&x, 1), 5.0);
        assert_eq!(topk_threshold(&x, 3), 3.0);
        assert_eq!(topk_threshold(&x, 5), 1.0);
        assert_eq!(topk_threshold(&x, 0), f32::INFINITY);
    }

    #[test]
    fn sorted_inputs_no_quadratic_blowup() {
        // 100k ascending values — pivot randomization keeps this fast;
        // the test is a smoke guard (completes well under the default
        // 60s test timeout even in debug).
        let x: Vec<f32> = (0..100_000).map(|i| i as f32).collect();
        let sel = select_topk(&x, 10);
        assert_eq!(sel, (99_990..100_000).collect::<Vec<u32>>());
    }
}
