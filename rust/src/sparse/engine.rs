//! The sharded sparsification engine: fused score + top-k select over
//! a persistent thread pool, with all scratch reused across rounds.
//!
//! The seed hot path did three sequential O(J) passes per worker per
//! round (error-feedback accumulate, score, select) with fresh
//! allocations in each.  [`SelectEngine`] collapses this to two
//! parallel passes and zero steady-state allocation:
//!
//! - **pass 1 (fused fill + histogram):** each shard computes its
//!   slice of the score vector (the caller's closure — accumulate,
//!   RegTop-k score, DGC velocity update, ... ) and, in the same
//!   cache-blocked pass, a 256-bucket histogram of the high byte of
//!   the magnitude bits (the chunked
//!   [`kernels::fill_abs_hist`](crate::util::kernels::fill_abs_hist),
//!   bit-identical to its scalar referee).
//! - **merge:** histograms are summed (256 x shards adds) and walked
//!   from the top to find the boundary bucket — exactly the
//!   [`select_topk_radix`](crate::sparse::topk::select_topk_radix)
//!   boundary rule.
//! - **pass 2 (collect):** each shard gathers its winners (strictly
//!   above the boundary bucket) and boundary-bucket candidates into
//!   per-shard reusable buffers.
//! - **exact select:** candidates are concatenated in shard order
//!   (== ascending global index order) and the remaining `need`
//!   entries are chosen by the same
//!   [`quickselect_keys`](crate::sparse::topk) kernel the serial path
//!   uses, so ties break toward the lower index **bit-identically to
//!   `select_topk_sort`** for every shard count (property-tested in
//!   `rust/tests/sharded_select.rs` across shards in {1, 2, 3, 8}).
//!
//! Determinism: shard ranges come from [`shard_range`], merges happen
//! in shard order on the caller, and the exact-select kernel is
//! deterministic — so results are independent of thread scheduling and
//! of the shard count itself.

use crate::sparse::topk::{boundary_bucket, quickselect_keys};
use crate::util::kernels;
use crate::util::pool::{self, shard_range, SharedSlice};

/// Below this dimension the trainer keeps sparsifiers on the serial
/// path: a parallel pass over a few thousand elements costs more in
/// handoff than it saves (see EXPERIMENTS.md §Perf).  Callers that
/// want sharding on smaller inputs (tests, benches) can still drive
/// [`SelectEngine`] directly.
pub const MIN_SHARDED_DIM: usize = 1 << 15;

/// Reusable sharded top-k selector.  One engine per sparsifier; all
/// buffers grow to their steady-state size on the first round and are
/// reused afterwards (zero heap allocation per round).
pub struct SelectEngine {
    shards: usize,
    /// per-shard 256-bucket histograms of the magnitude high byte
    hists: Vec<[u32; 256]>,
    /// per-shard winner indices (strictly above the boundary bucket)
    winners: Vec<Vec<u32>>,
    /// per-shard boundary-bucket candidate indices/values
    cand_idx: Vec<Vec<u32>>,
    cand_val: Vec<Vec<f32>>,
    /// scratch for the exact select among boundary candidates
    keys: Vec<(f32, u32)>,
}

impl SelectEngine {
    /// `shards >= 1`; `shards == 1` is valid and still uses the fused
    /// single-pass structure (just without the pool handoff).
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        SelectEngine {
            shards,
            hists: vec![[0u32; 256]; shards],
            winners: (0..shards).map(|_| Vec::new()).collect(),
            cand_idx: (0..shards).map(|_| Vec::new()).collect(),
            cand_val: (0..shards).map(|_| Vec::new()).collect(),
            keys: Vec::new(),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Indices of the k largest-|x| entries of `x`, sorted ascending,
    /// written into `out` (reused, no allocation at steady state).
    /// Bit-identical to `select_topk_sort(x, k)`.
    pub fn select_into(&mut self, x: &[f32], k: usize, out: &mut Vec<u32>) {
        let j = x.len();
        let k_eff = k.min(j);
        out.clear();
        if k_eff == 0 {
            return;
        }
        if k_eff == j {
            out.extend(0..j as u32);
            return;
        }
        self.pass1_hist(x);
        self.finish(x, k_eff, out);
    }

    /// Fused score + select: `fill(lo, slice)` must write the scores
    /// for the global range `[lo, lo + slice.len())` into `slice`; the
    /// engine histograms each shard's slice in the same parallel pass,
    /// then selects the top `k` of `|score|` into `out` (sorted
    /// ascending, bit-identical to `select_topk_sort(score, k)`).
    ///
    /// `fill` always runs over the whole vector — even for the trivial
    /// budgets k = 0 / k >= J — because callers fuse state updates
    /// (e.g. error-feedback accumulate) into it.
    pub fn fused_select_into<F>(&mut self, score: &mut [f32], fill: F, k: usize, out: &mut Vec<u32>)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let j = score.len();
        let k_eff = k.min(j);
        if k_eff == 0 || k_eff == j {
            // degenerate budget: still materialize the fused buffer
            self.fill_only(score, &fill);
            out.clear();
            if k_eff == j {
                out.extend(0..j as u32);
            }
            return;
        }
        self.pass1_fill_hist(score, &fill);
        self.finish(score, k_eff, out);
    }

    /// Parallel fill without histogramming (degenerate-budget path).
    /// `for_shards` owns the disjointness argument — no unsafe here.
    fn fill_only<F: Fn(usize, &mut [f32]) + Sync>(&self, score: &mut [f32], fill: &F) {
        pool::global().for_shards(score, self.shards, |_s, lo, slice| fill(lo, slice));
    }

    /// Pass 1, histogram-only variant (the input already exists).
    /// Each shard owns exactly its histogram slot, which is `map_mut`'s
    /// contract — no unsafe here.
    fn pass1_hist(&mut self, x: &[f32]) {
        let j = x.len();
        let shards = self.shards;
        pool::global().map_mut(&mut self.hists, |s, h| {
            let (lo, hi) = shard_range(j, shards, s);
            h.fill(0);
            kernels::abs_hist(&x[lo..hi], h);
        });
    }

    /// Pass 1, fused variant: fill the score slice and histogram it in
    /// one loop per shard.  Two slices are sharded by one task index,
    /// so this keeps raw [`SharedSlice`] hand-outs.
    fn pass1_fill_hist<F: Fn(usize, &mut [f32]) + Sync>(&mut self, score: &mut [f32], fill: &F) {
        let j = score.len();
        let shards = self.shards;
        let hist_sh = SharedSlice::new(&mut self.hists);
        let score_sh = SharedSlice::new(score);
        pool::global().run(shards, |s| {
            let (lo, hi) = shard_range(j, shards, s);
            // SAFETY: shard_range gives disjoint `[lo, hi)` score
            // ranges per task index, and `score` outlives the run.
            let slice = unsafe { score_sh.range(lo, hi) };
            // SAFETY: task `s` touches only histogram slot `s`, so the
            // one-element views are disjoint; `self.hists` outlives
            // the run.
            let h = unsafe { &mut hist_sh.range(s, s + 1)[0] };
            // blocked fused fill+hist: the closure contract (write the
            // scores for the global range, position-pure) already
            // permits arbitrary sub-ranges — shard boundaries are
            // arbitrary — so the kernel may block finer for locality.
            kernels::fill_abs_hist(lo, slice, h, |l, sl| fill(l, sl));
        });
    }

    /// Merge histograms, locate the boundary bucket, collect winners +
    /// candidates per shard (pass 2), exact-select the remainder.
    /// Requires `0 < k < x.len()`.
    fn finish(&mut self, x: &[f32], k: usize, out: &mut Vec<u32>) {
        let j = x.len();
        let shards = self.shards;
        // merge histograms, then locate the boundary with the same
        // walk select_topk_radix uses (shared fn = shared tie-break)
        let mut counts = [0usize; 256];
        for h in &self.hists {
            for (c, &v) in counts.iter_mut().zip(h.iter()) {
                *c += v as usize;
            }
        }
        let (b, above) = boundary_bucket(&counts, k);
        let need = k - above;
        // u64 floor avoids overflow when the boundary bucket is 255
        let hi_floor: u64 = ((b as u64) + 1) << 24;
        // pass 2: per-shard winner/candidate collection (parallel)
        {
            let win_sh = SharedSlice::new(&mut self.winners);
            let ci_sh = SharedSlice::new(&mut self.cand_idx);
            let cv_sh = SharedSlice::new(&mut self.cand_val);
            pool::global().run(shards, |s| {
                let (lo, hi) = shard_range(j, shards, s);
                // SAFETY: task `s` touches only winner buffer `s` —
                // one-element views are disjoint across tasks and
                // `self.winners` outlives the run.
                let w = unsafe { &mut win_sh.range(s, s + 1)[0] };
                // SAFETY: same per-task-slot argument for the
                // candidate index buffers (`self.cand_idx`).
                let ci = unsafe { &mut ci_sh.range(s, s + 1)[0] };
                // SAFETY: same per-task-slot argument for the
                // candidate value buffers (`self.cand_val`).
                let cv = unsafe { &mut cv_sh.range(s, s + 1)[0] };
                w.clear();
                ci.clear();
                cv.clear();
                kernels::boundary_collect(lo as u32, &x[lo..hi], b, hi_floor, w, ci, cv);
            });
        }
        // merge in shard order == ascending global index order, so the
        // exact select's lower-index tie-break matches the sort oracle
        out.clear();
        self.keys.clear();
        for s in 0..shards {
            out.extend_from_slice(&self.winners[s]);
            for (&i, &v) in self.cand_idx[s].iter().zip(&self.cand_val[s]) {
                let m = v.abs();
                self.keys.push((if m.is_nan() { 0.0 } else { m }, i));
            }
        }
        if need > 0 {
            quickselect_keys(&mut self.keys, need);
            out.extend(self.keys[..need].iter().map(|&(_, i)| i));
        }
        out.sort_unstable();
        debug_assert_eq!(out.len(), k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::topk::select_topk_sort;
    use crate::util::check;

    fn select(shards: usize, x: &[f32], k: usize) -> Vec<u32> {
        let mut eng = SelectEngine::new(shards);
        let mut out = Vec::new();
        eng.select_into(x, k, &mut out);
        out
    }

    #[test]
    fn matches_sort_oracle_across_shard_counts() {
        check::forall("engine_vs_sort", |rng, _| {
            let n = check::arb_len(rng, 400);
            let x = check::arb_vec(rng, n);
            let k = rng.below(n + 2);
            let want = select_topk_sort(&x, k);
            for shards in [1usize, 2, 3, 8] {
                assert_eq!(select(shards, &x, k), want, "n={n} k={k} shards={shards}");
            }
        });
    }

    #[test]
    fn fused_fill_runs_even_for_degenerate_budgets() {
        let mut eng = SelectEngine::new(3);
        let mut score = vec![0.0f32; 100];
        let mut out = vec![7u32];
        eng.fused_select_into(&mut score, |lo, s| {
            for (off, v) in s.iter_mut().enumerate() {
                *v = (lo + off) as f32;
            }
        }, 0, &mut out);
        assert!(out.is_empty());
        assert_eq!(score[99], 99.0, "fill must run at k=0");
        eng.fused_select_into(&mut score, |lo, s| {
            for (off, v) in s.iter_mut().enumerate() {
                *v = -((lo + off) as f32);
            }
        }, 200, &mut out);
        assert_eq!(out.len(), 100);
        assert_eq!(score[99], -99.0, "fill must run at k>=J");
    }

    #[test]
    fn fused_matches_separate_fill_then_select() {
        check::forall("engine_fused_vs_split", |rng, _| {
            let n = check::arb_len(rng, 300);
            let base = check::arb_vec(rng, n);
            let k = rng.below(n) + 1;
            // fused: score = 2*base + 1
            let mut eng = SelectEngine::new(4);
            let mut score = vec![0.0f32; n];
            let mut out = Vec::new();
            eng.fused_select_into(&mut score, |lo, s| {
                for (off, v) in s.iter_mut().enumerate() {
                    *v = 2.0 * base[lo + off] + 1.0;
                }
            }, k, &mut out);
            // split reference
            let reference: Vec<f32> = base.iter().map(|&v| 2.0 * v + 1.0).collect();
            assert_eq!(score, reference);
            assert_eq!(out, select_topk_sort(&reference, k));
        });
    }

    #[test]
    fn handles_infinities_nans_and_ties() {
        let mut x = vec![1.0f32; 9000];
        x[0] = f32::NAN;
        x[7] = f32::INFINITY;
        x[9] = f32::MAX;
        x[4000] = -f32::MAX;
        for shards in [1usize, 2, 8] {
            assert_eq!(select(shards, &x, 1), vec![7]);
            assert_eq!(select(shards, &x, 3), vec![7, 9, 4000]);
            // ties: lowest indices of the 1.0 plateau win; NaN never selected
            assert_eq!(select(shards, &x, 5), select_topk_sort(&x, 5));
            assert_eq!(select(shards, &x, 5), vec![1, 2, 7, 9, 4000]);
        }
    }

    #[test]
    fn steady_state_reuses_buffers() {
        let mut eng = SelectEngine::new(4);
        let mut out = Vec::new();
        let x: Vec<f32> = (0..50_000).map(|i| ((i * 2654435761u64 as usize) % 977) as f32).collect();
        eng.select_into(&x, 50, &mut out);
        let caps: Vec<usize> = eng.winners.iter().chain(&eng.cand_idx).map(Vec::capacity).collect();
        let keys_cap = eng.keys.capacity();
        let out_cap = out.capacity();
        for _ in 0..5 {
            eng.select_into(&x, 50, &mut out);
        }
        let caps2: Vec<usize> = eng.winners.iter().chain(&eng.cand_idx).map(Vec::capacity).collect();
        assert_eq!(caps, caps2, "scratch must not be reallocated");
        assert_eq!(keys_cap, eng.keys.capacity());
        assert_eq!(out_cap, out.capacity());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(select(4, &[], 3).is_empty());
        assert_eq!(select(4, &[2.0], 1), vec![0]);
        assert_eq!(select(8, &[1.0, -3.0, 2.0], 2), vec![1, 2]);
        assert!(select(2, &[1.0, 2.0], 0).is_empty());
        assert_eq!(select(2, &[1.0, 2.0], 9), vec![0, 1]);
    }
}
