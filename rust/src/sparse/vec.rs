//! `SparseVec`: the wire format of a sparsified gradient.

#![forbid(unsafe_code)]

/// A sparse view of a length-`dim` dense vector: parallel arrays of
/// strictly-increasing indices and their values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    dim: usize,
    idx: Vec<u32>,
    val: Vec<f32>,
}

impl SparseVec {
    /// Build from parallel arrays. Panics if indices are not strictly
    /// increasing or out of range (violating the wire invariant).
    pub fn new(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(idx.len(), val.len(), "index/value length mismatch");
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "indices must be strictly increasing");
        }
        if let Some(&last) = idx.last() {
            assert!((last as usize) < dim, "index {last} out of dim {dim}");
        }
        SparseVec { dim, idx, val }
    }

    /// Empty sparse vector.
    pub fn zeros(dim: usize) -> Self {
        SparseVec { dim, idx: Vec::new(), val: Vec::new() }
    }

    /// Reset to an empty vector of dimension `dim`, keeping the entry
    /// buffers' capacity (bucket-recycling path of `SparseUpdate`).
    pub fn reset(&mut self, dim: usize) {
        self.dim = dim;
        self.idx.clear();
        self.val.clear();
    }

    /// Append one entry; the wire invariant (strictly increasing
    /// in-range indices) is enforced at the point of insertion.
    pub fn push(&mut self, idx: u32, val: f32) {
        if let Some(&last) = self.idx.last() {
            assert!(idx > last, "indices must be strictly increasing ({last} then {idx})");
        }
        assert!((idx as usize) < self.dim, "index {idx} out of dim {}", self.dim);
        self.idx.push(idx);
        self.val.push(val);
    }

    /// Gather `dense[i]` for every `i` in a sorted index list.
    pub fn gather(dense: &[f32], idx: &[u32]) -> Self {
        let val = idx.iter().map(|&i| dense[i as usize]).collect();
        SparseVec::new(dense.len(), idx.to_vec(), val)
    }

    /// [`Self::gather`] into an existing vector, recycling its buffers
    /// (the hot-path variant used by `Sparsifier::step_into`: zero
    /// allocation once `out` has reached steady-state capacity).  The
    /// wire invariant stays ALWAYS-ON: every sparsifier round now
    /// routes through here, and the O(k) check is negligible next to
    /// the O(J) passes it guards — a selector bug must panic at the
    /// source, not corrupt aggregation downstream.
    pub fn gather_into(dense: &[f32], idx: &[u32], out: &mut SparseVec) {
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "indices must be strictly increasing");
        }
        if let Some(&last) = idx.last() {
            assert!((last as usize) < dense.len(), "index {last} out of dim {}", dense.len());
        }
        out.dim = dense.len();
        out.idx.clear();
        out.idx.extend_from_slice(idx);
        out.val.clear();
        out.val.extend(idx.iter().map(|&i| dense[i as usize]));
    }

    /// Densify into a fresh vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    /// `out += scale * self` (server-side aggregation hot path);
    /// rides the chunked [`kernels::scatter_add`] — bit-identical to
    /// the element-at-a-time loop by the kernel contract.
    pub fn axpy_into(&self, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        crate::util::kernels::scatter_add(out, &self.idx, &self.val, scale);
    }

    /// Bulk-append a sorted tail block (the sharded-merge concat
    /// path): one boundary check instead of a per-entry invariant
    /// assert, then two slice copies.
    pub fn append_tail(&mut self, idx: &[u32], val: &[f32]) {
        assert_eq!(idx.len(), val.len(), "index/value length mismatch");
        let Some(&first) = idx.first() else { return };
        if let Some(&last) = self.idx.last() {
            assert!(first > last, "indices must be strictly increasing ({last} then {first})");
        }
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "tail block must be sorted");
        assert!((idx[idx.len() - 1] as usize) < self.dim, "index out of dim {}", self.dim);
        self.idx.extend_from_slice(idx);
        self.val.extend_from_slice(val);
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }
    pub fn indices(&self) -> &[u32] {
        &self.idx
    }
    pub fn values(&self) -> &[f32] {
        &self.val
    }

    /// Mutable access to the values (the quantization path rewrites
    /// transmitted values in place; indices stay immutable so the wire
    /// invariant cannot be broken from here).
    pub fn values_mut(&mut self) -> &mut [f32] {
        &mut self.val
    }

    /// ell-2 norm of the stored values.
    pub fn norm2(&self) -> f32 {
        self.val.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Dot with a dense vector.
    pub fn dot(&self, dense: &[f32]) -> f32 {
        debug_assert_eq!(dense.len(), self.dim);
        self.idx
            .iter()
            .zip(&self.val)
            .map(|(&i, &v)| v * dense[i as usize])
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn roundtrip_dense_sparse_dense() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let sv = SparseVec::new(5, vec![1, 3], vec![1.5, -2.0]);
        assert_eq!(sv.to_dense(), dense);
        assert_eq!(sv.nnz(), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_unsorted_indices() {
        SparseVec::new(5, vec![3, 1], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        SparseVec::new(3, vec![0, 3], vec![1.0, 2.0]);
    }

    #[test]
    fn axpy_matches_dense_axpy() {
        check::forall("sparse_axpy", |rng, _| {
            let n = check::arb_len(rng, 200);
            let dense = check::arb_vec(rng, n);
            let k = rng.below(n + 1);
            let mut keep = rng.sample_indices(n, k);
            keep.sort_unstable();
            let idx: Vec<u32> = keep.iter().map(|&i| i as u32).collect();
            let sv = SparseVec::gather(&dense, &idx);
            let mut out = vec![1.0f32; n];
            sv.axpy_into(0.5, &mut out);
            for i in 0..n {
                let expect = if keep.binary_search(&i).is_ok() {
                    1.0 + 0.5 * dense[i]
                } else {
                    1.0
                };
                assert_eq!(out[i], expect);
            }
        });
    }

    #[test]
    fn reset_and_push_keep_invariants() {
        let mut sv = SparseVec::new(8, vec![1, 4], vec![1.0, 2.0]);
        sv.reset(5);
        assert_eq!(sv.nnz(), 0);
        assert_eq!(sv.dim(), 5);
        sv.push(0, 3.0);
        sv.push(4, -1.0);
        assert_eq!(sv.indices(), &[0, 4]);
        assert_eq!(sv.values(), &[3.0, -1.0]);
    }

    #[test]
    #[should_panic]
    fn push_rejects_non_increasing() {
        let mut sv = SparseVec::zeros(5);
        sv.push(3, 1.0);
        sv.push(3, 2.0);
    }

    #[test]
    #[should_panic]
    fn push_rejects_out_of_range() {
        let mut sv = SparseVec::zeros(2);
        sv.push(2, 1.0);
    }

    #[test]
    fn dot_matches_dense_dot() {
        let sv = SparseVec::new(4, vec![0, 2], vec![2.0, 3.0]);
        assert_eq!(sv.dot(&[1.0, 9.0, -1.0, 9.0]), 2.0 - 3.0);
    }

    #[test]
    fn norm2() {
        let sv = SparseVec::new(4, vec![0, 1], vec![3.0, 4.0]);
        assert_eq!(sv.norm2(), 5.0);
    }
}
