//! Seeded mini-batch samplers.
//!
//! The paper's §4.2 fairness condition: "the same initialization of the
//! global model for both algorithms and identical batch samplers."
//! A [`BatchSampler`] seeded identically produces the identical batch
//! sequence regardless of which sparsifier consumes it.

use crate::util::rng::Rng;

/// Epoch-shuffling mini-batch sampler over `rows` items.
pub struct BatchSampler {
    rows: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(rows: usize, batch: usize, seed: u64) -> Self {
        assert!(batch >= 1 && batch <= rows, "batch {batch} vs rows {rows}");
        let mut rng = Rng::seed_from(seed);
        let mut order: Vec<usize> = (0..rows).collect();
        rng.shuffle(&mut order);
        BatchSampler { rows, batch, order, cursor: 0, rng }
    }

    /// Next mini-batch of indices; reshuffles at epoch boundaries.
    /// Batches never straddle an epoch (the tail is dropped, standard
    /// drop_last=True semantics).
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.rows {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let b = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        b
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.rows / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_seeds_identical_sequences() {
        let mut a = BatchSampler::new(50, 8, 77);
        let mut b = BatchSampler::new(50, 8, 77);
        for _ in 0..20 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn each_epoch_is_a_permutation_prefix() {
        let mut s = BatchSampler::new(10, 2, 1);
        let mut seen = Vec::new();
        for _ in 0..5 {
            seen.extend_from_slice(s.next_batch());
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn batches_within_range_forever() {
        let mut s = BatchSampler::new(23, 5, 3);
        for _ in 0..100 {
            for &i in s.next_batch() {
                assert!(i < 23);
            }
        }
        assert_eq!(s.batches_per_epoch(), 4);
    }
}
