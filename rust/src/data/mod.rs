//! Dataset substrates.
//!
//! - [`linear`]     — the paper's §4.1 Gaussian linear-model generator
//!                    (exact parameters: U, sigma^2, h^2, epsilon).
//! - [`cifar_like`] — synthetic 32x32x3 10-class image generator (the
//!                    CIFAR-10 substitute; see DESIGN.md §3) plus a
//!                    loader for real CIFAR-10 binary batches when
//!                    present on disk.
//! - [`sampler`]    — seeded mini-batch samplers, identical across
//!                    algorithms (the paper's §4.2 fairness condition).

#![forbid(unsafe_code)]

pub mod cifar_like;
pub mod linear;
pub mod sampler;

/// A labelled dense-feature dataset shard held by one worker.
#[derive(Clone, Debug)]
pub struct Shard {
    /// row-major features, `rows x dim`
    pub x: Vec<f32>,
    /// labels: regression targets or class ids as f32
    pub y: Vec<f32>,
    pub rows: usize,
    pub dim: usize,
}

impl Shard {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather a batch by row indices into contiguous buffers.
    pub fn gather_batch(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_rows_and_batches() {
        let s = Shard { x: (0..6).map(|v| v as f32).collect(), y: vec![10.0, 20.0, 30.0], rows: 3, dim: 2 };
        assert_eq!(s.row(1), &[2.0, 3.0]);
        let (x, y) = s.gather_batch(&[2, 0]);
        assert_eq!(x, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(y, vec![30.0, 10.0]);
    }
}
