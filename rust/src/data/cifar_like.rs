//! CIFAR-10 substitute: a seeded class-conditional image generator with
//! the same tensor geometry (32x32x3, 10 classes), plus a loader for
//! the real CIFAR-10 binary format when the dataset is present on disk.
//!
//! Why this preserves the paper's comparison (DESIGN.md §3): the
//! sparsification dynamics depend on gradient statistics — magnitude
//! spread across entries and cross-worker disagreement — not on image
//! semantics.  The generator produces learnable class structure
//! (per-class mean images: low-frequency colour blobs) with per-sample
//! structured noise, so a CNN's gradients have realistic layer-wise
//! scale differences and worker heterogeneity comes from disjoint
//! sharding, exactly as with the real dataset.

use crate::util::rng::Rng;

pub const IMG_DIM: usize = 32 * 32 * 3;
pub const CLASSES: usize = 10;

/// An image-classification dataset: row-major NHWC f32 images in
/// [0,1]-ish, int class labels.
#[derive(Clone)]
pub struct ImageSet {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub rows: usize,
}

impl ImageSet {
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_DIM..(i + 1) * IMG_DIM]
    }

    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * IMG_DIM);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.image(i));
            y.push(self.labels[i]);
        }
        (x, y)
    }

    /// Split evenly into `n` worker shards (paper §4.2: "data-points
    /// distributed evenly among N=8 workers").
    pub fn shard(&self, n: usize) -> Vec<ImageSet> {
        let per = self.rows / n;
        (0..n)
            .map(|w| {
                let lo = w * per;
                let hi = lo + per;
                ImageSet {
                    images: self.images[lo * IMG_DIM..hi * IMG_DIM].to_vec(),
                    labels: self.labels[lo..hi].to_vec(),
                    rows: per,
                }
            })
            .collect()
    }
}

/// Per-class prototype: a smooth colour field parameterized by a few
/// random low-frequency sinusoids (deterministic per seed+class).
fn class_prototype(class: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed ^ (0xC1A55 + class as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let mut proto = vec![0.0f32; IMG_DIM];
    // 3 sinusoid components per channel
    for c in 0..3 {
        for _ in 0..3 {
            let fx = rng.uniform_range(0.5, 3.0);
            let fy = rng.uniform_range(0.5, 3.0);
            let phase = rng.uniform_range(0.0, std::f64::consts::TAU);
            let amp = rng.uniform_range(0.1, 0.35);
            for y in 0..32 {
                for x in 0..32 {
                    let v = amp
                        * (fx * x as f64 / 32.0 * std::f64::consts::TAU
                            + fy * y as f64 / 32.0 * std::f64::consts::TAU
                            + phase)
                            .sin();
                    proto[(y * 32 + x) * 3 + c] += v as f32;
                }
            }
        }
    }
    // shift to mid-gray
    proto.iter_mut().for_each(|v| *v += 0.5);
    proto
}

/// Generate `rows` labelled images (balanced classes, shuffled).
pub fn generate(rows: usize, noise: f32, seed: u64) -> ImageSet {
    let protos: Vec<Vec<f32>> = (0..CLASSES).map(|c| class_prototype(c, seed)).collect();
    let mut rng = Rng::seed_from(seed);
    let mut order: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut order);
    let mut images = vec![0.0f32; rows * IMG_DIM];
    let mut labels = vec![0i32; rows];
    for (slot, &i) in order.iter().enumerate() {
        let class = i % CLASSES;
        labels[slot] = class as i32;
        let dst = &mut images[slot * IMG_DIM..(slot + 1) * IMG_DIM];
        dst.copy_from_slice(&protos[class]);
        // structured noise: one random low-freq distortion + pixel noise
        let gain = 1.0 + 0.2 * rng.normal_f32(0.0, 1.0);
        let bias = 0.1 * rng.normal_f32(0.0, 1.0);
        for v in dst.iter_mut() {
            *v = (*v - 0.5) * gain + 0.5 + bias + noise * rng.normal_f32(0.0, 1.0);
        }
    }
    ImageSet { images, labels, rows }
}

/// Load real CIFAR-10 binary batches (data_batch_*.bin / test_batch.bin,
/// 3073 bytes per record: label + 3072 CHW uint8) if present.  Returns
/// None when the directory or files are missing — callers fall back to
/// [`generate`].
pub fn load_cifar10_bin(dir: &std::path::Path, files: &[&str]) -> Option<ImageSet> {
    const REC: usize = 3073;
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for f in files {
        let raw = std::fs::read(dir.join(f)).ok()?;
        if raw.len() % REC != 0 {
            return None;
        }
        for rec in raw.chunks_exact(REC) {
            labels.push(rec[0] as i32);
            // CHW u8 -> HWC f32 in [0,1]
            let px = &rec[1..];
            for y in 0..32 {
                for x in 0..32 {
                    for c in 0..3 {
                        images.push(px[c * 1024 + y * 32 + x] as f32 / 255.0);
                    }
                }
            }
        }
    }
    let rows = labels.len();
    (rows > 0).then_some(ImageSet { images, labels, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_balanced() {
        let a = generate(100, 0.1, 3);
        let b = generate(100, 0.1, 3);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let mut counts = [0usize; CLASSES];
        for &l in &a.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_nearest_prototype() {
        // sanity: with modest noise, nearest-prototype classification
        // on the generated data is far above chance -> learnable signal
        let set = generate(500, 0.15, 9);
        let protos: Vec<Vec<f32>> = (0..CLASSES).map(|c| class_prototype(c, 9)).collect();
        let mut correct = 0;
        for i in 0..set.rows {
            let img = set.image(i);
            let pred = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = img.iter().zip(&protos[a]).map(|(x, p)| (x - p) * (x - p)).sum();
                    let db: f32 = img.iter().zip(&protos[b]).map(|(x, p)| (x - p) * (x - p)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred as i32 == set.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / set.rows as f64;
        assert!(acc > 0.5, "nearest-prototype acc {acc}");
    }

    #[test]
    fn sharding_is_even_and_disjoint() {
        let set = generate(80, 0.1, 1);
        let shards = set.shard(8);
        assert_eq!(shards.len(), 8);
        assert!(shards.iter().all(|s| s.rows == 10));
        // reassembling shards reproduces the original prefix
        let mut recon = Vec::new();
        for s in &shards {
            recon.extend_from_slice(&s.images);
        }
        assert_eq!(recon, set.images);
    }

    #[test]
    fn gather_returns_requested_rows() {
        let set = generate(20, 0.1, 2);
        let (x, y) = set.gather(&[3, 0]);
        assert_eq!(x.len(), 2 * IMG_DIM);
        assert_eq!(x[..IMG_DIM], *set.image(3));
        assert_eq!(y, vec![set.labels[3], set.labels[0]]);
    }

    #[test]
    fn missing_cifar_dir_returns_none() {
        assert!(load_cifar10_bin(std::path::Path::new("/nonexistent"), &["x.bin"]).is_none());
    }
}
