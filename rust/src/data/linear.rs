//! The paper's §4.1 synthetic linear-regression testbed, exactly:
//!
//! * N workers, each with D i.i.d. N(0,1) data points of dimension J;
//! * per-worker ground truth t_n ~ N(u_n, h^2 I) with u_n ~ N(U, sigma^2);
//! * labels y = x^T t_n + eps, eps ~ N(0, epsilon).
//!
//! Fig. 2 uses N=20, D=500, J=100, U=0, sigma^2=5, h^2=1, epsilon=0.5.
//! Heterogeneity across workers comes from the worker-specific means
//! u_n — this is what makes sparsified entries cancel destructively
//! and lets REGTOP-k shine.

use crate::data::Shard;
use crate::util::rng::Rng;

/// Generator parameters (paper notation).
#[derive(Clone, Copy, Debug)]
pub struct LinearParams {
    pub workers: usize,
    pub rows_per_worker: usize,
    pub dim: usize,
    /// U: mean of the per-worker ground-truth means
    pub u: f64,
    /// sigma^2: variance of the per-worker means
    pub sigma2: f64,
    /// h^2: per-entry variance of t_n around u_n
    pub h2: f64,
    /// epsilon: label noise variance
    pub noise: f64,
}

impl LinearParams {
    /// The exact Fig. 2 configuration.
    pub fn fig2() -> Self {
        LinearParams { workers: 20, rows_per_worker: 500, dim: 100, u: 0.0, sigma2: 5.0, h2: 1.0, noise: 0.5 }
    }
}

/// A generated distributed linear-regression problem.
#[derive(Clone, Debug)]
pub struct LinearProblem {
    pub params: LinearParams,
    pub shards: Vec<Shard>,
    /// per-worker ground-truth models t_n
    pub truths: Vec<Vec<f32>>,
    /// global least-squares optimum w* of the averaged objective
    pub w_star: Vec<f32>,
}

pub fn generate(params: LinearParams, seed: u64) -> LinearProblem {
    let root = Rng::seed_from(seed);
    let mut shards = Vec::with_capacity(params.workers);
    let mut truths = Vec::with_capacity(params.workers);
    for n in 0..params.workers {
        let mut rng = root.derive(n as u64 + 1);
        let u_n = params.u + params.sigma2.sqrt() * rng.gaussian();
        let t_n: Vec<f32> = (0..params.dim).map(|_| rng.normal_f32(u_n, params.h2.sqrt())).collect();
        let rows = params.rows_per_worker;
        let mut x = Vec::with_capacity(rows * params.dim);
        let mut y = Vec::with_capacity(rows);
        for _ in 0..rows {
            let start = x.len();
            for _ in 0..params.dim {
                x.push(rng.normal_f32(0.0, 1.0));
            }
            let dot: f32 = x[start..].iter().zip(&t_n).map(|(a, b)| a * b).sum();
            y.push(dot + rng.normal_f32(0.0, params.noise.sqrt()));
        }
        shards.push(Shard { x, y, rows, dim: params.dim });
        truths.push(t_n);
    }
    let w_star = least_squares(&shards);
    LinearProblem { params, shards, truths, w_star }
}

/// Global LS optimum of (1/N) sum_n F_n via normal equations
/// (sum X^T X) w = sum X^T y, solved by Gaussian elimination with
/// partial pivoting (J is 100 in the paper — direct solve is exact
/// enough and dependency-free).
pub fn least_squares(shards: &[Shard]) -> Vec<f32> {
    let j = shards[0].dim;
    let mut ata = vec![0.0f64; j * j];
    let mut aty = vec![0.0f64; j];
    for s in shards {
        for r in 0..s.rows {
            let row = s.row(r);
            let yr = s.y[r] as f64;
            for a in 0..j {
                let ra = row[a] as f64;
                aty[a] += ra * yr;
                let base = a * j;
                for b in a..j {
                    ata[base + b] += ra * row[b] as f64;
                }
            }
        }
    }
    // mirror the upper triangle
    for a in 0..j {
        for b in 0..a {
            ata[a * j + b] = ata[b * j + a];
        }
    }
    solve_dense(&mut ata, &mut aty, j);
    aty.into_iter().map(|v| v as f32).collect()
}

/// In-place Gaussian elimination with partial pivoting: solves A x = b,
/// leaving x in `b`.
pub fn solve_dense(a: &mut [f64], b: &mut [f64], n: usize) {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        assert!(d.abs() > 1e-12, "singular normal equations");
        for r in col + 1..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in col + 1..n {
            s -= a[col * n + c] * b[c];
        }
        b[col] = s / a[col * n + col];
    }
}

/// Full-batch LS gradient of worker shard at w:  X^T (X w - y) / D
/// (matches `model.linreg_grad` with the 1/2-mean loss).
pub fn ls_gradient(shard: &Shard, w: &[f32], out: &mut [f32]) -> f32 {
    out.iter_mut().for_each(|v| *v = 0.0);
    let mut loss = 0.0f64;
    for r in 0..shard.rows {
        let row = shard.row(r);
        let resid: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - shard.y[r];
        loss += 0.5 * (resid as f64) * (resid as f64);
        for (o, &x) in out.iter_mut().zip(row) {
            *o += resid * x;
        }
    }
    let inv = 1.0 / shard.rows as f32;
    out.iter_mut().for_each(|v| *v *= inv);
    (loss / shard.rows as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LinearParams {
        LinearParams { workers: 3, rows_per_worker: 80, dim: 10, u: 0.0, sigma2: 5.0, h2: 1.0, noise: 0.5 }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(small(), 7);
        let b = generate(small(), 7);
        assert_eq!(a.shards[1].x, b.shards[1].x);
        assert_eq!(a.w_star, b.w_star);
        let c = generate(small(), 8);
        assert_ne!(a.shards[1].x, c.shards[1].x);
    }

    #[test]
    fn workers_are_heterogeneous() {
        let p = generate(small(), 1);
        // per-worker truths differ markedly (sigma^2 = 5)
        let d: f32 = p.truths[0].iter().zip(&p.truths[1]).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 1.0, "{d}");
    }

    #[test]
    fn w_star_zeroes_averaged_gradient() {
        let p = generate(small(), 3);
        let j = p.params.dim;
        let mut g = vec![0.0; j];
        let mut agg = vec![0.0f32; j];
        for s in &p.shards {
            ls_gradient(s, &p.w_star, &mut g);
            for i in 0..j {
                agg[i] += g[i] / p.params.workers as f32;
            }
        }
        let norm: f32 = agg.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm < 1e-3, "grad norm at w* = {norm}");
    }

    #[test]
    fn ls_gradient_matches_finite_difference() {
        let p = generate(small(), 5);
        let s = &p.shards[0];
        let w: Vec<f32> = (0..10).map(|i| 0.1 * i as f32).collect();
        let mut g = vec![0.0; 10];
        let loss0 = ls_gradient(s, &w, &mut g);
        let h = 1e-3f32;
        for i in [0usize, 4, 9] {
            let mut wp = w.clone();
            wp[i] += h;
            let mut tmp = vec![0.0; 10];
            let lp = ls_gradient(s, &wp, &mut tmp);
            let fd = (lp - loss0) / h;
            assert!((fd - g[i]).abs() < 0.05 * g[i].abs().max(1.0), "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn solver_solves_known_system() {
        let mut a = vec![4.0, 1.0, 1.0, 3.0];
        let mut b = vec![1.0, 2.0];
        solve_dense(&mut a, &mut b, 2);
        // exact solution of [[4,1],[1,3]] x = [1,2] is [1/11, 7/11]
        assert!((b[0] - 1.0 / 11.0).abs() < 1e-12);
        assert!((b[1] - 7.0 / 11.0).abs() < 1e-12);
    }
}
