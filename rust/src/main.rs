//! `repro` — the leader binary: regenerates every figure/table of the
//! paper and exposes the generic training entrypoint.
//!
//! ```text
//! repro fig1   [--iters 100] [--mu 0.5] [--q 1.0] [--out results]
//! repro fig2   [--iters 1000] [--s 0.4,0.5,0.6] [--seed 42] [--out results]
//! repro fig3   [--iters 300] [--model resnet8|mlp] [--s 0.001] [--dense]
//!              [--layerwise] [--policy 'conv*=regtopk:mu=0.3;*=topk']
//!              [--budget prop:0.001]  (layer-wise runs adopt the
//!                                      artifact's real per-layer layout;
//!                                      degrades to the linreg testbed
//!                                      when artifacts are unavailable)
//! repro sweep  --param mu|q|workers|approx|hetero|bits|codec|downlink ...
//! repro comm   [--s 0.4,0.1,0.01,0.001]
//! repro train  --config cfg.json [--groups 60,40 --budget prop:0.1]
//!              [--policy 'glob=family:k=v,...;...']
//!              [--downlink 'glob=:bits=..,idx=..,levels=..;...']
//!              [--transport inproc|tcp|uds]
//!                                      (generic linreg-testbed run;
//!                                       --groups switches on the
//!                                       layer-wise bucketed path,
//!                                       --policy makes it heterogeneous,
//!                                       --downlink compresses the
//!                                       server broadcast — codec-only
//!                                       keys, works flat or grouped;
//!                                       --transport tcp|uds spawns each
//!                                       worker as a separate OS process
//!                                       over framed sockets)
//! repro worker --connect ADDR --config cfg.json --worker I --iters T
//!                                      (one worker process; spawned by
//!                                       `repro train --transport tcp`,
//!                                       also usable by hand)
//! repro info                          (artifact + platform report)
//! repro lint   [--root DIR] [--json]  (repo-invariant static analyzer;
//!              [--schema]              exit 1 on any finding; --json
//!              [--schema-write]        emits machine-readable findings,
//!                                      --schema prints the canonical
//!                                      SCHEMA.lock rendering, and
//!                                      --schema-write regenerates it)
//! ```
//!
//! Every subcommand writes CSV + JSON under `--out` (default
//! `results/`) and prints a terminal summary with sparklines.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};

use regtopk::comm::{Tcp, TcpLink, Transport, TransportKind};
use regtopk::config::TrainConfig;
use regtopk::coordinator::Trainer;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::{comm_table, fig1, fig2, fig3, sweeps};
use regtopk::metrics::RunLog;
use regtopk::runtime::Runtime;
use regtopk::sparsify::SparsifierKind;
use regtopk::util::cli::Cli;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if args.is_empty() { "help".to_string() } else { args.remove(0) };
    let code = match cmd.as_str() {
        "fig1" => cmd_fig1(args),
        "fig2" => cmd_fig2(args),
        "fig3" => cmd_fig3(args),
        "sweep" => cmd_sweep(args),
        "baselines" => cmd_baselines(args),
        "comm" => cmd_comm(args),
        "train" => cmd_train(args),
        "worker" => cmd_worker(args),
        "info" => cmd_info(args),
        "lint" => cmd_lint(args),
        _ => {
            eprintln!(
                "usage: repro <fig1|fig2|fig3|sweep|baselines|comm|train|worker|info|lint> [flags]\n\
                 run `repro <cmd> --help` for per-command flags"
            );
            2
        }
    };
    std::process::exit(code);
}

fn write_logs(logs: &[RunLog], out: &str, stem: &str) {
    let dir = PathBuf::from(out);
    for log in logs {
        // sanitize: "topk-S0.6" would otherwise lose ".6" to
        // with_extension
        let safe = log.name.replace('.', "p");
        let base = dir.join(format!("{stem}_{safe}"));
        log.write_csv(&base.with_extension("csv")).expect("write csv");
        log.write_json(&base.with_extension("json")).expect("write json");
    }
    println!("wrote {} runs to {out}/{stem}_*.{{csv,json}}", logs.len());
}

fn cmd_fig1(args: Vec<String>) -> i32 {
    let p = Cli::new("Fig. 1: toy logistic regression (dense vs TOP-1 vs REGTOP-1)")
        .flag("iters", "100", "iterations")
        .flag("mu", "0.5", "REGTOP-k regularization temperature")
        .flag("q", "1.0", "REGTOP-k never-sent prior")
        .flag("out", "results", "output directory")
        .switch("lr-scaling", "also run the §1.2 G-extension diagnostic")
        .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let logs = fig1::run(p.get_usize("iters"), p.get_f32("mu"), p.get_f32("q"));
    println!("Fig.1 toy logistic regression (eta=0.9, w0=[0,1]):");
    for log in &logs {
        println!(
            "  {:<8} final loss {:.6}  {}",
            log.name,
            log.last().unwrap().loss,
            log.sparkline(|r| r.loss, 40)
        );
    }
    if p.get_bool("lr-scaling") {
        let (steps, factor) = fig1::lr_scaling(p.get_usize("iters"));
        let stall = steps.iter().take_while(|&&s| s < 1e-9).count();
        println!("  LR-scaling diagnostic: stall {stall} iters, then scaling factor {factor:.1}x");
    }
    write_logs(&logs, p.get("out"), "fig1");
    0
}

fn cmd_fig2(args: Vec<String>) -> i32 {
    let p = Cli::new("Fig. 2: distributed linear regression optimality gap")
        .flag("iters", "1000", "iterations")
        .flag("s", "0.4,0.5,0.6", "sparsity factors")
        .flag("workers", "20", "workers N")
        .flag("rows", "500", "data points per worker D")
        .flag("dim", "100", "feature dimension J")
        .flag("mu", "0.5", "REGTOP-k mu")
        .flag("q", "1.0", "REGTOP-k Q")
        .flag("eta", "0.01", "learning rate")
        .flag("seed", "42", "rng seed")
        .flag("out", "results", "output directory")
        .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let params = LinearParams {
        workers: p.get_usize("workers"),
        rows_per_worker: p.get_usize("rows"),
        dim: p.get_usize("dim"),
        ..LinearParams::fig2()
    };
    let logs = fig2::run(
        params,
        p.get_usize("seed") as u64,
        p.get_usize("iters"),
        &p.get_f64_list("s"),
        p.get_f32("mu"),
        p.get_f32("q"),
        p.get_f32("eta"),
    );
    println!(
        "Fig.2 linreg (N={} D={} J={} eta={}): final optimality gap ||w-w*||",
        params.workers, params.rows_per_worker, params.dim, p.get_f32("eta")
    );
    for log in &logs {
        println!(
            "  {:<14} gap {:>12.6}  {}",
            log.name,
            log.last().unwrap().opt_gap,
            log.sparkline(|r| r.opt_gap.max(1e-9).ln(), 40)
        );
    }
    write_logs(&logs, p.get("out"), "fig2");
    0
}

/// Per-layer ledger table of a layer-wise Fig. 3 run.
fn print_fig3_groups(name: &str, groups: &[(String, String, usize, usize)], iters: usize) {
    if groups.is_empty() {
        return;
    }
    let iters = iters.max(1);
    println!("  {name}: per-group upload bytes ({} groups):", groups.len());
    println!("    {:<18} {:<10} {:>12} {:>12} {:>10}", "group", "family", "B total", "B/round", "entries");
    for (g, fam, bytes, entries) in groups {
        println!("    {g:<18} {fam:<10} {bytes:>12} {:>12} {entries:>10}", bytes / iters);
    }
    let total: usize = groups.iter().map(|(_, _, b, _)| b).sum();
    println!("    {:<18} {:<10} {total:>12}", "(all groups)", "");
}

fn cmd_fig3(args: Vec<String>) -> i32 {
    let p = Cli::new("Fig. 3: CNN on CIFAR-like data, TOP-k vs REGTOP-k at S=0.001")
        .flag("iters", "300", "iterations")
        .flag("model", "resnet8", "resnet8 | mlp")
        .flag("workers", "8", "workers N")
        .flag("s", "0.001", "sparsity factor")
        .flag("eta", "0.01", "learning rate")
        .flag("mu", "0.5", "REGTOP-k mu")
        .flag("q", "1.0", "REGTOP-k Q")
        .flag("train-rows", "1600", "synthetic training rows")
        .flag("val-rows", "200", "synthetic validation rows")
        .flag("eval-every", "25", "accuracy eval period")
        .flag("seed", "42", "rng seed")
        .flag("out", "results", "output directory")
        .flag("policy", "", "heterogeneous per-layer policy 'glob=family:k=v,...;...' (implies --layerwise)")
        .flag("budget", "", "per-layer budget policy global:K|per:..|prop:F (default global at the flat k)")
        .switch("layerwise", "adopt the artifact model's real per-layer layout (bucketed path)")
        .switch("dense", "also run the dense reference")
        .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut cfg = fig3::Fig3Config {
        workers: p.get_usize("workers"),
        iters: p.get_usize("iters"),
        eta: p.get_f32("eta"),
        s: p.get_f64("s"),
        mu: p.get_f32("mu"),
        q: p.get_f32("q"),
        seed: p.get_usize("seed") as u64,
        train_rows: p.get_usize("train-rows"),
        val_rows: p.get_usize("val-rows"),
        eval_every: p.get_usize("eval-every"),
        layerwise: p.get_bool("layerwise"),
        ..fig3::Fig3Config::default()
    };
    if p.provided("policy") && !p.get("policy").is_empty() {
        cfg.policy = match regtopk::sparsify::PolicyTable::parse(p.get("policy")) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!("bad --policy: {e}");
                return 2;
            }
        };
        cfg.layerwise = true;
    }
    if p.provided("budget") && !p.get("budget").is_empty() {
        cfg.budget = match regtopk::sparsify::BudgetPolicy::parse(p.get("budget")) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("bad --budget: {e}");
                return 2;
            }
        };
        if !cfg.layerwise {
            eprintln!("--budget needs the layer-wise path: pass --layerwise");
            return 2;
        }
    }
    let model = p.get("model").to_string();
    let runs = match Runtime::open_default() {
        Ok(mut rt) => match fig3::run(&mut rt, &cfg, &model, p.get_bool("dense")) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("fig3 failed: {e:#}");
                return 1;
            }
        },
        Err(e) if cfg.layerwise => {
            // artifact-free degraded path: the same layer-wise protocol
            // on the linreg testbed with a synthetic CNN-shaped layout
            eprintln!(
                "artifacts unavailable ({e:#});\n\
                 running the DEGRADED layer-wise protocol on the linreg testbed \
                 (synthetic {model}-shaped layout)"
            );
            fig3::run_degraded(&cfg, &model, p.get_bool("dense"))
        }
        Err(e) => {
            eprintln!("cannot open artifacts: {e:#}");
            return 1;
        }
    };
    println!("Fig.3 {model} (N={}, S={}):", cfg.workers, cfg.s);
    for r in &runs {
        let log = &r.log;
        let acc = log
            .records()
            .iter()
            .rev()
            .find(|rec| !rec.accuracy.is_nan())
            .map(|rec| rec.accuracy)
            .unwrap_or(f32::NAN);
        println!(
            "  {:<12} final loss {:.4}  val acc {:.3}  {}",
            log.name,
            log.last().unwrap().loss,
            acc,
            log.sparkline(|rec| rec.loss, 40)
        );
    }
    for r in &runs {
        print_fig3_groups(&r.log.name, &r.groups, cfg.iters);
    }
    let logs: Vec<RunLog> = runs.into_iter().map(|r| r.log).collect();
    write_logs(&logs, p.get("out"), &format!("fig3_{model}"));
    0
}

fn cmd_sweep(args: Vec<String>) -> i32 {
    let p = Cli::new("Ablation sweeps (DESIGN.md Abl 1-4 + hetero + quantized bits + codec + downlink)")
        .required("param", "mu | q | workers | approx | hetero | bits | codec | downlink")
        .flag("values", "", "comma-separated sweep values (defaults per param)")
        .flag("s", "0.5", "sparsity factor")
        .flag("iters", "400", "iterations per point")
        .flag("seed", "42", "rng seed")
        .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seed = p.get_usize("seed") as u64;
    let iters = p.get_usize("iters");
    let s = p.get_f64("s");
    match p.get("param") {
        "mu" => {
            let vals = if p.get("values").is_empty() {
                vec![1e-4, 0.01, 0.1, 0.5, 1.0, 4.0]
            } else {
                p.get_f64_list("values")
            };
            println!("mu sweep (S={s}, final opt gap; topk = mu->0 reference):");
            for (name, gap) in sweeps::mu_sweep(&vals, s, iters, seed) {
                println!("  {name:<10} {gap:.6}");
            }
        }
        "q" => {
            let vals = if p.get("values").is_empty() {
                vec![0.0, 0.25, 0.5, 1.0, 2.0, 10.0]
            } else {
                p.get_f64_list("values")
            };
            println!("Q sweep (S={s}, mu=0.5, final opt gap):");
            for (name, gap) in sweeps::q_sweep(&vals, s, iters, seed) {
                println!("  {name:<10} {gap:.6}");
            }
        }
        "workers" => {
            let vals: Vec<usize> = if p.get("values").is_empty() {
                vec![2, 4, 8, 16, 32]
            } else {
                p.get_f64_list("values").into_iter().map(|v| v as usize).collect()
            };
            println!("worker sweep (S={s}): N, topk gap, regtopk gap");
            for (n, t, r) in sweeps::worker_sweep(&vals, s, iters, seed) {
                println!("  N={n:<4} topk {t:.5}  regtopk {r:.5}");
            }
        }
        "approx" => {
            let vals: Vec<usize> = if p.get("values").is_empty() {
                vec![2, 4, 8, 16, 32]
            } else {
                p.get_f64_list("values").into_iter().map(|v| v as usize).collect()
            };
            println!("approximate top-k recall (J=2^17, k=131):");
            for (ov, rec) in sweeps::approx_recall_sweep(&vals, 1 << 17, 131, 5) {
                println!("  oversample={ov:<4} recall {rec:.4}");
            }
        }
        "hetero" => {
            println!(
                "flat vs layer-wise vs heterogeneous RegTop-k (S={s}, {iters} iters, \
                 4-layer testbed; EXPERIMENTS.md §Heterogeneous):"
            );
            println!(
                "  {:<22} {:>12} {:>14} {:>14}",
                "variant", "final gap", "bytes/round", "entries/round"
            );
            for r in sweeps::hetero_sweep(s, iters, seed) {
                println!(
                    "  {:<22} {:>12.6} {:>14} {:>14}",
                    r.name, r.final_gap, r.bytes_per_round, r.entries_per_round
                );
            }
        }
        "bits" => {
            println!(
                "quantized transmission sweep (S={s}, {iters} iters, layer-wise \
                 RegTop-k, residual-in-EF; EXPERIMENTS.md §Quantization):"
            );
            println!(
                "  {:<14} {:>12} {:>14} {:>14}",
                "value bits", "final gap", "bytes/round", "entries/round"
            );
            for r in sweeps::bits_sweep(s, iters, seed) {
                println!(
                    "  {:<14} {:>12.6} {:>14} {:>14}",
                    r.name, r.final_gap, r.bytes_per_round, r.entries_per_round
                );
            }
        }
        "codec" => {
            println!(
                "wire-codec matrix sweep (S={s}, {iters} iters, layer-wise RegTop-k, \
                 index codec x value codec; EXPERIMENTS.md §Compression):"
            );
            println!(
                "  {:<18} {:>12} {:>14} {:>14}",
                "idx/levels", "final gap", "bytes/round", "entries/round"
            );
            for r in sweeps::codec_sweep(s, iters, seed) {
                println!(
                    "  {:<18} {:>12.6} {:>14} {:>14}",
                    r.name, r.final_gap, r.bytes_per_round, r.entries_per_round
                );
            }
        }
        "downlink" => {
            println!(
                "downlink sweep (S={s}, {iters} iters, flat RegTop-k, dense vs \
                 sparse-broadcast x codec; EXPERIMENTS.md §Downlink protocol):"
            );
            println!(
                "  {:<18} {:>12} {:>14} {:>14}",
                "downlink", "final gap", "up B/round", "down B/round"
            );
            for r in sweeps::downlink_sweep(s, iters, seed) {
                println!(
                    "  {:<18} {:>12.6} {:>14} {:>14}",
                    r.name, r.final_gap, r.up_bytes_per_round, r.down_bytes_per_round
                );
            }
        }
        other => {
            eprintln!("unknown sweep param '{other}'");
            return 2;
        }
    }
    0
}

fn cmd_baselines(args: Vec<String>) -> i32 {
    let p = Cli::new("Baseline shoot-out: every sparsifier at one budget")
        .flag("s", "0.3", "sparsity factor")
        .flag("iters", "400", "iterations")
        .flag("workers", "8", "workers")
        .flag("seed", "42", "rng seed")
        .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let params = regtopk::experiments::sweeps::sweep_params(p.get_usize("workers"));
    let rows = regtopk::experiments::baselines::run(
        params,
        p.get_f64("s"),
        p.get_usize("iters"),
        p.get_usize("seed") as u64,
    );
    println!(
        "baseline comparison (linreg testbed, J={}, S={}, {} iters):",
        params.dim,
        p.get_f64("s"),
        p.get_usize("iters")
    );
    println!("  {:<10} {:>12} {:>14} {:>8}", "algo", "final gap", "bytes/round", "mean k");
    for r in rows {
        println!(
            "  {:<10} {:>12.5} {:>14} {:>8.1}",
            r.name, r.final_gap, r.bytes_per_round, r.mean_k
        );
    }
    0
}

fn cmd_comm(args: Vec<String>) -> i32 {
    let p = Cli::new("Tab A: communication volume (analytic + measured)")
        .flag("s", "0.1,0.01,0.001", "sparsity factors")
        .flag("iters", "20", "measured-run iterations")
        .flag("seed", "42", "rng seed")
        .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let ss = p.get_f64_list("s");
    println!("analytic symbols/epoch/worker (1000 minibatches, §1 arithmetic):");
    println!(
        "  {:<10} {:>10} {:>8} {:>14} {:>14} {:>8} {:>9} {:>9}",
        "model", "J", "S", "symbols/ep", "bytes/ep", "ratio", "logJ b/i", "rice b/i"
    );
    for r in comm_table::analytic(&ss) {
        // index-cost pair: the paper's log J bound vs the measured
        // Golomb-Rice code (dense rows carry no indices)
        let (bound, rice) = if r.s >= 1.0 {
            ("-".to_string(), "-".to_string())
        } else {
            (format!("{:.0}", r.idx_bound_bits), format!("{:.2}", r.rice_bits))
        };
        println!(
            "  {:<10} {:>10} {:>8} {:>14.3e} {:>14.3e} {:>8.5} {bound:>9} {rice:>9}",
            r.model, r.dim, r.s, r.symbols_per_epoch, r.bytes_per_epoch, r.compression
        );
    }
    println!("\nmeasured bytes/round on the linreg testbed (8 workers, J=60):");
    println!(
        "    {:<12} {:>10} {:>10} {:>12} {:>10} {:>10}   (ledger-charged | socket-counted over loopback TCP)",
        "", "uplink B", "downlink B", "sim ms", "sock up B", "sock dn B"
    );
    for &s in &ss {
        println!("  S={s}:");
        for r in comm_table::measured(s, p.get_usize("iters"), p.get_usize("seed") as u64) {
            println!(
                "    {:<12} {:>10} {:>10} {:>12.3} {:>10} {:>10}",
                r.name,
                r.up_bytes,
                r.down_bytes,
                r.sim_s * 1e3,
                r.sock_up_bytes,
                r.sock_down_bytes
            );
        }
    }
    0
}

fn cmd_train(args: Vec<String>) -> i32 {
    let p = Cli::new(
        "Generic linreg-testbed training run from a JSON config.\n\
         CLI flags override the config: --sparsifier rebuilds the kind\n\
         from the full parameter set (incl. dgc momentum/clip and adak\n\
         ratio/k-min/k-max); --shards drives the sharded engine;\n\
         --groups/--budget switch on the layer-wise API (per-group\n\
         sparsifier stacks, bucketed uploads, per-group ledger bytes).",
    )
    .required("config", "path to config JSON (see config module docs)")
    .flag("out", "results", "output directory")
    .flag("shards", "", "engine shards: 0=auto, 1=serial, N=fixed (default: config)")
    .flag("groups", "", "parameter groups 'name:len,...' or 'len,len,...' (sum = model dim; empty = flat)")
    .flag("budget", "", "per-group budget policy: global:K | per:K1,K2,... | prop:FRAC")
    .flag("policy", "", "heterogeneous per-group policies 'glob=family:k=v,...;...' (empty = homogeneous)")
    .flag("downlink", "", "downlink codec rules 'glob=:bits=..,idx=..,levels=..;...' (codec-only keys; empty = dense broadcast)")
    .flag("transport", "", "inproc | tcp | uds: tcp/uds run each worker as a separate OS process over framed sockets (default: config)")
    .flag("sparsifier", "", "override sparsifier by name (dense|topk|regtopk|randk|threshold|gtopk|dgc|adak)")
    .flag("k", "1", "sparsity budget k")
    .flag("mu", "0.5", "regtopk temperature")
    .flag("q", "1.0", "regtopk never-sent prior")
    .flag("tau", "1.0", "threshold tau")
    .flag("sp-seed", "0", "randk stream seed")
    .flag("momentum", "0.9", "dgc momentum-correction factor")
    .flag("clip", "0.0", "dgc local l2 clip (0 disables)")
    .flag("ratio", "1.0", "adak residual trigger ratio")
    .flag("k-min", "1", "adak lower budget bound")
    .flag("k-max", "0", "adak upper budget bound (0 = k)")
    .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut cfg = match TrainConfig::from_json_file(Path::new(p.get("config"))) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bad config: {e}");
            return 2;
        }
    };
    if p.provided("shards") {
        cfg.shards = p.get_usize("shards");
    }
    if p.provided("groups") {
        let spec = p.get("groups");
        if spec.is_empty() {
            cfg.groups = None; // explicit flat override
        } else {
            cfg.groups = match regtopk::grad::GradLayout::parse_spec(spec) {
                Ok(l) => Some(l),
                Err(e) => {
                    eprintln!("bad --groups: {e}");
                    return 2;
                }
            };
        }
    }
    if p.provided("budget") {
        cfg.budget = match regtopk::sparsify::BudgetPolicy::parse(p.get("budget")) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("bad --budget: {e}");
                return 2;
            }
        };
    }
    if p.provided("policy") {
        let spec = p.get("policy");
        if spec.is_empty() {
            cfg.policy = None; // explicit homogeneous override
        } else {
            cfg.policy = match regtopk::sparsify::PolicyTable::parse(spec) {
                Ok(t) => Some(t),
                Err(e) => {
                    eprintln!("bad --policy: {e}");
                    return 2;
                }
            };
        }
    }
    if p.provided("downlink") {
        let spec = p.get("downlink");
        if spec.is_empty() {
            cfg.downlink = None; // explicit dense-broadcast override
        } else {
            // parse + the codec-only validation (sparsifier keys and
            // bits=auto are uplink concepts)
            let table = match regtopk::sparsify::PolicyTable::parse(spec) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("bad --downlink: {e}");
                    return 2;
                }
            };
            if let Err(e) = table.validate_downlink() {
                eprintln!("bad --downlink: {e}");
                return 2;
            }
            cfg.downlink = Some(table);
        }
    }
    if p.provided("transport") && !p.get("transport").is_empty() {
        cfg.transport = match TransportKind::parse(p.get("transport")) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("bad --transport: {e}");
                return 2;
            }
        };
    }
    // budgets/policies are only consulted on the grouped path —
    // silently ignoring them would misreport the experiment, so reject
    if cfg.budget.is_some() && cfg.groups.is_none() {
        eprintln!("a budget policy needs parameter groups: pass --groups (or \"groups\" in the config)");
        return 2;
    }
    if cfg.policy.is_some() && cfg.groups.is_none() {
        eprintln!("a policy table needs parameter groups: pass --groups (or \"groups\" in the config)");
        return 2;
    }
    // Sparsifier overrides start from the CONFIG's parameters and
    // overlay only the flags the user actually passed, so
    // `--sparsifier regtopk --mu 0.3` tweaks mu without resetting k,
    // and `--k 500` alone adjusts the configured kind.
    let param_flags =
        ["k", "mu", "q", "tau", "sp-seed", "momentum", "clip", "ratio", "k-min", "k-max"];
    if p.provided("sparsifier") || param_flags.iter().any(|f| p.provided(f)) {
        let name = if p.provided("sparsifier") {
            p.get("sparsifier").to_string()
        } else {
            cfg.sparsifier.name().to_string()
        };
        let mut params = cfg.sparsifier.to_params();
        if p.provided("k") {
            params.k = p.get_usize("k");
        }
        if p.provided("mu") {
            params.mu = p.get_f32("mu");
        }
        if p.provided("q") {
            params.q = p.get_f32("q");
        }
        if p.provided("tau") {
            params.tau = p.get_f32("tau");
        }
        if p.provided("sp-seed") {
            params.seed = p.get_usize("sp-seed") as u64;
        }
        if p.provided("momentum") {
            params.momentum = p.get_f32("momentum");
        }
        if p.provided("clip") {
            params.clip = p.get_f32("clip");
        }
        if p.provided("ratio") {
            params.ratio = p.get_f32("ratio");
        }
        if p.provided("k-min") {
            params.k_min = p.get_usize("k-min");
        }
        if p.provided("k-max") {
            params.k_max = p.get_usize("k-max");
        }
        cfg.sparsifier = match SparsifierKind::from_params(&name, &params) {
            Some(kind) => kind,
            None => {
                eprintln!("unknown sparsifier '{name}'");
                return 2;
            }
        };
    }
    let params = LinearParams {
        workers: cfg.workers,
        ..LinearParams::fig2()
    };
    if let Some(groups) = &cfg.groups {
        if groups.total() != params.dim {
            eprintln!(
                "--groups total {} != testbed model dim {} (adjust the group lengths)",
                groups.total(),
                params.dim
            );
            return 2;
        }
    }
    let problem = generate(params, cfg.seed);
    let mut tr = fig2::trainer_from_config(&cfg, &problem);
    let log = match cfg.transport {
        TransportKind::InProc => fig2::run_curve_with(&mut tr, &problem, "train", cfg.iters),
        TransportKind::Tcp | TransportKind::Uds => {
            match run_train_networked(&mut tr, &cfg) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("networked train failed: {e}");
                    return 1;
                }
            }
        }
    };
    // report the shard count that actually ran: small testbeds fall
    // back to serial regardless of the configured value.  The final
    // gap comes from the server model directly so the summary line is
    // byte-comparable across transports (scripts/verify.sh diffs it).
    println!(
        "train: {} iters ({} / shards={} effective={}), final loss {:.6}, final gap {:.6}",
        cfg.iters,
        cfg.sparsifier_name(),
        cfg.shards,
        cfg.effective_shards(params.dim),
        log.last().unwrap().loss,
        fig2::opt_gap(&tr.server.w, &problem.w_star)
    );
    // downlink-compressed runs: both ledger directions, next to the
    // dense 32J baseline the broadcast would otherwise have cost
    if cfg.downlink.is_some() {
        let iters = cfg.iters.max(1);
        let dense = tr.ledger.cost.broadcast_bytes(params.dim) * cfg.workers;
        println!(
            "downlink: {} B/round sparse broadcast (dense baseline {dense} B/round), uplink {} B/round",
            tr.ledger.total_download_bytes() / iters,
            tr.ledger.total_upload_bytes() / iters
        );
    }
    // layer-wise runs: per-group upload accounting from the ledger,
    // with the per-group family (heterogeneous policies) and entries
    let group_totals = tr.ledger.group_upload_totals();
    if group_totals.len() > 1 {
        let iters = cfg.iters.max(1);
        let entries = tr.ledger.group_upload_entries();
        let families = tr.workers[0].sparsifier.group_families();
        let bits = tr.workers[0].sparsifier.group_value_bits();
        let bits_end = tr.workers[0].sparsifier.group_value_bits_end();
        let idx_codecs = tr.workers[0].sparsifier.group_index_codecs();
        let shards = tr.workers[0].sparsifier.group_shards();
        println!("per-group upload bytes ({} groups):", group_totals.len());
        println!(
            "  {:<16} {:<10} {:>6} {:>6} {:>7} {:>12} {:>10} {:>10}",
            "group", "family", "bits", "idx", "shards", "B total", "B/round", "entries"
        );
        for (g, (name, bytes)) in group_totals.iter().enumerate() {
            let b0 = bits.get(g).copied().unwrap_or(32);
            let b1 = bits_end.get(g).copied().unwrap_or(32);
            // a scheduled width prints as its start..settled range
            let bcol =
                if b1 == b0 { format!("{b0}") } else { format!("{b0}..{b1}") };
            println!(
                "  {name:<16} {:<10} {bcol:>6} {:>6} {:>7} {bytes:>12} {:>10} {:>10}",
                families.get(g).copied().unwrap_or("?"),
                idx_codecs.get(g).copied().unwrap_or("packed"),
                shards.get(g).copied().unwrap_or(1),
                bytes / iters,
                entries.get(g).map(|(_, n)| *n).unwrap_or(0)
            );
        }
        let total: usize = group_totals.iter().map(|(_, b)| b).sum();
        println!(
            "  {:<16} {:<10} {:>6} {:>6} {:>7} {total:>12}",
            "(all groups)", "", "", "", ""
        );
    }
    write_logs(&[log], p.get("out"), "train");
    0
}

/// `repro train --transport tcp|uds`: bind a framed-socket star,
/// spawn every worker as a SEPARATE OS PROCESS of this same binary
/// (`repro worker --connect ...` against the resolved config written
/// to a temp file), and drive the server loop.  The trajectory is
/// bit-identical to the in-process path; `Trainer::run_transport`
/// additionally asserts the per-round socket bytes equal the ledger's
/// charged bytes.
fn run_train_networked(tr: &mut Trainer, cfg: &TrainConfig) -> Result<RunLog, String> {
    let uds_path = std::env::temp_dir()
        .join(format!("regtopk-train-{}.sock", std::process::id()));
    let mut net = match cfg.transport {
        TransportKind::Tcp => Tcp::bind()?,
        TransportKind::Uds => bind_uds(&uds_path)?,
        TransportKind::InProc => unreachable!("networked driver called for inproc"),
    };
    // workers rebuild the run from the RESOLVED config (CLI overrides
    // already applied), so both sides derive identical state
    let cfg_path = std::env::temp_dir()
        .join(format!("regtopk-train-{}.json", std::process::id()));
    std::fs::write(&cfg_path, cfg.to_json().dump())
        .map_err(|e| format!("writing {}: {e}", cfg_path.display()))?;
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let mut children = Vec::with_capacity(cfg.workers);
    for i in 0..cfg.workers {
        let mut c = std::process::Command::new(&exe);
        c.arg("worker")
            .arg("--connect")
            .arg(net.addr())
            .arg("--config")
            .arg(&cfg_path)
            .arg("--worker")
            .arg(i.to_string())
            .arg("--iters")
            .arg(cfg.iters.to_string());
        if cfg.transport == TransportKind::Uds {
            c.arg("--uds");
        }
        children.push(c.spawn().map_err(|e| format!("spawning worker {i}: {e}"))?);
    }
    net.accept(cfg.workers)?;
    let log = tr.run_transport(&mut net, cfg.iters);
    for (i, mut ch) in children.into_iter().enumerate() {
        let st = ch.wait().map_err(|e| format!("waiting for worker {i}: {e}"))?;
        if !st.success() {
            return Err(format!("worker process {i} exited with {st}"));
        }
    }
    let _ = std::fs::remove_file(&cfg_path);
    let _ = std::fs::remove_file(&uds_path);
    if let Some(c) = net.counters() {
        println!(
            "transport {}: {} worker processes; socket charged bytes up {} / down {} \
             ({} frames in, {} frames out; {} raw bytes in, {} out)",
            cfg.transport.name(),
            cfg.workers,
            c.recv_wire,
            c.sent_wire,
            c.recv_frames,
            c.sent_frames,
            c.recv_bytes,
            c.sent_bytes
        );
    }
    Ok(log)
}

/// Bind the `--transport uds` listener (a stale socket file from a
/// crashed run is removed first).
#[cfg(unix)]
fn bind_uds(path: &Path) -> Result<Tcp, String> {
    let _ = std::fs::remove_file(path);
    Tcp::bind_uds(&path.to_string_lossy())
}

#[cfg(not(unix))]
fn bind_uds(_path: &Path) -> Result<Tcp, String> {
    Err("unix domain sockets are unavailable on this platform".to_string())
}

/// `repro worker` — one worker of a networked run, as its own OS
/// process: rebuild worker state from the resolved config, connect to
/// the server's framed socket, and serve rounds.
fn cmd_worker(args: Vec<String>) -> i32 {
    let p = Cli::new(
        "Worker process for `repro train --transport tcp|uds`: connects\n\
         to the server, handshakes its worker id, then serves the round\n\
         protocol (recv broadcast, compute, sparsify, send update).",
    )
    .required("connect", "server address host:port (or socket path with --uds)")
    .required("config", "path to the RESOLVED config JSON the server wrote")
    .flag("worker", "0", "this worker's id (0-based)")
    .flag("iters", "0", "rounds to serve (must match the server)")
    .switch("uds", "connect over a unix domain socket")
    .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = match TrainConfig::from_json_file(Path::new(p.get("config"))) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bad config: {e}");
            return 2;
        }
    };
    let i = p.get_usize("worker");
    if i >= cfg.workers {
        eprintln!("worker id {i} out of range (config has {} workers)", cfg.workers);
        return 2;
    }
    // identical problem derivation to cmd_train: the generator is
    // seeded, so every process sees the same shards
    let params = LinearParams { workers: cfg.workers, ..LinearParams::fig2() };
    let problem = generate(params, cfg.seed);
    let worker = fig2::worker_from_config(&cfg, &problem, i);
    let addr = p.get("connect");
    #[cfg(unix)]
    let link_res = if p.get_bool("uds") {
        TcpLink::connect_uds(addr, i)
    } else {
        TcpLink::connect(addr, i)
    };
    #[cfg(not(unix))]
    let link_res = if p.get_bool("uds") {
        Err("unix domain sockets are unavailable on this platform".to_string())
    } else {
        TcpLink::connect(addr, i)
    };
    let mut link = match link_res {
        Ok(l) => l,
        Err(e) => {
            eprintln!("worker {i}: {e}");
            return 1;
        }
    };
    regtopk::coordinator::serve_worker(worker, &mut link, cfg.omega(i), p.get_usize("iters"));
    0
}

fn cmd_lint(args: Vec<String>) -> i32 {
    let p = Cli::new(
        "Repo-invariant static analyzer (the `scripts/ci.sh analyze` gate).\n\
         Line rules: SAFETY comments on every unsafe block/impl/fn, unsafe\n\
         only in allowlisted modules, no thread::spawn outside the pool,\n\
         byte accounting only in comm::codec::WireCost, no wall-clock or\n\
         OS entropy in deterministic paths, every SparsifierKind family in\n\
         the resume + determinism test matrices.  Semantic gates: wire/\n\
         persisted schema drift vs SCHEMA.lock (+ docs/WIRE.md note),\n\
         module layering over the declared DAG, dead `pub` surface, and\n\
         literal match exhaustiveness over the wire enums.  Waive a single\n\
         line with a `repro-lint: allow(<rule>)` comment (layering and\n\
         schema rules are not waivable).",
    )
    .flag("root", "", "repo root (default: walk up from the current directory)")
    .switch("json", "machine-readable findings (including waived) on stdout")
    .switch("schema", "print the canonical SCHEMA.lock rendering and exit")
    .switch("schema-write", "regenerate SCHEMA.lock from the tree")
    .parse_from(args);
    let p = match p {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let root = if p.get("root").is_empty() {
        let cwd = std::env::current_dir().expect("cwd");
        match regtopk::analysis::find_root(&cwd) {
            Some(r) => r,
            None => {
                eprintln!("no repo root (Cargo.toml + rust/src) above {}", cwd.display());
                return 2;
            }
        }
    } else {
        PathBuf::from(p.get("root"))
    };
    if p.get_bool("schema") || p.get_bool("schema-write") {
        return cmd_lint_schema(&root, p.get_bool("schema-write"));
    }
    // timing the analyzer is observability, not a deterministic path:
    // repro-lint: allow(wall-clock)
    let t0 = std::time::Instant::now();
    let report = match regtopk::analysis::analyze_tree_full(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: cannot walk {}: {e}", root.display());
            return 2;
        }
    };
    let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
    let failing = report.failing().count();
    let waived = report.findings.len() - failing;
    if p.get_bool("json") {
        println!("{}", findings_json(&report.findings));
    } else {
        for f in report.failing() {
            println!("{f}");
        }
    }
    let verdict = if failing == 0 { "clean" } else { "FAIL" };
    eprintln!(
        "lint: {verdict} — {failing} finding(s), {waived} waived, {} rules, \
         {} files in {elapsed_ms:.0} ms (root {})",
        regtopk::analysis::RULES.len(),
        report.files_scanned,
        root.display()
    );
    i32::from(failing != 0)
}

/// `repro lint --schema` / `--schema-write`: print or rewrite the
/// canonical `SCHEMA.lock` rendering of the tree.  CI pipes `--schema`
/// into `cmp - SCHEMA.lock`, which is the determinism acceptance check.
fn cmd_lint_schema(root: &Path, write: bool) -> i32 {
    let files = match regtopk::analysis::read_tree(root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lint: cannot walk {}: {e}", root.display());
            return 2;
        }
    };
    let parsed = regtopk::analysis::extract::parse_all(&files);
    if write {
        return match regtopk::analysis::schema::write_lock(root, &parsed) {
            Ok(note) => {
                println!("{note}");
                0
            }
            Err(e) => {
                eprintln!("lint: {e}");
                1
            }
        };
    }
    let (text, findings) = regtopk::analysis::schema::render_for_tree(root, &parsed);
    if !findings.is_empty() {
        for f in &findings {
            eprintln!("{f}");
        }
        return 1;
    }
    print!("{text}");
    0
}

/// Serialize findings as a JSON array (stable key order; the repo's
/// own minimal escaping — messages are ASCII by construction).
fn findings_json(findings: &[regtopk::analysis::Finding]) -> String {
    let esc = |s: &str| {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    };
    let rows: Vec<String> = findings
        .iter()
        .map(|f| {
            format!(
                "  {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"waived\": {}, \"msg\": \"{}\"}}",
                esc(f.rule),
                esc(&f.path),
                f.line,
                f.waived,
                esc(&f.msg)
            )
        })
        .collect();
    if rows.is_empty() {
        "[]".to_string()
    } else {
        format!("[\n{}\n]", rows.join(",\n"))
    }
}

fn cmd_info(_args: Vec<String>) -> i32 {
    match Runtime::open_default() {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("artifacts ({}):", rt.manifest.artifacts.len());
            for (name, a) in &rt.manifest.artifacts {
                println!("  {:<26} {} in / {} out  {}", name, a.inputs.len(), a.outputs, a.doc);
            }
            println!("models:");
            for (name, m) in &rt.manifest.models {
                println!("  {:<12} J={} ({} layers)", name, m.param_count, m.layout.layers.len());
            }
            0
        }
        Err(e) => {
            eprintln!("artifacts unavailable: {e:#}\nrun `make artifacts` first");
            1
        }
    }
}
