//! Logistic regression (the Fig. 1 toy workload, §1.2) with ±1 labels.
//!
//! loss_i = log(1 + exp(-y_i <w; x_i>)),
//! grad   = -(1/D) sum_i  y_i sigma(-y_i <w;x_i>) x_i      (paper eq. 2)

use crate::models::GradModel;

pub struct Logistic {
    /// row-major features, rows x dim
    pub x: Vec<f32>,
    /// ±1 labels
    pub y: Vec<f32>,
    pub rows: usize,
    pub dim: usize,
    /// optional additive gradient offset dG/dw (the §1.2 "G(theta_2)"
    /// extension: a constant extra derivative on chosen coordinates)
    pub grad_offset: Vec<f32>,
}

impl Logistic {
    pub fn new(x: Vec<f32>, y: Vec<f32>, dim: usize) -> Self {
        assert_eq!(x.len() % dim, 0);
        let rows = x.len() / dim;
        assert_eq!(y.len(), rows);
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        Logistic { x, y, rows, dim, grad_offset: vec![0.0; dim] }
    }

    /// The paper's worker n of the toy problem: single data point.
    pub fn toy_worker(point: Vec<f32>) -> Self {
        let dim = point.len();
        Logistic::new(point, vec![1.0], dim)
    }

    pub fn loss(&self, w: &[f32]) -> f32 {
        let mut total = 0.0f64;
        for r in 0..self.rows {
            let row = &self.x[r * self.dim..(r + 1) * self.dim];
            let z: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() * self.y[r];
            // stable log(1 + exp(-z))
            total += if z > 0.0 {
                (-z as f64).exp().ln_1p()
            } else {
                -z as f64 + (z as f64).exp().ln_1p()
            };
        }
        (total / self.rows as f64) as f32
    }
}

impl GradModel for Logistic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss_grad(&mut self, w: &[f32], out: &mut [f32]) -> f32 {
        out.copy_from_slice(&self.grad_offset);
        let mut total = 0.0f64;
        let inv = 1.0 / self.rows as f32;
        for r in 0..self.rows {
            let row = &self.x[r * self.dim..(r + 1) * self.dim];
            let z: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() * self.y[r];
            total += if z > 0.0 {
                (-z as f64).exp().ln_1p()
            } else {
                -z as f64 + (z as f64).exp().ln_1p()
            };
            // sigma(-z) = 1/(1+e^z)
            let s = 1.0 / (1.0 + (z as f64).exp());
            let coef = -(self.y[r] as f64 * s) as f32 * inv;
            for (o, &xv) in out.iter_mut().zip(row) {
                *o += coef * xv;
            }
        }
        (total / self.rows as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_matches_paper_eq2_at_toy_w0() {
        // worker 1: x=[100,1], w0=[0,1] => z=1, sigma(-1)=0.2689
        let mut m = Logistic::toy_worker(vec![100.0, 1.0]);
        let mut g = vec![0.0; 2];
        m.loss_grad(&[0.0, 1.0], &mut g);
        let s = 1.0 / (1.0 + 1f64.exp());
        assert!((g[0] as f64 + s * 100.0).abs() < 1e-5, "{g:?}");
        assert!((g[1] as f64 + s).abs() < 1e-6);
    }

    #[test]
    fn toy_gradients_cancel_in_first_entry() {
        let mut m1 = Logistic::toy_worker(vec![100.0, 1.0]);
        let mut m2 = Logistic::toy_worker(vec![-100.0, 1.0]);
        let (mut g1, mut g2) = (vec![0.0; 2], vec![0.0; 2]);
        m1.loss_grad(&[0.0, 1.0], &mut g1);
        m2.loss_grad(&[0.0, 1.0], &mut g2);
        assert!((g1[0] + g2[0]).abs() < 1e-7);
        assert!((g1[1] - g2[1]).abs() < 1e-7);
        assert!(g1[1] < 0.0); // descent direction increases theta_2
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut m = Logistic::new(
            vec![1.0, 2.0, -0.5, 1.5, 0.3, -2.0],
            vec![1.0, -1.0, 1.0],
            2,
        );
        let w = vec![0.3, -0.7];
        let mut g = vec![0.0; 2];
        let l0 = m.loss_grad(&w, &mut g);
        let h = 1e-3;
        for i in 0..2 {
            let mut wp = w.clone();
            wp[i] += h;
            let mut tmp = vec![0.0; 2];
            let lp = m.loss_grad(&wp, &mut tmp);
            let fd = (lp - l0) / h;
            assert!((fd - g[i]).abs() < 1e-2, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn grad_offset_adds_constant_derivative() {
        let mut m = Logistic::toy_worker(vec![100.0, 1.0]);
        m.grad_offset = vec![0.0, 1.0];
        let mut g0 = vec![0.0; 2];
        m.loss_grad(&[0.0, 1.0], &mut g0);
        let mut plain = Logistic::toy_worker(vec![100.0, 1.0]);
        let mut g1 = vec![0.0; 2];
        plain.loss_grad(&[0.0, 1.0], &mut g1);
        assert_eq!(g0[0], g1[0]);
        assert!((g0[1] - (g1[1] + 1.0)).abs() < 1e-7);
    }

    #[test]
    fn descent_reduces_loss() {
        let mut m = Logistic::new(vec![2.0, -1.0, -1.5, 2.5], vec![1.0, -1.0], 2);
        let mut w = vec![0.0, 0.0];
        let mut g = vec![0.0; 2];
        let l0 = m.loss_grad(&w, &mut g);
        for _ in 0..50 {
            m.loss_grad(&w, &mut g);
            for i in 0..2 {
                w[i] -= 0.5 * g[i];
            }
        }
        assert!(m.loss(&w) < l0 * 0.5);
    }
}
