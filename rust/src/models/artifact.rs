//! Artifact-backed models: worker gradient computation through the
//! AOT-compiled JAX/Pallas executables (the production hot path —
//! python is never in the loop, only its build-time artifacts).

use std::sync::Arc;

use crate::data::cifar_like::{ImageSet, IMG_DIM};
use crate::data::sampler::BatchSampler;
use crate::models::GradModel;
use crate::runtime::{Executable, Tensor};

/// CNN worker model: samples a mini-batch from its image shard and
/// computes (loss, grad) via the `cnn_grad_*` artifact.
pub struct CnnModel {
    exe: Arc<Executable>,
    shard: ImageSet,
    sampler: BatchSampler,
    batch: usize,
    dim: usize,
}

impl CnnModel {
    pub fn new(exe: Arc<Executable>, shard: ImageSet, seed: u64) -> Self {
        let dim = exe.spec.inputs[0].shape[0];
        let batch = exe.spec.inputs[1].shape[0];
        assert!(shard.rows >= batch, "shard smaller than batch");
        let sampler = BatchSampler::new(shard.rows, batch, seed);
        CnnModel { exe, shard, sampler, batch, dim }
    }
}

impl GradModel for CnnModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss_grad(&mut self, w: &[f32], out: &mut [f32]) -> f32 {
        let idx = self.sampler.next_batch().to_vec();
        let (x, y) = self.shard.gather(&idx);
        let res = self
            .exe
            .call(&[
                Tensor::f32(w.to_vec(), &[self.dim]),
                Tensor::f32(x, &[self.batch, 32, 32, 3]),
                Tensor::i32(y, &[self.batch]),
            ])
            .expect("cnn_grad artifact failed");
        out.copy_from_slice(&res[1]);
        res[0][0]
    }
}

/// Validation-accuracy evaluator over the `cnn_eval_*` artifact
/// (logits for a fixed eval batch size; the val set is chunked).
pub struct CnnEval {
    exe: Arc<Executable>,
    val: ImageSet,
    batch: usize,
    dim: usize,
}

impl CnnEval {
    pub fn new(exe: Arc<Executable>, val: ImageSet) -> Self {
        let dim = exe.spec.inputs[0].shape[0];
        let batch = exe.spec.inputs[1].shape[0];
        CnnEval { exe, val, batch, dim }
    }

    /// Top-1 accuracy of model `w` on the validation set (full chunks
    /// only — drop_last semantics, matching the sampler).
    pub fn accuracy(&self, w: &[f32]) -> f32 {
        let chunks = self.val.rows / self.batch;
        assert!(chunks > 0, "val set smaller than eval batch");
        let mut correct = 0usize;
        for c in 0..chunks {
            let idx: Vec<usize> = (c * self.batch..(c + 1) * self.batch).collect();
            let (x, y) = self.val.gather(&idx);
            let logits = &self
                .exe
                .call(&[
                    Tensor::f32(w.to_vec(), &[self.dim]),
                    Tensor::f32(x, &[self.batch, 32, 32, 3]),
                ])
                .expect("cnn_eval artifact failed")[0];
            for (b, &label) in y.iter().enumerate() {
                let row = &logits[b * 10..(b + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as i32 == label {
                    correct += 1;
                }
            }
        }
        correct as f32 / (chunks * self.batch) as f32
    }
}

/// MLP worker model over flattened images (`mlp_grad` artifact).
pub struct MlpModel {
    exe: Arc<Executable>,
    shard: ImageSet,
    sampler: BatchSampler,
    batch: usize,
    dim: usize,
}

impl MlpModel {
    pub fn new(exe: Arc<Executable>, shard: ImageSet, seed: u64) -> Self {
        let dim = exe.spec.inputs[0].shape[0];
        let batch = exe.spec.inputs[1].shape[0];
        let sampler = BatchSampler::new(shard.rows, batch, seed);
        MlpModel { exe, shard, sampler, batch, dim }
    }
}

impl GradModel for MlpModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss_grad(&mut self, w: &[f32], out: &mut [f32]) -> f32 {
        let idx = self.sampler.next_batch().to_vec();
        let (x, y) = self.shard.gather(&idx);
        let res = self
            .exe
            .call(&[
                Tensor::f32(w.to_vec(), &[self.dim]),
                Tensor::f32(x, &[self.batch, IMG_DIM]),
                Tensor::i32(y, &[self.batch]),
            ])
            .expect("mlp_grad artifact failed");
        out.copy_from_slice(&res[1]);
        res[0][0]
    }
}
