//! Model substrate on the rust side.
//!
//! Two kinds of model back a worker's gradient computation:
//!
//! - **Native** ([`linreg`], [`logistic`]): closed-form losses whose
//!   gradients are computed directly in rust.  Used by the Fig. 1 toy
//!   and as the fallback/cross-check for the Fig. 2 testbed.
//! - **Artifact-backed** (see [`crate::runtime`]): the JAX/Pallas HLO
//!   executables (linreg, MLP, ResNet) loaded through PJRT; the
//!   manifest in `artifacts/manifest.json` defines shapes and layouts.

#![forbid(unsafe_code)]

pub mod artifact;
pub mod logistic;

pub use crate::data::linear::ls_gradient;

/// A differentiable empirical loss over a flat parameter vector.
/// Implementations must be deterministic given (w, batch).
pub trait GradModel: Send {
    /// Parameter dimension J.
    fn dim(&self) -> usize;
    /// Compute loss and write the gradient into `out` for the worker's
    /// current batch.  Returns the loss.
    fn loss_grad(&mut self, w: &[f32], out: &mut [f32]) -> f32;
}

/// Full-batch least-squares model over one worker shard (Fig. 2).
pub struct LinRegShard {
    pub shard: crate::data::Shard,
}

impl GradModel for LinRegShard {
    fn dim(&self) -> usize {
        self.shard.dim
    }
    fn loss_grad(&mut self, w: &[f32], out: &mut [f32]) -> f32 {
        ls_gradient(&self.shard, w, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::linear::{generate, LinearParams};

    #[test]
    fn linreg_shard_implements_gradmodel() {
        let p = generate(
            LinearParams { workers: 1, rows_per_worker: 30, dim: 5, u: 0.0, sigma2: 1.0, h2: 1.0, noise: 0.1 },
            1,
        );
        let mut m = LinRegShard { shard: p.shards[0].clone() };
        let w = vec![0.0; 5];
        let mut g = vec![0.0; 5];
        let loss = m.loss_grad(&w, &mut g);
        assert!(loss > 0.0);
        assert!(g.iter().any(|v| v.abs() > 0.0));
        assert_eq!(m.dim(), 5);
    }
}
