//! The repo-invariant rule set.
//!
//! Each rule is mechanical on purpose: these are the invariants the
//! review history keeps re-litigating by hand, written down once and
//! enforced on every line of the tree.  Rules match the lexer's code
//! channel, so tokens inside strings and comments never fire.
//!
//! | id                  | invariant                                            |
//! |---------------------|------------------------------------------------------|
//! | `safety-comment`    | every `unsafe` token carries a `SAFETY:` comment      |
//! | `unsafe-allowlist`  | `unsafe` appears only in the allowlisted module set   |
//! | `spawn-outside-pool`| `thread::spawn` only in `util/pool.rs` (or tests)     |
//! | `byte-accounting`   | bits→bytes (`div_ceil(8)`) only inside `comm/codec/`  |
//! | `wall-clock`        | no wall-clock/OS-entropy calls in deterministic paths |
//! | `kind-matrix`       | every `SparsifierKind` family in both test matrices   |
//!
//! A finding on a specific line can be waived with a
//! `repro-lint: allow(<rule-id>)` comment on the same line or the
//! line directly above — the waiver is itself a comment, so it shows
//! up in review next to the code it excuses.

#![forbid(unsafe_code)]

use super::lexer::{has_word, split, Line};

/// Every rule id the analyzer can report, in the order of the module
/// docs table.  A waiver comment must name one of these.
pub const RULES: &[&str] = &[
    "safety-comment",
    "unsafe-allowlist",
    "spawn-outside-pool",
    "byte-accounting",
    "wall-clock",
    "kind-matrix",
];

/// Files allowed to contain the `unsafe` keyword.  Everything else in
/// the tree is expected to carry `#![forbid(unsafe_code)]` (directly
/// or via its parent module); this list is the single place a new
/// unsafe module must be registered, and `analyze_tree` fails on
/// stale entries so the list cannot drift from the tree.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/util/pool.rs",
    "rust/src/sparse/engine.rs",
    "rust/src/sparsify/regtopk.rs",
    "rust/src/sparsify/dgc.rs",
    "rust/src/runtime/mod.rs",
    "rust/tests/pool_audit.rs",
];

/// Wall-clock / OS-entropy / iteration-order tokens that must not
/// appear in deterministic paths.  `HashMap`/`HashSet` are here for
/// their `RandomState` hasher: seeded-random iteration order is how
/// "deterministic" trees silently stop being deterministic.
const WALL_CLOCK_TOKENS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "HashMap",
    "HashSet",
    "RandomState",
    "thread_rng",
    "from_entropy",
];

/// The wall-clock rule does not apply here: measuring elapsed time is
/// the bench harness's whole job.
const WALL_CLOCK_EXEMPT: &[&str] = &["rust/src/util/bench.rs"];

/// The two test matrices every `SparsifierKind` family must appear in.
const KIND_MATRIX_FILES: &[&str] = &["rust/tests/resume.rs", "rust/tests/determinism.rs"];

/// Where the `SparsifierKind` enum itself lives.
const KIND_ENUM_FILE: &str = "rust/src/sparsify/mod.rs";

/// One analyzer finding.  `line` is 1-based; 0 means the finding is
/// about the file (or the tree) as a whole.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Analyze a set of `(relative_path, source)` pairs.  This is the
/// whole analyzer minus the filesystem walk, so the self-test can
/// feed it fixture trees.  Paths use `/` separators relative to the
/// repo root (e.g. `rust/src/util/pool.rs`).
pub fn analyze_sources(files: &[(String, String)]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (path, src) in files {
        scan_file(path, src, &mut findings);
    }
    kind_matrix(files, &mut findings);
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    findings
}

/// Is this path inherently test/bench code (rules scoped to shipped
/// library paths skip it entirely)?
fn is_test_path(path: &str) -> bool {
    !path.starts_with("rust/src/")
}

fn scan_file(path: &str, src: &str, findings: &mut Vec<Finding>) {
    let lines = split(src);
    // Repo convention: `#[cfg(test)] mod tests` sits at the end of
    // the file, so everything from the first `#[cfg(test)]` on is
    // treated as test region for the test-exempt rules.
    let test_from = if is_test_path(path) {
        0
    } else {
        lines
            .iter()
            .position(|l| l.code.contains("#[cfg(test)]"))
            .unwrap_or(lines.len())
    };
    let allowlisted = UNSAFE_ALLOWLIST.contains(&path);
    let wall_exempt = WALL_CLOCK_EXEMPT.contains(&path);

    for (idx, line) in lines.iter().enumerate() {
        let n = idx + 1;
        let in_test = idx >= test_from;
        let waived = |rule: &str| has_waiver(&lines, idx, rule);

        if has_word(&line.code, "unsafe") {
            if !allowlisted && !waived("unsafe-allowlist") {
                findings.push(Finding {
                    rule: "unsafe-allowlist",
                    path: path.to_string(),
                    line: n,
                    msg: format!(
                        "`unsafe` outside the allowlisted module set; \
                         add a safe wrapper in an allowlisted module or \
                         register `{path}` in analysis::rules::UNSAFE_ALLOWLIST"
                    ),
                });
            }
            if !has_safety_comment(&lines, idx) && !waived("safety-comment") {
                findings.push(Finding {
                    rule: "safety-comment",
                    path: path.to_string(),
                    line: n,
                    msg: "`unsafe` without a `SAFETY:` comment on the same line or \
                          directly above (unsafe fn declarations may use a \
                          `# Safety` doc heading instead)"
                        .to_string(),
                });
            }
        }

        if !in_test
            && line.code.contains("thread::spawn")
            && path != "rust/src/util/pool.rs"
            && !waived("spawn-outside-pool")
        {
            findings.push(Finding {
                rule: "spawn-outside-pool",
                path: path.to_string(),
                line: n,
                msg: "`thread::spawn` outside util/pool.rs — hot paths must reuse \
                      the persistent pool, not spawn per call"
                    .to_string(),
            });
        }

        if !in_test
            && line.code.contains("div_ceil(8)")
            && !path.starts_with("rust/src/comm/codec/")
            && !waived("byte-accounting")
        {
            findings.push(Finding {
                rule: "byte-accounting",
                path: path.to_string(),
                line: n,
                msg: "bits→bytes conversion outside comm/codec — all byte \
                      accounting must go through codec::WireCost so reported \
                      bytes stay the wire bytes by construction"
                    .to_string(),
            });
        }

        if !in_test && !wall_exempt {
            for tok in WALL_CLOCK_TOKENS {
                let hit = if tok.contains("::") {
                    line.code.contains(tok)
                } else {
                    has_word(&line.code, tok)
                };
                if hit && !waived("wall-clock") {
                    findings.push(Finding {
                        rule: "wall-clock",
                        path: path.to_string(),
                        line: n,
                        msg: format!(
                            "`{tok}` in a deterministic path — wall-clock and \
                             OS-entropy (and randomly-seeded hash iteration) \
                             break bit-reproducibility; use util::rng / BTree \
                             collections, or waive with a justification"
                        ),
                    });
                    break;
                }
            }
        }
    }
}

/// `repro-lint: allow(<rule>)` in a comment on this line or the line
/// directly above waives that rule here.
fn has_waiver(lines: &[Line], idx: usize, rule: &str) -> bool {
    let tag = format!("repro-lint: allow({rule})");
    lines[idx].comment.contains(&tag)
        || (idx > 0 && lines[idx - 1].comment.contains(&tag))
}

/// Accept a `SAFETY:` marker on the unsafe line itself or anywhere in
/// the contiguous run of comment/attribute/blank lines directly above
/// it (so an attribute between the comment and the item is fine).  A
/// `# Safety` doc heading also counts — that is rustdoc's convention
/// for `unsafe fn` contracts.
fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    let marks = |l: &Line| l.comment.contains("SAFETY:") || l.comment.contains("# Safety");
    if marks(&lines[idx]) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        let comment_ish = code.is_empty() || code.starts_with("#[") || code.starts_with("#![");
        if !comment_ish {
            return false;
        }
        if marks(l) {
            return true;
        }
    }
    false
}

/// Parse the `SparsifierKind` variant names and require each to
/// appear as `SparsifierKind::<Variant>` in every matrix file.  New
/// families then cannot land without resume + bit-identity coverage.
fn kind_matrix(files: &[(String, String)], findings: &mut Vec<Finding>) {
    let Some((_, enum_src)) = files.iter().find(|(p, _)| p == KIND_ENUM_FILE) else {
        return;
    };
    let variants = parse_kind_variants(enum_src);
    if variants.is_empty() {
        return;
    }
    for matrix in KIND_MATRIX_FILES {
        let Some((_, src)) = files.iter().find(|(p, _)| p == *matrix) else {
            findings.push(Finding {
                rule: "kind-matrix",
                path: (*matrix).to_string(),
                line: 0,
                msg: "matrix test file missing from tree".to_string(),
            });
            continue;
        };
        let code: String = split(src).into_iter().map(|l| l.code + "\n").collect();
        for v in &variants {
            if !code.contains(&format!("SparsifierKind::{v}")) {
                findings.push(Finding {
                    rule: "kind-matrix",
                    path: (*matrix).to_string(),
                    line: 0,
                    msg: format!(
                        "SparsifierKind::{v} is not exercised here — every \
                         sparsifier family must appear in the resume and \
                         bit-identity matrices"
                    ),
                });
            }
        }
    }
}

fn parse_kind_variants(src: &str) -> Vec<String> {
    let lines = split(src);
    let Some(open) = lines.iter().position(|l| l.code.contains("pub enum SparsifierKind")) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for l in &lines[open + 1..] {
        let code = l.code.trim();
        if code.starts_with('}') {
            break;
        }
        let name: String =
            code.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        if !name.is_empty() && name.chars().next().is_some_and(|c| c.is_uppercase()) {
            out.push(name);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| ((*p).to_string(), (*s).to_string())).collect();
        analyze_sources(&owned)
    }

    #[test]
    fn clean_file_has_no_findings() {
        let f = run(&[(
            "rust/src/util/pool.rs",
            "// SAFETY: ptr valid for len elements\nunsafe { go() }\n",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn safety_comment_rule_fires() {
        let f = run(&[("rust/src/util/pool.rs", "unsafe { go() }\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "safety-comment");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn safety_comment_accepts_same_line_and_attr_gap() {
        let src = "// SAFETY: checked above\n#[allow(clippy::x)]\nunsafe { a() }\n\
                   let x = unsafe { b() }; // SAFETY: b is infallible here\n";
        assert!(run(&[("rust/src/util/pool.rs", src)]).is_empty());
    }

    #[test]
    fn safety_comment_does_not_leak_past_code() {
        // the comment belongs to the first impl only
        let src = "// SAFETY: T is Send\nunsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        let f = run(&[("rust/src/util/pool.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("safety-comment", 3));
    }

    #[test]
    fn allowlist_rule_fires_off_list() {
        let f = run(&[(
            "rust/src/metrics/mod.rs",
            "// SAFETY: justified\nunsafe { go() }\n",
        )]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "unsafe-allowlist");
    }

    #[test]
    fn spawn_rule_fires_outside_pool_but_not_in_tests() {
        let f = run(&[("rust/src/comm/transport.rs", "std::thread::spawn(|| {});\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "spawn-outside-pool");
        let src = "fn main() {}\n#[cfg(test)]\nmod tests {\n  fn t() { std::thread::spawn(|| {}); }\n}\n";
        assert!(run(&[("rust/src/comm/transport.rs", src)]).is_empty());
        assert!(run(&[("rust/tests/pool_extra.rs", "std::thread::spawn(|| {});\n")]).is_empty());
    }

    #[test]
    fn byte_accounting_rule_fires_outside_codec() {
        let f = run(&[("rust/src/sparsify/layerwise.rs", "let b = (n * bits).div_ceil(8);\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "byte-accounting");
        assert!(run(&[("rust/src/comm/codec/cost.rs", "let b = x.div_ceil(8);\n")]).is_empty());
    }

    #[test]
    fn wall_clock_rule_fires_and_bench_is_exempt() {
        let f = run(&[("rust/src/coordinator/trainer.rs", "let t0 = Instant::now();\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "wall-clock");
        let f = run(&[("rust/src/grad/layout.rs", "use std::collections::HashMap;\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "wall-clock");
        assert!(run(&[("rust/src/util/bench.rs", "let t0 = Instant::now();\n")]).is_empty());
    }

    #[test]
    fn waiver_suppresses_exactly_one_rule() {
        let src = "// why: reported metric only — repro-lint: allow(wall-clock)\n\
                   let t0 = Instant::now();\n";
        assert!(run(&[("rust/src/coordinator/trainer.rs", src)]).is_empty());
        // a waiver for a different rule does not suppress
        let src = "// repro-lint: allow(byte-accounting)\nlet t0 = Instant::now();\n";
        let f = run(&[("rust/src/coordinator/trainer.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_fire() {
        let src = "// unsafe thread::spawn HashMap div_ceil(8) Instant::now\n\
                   let s = \"unsafe thread::spawn HashMap Instant::now\";\n";
        assert!(run(&[("rust/src/metrics/mod.rs", src)]).is_empty());
    }

    #[test]
    fn kind_matrix_catches_missing_family() {
        let enum_src = "pub enum SparsifierKind {\n    Dense,\n    TopK { k: usize },\n}\n";
        let covered = "t(SparsifierKind::Dense); t(SparsifierKind::TopK { k });\n";
        let partial = "t(SparsifierKind::Dense);\n";
        let f = run(&[
            (KIND_ENUM_FILE, enum_src),
            ("rust/tests/resume.rs", covered),
            ("rust/tests/determinism.rs", partial),
        ]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "kind-matrix");
        assert_eq!(f[0].path, "rust/tests/determinism.rs");
        assert!(f[0].msg.contains("TopK"));
        let f = run(&[
            (KIND_ENUM_FILE, enum_src),
            ("rust/tests/resume.rs", covered),
            ("rust/tests/determinism.rs", covered),
        ]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn parse_variants_reads_real_shape() {
        let src = "pub enum SparsifierKind {\n    Dense,\n    RegTopK { k: usize, mu: f32 },\n    AdaK { ratio: f32 },\n}\n";
        assert_eq!(parse_kind_variants(src), vec!["Dense", "RegTopK", "AdaK"]);
    }
}
