//! The repo-invariant rule set.
//!
//! Each rule is mechanical on purpose: these are the invariants the
//! review history keeps re-litigating by hand, written down once and
//! enforced on every line of the tree.  Rules match the lexer's code
//! channel, so tokens inside strings and comments never fire.
//!
//! | id                  | invariant                                            |
//! |---------------------|------------------------------------------------------|
//! | `safety-comment`    | every `unsafe` token carries a `SAFETY:` comment      |
//! | `unsafe-allowlist`  | `unsafe` appears only in the allowlisted module set   |
//! | `spawn-outside-pool`| `thread::spawn` only in `util/pool.rs` (or tests)     |
//! | `byte-accounting`   | bits→bytes (`div_ceil(8)`) only inside `comm/codec/`  |
//! | `net-outside-transport` | `std::net` sockets only in `comm/transport.rs`    |
//! | `wall-clock`        | no wall-clock/OS-entropy calls in deterministic paths |
//! | `bit-kernels-outside-kernels` | float bit-twiddling only in the kernel layer |
//! | `kind-matrix`       | every `SparsifierKind` family in both test matrices   |
//! | `wildcard`          | no `_`/binding arm in matches over wire enums/tags    |
//! | `layering`          | `use` edges respect the declared module DAG           |
//! | `dead-pub`          | top-level `pub` items have cross-module references    |
//! | `schema-drift`      | wire/persisted formats match committed `SCHEMA.lock`  |
//! | `schema-tag-reuse`  | checkpoint tags/magics are never renumbered or reused |
//! | `schema-doc`        | every SCHEMA.lock version has a docs/WIRE.md `## vN`  |
//!
//! A finding on a specific line can be waived with a
//! `repro-lint: allow(<rule-id>)` comment on the same line or the
//! line directly above — the waiver is itself a comment, so it shows
//! up in review next to the code it excuses.  The schema and layering
//! rules are **not** waivable: their escape hatch is an explicit edit
//! (regenerate the lockfile + document, or re-declare the DAG), never
//! a comment.
//!
//! Every file is read and lexed exactly once (see
//! [`super::extract::parse_all`]); all rules — line-lexical and
//! semantic — share that pass.

#![forbid(unsafe_code)]

use super::extract::{is_wildcard_head, parse_all, FileItems, Parsed, SourceFile};
use super::graph;
use super::lexer::has_word;

/// Every rule id the analyzer can report, in the order of the module
/// docs table.  A waiver comment must name one of these.
pub const RULES: &[&str] = &[
    "safety-comment",
    "unsafe-allowlist",
    "spawn-outside-pool",
    "byte-accounting",
    "net-outside-transport",
    "wall-clock",
    "bit-kernels-outside-kernels",
    "kind-matrix",
    "wildcard",
    "layering",
    "dead-pub",
    "schema-drift",
    "schema-tag-reuse",
    "schema-doc",
];

/// Files allowed to contain the `unsafe` keyword.  Everything else in
/// the tree is expected to carry `#![forbid(unsafe_code)]` (directly
/// or via its parent module); this list is the single place a new
/// unsafe module must be registered, and `analyze_tree` fails on
/// stale entries so the list cannot drift from the tree.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/util/pool.rs",
    "rust/src/sparse/engine.rs",
    "rust/src/sparsify/regtopk.rs",
    "rust/src/sparsify/dgc.rs",
    "rust/src/runtime/mod.rs",
    "rust/tests/pool_audit.rs",
];

/// Socket-API tokens confined to the transport module.  Every other
/// file reaches peers through the `comm::Transport` trait, so the
/// framing and byte-accounting invariants (frames carry exactly the
/// ledger-charged bytes) cannot be bypassed by a stray socket.
const NET_TOKENS: &[&str] = &["TcpStream", "TcpListener", "UnixStream", "UnixListener"];

/// The one non-test file allowed to touch `std::net` directly.
const NET_FILE: &str = "rust/src/comm/transport.rs";

/// Wall-clock / OS-entropy / iteration-order tokens that must not
/// appear in deterministic paths.  `HashMap`/`HashSet` are here for
/// their `RandomState` hasher: seeded-random iteration order is how
/// "deterministic" trees silently stop being deterministic.
const WALL_CLOCK_TOKENS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "HashMap",
    "HashSet",
    "RandomState",
    "thread_rng",
    "from_entropy",
];

/// The wall-clock rule does not apply here: measuring elapsed time is
/// the bench harness's whole job.
const WALL_CLOCK_EXEMPT: &[&str] = &["rust/src/util/bench.rs"];

/// Float bit-reinterpretation tokens confined to the kernel layer.
/// `util::kernels` owns every bit-level float primitive (magnitude
/// keys, bf16/f16 converts, histogram bin edges) with a scalar
/// referee pinning each one bit-identical; a `to_bits`/`from_bits`
/// scattered anywhere else escapes that contract.
const BIT_KERNEL_TOKENS: &[&str] = &["to_bits", "from_bits", "mag_bits"];

/// Files allowed to bit-twiddle floats directly: the kernel layer
/// itself and the select path's radix loops (the kernels' independent
/// scalar referee — sharing an implementation would make the
/// bit-identity tests tautological).
const BIT_KERNEL_FILES: &[&str] = &["rust/src/util/kernels.rs", "rust/src/sparse/topk.rs"];

/// The two test matrices every `SparsifierKind` family must appear in.
const KIND_MATRIX_FILES: &[&str] = &["rust/tests/resume.rs", "rust/tests/determinism.rs"];

/// Where the `SparsifierKind` enum itself lives.
const KIND_ENUM_FILE: &str = "rust/src/sparsify/mod.rs";

/// Enums (and the tag-const prefix) whose `match` sites must be
/// literally exhaustive: a new wire/persisted variant must fail to
/// compile at every decode site, not fall into a `_` arm.
const WATCHED_ENUMS: &[&str] =
    &["SparsifierKind", "SparsifierState", "Msg", "LevelKind", "IndexCodec", "FrameKind"];

/// One analyzer finding.  `line` is 1-based; 0 means the finding is
/// about the file (or the tree) as a whole.  `waived` findings are
/// suppressed from the failing set but kept for `repro lint --json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
    pub waived: bool,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let w = if self.waived { " (waived)" } else { "" };
        write!(f, "{}:{}: [{}]{} {}", self.path, self.line, self.rule, w, self.msg)
    }
}

/// Analyze a set of `(relative_path, source)` pairs, returning only
/// unwaived findings.  This is the whole analyzer minus the
/// filesystem walk and the SCHEMA.lock comparison (which need a repo
/// root), so the self-test can feed it fixture trees.  Paths use `/`
/// separators relative to the repo root (e.g. `rust/src/util/pool.rs`).
pub fn analyze_sources(files: &[(String, String)]) -> Vec<Finding> {
    let parsed = parse_all(files);
    analyze_parsed(&parsed).into_iter().filter(|f| !f.waived).collect()
}

/// All rules over an already-parsed tree: every file was read and
/// lexed exactly once, and the line rules plus the semantic gates
/// (wildcard, layering, dead-pub, kind-matrix) share that pass.
/// Returns waived findings too, flagged.
pub fn analyze_parsed(p: &Parsed) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (file, items) in &p.files {
        scan_file(file, &mut findings);
        wildcard_rule(file, items, &mut findings);
    }
    graph::layering(p, &mut findings);
    graph::dead_pubs(p, &mut findings);
    kind_matrix(p, &mut findings);
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    findings
}

fn scan_file(file: &SourceFile, findings: &mut Vec<Finding>) {
    let path = file.path.as_str();
    let allowlisted = UNSAFE_ALLOWLIST.contains(&path);
    let wall_exempt = WALL_CLOCK_EXEMPT.contains(&path);

    for (idx, line) in file.lines.iter().enumerate() {
        let n = idx + 1;
        let in_test = file.is_test_path() || file.is_test_line(idx);

        if has_word(&line.code, "unsafe") {
            if !allowlisted {
                findings.push(Finding {
                    rule: "unsafe-allowlist",
                    path: path.to_string(),
                    line: n,
                    msg: format!(
                        "`unsafe` outside the allowlisted module set; \
                         add a safe wrapper in an allowlisted module or \
                         register `{path}` in analysis::rules::UNSAFE_ALLOWLIST"
                    ),
                    waived: file.has_waiver(idx, "unsafe-allowlist"),
                });
            }
            if !has_safety_comment(file, idx) {
                findings.push(Finding {
                    rule: "safety-comment",
                    path: path.to_string(),
                    line: n,
                    msg: "`unsafe` without a `SAFETY:` comment on the same line or \
                          directly above (unsafe fn declarations may use a \
                          `# Safety` doc heading instead)"
                        .to_string(),
                    waived: file.has_waiver(idx, "safety-comment"),
                });
            }
        }

        if !in_test && line.code.contains("thread::spawn") && path != "rust/src/util/pool.rs" {
            findings.push(Finding {
                rule: "spawn-outside-pool",
                path: path.to_string(),
                line: n,
                msg: "`thread::spawn` outside util/pool.rs — hot paths must reuse \
                      the persistent pool, not spawn per call"
                    .to_string(),
                waived: file.has_waiver(idx, "spawn-outside-pool"),
            });
        }

        if !in_test
            && path != NET_FILE
            && (line.code.contains("std::net")
                || NET_TOKENS.iter().any(|t| has_word(&line.code, t)))
        {
            findings.push(Finding {
                rule: "net-outside-transport",
                path: path.to_string(),
                line: n,
                msg: "direct socket use outside comm/transport.rs — peers are \
                      reached only through the `comm::Transport` trait so the \
                      framing and byte-accounting invariants hold by construction"
                    .to_string(),
                waived: file.has_waiver(idx, "net-outside-transport"),
            });
        }

        if !in_test
            && line.code.contains("div_ceil(8)")
            && !path.starts_with("rust/src/comm/codec/")
        {
            findings.push(Finding {
                rule: "byte-accounting",
                path: path.to_string(),
                line: n,
                msg: "bits→bytes conversion outside comm/codec — all byte \
                      accounting must go through codec::WireCost so reported \
                      bytes stay the wire bytes by construction"
                    .to_string(),
                waived: file.has_waiver(idx, "byte-accounting"),
            });
        }

        if !in_test
            && !BIT_KERNEL_FILES.contains(&path)
            && BIT_KERNEL_TOKENS.iter().any(|t| has_word(&line.code, t))
        {
            findings.push(Finding {
                rule: "bit-kernels-outside-kernels",
                path: path.to_string(),
                line: n,
                msg: "float bit reinterpretation outside the kernel layer — \
                      route through util::kernels (or sparse/topk.rs's referee \
                      loops) so the scalar-referee bit-identity contract covers \
                      it, or waive with a justification"
                    .to_string(),
                waived: file.has_waiver(idx, "bit-kernels-outside-kernels"),
            });
        }

        if !in_test && !wall_exempt {
            for tok in WALL_CLOCK_TOKENS {
                let hit = if tok.contains("::") {
                    line.code.contains(tok)
                } else {
                    has_word(&line.code, tok)
                };
                if hit {
                    findings.push(Finding {
                        rule: "wall-clock",
                        path: path.to_string(),
                        line: n,
                        msg: format!(
                            "`{tok}` in a deterministic path — wall-clock and \
                             OS-entropy (and randomly-seeded hash iteration) \
                             break bit-reproducibility; use util::rng / BTree \
                             collections, or waive with a justification"
                        ),
                        waived: file.has_waiver(idx, "wall-clock"),
                    });
                    break;
                }
            }
        }
    }
}

/// Accept a `SAFETY:` marker on the unsafe line itself or anywhere in
/// the contiguous run of comment/attribute/blank lines directly above
/// it (so an attribute between the comment and the item is fine).  A
/// `# Safety` doc heading also counts — that is rustdoc's convention
/// for `unsafe fn` contracts.
fn has_safety_comment(file: &SourceFile, idx: usize) -> bool {
    let marks = |i: usize| {
        file.lines[i].comment.contains("SAFETY:") || file.lines[i].comment.contains("# Safety")
    };
    if marks(idx) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let code = file.lines[j].code.trim();
        let comment_ish = code.is_empty() || code.starts_with("#[") || code.starts_with("#![");
        if !comment_ish {
            return false;
        }
        if marks(j) {
            return true;
        }
    }
    false
}

/// A `match` whose arms mention a watched wire/persisted enum (or a
/// `STATE_TAG_*` const) must be literally exhaustive: with no
/// wildcard arm the *compiler* guarantees every variant is handled,
/// so a new wire variant breaks the build at every decode site
/// instead of vanishing into a `_`.  Waivable per arm (or on the
/// `match` line) with `repro-lint: allow(wildcard)`.
fn wildcard_rule(file: &SourceFile, items: &FileItems, findings: &mut Vec<Finding>) {
    if file.is_test_path() {
        return;
    }
    for site in &items.matches {
        if file.is_test_line(site.line - 1) {
            continue;
        }
        let watched = site.arms.iter().find_map(|a| {
            WATCHED_ENUMS
                .iter()
                .find(|e| a.head.contains(&format!("{e}::")))
                .map(|e| (*e).to_string())
                .or_else(|| a.head.contains("STATE_TAG_").then(|| "state tags".to_string()))
        });
        let Some(subject) = watched else { continue };
        for arm in &site.arms {
            if !is_wildcard_head(&arm.head) {
                continue;
            }
            let idx = arm.line - 1;
            findings.push(Finding {
                rule: "wildcard",
                path: file.path.clone(),
                line: arm.line,
                msg: format!(
                    "wildcard arm `{}` in a match over {subject} — wire/persisted \
                     enums must be matched exhaustively so a new variant fails \
                     loud at every decode site; spell out the variants or waive \
                     with `repro-lint: allow(wildcard)`",
                    arm.head
                ),
                waived: file.has_waiver(idx, "wildcard")
                    || file.has_waiver(site.line - 1, "wildcard"),
            });
        }
    }
}

/// Parse the `SparsifierKind` variant names and require each to
/// appear as `SparsifierKind::<Variant>` in every matrix file.  New
/// families then cannot land without resume + bit-identity coverage.
fn kind_matrix(p: &Parsed, findings: &mut Vec<Finding>) {
    let Some((_, items)) = p.files.iter().find(|(f, _)| f.path == KIND_ENUM_FILE) else {
        return;
    };
    let Some(e) = items.enums.iter().find(|e| e.name == "SparsifierKind") else {
        return;
    };
    let variants: Vec<String> = e
        .variants
        .iter()
        .map(|(d, _)| {
            d.split(|c: char| !(c.is_alphanumeric() || c == '_'))
                .next()
                .unwrap_or("")
                .to_string()
        })
        .filter(|v| !v.is_empty())
        .collect();
    if variants.is_empty() {
        return;
    }
    for matrix in KIND_MATRIX_FILES {
        let Some((file, _)) = p.files.iter().find(|(f, _)| f.path == *matrix) else {
            findings.push(Finding {
                rule: "kind-matrix",
                path: (*matrix).to_string(),
                line: 0,
                msg: "matrix test file missing from tree".to_string(),
                waived: false,
            });
            continue;
        };
        let code: String = file.lines.iter().map(|l| l.code.clone() + "\n").collect();
        for v in &variants {
            if !code.contains(&format!("SparsifierKind::{v}")) {
                findings.push(Finding {
                    rule: "kind-matrix",
                    path: (*matrix).to_string(),
                    line: 0,
                    msg: format!(
                        "SparsifierKind::{v} is not exercised here — every \
                         sparsifier family must appear in the resume and \
                         bit-identity matrices"
                    ),
                    waived: false,
                });
            }
        }
    }
}

/// Variant names of a `SparsifierKind` enum source (test helper /
/// back-compat shim over the item extractor).
pub fn parse_kind_variants(src: &str) -> Vec<String> {
    let file = SourceFile::parse(KIND_ENUM_FILE, src);
    let items = super::extract::extract(&file);
    items
        .enums
        .iter()
        .find(|e| e.name == "SparsifierKind")
        .map(|e| {
            e.variants
                .iter()
                .filter_map(|(d, _)| {
                    d.split(|c: char| !(c.is_alphanumeric() || c == '_')).next().map(str::to_string)
                })
                .filter(|v| !v.is_empty())
                .collect()
        })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| ((*p).to_string(), (*s).to_string())).collect();
        analyze_sources(&owned)
    }

    #[test]
    fn clean_file_has_no_findings() {
        let f = run(&[(
            "rust/src/util/pool.rs",
            "// SAFETY: ptr valid for len elements\nunsafe { go() }\n",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn safety_comment_rule_fires() {
        let f = run(&[("rust/src/util/pool.rs", "unsafe { go() }\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "safety-comment");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn safety_comment_accepts_same_line_and_attr_gap() {
        let src = "// SAFETY: checked above\n#[allow(clippy::x)]\nunsafe { a() }\n\
                   let x = unsafe { b() }; // SAFETY: b is infallible here\n";
        assert!(run(&[("rust/src/util/pool.rs", src)]).is_empty());
    }

    #[test]
    fn safety_comment_does_not_leak_past_code() {
        // the comment belongs to the first impl only
        let src = "// SAFETY: T is Send\nunsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        let f = run(&[("rust/src/util/pool.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("safety-comment", 3));
    }

    #[test]
    fn allowlist_rule_fires_off_list() {
        let f = run(&[(
            "rust/src/metrics/mod.rs",
            "// SAFETY: justified\nunsafe { go() }\n",
        )]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "unsafe-allowlist");
    }

    #[test]
    fn spawn_rule_fires_outside_pool_but_not_in_tests() {
        let f = run(&[("rust/src/comm/transport.rs", "std::thread::spawn(|| {});\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "spawn-outside-pool");
        let src =
            "fn main() {}\n#[cfg(test)]\nmod tests {\n  fn t() { std::thread::spawn(|| {}); }\n}\n";
        assert!(run(&[("rust/src/comm/transport.rs", src)]).is_empty());
        assert!(run(&[("rust/tests/pool_extra.rs", "std::thread::spawn(|| {});\n")]).is_empty());
    }

    #[test]
    fn byte_accounting_rule_fires_outside_codec() {
        let f = run(&[("rust/src/sparsify/layerwise.rs", "let b = (n * bits).div_ceil(8);\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "byte-accounting");
        assert!(run(&[("rust/src/comm/codec/cost.rs", "let b = x.div_ceil(8);\n")]).is_empty());
    }

    #[test]
    fn net_rule_confines_sockets_to_the_transport_module() {
        let f = run(&[("rust/src/coordinator/trainer.rs", "let s = TcpStream::connect(a);\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "net-outside-transport");
        let f = run(&[("rust/src/main.rs", "use std::net::TcpListener;\n")]);
        assert_eq!(f.len(), 1, "one finding per offending line: {f:?}");
        assert_eq!(f[0].rule, "net-outside-transport");
        // the transport module itself, and test code anywhere, are free
        let ok = "use std::net::{TcpListener, TcpStream};\n";
        assert!(run(&[("rust/src/comm/transport.rs", ok)]).is_empty());
        assert!(run(&[("rust/tests/transport.rs", ok)]).is_empty());
        // waivable like the other line rules
        let src = "// fixture server — repro-lint: allow(net-outside-transport)\n\
                   let l = UnixListener::bind(p);\n";
        assert!(run(&[("rust/src/util/bench.rs", src)]).is_empty());
    }

    #[test]
    fn wall_clock_rule_fires_and_bench_is_exempt() {
        let f = run(&[("rust/src/coordinator/trainer.rs", "let t0 = Instant::now();\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "wall-clock");
        let f = run(&[("rust/src/grad/layout.rs", "use std::collections::HashMap;\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "wall-clock");
        assert!(run(&[("rust/src/util/bench.rs", "let t0 = Instant::now();\n")]).is_empty());
    }

    #[test]
    fn bit_kernel_rule_confines_float_twiddling() {
        let f = run(&[("rust/src/comm/codec/packed.rs", "let b = v.to_bits();\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "bit-kernels-outside-kernels");
        let f = run(&[("rust/src/optim/mod.rs", "let v = f32::from_bits(u);\n")]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "bit-kernels-outside-kernels");
        // the kernel layer and the referee radix loops are free
        assert!(run(&[("rust/src/util/kernels.rs", "let b = v.to_bits();\n")]).is_empty());
        assert!(run(&[("rust/src/sparse/topk.rs", "let m = mag_bits(v);\n")]).is_empty());
        // test code anywhere is free (bit-identity asserts live there)
        assert!(run(&[("rust/tests/codec.rs", "let b = v.to_bits();\n")]).is_empty());
        // `auto_bits` must not trip the `to_bits` token (word bound)
        assert!(run(&[("rust/src/sparsify/mod.rs", "auto_bits: Option<usize>,\n")]).is_empty());
        // waivable with a justification
        let src = "// raw f32 word on the wire — repro-lint: allow(bit-kernels-outside-kernels)\n\
                   bw.put(v.to_bits(), 32);\n";
        assert!(run(&[("rust/src/comm/codec/frame.rs", src)]).is_empty());
    }

    #[test]
    fn waiver_suppresses_exactly_one_rule() {
        let src = "// why: reported metric only — repro-lint: allow(wall-clock)\n\
                   let t0 = Instant::now();\n";
        assert!(run(&[("rust/src/coordinator/trainer.rs", src)]).is_empty());
        // a waiver for a different rule does not suppress
        let src = "// repro-lint: allow(byte-accounting)\nlet t0 = Instant::now();\n";
        let f = run(&[("rust/src/coordinator/trainer.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn waived_findings_survive_in_full_output() {
        let src = "// metric — repro-lint: allow(wall-clock)\nlet t0 = Instant::now();\n";
        let files = vec![("rust/src/coordinator/trainer.rs".to_string(), src.to_string())];
        let full = analyze_parsed(&parse_all(&files));
        assert_eq!(full.len(), 1, "{full:?}");
        assert!(full[0].waived);
        assert!(full[0].to_string().contains("(waived)"));
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_fire() {
        let src = "// unsafe thread::spawn HashMap div_ceil(8) Instant::now\n\
                   let s = \"unsafe thread::spawn HashMap Instant::now\";\n";
        assert!(run(&[("rust/src/metrics/mod.rs", src)]).is_empty());
    }

    #[test]
    fn wildcard_rule_catches_watched_matches_only() {
        // watched: Msg:: appears in an arm head; `other` is a wildcard
        let src = "fn f(m: Msg) {\n    match m {\n        Msg::Update { .. } => a(),\n        other => b(other),\n    }\n}\n";
        let f = run(&[("rust/src/comm/transport.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("wildcard", 4));
        assert!(f[0].msg.contains("Msg"));
        // exhaustive watched match: clean
        let src = "fn f(m: LevelKind) {\n    match m {\n        LevelKind::Uniform => a(),\n        LevelKind::Nuq => b(),\n    }\n}\n";
        assert!(run(&[("rust/src/comm/codec/packed.rs", src)]).is_empty());
        // unwatched enum: wildcard is fine
        let src = "fn f(x: Option<u8>) {\n    match x {\n        Some(v) => a(v),\n        _ => b(),\n    }\n}\n";
        assert!(run(&[("rust/src/comm/transport.rs", src)]).is_empty());
        // state tags are watched; binding-with-pattern is not a wildcard
        let src = "fn g(t: u8) {\n    match t {\n        STATE_TAG_EF => a(),\n        t @ (6 | 7) => b(t),\n        t => c(t),\n    }\n}\n";
        let f = run(&[("rust/src/coordinator/checkpoint.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("wildcard", 5));
        // waiver on the arm line suppresses
        let src = "fn g(t: u8) {\n    match t {\n        STATE_TAG_EF => a(),\n        // versioned fallback — repro-lint: allow(wildcard)\n        t => c(t),\n    }\n}\n";
        assert!(run(&[("rust/src/coordinator/checkpoint.rs", src)]).is_empty());
    }

    #[test]
    fn wildcard_rule_skips_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(m: Msg) {\n        match m { Msg::Update { .. } => a(), _ => b() }\n    }\n}\n";
        assert!(run(&[("rust/src/comm/transport.rs", src)]).is_empty());
        let src = "fn f(m: Msg) {\n    match m { Msg::Update { .. } => a(), _ => b() }\n}\n";
        assert!(run(&[("rust/tests/transport.rs", src)]).is_empty());
    }

    #[test]
    fn kind_matrix_catches_missing_family() {
        let enum_src = "pub enum SparsifierKind {\n    Dense,\n    TopK { k: usize },\n}\n";
        let covered = "t(SparsifierKind::Dense); t(SparsifierKind::TopK { k });\n";
        let partial = "t(SparsifierKind::Dense);\n";
        let f = run(&[
            (KIND_ENUM_FILE, enum_src),
            ("rust/tests/resume.rs", covered),
            ("rust/tests/determinism.rs", partial),
        ]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "kind-matrix");
        assert_eq!(f[0].path, "rust/tests/determinism.rs");
        assert!(f[0].msg.contains("TopK"));
        let f = run(&[
            (KIND_ENUM_FILE, enum_src),
            ("rust/tests/resume.rs", covered),
            ("rust/tests/determinism.rs", covered),
        ]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn parse_variants_reads_real_shape() {
        let src = "pub enum SparsifierKind {\n    Dense,\n    RegTopK { k: usize, mu: f32 },\n    AdaK { ratio: f32 },\n}\n";
        assert_eq!(parse_kind_variants(src), vec!["Dense", "RegTopK", "AdaK"]);
    }
}
