//! Comment/string-aware source splitter for the repo-invariant
//! analyzer.
//!
//! The analyzer's rules are *mechanical*: they match tokens in code.
//! A naive grep would fire on the word "unsafe" inside a doc comment
//! or a string literal (including the analyzer's own rule tables), so
//! every file is first split, line by line, into a **code channel**
//! (string-literal contents blanked to spaces, comments removed) and a
//! **comment channel** (the text of `//`, `///`, `//!` and `/* */`
//! comments).  Rules match the code channel; `SAFETY:` annotations and
//! `repro-lint: allow(...)` waivers are looked up in the comment
//! channel.
//!
//! The lexer handles the Rust surface this repo actually uses: line
//! comments, nested block comments, `"..."` strings with escapes,
//! `r"..."`/`r#"..."#` raw strings, and character literals (so `'"'`
//! and `'\''` do not open a bogus string).  Lifetimes (`'a`,
//! `'static`) are recognized and left in the code channel.

#![forbid(unsafe_code)]

/// One source line, split into its two channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Line {
    /// Code with comments stripped and string/char contents blanked.
    pub code: String,
    /// Concatenated comment text on this line (without `//` markers).
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    /// inside a block comment, at the given nesting depth
    Block(usize),
    /// inside a `"..."` string
    Str,
    /// inside a raw string closed by `"` + this many `#`
    RawStr(usize),
}

/// Split `src` into per-line code/comment channels.
pub fn split(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw in src.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut comment = String::new();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match state {
                State::Code => match c {
                    '/' if next == Some('/') => {
                        // line comment: the rest of the line is comment
                        comment.push_str(&chars[i + 2..].iter().collect::<String>());
                        i = chars.len();
                    }
                    '/' if next == Some('*') => {
                        state = State::Block(1);
                        i += 2;
                    }
                    '"' => {
                        code.push('"');
                        state = State::Str;
                        i += 1;
                    }
                    'r' if starts_raw_string(&chars, i) => {
                        let hashes = count_hashes(&chars, i + 1);
                        code.push_str("r\"");
                        state = State::RawStr(hashes);
                        i += 2 + hashes;
                    }
                    '\'' => {
                        // char literal vs lifetime: 'x' or '\n' is a
                        // literal; anything not closed by a near ' is
                        // a lifetime and stays in the code channel
                        if next == Some('\\') {
                            // escaped char literal: skip to closing '
                            code.push_str("' '");
                            let mut j = i + 2;
                            // the escape body is at most a few chars
                            // (\u{...} worst case); scan to the quote
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            i = j + 1;
                        } else if chars.get(i + 2).copied() == Some('\'') {
                            code.push_str("' '");
                            i += 3;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    }
                    _ => {
                        code.push(c);
                        i += 1;
                    }
                },
                State::Block(depth) => {
                    if c == '*' && next == Some('/') {
                        state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                State::Str => match c {
                    '\\' => {
                        code.push(' ');
                        if next.is_some() {
                            code.push(' ');
                        }
                        i += 2;
                    }
                    '"' => {
                        code.push('"');
                        state = State::Code;
                        i += 1;
                    }
                    _ => {
                        code.push(' ');
                        i += 1;
                    }
                },
                State::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i + 1, hashes) {
                        code.push('"');
                        state = State::Code;
                        i += 1 + hashes;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
            }
        }
        out.push(Line { code, comment });
    }
    out
}

/// Is `chars[i]` the `r` of `r"..."` / `r#"..."#` (and not part of an
/// identifier such as `for` or `r2`)?
fn starts_raw_string(chars: &[char], i: usize) -> bool {
    if i > 0 {
        let prev = chars[i - 1];
        if prev.is_alphanumeric() || prev == '_' {
            return false;
        }
    }
    let mut j = i + 1;
    while chars.get(j).copied() == Some('#') {
        j += 1;
    }
    chars.get(j).copied() == Some('"')
}

fn count_hashes(chars: &[char], mut i: usize) -> usize {
    let mut n = 0;
    while chars.get(i).copied() == Some('#') {
        n += 1;
        i += 1;
    }
    n
}

fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| chars.get(i + k).copied() == Some('#'))
}

/// Does `code` contain `word` as a standalone token (not part of a
/// longer identifier)?  Used for keywords like `unsafe`, so that
/// `unsafe_op_in_unsafe_fn` inside an attribute does not match.
pub fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        split(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_doc_comments() {
        let lines = split("let x = 1; // unsafe here\n//! unsafe docs\nx += 1;");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert!(lines[0].comment.contains("unsafe here"));
        assert_eq!(lines[1].code, "");
        assert!(lines[1].comment.contains("unsafe docs"));
        assert_eq!(lines[2].code, "x += 1;");
    }

    #[test]
    fn blanks_string_contents() {
        let c = code_of(r#"let s = "unsafe // not code"; f(s);"#);
        assert!(!c[0].contains("unsafe"));
        assert!(!c[0].contains("//"));
        assert!(c[0].contains("f(s);"));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let c = code_of("let s = r#\"unsafe \" inner\"# + r\"thread::spawn\";");
        assert!(!c[0].contains("unsafe"));
        assert!(!c[0].contains("spawn"));
        assert!(c[0].ends_with(';'));
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let lines = split("a /* one /* two */ still */ b\nc /* open\nunsafe\n*/ d");
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert_eq!(lines[2].code, "");
        assert!(lines[2].comment.contains("unsafe"));
        assert_eq!(lines[3].code.trim(), "d");
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        let c = code_of("if c == '\"' || c == '\\'' { x('/') } // unsafe\nlet l: &'static str = y;");
        assert!(!c[0].contains("unsafe"));
        // the lifetime survives in the code channel
        assert!(c[1].contains("'static"));
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(has_word("pub unsafe fn f()", "unsafe"));
        assert!(!has_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(!has_word("my_unsafe", "unsafe"));
        assert!(has_word("x.unsafe", "unsafe"));
    }
}
