//! Comment/string-aware source splitter for the repo-invariant
//! analyzer.
//!
//! The analyzer's rules are *mechanical*: they match tokens in code.
//! A naive grep would fire on the word "unsafe" inside a doc comment
//! or a string literal (including the analyzer's own rule tables), so
//! every file is first split, line by line, into a **code channel**
//! (string-literal contents blanked to spaces, comments removed), a
//! **text channel** (comments stripped but string contents kept — the
//! schema extractor reads `const` values such as section magics from
//! here), and a **comment channel** (the text of `//`, `///`, `//!`
//! and `/* */`, `/*! */` comments).  Rules match the code channel;
//! `SAFETY:` annotations and `repro-lint: allow(...)` waivers are
//! looked up in the comment channel.
//!
//! The lexer handles the Rust surface this repo actually uses: line
//! comments (incl. `//!` inner docs), nested block comments (incl.
//! `/*!`), `"..."` strings with escapes, `r"..."`/`r#"..."#`/
//! `r##"..."##` raw strings with any hash count, byte strings, and
//! character literals (so `'"'` and `'\''` do not open a bogus
//! string, and a `/*` inside a string does not open a comment).
//! Lifetimes (`'a`, `'static`) are recognized and left in the code
//! channel.

#![forbid(unsafe_code)]

/// One source line, split into its channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Line {
    /// Code with comments stripped and string/char contents blanked.
    pub code: String,
    /// Code with comments stripped but string contents preserved
    /// (same token structure as `code`; used by the item extractor to
    /// read `const` values like `b"RTKS"` literally).
    pub text: String,
    /// Concatenated comment text on this line (without `//` markers).
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    /// inside a block comment, at the given nesting depth
    Block(usize),
    /// inside a `"..."` string
    Str,
    /// inside a raw string closed by `"` + this many `#`
    RawStr(usize),
}

/// Split `src` into per-line code/text/comment channels.
pub fn split(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw in src.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut text = String::with_capacity(chars.len());
        let mut comment = String::new();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match state {
                State::Code => match c {
                    '/' if next == Some('/') => {
                        // line comment: the rest of the line is comment
                        comment.push_str(&chars[i + 2..].iter().collect::<String>());
                        i = chars.len();
                    }
                    '/' if next == Some('*') => {
                        state = State::Block(1);
                        i += 2;
                    }
                    '"' => {
                        code.push('"');
                        text.push('"');
                        state = State::Str;
                        i += 1;
                    }
                    'r' if starts_raw_string(&chars, i) => {
                        let hashes = count_hashes(&chars, i + 1);
                        code.push_str("r\"");
                        text.push('r');
                        text.extend(std::iter::repeat('#').take(hashes));
                        text.push('"');
                        state = State::RawStr(hashes);
                        i += 2 + hashes;
                    }
                    '\'' => {
                        // char literal vs lifetime: 'x' or '\n' is a
                        // literal; anything not closed by a near ' is
                        // a lifetime and stays in the code channel
                        if next == Some('\\') {
                            // escaped char literal: the escape body is
                            // at least one char ('\'', '\\', '\u{..}'),
                            // so skip it before scanning for the close
                            code.push_str("' '");
                            text.push_str("' '");
                            let mut j = i + 3;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            i = j + 1;
                        } else if chars.get(i + 2).copied() == Some('\'') {
                            code.push_str("' '");
                            text.push_str("' '");
                            i += 3;
                        } else {
                            code.push('\'');
                            text.push('\'');
                            i += 1;
                        }
                    }
                    _ => {
                        code.push(c);
                        text.push(c);
                        i += 1;
                    }
                },
                State::Block(depth) => {
                    if c == '*' && next == Some('/') {
                        state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                State::Str => match c {
                    '\\' => {
                        code.push(' ');
                        text.push('\\');
                        if let Some(n) = next {
                            code.push(' ');
                            text.push(n);
                        }
                        i += 2;
                    }
                    '"' => {
                        code.push('"');
                        text.push('"');
                        state = State::Code;
                        i += 1;
                    }
                    _ => {
                        code.push(' ');
                        text.push(c);
                        i += 1;
                    }
                },
                State::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i + 1, hashes) {
                        code.push('"');
                        text.push('"');
                        text.extend(std::iter::repeat('#').take(hashes));
                        state = State::Code;
                        i += 1 + hashes;
                    } else {
                        code.push(' ');
                        text.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(Line { code, text, comment });
    }
    out
}

/// Is `chars[i]` the `r` of `r"..."` / `r#"..."#` (and not part of an
/// identifier such as `for` or `r2`)?
fn starts_raw_string(chars: &[char], i: usize) -> bool {
    if i > 0 {
        let prev = chars[i - 1];
        if prev.is_alphanumeric() || prev == '_' {
            return false;
        }
    }
    let mut j = i + 1;
    while chars.get(j).copied() == Some('#') {
        j += 1;
    }
    chars.get(j).copied() == Some('"')
}

fn count_hashes(chars: &[char], mut i: usize) -> usize {
    let mut n = 0;
    while chars.get(i).copied() == Some('#') {
        n += 1;
        i += 1;
    }
    n
}

fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| chars.get(i + k).copied() == Some('#'))
}

/// Does `code` contain `word` as a standalone token (not part of a
/// longer identifier)?  Used for keywords like `unsafe`, so that
/// `unsafe_op_in_unsafe_fn` inside an attribute does not match.
pub fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        split(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_doc_comments() {
        let lines = split("let x = 1; // unsafe here\n//! unsafe docs\nx += 1;");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert!(lines[0].comment.contains("unsafe here"));
        assert_eq!(lines[1].code, "");
        assert!(lines[1].comment.contains("unsafe docs"));
        assert_eq!(lines[2].code, "x += 1;");
    }

    #[test]
    fn inner_doc_comments_are_comment_channel() {
        // `//!` and `/*! ... */` are comments, not code
        let lines = split("//! module docs with unsafe\n/*! inner block unsafe */ let a = 1;");
        assert_eq!(lines[0].code, "");
        assert!(lines[0].comment.contains("module docs"));
        assert!(!lines[1].code.contains("unsafe"));
        assert!(lines[1].code.contains("let a = 1;"));
        assert!(lines[1].comment.contains("inner block"));
    }

    #[test]
    fn blanks_string_contents() {
        let c = code_of(r#"let s = "unsafe // not code"; f(s);"#);
        assert!(!c[0].contains("unsafe"));
        assert!(!c[0].contains("//"));
        assert!(c[0].contains("f(s);"));
    }

    #[test]
    fn text_channel_keeps_string_contents() {
        let lines = split("pub const EF_MAGIC: &[u8; 4] = b\"RTKS\"; // magic");
        assert!(lines[0].text.contains("b\"RTKS\""));
        assert!(!lines[0].code.contains("RTKS"));
        assert!(!lines[0].text.contains("magic"));
    }

    #[test]
    fn block_comment_opener_inside_string_stays_string() {
        // the `/*` in the string must not open a comment: the next
        // line is still code
        let lines = split("let s = \"a /* b\";\nlet t = 1;");
        assert!(lines[0].code.contains("let s = "));
        assert_eq!(lines[1].code, "let t = 1;");
        assert!(lines[1].comment.is_empty());
        assert!(lines[0].text.contains("a /* b"));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let c = code_of("let s = r#\"unsafe \" inner\"# + r\"thread::spawn\";");
        assert!(!c[0].contains("unsafe"));
        assert!(!c[0].contains("spawn"));
        assert!(c[0].ends_with(';'));
    }

    #[test]
    fn multi_hash_raw_strings() {
        // r##"..."## : an inner `"#` must not close the string
        let lines = split("let s = r##\"unsafe \"# still inside\"##; done();");
        assert!(!lines[0].code.contains("unsafe"));
        assert!(!lines[0].code.contains("still"));
        assert!(lines[0].code.contains("done();"));
        assert!(lines[0].text.contains("r##\""));
        assert!(lines[0].text.contains("unsafe \"# still inside"));
        // spanning lines
        let lines = split("let s = r##\"open\nthread::spawn\n\"## ; after();");
        assert!(!lines[1].code.contains("spawn"));
        assert!(lines[2].code.contains("after();"));
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let lines = split("a /* one /* two */ still */ b\nc /* open\nunsafe\n*/ d");
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert_eq!(lines[2].code, "");
        assert!(lines[2].comment.contains("unsafe"));
        assert_eq!(lines[3].code.trim(), "d");
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        let c = code_of("if c == '\"' || c == '\\'' { x('/') } // unsafe\nlet l: &'static str = y;");
        assert!(!c[0].contains("unsafe"));
        // the lifetime survives in the code channel
        assert!(c[1].contains("'static"));
    }

    #[test]
    fn escaped_quote_char_literal_does_not_leak() {
        // '\'' : the escape body IS the quote — the scan must not stop
        // on it and leave a stray ' in the code channel
        let c = code_of("if c == '\\'' { f() } let l: &'static str = s;");
        assert!(c[0].contains("{ f() }"), "{c:?}");
        assert!(c[0].contains("'static"), "{c:?}");
        // and a following string is still recognized as a string
        let c = code_of("x('\\''); let s = \"unsafe\";");
        assert!(!c[0].contains("unsafe"), "{c:?}");
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(has_word("pub unsafe fn f()", "unsafe"));
        assert!(!has_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(!has_word("my_unsafe", "unsafe"));
        assert!(has_word("x.unsafe", "unsafe"));
    }
}
