//! Std-only item extractor: the analyzer's "semantic" layer.
//!
//! Built on [`super::lexer`], this module parses just enough Rust item
//! structure to power the schema-drift, layering, and match-
//! exhaustiveness gates: `enum` variant lists, `struct` field lists,
//! `const` declarations (with their *values*, read from the lexer's
//! text channel so byte-string magics like `b"RTKS"` survive),
//! `match` sites with their arm heads, `crate::` / `regtopk::` module
//! references, and top-level `pub` items.  It is NOT a Rust parser —
//! it understands exactly the surface this repo uses, and every gate
//! built on it fails *loud* (a finding) rather than silently skipping
//! what it cannot parse.
//!
//! Each file is read and lexed ONCE into a [`SourceFile`]; the
//! line-lexical rules and all three semantic gates share that pass.

#![forbid(unsafe_code)]

use super::lexer::{self, Line};

/// One source file: path, lexed lines, and the line index where the
/// embedded test region (`#[cfg(test)]` onward) begins.
pub struct SourceFile {
    /// repo-relative path, `/`-separated
    pub path: String,
    pub lines: Vec<Line>,
    /// first line index (0-based) of the test region; `lines.len()`
    /// if the file has no embedded tests
    pub test_from: usize,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> Self {
        let lines = lexer::split(src);
        let test_from = lines
            .iter()
            .position(|l| l.code.contains("#[cfg(test)]"))
            .unwrap_or(lines.len());
        SourceFile { path: path.to_string(), lines, test_from }
    }

    /// Is the (0-based) line inside the embedded test region?
    pub fn is_test_line(&self, idx: usize) -> bool {
        idx >= self.test_from
    }

    /// Is the whole file test/bench/example code (outside `rust/src`)?
    pub fn is_test_path(&self) -> bool {
        !self.path.starts_with("rust/src/")
    }

    /// Does line `idx` (0-based) carry the waiver tag for `rule`,
    /// either on the same line or the line above?
    pub fn has_waiver(&self, idx: usize, rule: &str) -> bool {
        let tag = format!("repro-lint: allow({rule})");
        if self.lines[idx].comment.contains(&tag) {
            return true;
        }
        idx > 0 && self.lines[idx - 1].comment.contains(&tag)
    }
}

/// An `enum` declaration: name + normalized variant declarations.
pub struct EnumItem {
    pub name: String,
    /// 1-based declaration line
    pub line: usize,
    /// (normalized variant decl, 1-based line), in source order
    pub variants: Vec<(String, usize)>,
}

/// A braced `struct` declaration: name + normalized field declarations.
pub struct StructItem {
    pub name: String,
    pub line: usize,
    /// (normalized `name: Type`, 1-based line), in source order
    pub fields: Vec<(String, usize)>,
}

/// A `const` declaration with its literal value (from the text
/// channel, so string/byte-string contents are preserved).
pub struct ConstItem {
    pub name: String,
    pub ty: String,
    pub value: String,
    pub line: usize,
}

/// One arm of a `match`: the pattern head (guard stripped) + line.
pub struct MatchArm {
    pub head: String,
    pub line: usize,
}

/// A `match` site with its parsed arms.
pub struct MatchSite {
    pub line: usize,
    pub arms: Vec<MatchArm>,
}

/// A `crate::x` / `regtopk::x` module reference.
pub struct UseEdge {
    pub module: String,
    pub line: usize,
}

/// A top-level `pub` item (dead-pub rule input).
pub struct PubItem {
    pub kind: String,
    pub name: String,
    pub line: usize,
}

/// Everything the semantic gates need from one file.
pub struct FileItems {
    pub enums: Vec<EnumItem>,
    pub structs: Vec<StructItem>,
    pub consts: Vec<ConstItem>,
    pub matches: Vec<MatchSite>,
    pub uses: Vec<UseEdge>,
    pub pubs: Vec<PubItem>,
}

/// The code channel joined with `\n`, plus the text channel and a
/// byte-offset → line-index map.  All item scanning happens here so
/// that declarations spanning lines need no special casing.
struct Joined {
    code: Vec<u8>,
    text: Vec<String>,
    /// byte offset in `code` where each line starts
    offsets: Vec<usize>,
}

impl Joined {
    fn new(file: &SourceFile) -> Self {
        let mut code = Vec::new();
        let mut offsets = Vec::with_capacity(file.lines.len());
        for l in &file.lines {
            offsets.push(code.len());
            code.extend_from_slice(l.code.as_bytes());
            code.push(b'\n');
        }
        let text = file.lines.iter().map(|l| l.text.clone()).collect();
        Joined { code, text, offsets }
    }

    /// 0-based line index containing byte offset `pos`.
    fn line_of(&self, pos: usize) -> usize {
        self.offsets.partition_point(|&o| o <= pos).saturating_sub(1)
    }
}

/// All files of a tree, each read and lexed exactly once; every rule
/// and gate shares this single pass (ISSUE-8 satellite c).
pub struct Parsed {
    pub files: Vec<(SourceFile, FileItems)>,
}

/// Lex and extract every `(path, source)` pair once, in input order.
pub fn parse_all(sources: &[(String, String)]) -> Parsed {
    let files = sources
        .iter()
        .map(|(p, s)| {
            let f = SourceFile::parse(p, s);
            let items = extract(&f);
            (f, items)
        })
        .collect();
    Parsed { files }
}

pub fn extract(file: &SourceFile) -> FileItems {
    let j = Joined::new(file);
    FileItems {
        enums: scan_adts(&j, b"enum")
            .into_iter()
            .map(|(n, l, m)| EnumItem { name: n, line: l, variants: m })
            .collect(),
        structs: scan_adts(&j, b"struct")
            .into_iter()
            .map(|(n, l, m)| StructItem { name: n, line: l, fields: m })
            .collect(),
        consts: scan_consts(&j),
        matches: scan_matches(&j),
        uses: scan_uses(&j, file.is_test_path()),
        pubs: scan_pubs(&j),
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Find every standalone occurrence of `word` in `code`.
fn word_positions(code: &[u8], word: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    if word.len() > code.len() {
        return out;
    }
    for at in 0..=code.len() - word.len() {
        if &code[at..at + word.len()] != word {
            continue;
        }
        let before_ok = at == 0 || !is_ident(code[at - 1]);
        let end = at + word.len();
        let after_ok = end >= code.len() || !is_ident(code[end]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

fn skip_ws(code: &[u8], mut i: usize) -> usize {
    while i < code.len() && code[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

fn read_ident(code: &[u8], mut i: usize) -> (String, usize) {
    let start = i;
    while i < code.len() && is_ident(code[i]) {
        i += 1;
    }
    (String::from_utf8_lossy(&code[start..i]).into_owned(), i)
}

/// Advance past a balanced `{...}` / `(...)` / `[...]` starting at
/// the opener at `i`; returns the index just past the closer.
fn skip_balanced(code: &[u8], i: usize) -> usize {
    let (open, close) = match code[i] {
        b'{' => (b'{', b'}'),
        b'(' => (b'(', b')'),
        b'[' => (b'[', b']'),
        _ => return i + 1,
    };
    let mut depth = 0usize;
    let mut k = i;
    while k < code.len() {
        if code[k] == open {
            depth += 1;
        } else if code[k] == close {
            depth -= 1;
            if depth == 0 {
                return k + 1;
            }
        }
        k += 1;
    }
    code.len()
}

/// Collapse runs of whitespace to single spaces and trim.
fn normalize(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut last_space = true;
    for c in s.chars() {
        if c.is_whitespace() {
            if !last_space {
                out.push(' ');
            }
            last_space = true;
        } else {
            out.push(c);
            last_space = false;
        }
    }
    while out.ends_with(' ') {
        out.pop();
    }
    out
}

/// Strip leading `#[...]` attribute groups from a declaration chunk.
fn strip_attrs(s: &str) -> String {
    let b = s.as_bytes();
    let mut i = 0usize;
    loop {
        i = skip_ws(b, i);
        if i + 1 < b.len() && b[i] == b'#' && b[i + 1] == b'[' {
            i = skip_balanced(b, i + 1);
        } else {
            break;
        }
    }
    String::from_utf8_lossy(&b[i..]).into_owned()
}

/// Split a `{...}` body at top-level commas, tracking `(){}[]` and a
/// best-effort `<>` depth (a `<` after an identifier opens a generic
/// list; `->` does not close one).
fn split_top_commas(body: &[u8]) -> Vec<(usize, usize)> {
    let mut parts = Vec::new();
    let (mut depth, mut angle) = (0isize, 0isize);
    let mut start = 0usize;
    for k in 0..body.len() {
        match body[k] {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b'<' if k > 0 && is_ident(body[k - 1]) => angle += 1,
            b'>' if angle > 0 && (k == 0 || body[k - 1] != b'-') => angle -= 1,
            b',' if depth == 0 && angle == 0 => {
                parts.push((start, k));
                start = k + 1;
            }
            _ => {}
        }
    }
    if start < body.len() {
        parts.push((start, body.len()));
    }
    parts
}

/// Scan `enum Name { ... }` / `struct Name { ... }` declarations,
/// returning (name, decl line, members) with attribute-stripped,
/// whitespace-normalized member declarations.  Tuple structs and unit
/// structs yield an empty member list.
fn scan_adts(j: &Joined, kw: &[u8]) -> Vec<(String, usize, Vec<(String, usize)>)> {
    let mut out = Vec::new();
    for at in word_positions(&j.code, kw) {
        let mut i = skip_ws(&j.code, at + kw.len());
        let (name, ni) = read_ident(&j.code, i);
        if name.is_empty() {
            continue;
        }
        i = skip_ws(&j.code, ni);
        // skip a generic parameter list on the declaration
        if i < j.code.len() && j.code[i] == b'<' {
            let mut depth = 0isize;
            while i < j.code.len() {
                match j.code[i] {
                    b'<' => depth += 1,
                    b'>' => {
                        depth -= 1;
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
            i = skip_ws(&j.code, i);
        }
        if i >= j.code.len() || j.code[i] != b'{' {
            // tuple struct / unit struct / `struct` in type position
            out.push((name, j.line_of(at) + 1, Vec::new()));
            continue;
        }
        let end = skip_balanced(&j.code, i);
        let body = &j.code[i + 1..end - 1];
        let mut members = Vec::new();
        for (s, e) in split_top_commas(body) {
            let chunk = String::from_utf8_lossy(&body[s..e]).into_owned();
            let decl = normalize(&strip_attrs(&chunk));
            if decl.is_empty() {
                continue;
            }
            // line of the first non-attribute token in the chunk
            let lead = chunk.len() - strip_attrs(&chunk).len();
            members.push((decl, j.line_of(i + 1 + s + lead) + 1));
        }
        out.push((name, j.line_of(at) + 1, members));
    }
    out
}

fn scan_consts(j: &Joined) -> Vec<ConstItem> {
    let mut out = Vec::new();
    for at in word_positions(&j.code, b"const") {
        let mut i = skip_ws(&j.code, at + 5);
        let (name, ni) = read_ident(&j.code, i);
        // `const fn`, `const {}` blocks, `*const T` have no NAME `:`
        i = skip_ws(&j.code, ni);
        if name.is_empty() || name == "fn" || i >= j.code.len() || j.code[i] != b':' {
            continue;
        }
        // type runs to the assignment `=` at bracket depth 0 (the type
        // may contain `;` as in `&[u8; 4]`, so track brackets)
        let ty_start = i + 1;
        let mut depth = 0isize;
        let mut k = ty_start;
        let mut eq = None;
        while k < j.code.len() {
            match j.code[k] {
                b'(' | b'[' | b'{' => depth += 1,
                b')' | b']' | b'}' => depth -= 1,
                b'=' if depth == 0
                    && j.code.get(k + 1) != Some(&b'=')
                    && j.code.get(k + 1) != Some(&b'>') =>
                {
                    eq = Some(k);
                    break;
                }
                b';' if depth == 0 => break, // associated const without value
                _ => {}
            }
            k += 1;
        }
        let Some(eq) = eq else { continue };
        let ty = normalize(&String::from_utf8_lossy(&j.code[ty_start..eq]));
        // value: from just past `=` to `;` at depth 0, read from the
        // TEXT channel so string literal contents survive
        let mut depth = 0isize;
        let mut k = eq + 1;
        let mut semi = None;
        while k < j.code.len() {
            match j.code[k] {
                b'(' | b'[' | b'{' => depth += 1,
                b')' | b']' | b'}' => depth -= 1,
                b';' if depth == 0 => {
                    semi = Some(k);
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        let Some(semi) = semi else { continue };
        let value = normalize(&text_slice(j, eq + 1, semi));
        out.push(ConstItem { name, ty, value, line: j.line_of(at) + 1 });
    }
    out
}

/// Reconstruct the TEXT-channel content corresponding to the code
/// span `[from, to)`.  Columns in the two channels line up except
/// inside raw-string openers/closers; const values in this repo never
/// put a raw string before the value on the same line, and full lines
/// are taken from the text channel verbatim.
fn text_slice(j: &Joined, from: usize, to: usize) -> String {
    let (l0, l1) = (j.line_of(from), j.line_of(to.saturating_sub(1).max(from)));
    let mut out = String::new();
    for li in l0..=l1.min(j.text.len() - 1) {
        let line_start = j.offsets[li];
        let t = &j.text[li];
        let s = from.saturating_sub(line_start);
        let line_code_len = j
            .offsets
            .get(li + 1)
            .map(|n| n - 1 - line_start)
            .unwrap_or_else(|| j.code.len().saturating_sub(line_start));
        let e = (to - line_start).min(line_code_len);
        // clamp to the text line (lengths differ only around raw
        // strings, where the text channel is longer than the code)
        if li == l0 || li == l1 {
            let s = s.min(t.len());
            let e = if li == l1 { e.min(t.len()) } else { t.len() };
            if s < e {
                out.push_str(&t[s..e]);
            }
        } else {
            out.push_str(t);
        }
        if li < l1 {
            out.push(' ');
        }
    }
    out
}

fn scan_matches(j: &Joined) -> Vec<MatchSite> {
    let mut out = Vec::new();
    for at in word_positions(&j.code, b"match") {
        // scrutinee: to the body `{` at paren/bracket depth 0 (Rust
        // forbids bare struct literals in match scrutinees)
        let mut depth = 0isize;
        let mut k = at + 5;
        let mut body_open = None;
        while k < j.code.len() {
            match j.code[k] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b'{' if depth == 0 => {
                    body_open = Some(k);
                    break;
                }
                b';' if depth == 0 => break, // `match` used as ident-ish? bail
                _ => {}
            }
            k += 1;
        }
        let Some(open) = body_open else { continue };
        let close = skip_balanced(&j.code, open);
        let body = &j.code[open + 1..close.saturating_sub(1)];
        let mut arms = Vec::new();
        let mut i = 0usize;
        while i < body.len() {
            i = skip_ws(body, i);
            if i >= body.len() {
                break;
            }
            // pattern: to `=>` at depth 0 (struct patterns raise depth)
            let pat_start = i;
            let mut depth = 0isize;
            let mut arrow = None;
            while i < body.len() {
                match body[i] {
                    b'(' | b'[' | b'{' => depth += 1,
                    b')' | b']' | b'}' => depth -= 1,
                    b'=' if depth == 0 && body.get(i + 1) == Some(&b'>') => {
                        arrow = Some(i);
                        break;
                    }
                    _ => {}
                }
                i += 1;
            }
            let Some(arrow) = arrow else { break };
            let head = normalize(&String::from_utf8_lossy(&body[pat_start..arrow]));
            arms.push(MatchArm { head, line: j.line_of(open + 1 + pat_start) + 1 });
            // arm body: a balanced block, or an expression to `,` at depth 0
            i = skip_ws(body, arrow + 2);
            if i < body.len() && body[i] == b'{' {
                i = skip_balanced(body, i);
                // optional trailing comma
                let n = skip_ws(body, i);
                if n < body.len() && body[n] == b',' {
                    i = n + 1;
                }
            } else {
                let mut depth = 0isize;
                while i < body.len() {
                    match body[i] {
                        b'(' | b'[' | b'{' => depth += 1,
                        b')' | b']' | b'}' => depth -= 1,
                        b',' if depth == 0 => {
                            i += 1;
                            break;
                        }
                        _ => {}
                    }
                    i += 1;
                }
            }
        }
        out.push(MatchSite { line: j.line_of(at) + 1, arms });
    }
    out
}

/// Strip a trailing ` if <guard>` from an arm head (top-level only).
pub fn strip_guard(head: &str) -> &str {
    let b = head.as_bytes();
    let mut depth = 0isize;
    for at in word_positions(b, b"if") {
        for &c in &b[..at] {
            match c {
                b'(' | b'[' | b'{' => depth += 1,
                b')' | b']' | b'}' => depth -= 1,
                _ => {}
            }
        }
        if depth == 0 {
            return head[..at].trim_end();
        }
        depth = 0;
    }
    head
}

/// Is this (guard-stripped) arm head a wildcard: `_`, or a bare
/// lowercase binding (`other`)?  Or-patterns count if ANY branch is.
pub fn is_wildcard_head(head: &str) -> bool {
    let head = strip_guard(head);
    split_top_level(head, '|').iter().any(|p| {
        let p = p.trim();
        let p = p.strip_prefix("ref ").unwrap_or(p).trim();
        let p = p.strip_prefix("mut ").unwrap_or(p).trim();
        if p == "_" {
            return true;
        }
        p.bytes().all(is_ident)
            && p.bytes().next().is_some_and(|b| b.is_ascii_lowercase() || b == b'_')
            && !matches!(p, "true" | "false")
            && !p.is_empty()
    })
}

/// Split at a separator char at `(){}[]` depth 0.
fn split_top_level(s: &str, sep: char) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0isize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            _ => {}
        }
        if c == sep && depth == 0 {
            parts.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    parts.push(cur);
    parts
}

/// Every `crate::<module>` reference, including grouped imports
/// `use crate::{a::X, b::Y}` (possibly multi-line).  The crate's
/// external name `regtopk::<module>` counts only in test/bench/example
/// paths (`by_extern_name`): inside `rust/src` a `regtopk::` path is
/// the `sparsify::regtopk` submodule, not the crate root.
fn scan_uses(j: &Joined, by_extern_name: bool) -> Vec<UseEdge> {
    let mut out = Vec::new();
    let roots: &[&[u8]] = if by_extern_name { &[b"crate", b"regtopk"] } else { &[b"crate"] };
    for &root in roots {
        for at in word_positions(&j.code, root) {
            let mut i = at + root.len();
            if j.code.get(i) != Some(&b':') || j.code.get(i + 1) != Some(&b':') {
                continue;
            }
            i = skip_ws(&j.code, i + 2);
            if i >= j.code.len() {
                continue;
            }
            if j.code[i] == b'{' {
                // grouped: collect the leading ident of each element
                let end = skip_balanced(&j.code, i);
                let body = &j.code[i + 1..end.saturating_sub(1)];
                for (s, e) in split_top_commas(body) {
                    let k = skip_ws(body, s);
                    if k >= e {
                        continue;
                    }
                    let (m, _) = read_ident(body, k);
                    if !m.is_empty() && m != "self" {
                        out.push(UseEdge { module: m, line: j.line_of(i + 1 + k) + 1 });
                    }
                }
            } else {
                let (m, _) = read_ident(&j.code, i);
                if !m.is_empty() {
                    out.push(UseEdge { module: m, line: j.line_of(at) + 1 });
                }
            }
        }
    }
    out.sort_by_key(|e| e.line);
    out
}

/// Top-level (brace depth 0) plain-`pub` items.
fn scan_pubs(j: &Joined) -> Vec<PubItem> {
    const KINDS: [&str; 8] = ["fn", "struct", "enum", "const", "static", "trait", "type", "mod"];
    let mut out = Vec::new();
    // brace depth at every byte
    let mut depth = vec![0i32; j.code.len()];
    let mut d = 0i32;
    for (k, &c) in j.code.iter().enumerate() {
        if c == b'{' {
            d += 1;
        } else if c == b'}' {
            d -= 1;
        }
        depth[k] = if c == b'{' { d - 1 } else { d };
    }
    for at in word_positions(&j.code, b"pub") {
        if depth[at] != 0 {
            continue;
        }
        let mut i = skip_ws(&j.code, at + 3);
        // skip `pub(crate)` etc. — restricted visibility is exempt
        if i < j.code.len() && j.code[i] == b'(' {
            continue;
        }
        // skip qualifiers: unsafe/const/async/extern "C"
        loop {
            let (w, ni) = read_ident(&j.code, i);
            match w.as_str() {
                "unsafe" | "async" => i = skip_ws(&j.code, ni),
                "extern" => {
                    i = skip_ws(&j.code, ni);
                    if i < j.code.len() && j.code[i] == b'"' {
                        i += 1;
                        while i < j.code.len() && j.code[i] != b'"' {
                            i += 1;
                        }
                        i = skip_ws(&j.code, i + 1);
                    }
                }
                "const" => {
                    // `pub const fn` is a fn; `pub const NAME` is a const
                    let n = skip_ws(&j.code, ni);
                    let (w2, _) = read_ident(&j.code, n);
                    if w2 == "fn" {
                        i = n;
                    } else {
                        break;
                    }
                }
                _ => break,
            }
        }
        let (kind, ki) = read_ident(&j.code, i);
        if !KINDS.contains(&kind.as_str()) {
            continue;
        }
        let (name, _) = read_ident(&j.code, skip_ws(&j.code, ki));
        if name.is_empty() {
            continue;
        }
        out.push(PubItem { kind, name, line: j.line_of(at) + 1 });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(src: &str) -> FileItems {
        extract(&SourceFile::parse("rust/src/x/mod.rs", src))
    }

    #[test]
    fn extracts_enum_variants_with_payloads() {
        let it = items(
            "pub enum Msg {\n    #[serde(rename = \"u\")]\n    Update { worker: usize, loss: f32 },\n    Broadcast { round: usize, gagg: Vec<f32> },\n    Ping,\n}\n",
        );
        assert_eq!(it.enums.len(), 1);
        let e = &it.enums[0];
        assert_eq!(e.name, "Msg");
        let decls: Vec<&str> = e.variants.iter().map(|(d, _)| d.as_str()).collect();
        assert_eq!(
            decls,
            [
                "Update { worker: usize, loss: f32 }",
                "Broadcast { round: usize, gagg: Vec<f32> }",
                "Ping"
            ]
        );
        // attribute stripped, line points at the variant itself
        assert_eq!(e.variants[0].1, 3);
    }

    #[test]
    fn extracts_struct_fields_and_consts() {
        let it = items(
            "pub struct QuantPayload {\n    pub bits: usize,\n    pub words: Vec<u32>,\n}\npub const EF_MAGIC: &[u8; 4] = b\"RTKS\";\nconst STATE_TAG_EF: u8 = 1;\n",
        );
        let s = &it.structs[0];
        assert_eq!(s.name, "QuantPayload");
        assert_eq!(s.fields.len(), 2);
        assert_eq!(s.fields[1].0, "pub words: Vec<u32>");
        assert_eq!(it.consts.len(), 2);
        assert_eq!(it.consts[0].name, "EF_MAGIC");
        assert_eq!(it.consts[0].ty, "&[u8; 4]");
        assert_eq!(it.consts[0].value, "b\"RTKS\"");
        assert_eq!(it.consts[1].value, "1");
    }

    #[test]
    fn multiline_const_value_from_text_channel() {
        let it = items("pub const KEYS: [&str; 3] = [\n    \"k\", // per-bucket k\n    \"mu\",\n    \"q\",\n];\n");
        assert_eq!(it.consts[0].name, "KEYS");
        assert_eq!(it.consts[0].value, "[ \"k\", \"mu\", \"q\", ]");
    }

    #[test]
    fn match_arms_and_wildcards() {
        let it = items(
            "fn f(m: Msg) {\n    match m {\n        Msg::Update { worker, .. } => go(worker),\n        Msg::Broadcast { .. } => {\n            let _inner = match 3u8 { 0 => 1, t => t };\n        }\n        other => panic!(\"{other:?}\"),\n    }\n}\n",
        );
        assert_eq!(it.matches.len(), 2);
        let outer = &it.matches[0];
        assert_eq!(outer.arms.len(), 3);
        assert!(outer.arms[0].head.starts_with("Msg::Update"));
        assert!(!is_wildcard_head(&outer.arms[0].head));
        assert!(is_wildcard_head(&outer.arms[2].head));
        assert_eq!(outer.arms[2].line, 7);
        // binding-with-pattern is NOT a wildcard
        assert!(!is_wildcard_head("m @ Msg::Update { .. }") || false);
        assert!(is_wildcard_head("t @ (6 | 7)") == false);
        assert!(is_wildcard_head("_"));
        assert!(is_wildcard_head("Some(x) | other"));
        assert!(!is_wildcard_head("true"));
        assert_eq!(strip_guard("_ if x > 3"), "_");
        assert_eq!(strip_guard("Msg::Update { .. } if ok"), "Msg::Update { .. }");
    }

    #[test]
    fn use_edges_plain_and_grouped() {
        // inside rust/src, `regtopk::` is the sparsify submodule (as in
        // `pub use regtopk::RegTopK`), NOT a crate-root edge
        let it = items(
            "use crate::comm::Msg;\nuse crate::{grad::GradLayout, util::json};\nuse regtopk::sparsify::Sparsifier;\nfn f() { crate::metrics::quantiles(&[]); }\n",
        );
        let mods: Vec<&str> = it.uses.iter().map(|u| u.module.as_str()).collect();
        assert_eq!(mods, ["comm", "grad", "util", "metrics"]);
        assert_eq!(it.uses[1].line, 2);
        // in a test/bench path the crate's extern name does count
        let f = SourceFile::parse(
            "rust/tests/t.rs",
            "use regtopk::comm::Msg;\nuse regtopk::{sparse::SparseVec, util::json};\n",
        );
        let it = extract(&f);
        let mods: Vec<&str> = it.uses.iter().map(|u| u.module.as_str()).collect();
        assert_eq!(mods, ["comm", "sparse", "util"]);
    }

    #[test]
    fn pub_items_top_level_only() {
        let it = items(
            "pub fn alpha() {}\npub(crate) fn hidden() {}\nimpl X {\n    pub fn method(&self) {}\n}\npub const N: usize = 3;\npub struct S;\n",
        );
        let names: Vec<&str> = it.pubs.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["alpha", "N", "S"]);
        assert_eq!(it.pubs[0].kind, "fn");
    }

    #[test]
    fn test_region_is_tracked() {
        let f = SourceFile::parse("rust/src/x/mod.rs", "fn a() {}\n#[cfg(test)]\nmod tests {}\n");
        assert!(!f.is_test_line(0));
        assert!(f.is_test_line(1));
        assert!(f.is_test_line(2));
    }
}
