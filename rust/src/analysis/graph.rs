//! Module-layering gate: the dependency graph derived from `use`
//! edges must match the declared DAG, with no cycles and no dead
//! `pub` surface.
//!
//! The ISSUE-8 contract is the coarse chain `util → {sparse, analysis}
//! → comm → grad/sparsify → coordinator → experiments → main`;
//! [`LAYERS`] refines it to one integer per top-level module (higher
//! = closer to the binary).  Every `crate::<mod>` / `regtopk::<mod>`
//! reference in non-test code of `rust/src` is an edge, and an edge
//! is legal only if it points strictly *down* (`layer(from) >
//! layer(to)`).  Same-layer cross-module edges are violations too —
//! siblings talk through a lower layer, not to each other.  A module
//! absent from the table is a finding: adding a top-level module
//! means declaring its place in the DAG, in this file, in review.
//!
//! `dead-pub` is the companion surface check: a top-level plain-`pub`
//! item that no other module (and no test/bench/example) references
//! is unused API — make it private or waive it with
//! `repro-lint: allow(dead-pub)`.

#![forbid(unsafe_code)]

use std::collections::{BTreeMap, BTreeSet};

use super::extract::Parsed;
use super::rules::Finding;

/// The declared layering.  `layer(from) > layer(to)` for every edge.
pub const LAYERS: &[(&str, u32)] = &[
    ("util", 0),
    ("sparse", 1),
    ("analysis", 1),
    ("data", 1),
    ("metrics", 1),
    ("comm", 2),
    ("grad", 3),
    ("sparsify", 4),
    ("optim", 4),
    ("runtime", 4),
    ("config", 5),
    ("models", 5),
    ("coordinator", 6),
    ("experiments", 7),
    ("lib", 8),
    ("main", 8),
];

fn layer_of(module: &str) -> Option<u32> {
    LAYERS.iter().find(|(m, _)| *m == module).map(|(_, l)| *l)
}

/// Top-level module owning a `rust/src` path (`lib` / `main` for the
/// crate roots); `None` for tests/benches/examples.
pub fn module_of(path: &str) -> Option<&str> {
    let rest = path.strip_prefix("rust/src/")?;
    match rest {
        "lib.rs" => Some("lib"),
        "main.rs" => Some("main"),
        _ => {
            let end = rest.find('/').unwrap_or_else(|| rest.rfind(".rs").unwrap_or(rest.len()));
            Some(&rest[..end])
        }
    }
}

/// Enforce the declared layering over all non-test `use` edges and
/// reject cycles.  Neither finding is waivable: the DAG is edited by
/// changing [`LAYERS`], not by sprinkling waivers.
pub fn layering(p: &Parsed, findings: &mut Vec<Finding>) {
    // module -> set of (target, witness path, witness line)
    let mut edges: BTreeMap<&str, BTreeMap<String, (String, usize)>> = BTreeMap::new();
    for (file, items) in &p.files {
        let Some(from) = module_of(&file.path) else { continue };
        if layer_of(from).is_none() {
            findings.push(Finding {
                rule: "layering",
                path: file.path.clone(),
                line: 0,
                msg: format!(
                    "module `{from}` is not in the declared DAG — register it \
                     (with a layer) in analysis::graph::LAYERS"
                ),
                waived: false,
            });
            continue;
        }
        for e in &items.uses {
            if file.is_test_line(e.line - 1) || e.module == from {
                continue;
            }
            edges
                .entry(from)
                .or_default()
                .entry(e.module.clone())
                .or_insert((file.path.clone(), e.line));
        }
    }
    for (from, tos) in &edges {
        let lf = layer_of(from).expect("checked above");
        for (to, (path, line)) in tos {
            let Some(lt) = layer_of(to) else {
                findings.push(Finding {
                    rule: "layering",
                    path: path.clone(),
                    line: *line,
                    msg: format!(
                        "edge `{from}` → `{to}`: target module is not in the \
                         declared DAG — register it in analysis::graph::LAYERS"
                    ),
                    waived: false,
                });
                continue;
            };
            if lf <= lt {
                findings.push(Finding {
                    rule: "layering",
                    path: path.clone(),
                    line: *line,
                    msg: format!(
                        "edge `{from}` (layer {lf}) → `{to}` (layer {lt}) points up \
                         or sideways in the declared DAG — depend on a lower layer, \
                         move the shared code down, or re-declare the layering in \
                         analysis::graph::LAYERS"
                    ),
                    waived: false,
                });
            }
        }
    }
    // cycle detection on the raw edge set (independent of the layer
    // table, so a cycle is reported even if LAYERS is edited to allow
    // both directions)
    if let Some(cycle) = find_cycle(&edges) {
        findings.push(Finding {
            rule: "layering",
            path: "rust/src".to_string(),
            line: 0,
            msg: format!("module dependency cycle: {}", cycle.join(" → ")),
            waived: false,
        });
    }
}

/// DFS three-color cycle search; returns the cycle path if any.
fn find_cycle(edges: &BTreeMap<&str, BTreeMap<String, (String, usize)>>) -> Option<Vec<String>> {
    let mut done: BTreeSet<&str> = BTreeSet::new();
    for &start in edges.keys() {
        if done.contains(start) {
            continue;
        }
        let mut stack: Vec<(&str, Vec<&str>)> = vec![(start, Vec::new())];
        while let Some((node, path)) = stack.pop() {
            if let Some(at) = path.iter().position(|&n| n == node) {
                let mut cycle: Vec<String> = path[at..].iter().map(|s| s.to_string()).collect();
                cycle.push(node.to_string());
                return Some(cycle);
            }
            if done.contains(node) {
                continue;
            }
            let mut next_path = path.clone();
            next_path.push(node);
            // mark finished once all children are expanded: a node is
            // safe to skip only after full exploration, but for a
            // DAG-sized graph (≤16 modules) re-exploration is cheap,
            // so "done" is set eagerly per start node instead
            if next_path.len() > edges.len() + 1 {
                continue;
            }
            if let Some(tos) = edges.get(node) {
                for to in tos.keys() {
                    if let Some((k, _)) = edges.get_key_value(to.as_str()) {
                        stack.push((k, next_path.clone()));
                    }
                }
            }
        }
        done.insert(start);
    }
    None
}

/// Flag top-level plain-`pub` items with zero references from any
/// other module (tests/benches/examples count as references).
/// Waivable with `repro-lint: allow(dead-pub)` at the declaration.
pub fn dead_pubs(p: &Parsed, findings: &mut Vec<Finding>) {
    // (path, module, joined non-blanked code) for the reference scan
    let joined: Vec<(&str, Option<&str>, String)> = p
        .files
        .iter()
        .map(|(f, _)| {
            let code: String =
                f.lines.iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
            (f.path.as_str(), module_of(&f.path), code)
        })
        .collect();
    for (file, items) in &p.files {
        let Some(module) = module_of(&file.path) else { continue };
        for item in &items.pubs {
            if file.is_test_line(item.line - 1) {
                continue;
            }
            let referenced = joined.iter().any(|(path, m, code)| {
                *path != file.path
                    && m.map_or(true, |m| m != module)
                    && super::lexer::has_word(code, &item.name)
            });
            if referenced {
                continue;
            }
            findings.push(Finding {
                rule: "dead-pub",
                path: file.path.clone(),
                line: item.line,
                msg: format!(
                    "`pub {} {}` has no cross-module references — narrow its \
                     visibility, exercise it from a test, or waive with \
                     `repro-lint: allow(dead-pub)`",
                    item.kind, item.name
                ),
                waived: file.has_waiver(item.line - 1, "dead-pub"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::extract::parse_all;
    use super::*;

    fn src(files: &[(&str, &str)]) -> Parsed {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| ((*p).to_string(), (*s).to_string())).collect();
        parse_all(&owned)
    }

    #[test]
    fn module_of_maps_paths() {
        assert_eq!(module_of("rust/src/comm/codec/mod.rs"), Some("comm"));
        assert_eq!(module_of("rust/src/lib.rs"), Some("lib"));
        assert_eq!(module_of("rust/src/main.rs"), Some("main"));
        assert_eq!(module_of("rust/tests/resume.rs"), None);
        assert_eq!(module_of("rust/benches/codec.rs"), None);
    }

    #[test]
    fn downward_edges_are_clean() {
        let p = src(&[
            ("rust/src/comm/mod.rs", "use crate::sparse::SparseVec;\nuse crate::util::json;\n"),
            ("rust/src/sparse/mod.rs", "use crate::util::pool::Pool;\n"),
        ]);
        let mut f = Vec::new();
        layering(&p, &mut f);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn upward_and_sideways_edges_fire() {
        let p = src(&[("rust/src/sparse/vec.rs", "use crate::comm::codec::WireCost;\n")]);
        let mut f = Vec::new();
        layering(&p, &mut f);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "layering");
        assert!(f[0].msg.contains("`sparse` (layer 1) → `comm` (layer 2)"), "{}", f[0].msg);
        // same layer is sideways, also rejected
        let p = src(&[("rust/src/sparsify/mod.rs", "use crate::optim::Sgd;\n")]);
        let mut f = Vec::new();
        layering(&p, &mut f);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn cycles_are_reported() {
        // both edges individually violate layering; the cycle finding
        // names the loop itself
        let p = src(&[
            ("rust/src/sparse/mod.rs", "use crate::comm::Msg;\n"),
            ("rust/src/comm/mod.rs", "use crate::sparse::SparseVec;\n"),
        ]);
        let mut f = Vec::new();
        layering(&p, &mut f);
        let cyc: Vec<_> = f.iter().filter(|x| x.msg.contains("cycle")).collect();
        assert_eq!(cyc.len(), 1, "{f:?}");
        assert!(cyc[0].msg.contains("→"));
    }

    #[test]
    fn unknown_module_fires() {
        let p = src(&[("rust/src/telemetry/mod.rs", "use crate::util::json;\n")]);
        let mut f = Vec::new();
        layering(&p, &mut f);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].msg.contains("telemetry"));
    }

    #[test]
    fn test_region_and_test_paths_do_not_add_edges() {
        let p = src(&[
            (
                "rust/src/sparse/mod.rs",
                "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    use crate::comm::Msg;\n}\n",
            ),
            ("rust/tests/codec.rs", "use regtopk::comm::Msg;\nuse regtopk::sparse::SparseVec;\n"),
        ]);
        let mut f = Vec::new();
        layering(&p, &mut f);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn dead_pub_fires_and_waives() {
        let p = src(&[
            (
                "rust/src/metrics/mod.rs",
                "pub fn used() {}\npub fn lonely() {}\n\
                 // repro-lint: allow(dead-pub)\npub fn excused() {}\n",
            ),
            ("rust/src/coordinator/mod.rs", "pub fn go() { crate::metrics::used(); }\n"),
            ("rust/tests/t.rs", "fn t() { regtopk::coordinator::go(); }\n"),
        ]);
        let mut f = Vec::new();
        dead_pubs(&p, &mut f);
        assert_eq!(f.len(), 2, "{f:?}");
        let lonely = f.iter().find(|x| x.msg.contains("lonely")).expect("lonely finding");
        assert!(!lonely.waived);
        let excused = f.iter().find(|x| x.msg.contains("excused")).expect("excused finding");
        assert!(excused.waived);
    }
}
