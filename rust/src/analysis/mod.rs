//! Repo-invariant static analyzer (`repro lint` / `scripts/ci.sh
//! analyze`).
//!
//! Every headline claim this reproduction makes — RegTop-k bit-
//! identical to the sort oracle at every shard count, sparse
//! aggregation bit-identical to the dense axpy, bit-exact checkpoint
//! resume — rests on a small hand-rolled unsafe concurrency core and
//! on a handful of repo-wide conventions (one byte accountant, no
//! wall-clock in deterministic paths, every sparsifier family in the
//! test matrices).  Those conventions are enforceable mechanically,
//! so this module enforces them: [`analyze_tree`] walks the source
//! tree and returns a deterministic, sorted list of [`Finding`]s;
//! the CI lint job fails on any.
//!
//! Since ISSUE 8 the analyzer is *semantic*, not just lexical: the
//! std-only item extractor in [`extract`] parses enum variants,
//! struct fields, `const` values, `match` arms, and `use` edges on
//! top of the [`lexer`] channels, powering three gates beyond the
//! line rules — wire/persisted **schema drift** against the committed
//! `SCHEMA.lock` ([`schema`]), module **layering** over the declared
//! DAG plus dead-`pub` surface ([`graph`]), and match
//! **exhaustiveness** over the wire enums ([`rules`]).  Every file is
//! read and lexed exactly once per run; all rules share that pass.
//!
//! The rule set, the unsafe-module allowlist, and the waiver syntax
//! live in [`rules`]; the comment/string-aware line splitter the
//! rules match against lives in [`lexer`].  The analyzer is std-only
//! and self-hosting: it scans its own sources (rule tables mention
//! forbidden tokens only inside string literals, which the lexer
//! blanks), and its self-test seeds one violation of each rule and
//! asserts the rule fires — see `rules::tests` and
//! `rust/tests/pool_audit.rs`.

#![forbid(unsafe_code)]

pub mod extract;
pub mod graph;
pub mod lexer;
pub mod rules;
pub mod schema;

pub use rules::{analyze_sources, Finding, RULES, UNSAFE_ALLOWLIST};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The tree regions the analyzer scans, relative to the repo root.
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// The result of a full tree analysis: every finding (waived ones
/// flagged, for `repro lint --json`) plus scan statistics for the
/// lint summary line.
pub struct TreeReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl TreeReport {
    /// The findings that fail the gate (waived ones excluded).
    pub fn failing(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.waived)
    }
}

/// Read every `.rs` file under the scan roots as `(relative_path,
/// source)`, sorted by path.  Single filesystem pass for the whole
/// analyzer — parsing/lexing happens once on this list.
pub fn read_tree(root: &Path) -> io::Result<Vec<(String, String)>> {
    let mut files: Vec<(String, String)> = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if !dir.is_dir() {
            continue;
        }
        let mut paths = Vec::new();
        collect_rs(&dir, &mut paths)?;
        for p in paths {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push((rel, fs::read_to_string(&p)?));
        }
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(files)
}

/// Walk the repo tree under `root` (the directory holding
/// `Cargo.toml`), analyze every `.rs` file, and return all findings
/// (including waived ones) in deterministic (path, line, rule) order.
/// Beyond the per-source rules this adds the tree-level gates: the
/// unsafe-allowlist staleness check and the `SCHEMA.lock` /
/// `docs/WIRE.md` schema-drift comparison.
pub fn analyze_tree_full(root: &Path) -> io::Result<TreeReport> {
    let files = read_tree(root)?;
    let parsed = extract::parse_all(&files);
    let mut findings = rules::analyze_parsed(&parsed);
    for entry in UNSAFE_ALLOWLIST {
        if !files.iter().any(|(p, _)| p == entry) {
            findings.push(Finding {
                rule: "unsafe-allowlist",
                path: (*entry).to_string(),
                line: 0,
                msg: "stale allowlist entry: file not found in tree — remove it \
                      from analysis::rules::UNSAFE_ALLOWLIST"
                    .to_string(),
                waived: false,
            });
        }
    }
    schema::check_tree(root, &parsed, &mut findings);
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(TreeReport { findings, files_scanned: files.len() })
}

/// [`analyze_tree_full`] filtered to the failing (unwaived) findings —
/// the CI gate surface.
pub fn analyze_tree(root: &Path) -> io::Result<Vec<Finding>> {
    Ok(analyze_tree_full(root)?.findings.into_iter().filter(|f| !f.waived).collect())
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the repo root by walking up from `start` until a directory
/// holding both `Cargo.toml` and `rust/src` is found.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(dir) = cur {
        if dir.join("Cargo.toml").is_file() && dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        cur = dir.parent().map(Path::to_path_buf);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn own_tree_is_clean() {
        // CARGO_MANIFEST_DIR is the repo root (the crate lives at the
        // top level with sources under rust/).
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = analyze_tree(root).expect("tree walk");
        assert!(
            findings.is_empty(),
            "analyzer findings on the repo tree:\n{}",
            findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
        );
    }

    #[test]
    fn own_schema_lock_is_canonical() {
        // regeneration is deterministic and byte-identical to the
        // committed lockfile — the acceptance criterion of ISSUE 8
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = read_tree(root).expect("tree walk");
        let parsed = extract::parse_all(&files);
        let (text, f) = schema::render_for_tree(root, &parsed);
        assert!(f.is_empty(), "{f:?}");
        let committed = fs::read_to_string(root.join("SCHEMA.lock")).expect("SCHEMA.lock");
        assert_eq!(text, committed, "SCHEMA.lock is not the canonical rendering of the tree");
    }

    #[test]
    fn find_root_walks_up() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        assert_eq!(find_root(&root.join("rust/src/analysis")).as_deref(), Some(root));
    }
}
