//! Gradient bookkeeping: error-feedback state, parameter-group layout
//! and flat-vector layout.
//!
//! Each worker owns one [`ErrorFeedback`] holding the sparsification
//! error eps_n^t and the REGTOP-k history (a_n^{t-1}, s_n^{t-1}).  The
//! conservation law  a = ghat + eps'  is enforced bit-exactly and
//! property-tested (DESIGN.md invariant 2).
//!
//! [`GradLayout`]/[`GradView`] (see [`layout`]) carve the flat vector
//! into named parameter groups — the layer-wise gradient API's single
//! source of truth, consumed by `sparsify::LayerwiseSparsifier` and
//! the bucketed `comm::SparseUpdate` wire format.
//!
//! Perf note (EXPERIMENTS.md §Perf): the per-round path is
//! zero-allocation for the length-J state — `accumulate` writes into
//! an internal buffer, `commit` swaps it into the history and reuses
//! the previous round's buffers; only the k-entry [`SparseVec`] is
//! allocated per round.

#![forbid(unsafe_code)]

pub mod layout;

pub use layout::{GradLayout, GradView, GroupSpec};

use crate::sparse::SparseVec;

/// Checkpointable snapshot of an [`ErrorFeedback`]'s persistent
/// history: everything Alg. 1 carries across rounds.  `acc` (the
/// current-round scratch) and `prev_sel` (derived from `mask_prev`)
/// are rebuilt on restore.
#[derive(Clone, Debug, PartialEq)]
pub struct EfState {
    pub eps: Vec<f32>,
    pub acc_prev: Vec<f32>,
    pub mask_prev: Vec<f32>,
    pub warm: bool,
}

/// Per-worker error-feedback state (paper §1.1 / Alg. 1).
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    /// eps_n^t — sparsification error carried across iterations
    pub eps: Vec<f32>,
    /// a_n^t — current accumulated gradient (valid between
    /// [`Self::accumulate`] and [`Self::commit`])
    pub acc: Vec<f32>,
    /// a_n^{t-1} — previous accumulated gradient (REGTOP-k history)
    pub acc_prev: Vec<f32>,
    /// s_n^{t-1} — previous mask as a dense {0,1} vector
    pub mask_prev: Vec<f32>,
    /// indices set in `mask_prev` (for O(k) clearing)
    prev_sel: Vec<u32>,
    /// whether any iteration has completed (Alg. 1 line 1 switch)
    pub warm: bool,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> Self {
        ErrorFeedback {
            eps: vec![0.0; dim],
            acc: vec![0.0; dim],
            acc_prev: vec![0.0; dim],
            mask_prev: vec![0.0; dim],
            prev_sel: Vec::new(),
            warm: false,
        }
    }

    pub fn dim(&self) -> usize {
        self.eps.len()
    }

    /// a_n^t = eps_n^t + g_n^t   (Alg. 1 line 4), written into
    /// `self.acc` (no allocation).  Returns a borrow of the result.
    pub fn accumulate(&mut self, grad: &[f32]) -> &[f32] {
        debug_assert_eq!(grad.len(), self.eps.len());
        for ((a, e), g) in self.acc.iter_mut().zip(&self.eps).zip(grad) {
            *a = e + g;
        }
        &self.acc
    }

    /// Allocation-free peek used by the genie channel: a = eps + g into
    /// a caller buffer.
    pub fn accumulate_into(&self, grad: &[f32], out: &mut [f32]) {
        for ((o, e), g) in out.iter_mut().zip(&self.eps).zip(grad) {
            *o = e + g;
        }
    }

    /// Split the accumulated gradient (from the latest
    /// [`Self::accumulate`]) by `selected`: returns the sparse gradient
    /// to transmit, stores eps' = acc - ghat (Alg. 1 lines 7-8) and
    /// records (acc, mask) as the t-1 history for REGTOP-k.
    pub fn commit(&mut self, selected: &[u32]) -> SparseVec {
        let mut ghat = SparseVec::zeros(self.dim());
        self.commit_into(selected, &mut ghat);
        ghat
    }

    /// [`Self::commit`] into a recycled [`SparseVec`] — the
    /// zero-allocation variant behind `Sparsifier::step_into`.
    pub fn commit_into(&mut self, selected: &[u32], out: &mut SparseVec) {
        debug_assert!(selected.windows(2).all(|w| w[0] < w[1]));
        SparseVec::gather_into(&self.acc, selected, out);
        // history: acc_prev <- acc (buffer swap; old acc_prev becomes
        // next round's acc scratch)
        std::mem::swap(&mut self.acc_prev, &mut self.acc);
        // eps' = acc with selected entries zeroed (bit-exact
        // conservation: untouched entries are copied verbatim)
        self.eps.copy_from_slice(&self.acc_prev);
        for &i in selected {
            self.eps[i as usize] = 0.0;
        }
        // mask_prev: clear previous k bits, set new k bits
        for &i in &self.prev_sel {
            self.mask_prev[i as usize] = 0.0;
        }
        for &i in selected {
            self.mask_prev[i as usize] = 1.0;
        }
        self.prev_sel.clear();
        self.prev_sel.extend_from_slice(selected);
        self.warm = true;
    }

    /// Fold a post-commit residual (e.g. quantization error on the
    /// transmitted values) back into the error store at `indices`, so
    /// lossy compression stays unbiased over rounds: the next
    /// accumulate sees  eps + r  exactly where the wire dropped `r`.
    pub fn fold_residual(&mut self, indices: &[u32], residual: &[f32]) {
        fold_residual_into(&mut self.eps, indices, residual);
    }

    /// Snapshot the persistent history for checkpointing.
    pub fn snapshot(&self) -> EfState {
        EfState {
            eps: self.eps.clone(),
            acc_prev: self.acc_prev.clone(),
            mask_prev: self.mask_prev.clone(),
            warm: self.warm,
        }
    }

    /// Restore a snapshot (resume path).  `prev_sel` is rebuilt from
    /// the mask so the next `commit` clears exactly the restored bits.
    pub fn restore(&mut self, st: &EfState) -> Result<(), String> {
        let dim = self.dim();
        if st.eps.len() != dim || st.acc_prev.len() != dim || st.mask_prev.len() != dim {
            return Err(format!(
                "error-feedback state dim {} != sparsifier dim {dim}",
                st.eps.len()
            ));
        }
        self.eps.copy_from_slice(&st.eps);
        self.acc_prev.copy_from_slice(&st.acc_prev);
        self.mask_prev.copy_from_slice(&st.mask_prev);
        self.prev_sel.clear();
        self.prev_sel.extend(
            st.mask_prev
                .iter()
                .enumerate()
                .filter(|(_, &m)| m != 0.0)
                .map(|(i, _)| i as u32),
        );
        self.warm = st.warm;
        Ok(())
    }
}

/// `store[i] += r` at `indices` — the one element-wise residual fold
/// shared by [`ErrorFeedback`] and the families with bespoke error
/// stores (DGC's accumulated velocity, AdaK's residual vector).
pub fn fold_residual_into(store: &mut [f32], indices: &[u32], residual: &[f32]) {
    debug_assert_eq!(indices.len(), residual.len());
    for (&i, &r) in indices.iter().zip(residual) {
        store[i as usize] += r;
    }
}

/// Layer layout of a flat parameter vector (mirrors the python
/// `ParamSpec` exported in artifacts/manifest.json).
#[derive(Clone, Debug)]
pub struct FlatLayout {
    pub layers: Vec<LayerSlice>,
    pub total: usize,
}

#[derive(Clone, Debug)]
pub struct LayerSlice {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

impl FlatLayout {
    /// Per-layer l2 norms of a flat vector — used by the metrics sink
    /// for layer-wise sparsification diagnostics.
    pub fn layer_norms(&self, w: &[f32]) -> Vec<(String, f32)> {
        self.layers
            .iter()
            .map(|l| {
                let s = &w[l.offset..l.offset + l.size];
                (l.name.clone(), s.iter().map(|v| v * v).sum::<f32>().sqrt())
            })
            .collect()
    }

    /// Count of selected indices per layer (diagnostic: where does the
    /// sparsifier spend its budget?).  Indices no layer covers — before
    /// the first offset, inside a gap of a non-contiguous manifest, or
    /// past the end — are tallied under a trailing `"(unmapped)"` entry
    /// instead of panicking (regression: `Err(0) - 1` underflow).
    pub fn selection_histogram(&self, selected: &[u32]) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> =
            self.layers.iter().map(|l| (l.name.clone(), 0usize)).collect();
        let mut unmapped = 0usize;
        for &i in selected {
            let i = i as usize;
            // layers are sorted by offset: binary search
            let li = match self.layers.binary_search_by(|l| l.offset.cmp(&i)) {
                Ok(exact) => Some(exact),
                Err(0) => None,
                Err(ins) => Some(ins - 1),
            };
            match li {
                Some(li) if i < self.layers[li].offset + self.layers[li].size => {
                    out[li].1 += 1;
                }
                _ => unmapped += 1,
            }
        }
        if unmapped > 0 {
            out.push(("(unmapped)".to_string(), unmapped));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::select_topk;
    use crate::util::check;

    #[test]
    fn conservation_law_bit_exact() {
        check::forall("ef_conservation", |rng, _| {
            let n = check::arb_len(rng, 200);
            let mut ef = ErrorFeedback::new(n);
            ef.eps = check::arb_vec(rng, n);
            let g = check::arb_vec(rng, n);
            let acc_copy = ef.accumulate(&g).to_vec();
            let k = rng.below(n + 1);
            let sel = select_topk(&acc_copy, k);
            let ghat = ef.commit(&sel);
            // acc == ghat + eps' exactly
            let dense = ghat.to_dense();
            for i in 0..n {
                assert_eq!(dense[i] + ef.eps[i], acc_copy[i], "i={i}");
                // disjoint support
                assert!(dense[i] == 0.0 || ef.eps[i] == 0.0);
            }
            // history stored exactly
            assert_eq!(ef.acc_prev, acc_copy);
            assert_eq!(
                ef.mask_prev.iter().filter(|&&m| m == 1.0).count(),
                sel.len()
            );
        });
    }

    #[test]
    fn mask_prev_cleared_between_rounds() {
        let mut ef = ErrorFeedback::new(4);
        ef.accumulate(&[1.0, 5.0, 2.0, 0.1]);
        ef.commit(&[1]);
        assert_eq!(ef.mask_prev, vec![0.0, 1.0, 0.0, 0.0]);
        ef.accumulate(&[1.0, 0.0, 2.0, 0.1]);
        ef.commit(&[2, 3]);
        assert_eq!(ef.mask_prev, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn unselected_entries_accumulate_over_rounds() {
        let mut ef = ErrorFeedback::new(3);
        let g = vec![10.0, 1.0, 0.1];
        for t in 1..=5 {
            ef.accumulate(&g);
            let sel = select_topk(&ef.acc, 1); // always picks index 0
            assert_eq!(sel, vec![0]);
            ef.commit(&sel);
            assert_eq!(ef.eps[1], t as f32 * 1.0);
        }
    }

    #[test]
    fn accumulate_into_matches_accumulate() {
        let mut ef = ErrorFeedback::new(3);
        ef.eps = vec![1.0, -2.0, 3.0];
        let g = vec![0.5, 0.5, 0.5];
        let mut out = vec![0.0; 3];
        ef.accumulate_into(&g, &mut out);
        assert_eq!(ef.accumulate(&g), out.as_slice());
    }

    #[test]
    fn snapshot_restore_roundtrips_history() {
        let mut ef = ErrorFeedback::new(4);
        ef.accumulate(&[1.0, 5.0, 2.0, 0.1]);
        ef.commit(&[1, 3]);
        let snap = ef.snapshot();
        assert!(snap.warm);
        // a fresh EF restored from the snapshot continues identically
        let mut re = ErrorFeedback::new(4);
        re.restore(&snap).unwrap();
        let g = [0.5, -1.0, 3.0, 2.0];
        ef.accumulate(&g);
        re.accumulate(&g);
        let a = ef.commit(&[0, 2]);
        let b = re.commit(&[0, 2]);
        assert_eq!(a, b);
        assert_eq!(ef.eps, re.eps);
        assert_eq!(ef.mask_prev, re.mask_prev);
        // dim mismatch is an error, not a panic
        assert!(ErrorFeedback::new(5).restore(&snap).is_err());
    }

    #[test]
    fn histogram_unmapped_indices_do_not_panic() {
        // non-contiguous manifest: first layer starts at 5, gap at 8..10
        let layout = FlatLayout {
            layers: vec![
                LayerSlice { name: "a".into(), offset: 5, size: 3, shape: vec![3] },
                LayerSlice { name: "b".into(), offset: 10, size: 2, shape: vec![2] },
            ],
            total: 12,
        };
        // 0 precedes the first offset (the old `Err(0) - 1` underflow),
        // 8 falls in the gap, 20 is past the end
        let h = layout.selection_histogram(&[0, 5, 8, 10, 20]);
        assert_eq!(
            h,
            vec![
                ("a".to_string(), 1),
                ("b".to_string(), 1),
                ("(unmapped)".to_string(), 3)
            ]
        );
        // empty layer list: everything is unmapped
        let empty = FlatLayout { layers: vec![], total: 0 };
        assert_eq!(
            empty.selection_histogram(&[1]),
            vec![("(unmapped)".to_string(), 1)]
        );
        // fully-mapped selections get no synthetic bucket
        assert_eq!(layout.selection_histogram(&[6, 11]).len(), 2);
    }

    #[test]
    fn layout_histogram_and_norms() {
        let layout = FlatLayout {
            layers: vec![
                LayerSlice { name: "a".into(), offset: 0, size: 3, shape: vec![3] },
                LayerSlice { name: "b".into(), offset: 3, size: 2, shape: vec![2] },
            ],
            total: 5,
        };
        let h = layout.selection_histogram(&[0, 2, 3]);
        assert_eq!(h, vec![("a".to_string(), 2), ("b".to_string(), 1)]);
        let n = layout.layer_norms(&[3.0, 0.0, 4.0, 1.0, 0.0]);
        assert_eq!(n[0].1, 5.0);
        assert_eq!(n[1].1, 1.0);
    }
}
