//! Parameter-group layout: the single source of truth for how a flat
//! gradient vector is partitioned into named, contiguous groups
//! (layers, parameter blocks, ...).
//!
//! The journal formulation of REGTOP-k ("Regularized Top-k", arXiv
//! 2501.05633) states the posterior statistics and the budget k
//! per layer, and real DDP stacks exchange gradients in per-layer
//! buckets (arXiv 1911.08772).  [`GradLayout`] carries that structure
//! through the whole stack: the config declares it, workers carve
//! their gradients with a [`GradView`], sparsifiers emit one bucket
//! per group (`comm::SparseUpdate`), and the ledger accounts wire
//! bytes with per-group index widths (`ceil(log2 group_len)` bits
//! instead of `ceil(log2 J)`).  `comm` itself never names this type:
//! it consumes the [`crate::comm::BucketLayout`] trait, which
//! [`GradLayout`] implements below (dependency inversion keeps the
//! module DAG pointing down).
//!
//! The degenerate single-group layout ([`GradLayout::single`]) is the
//! seed's flat path and is bit-identical to it end to end (pinned by
//! `rust/tests/layerwise.rs`).

use crate::util::json::{obj, Json};

/// One named parameter group: a contiguous `[offset, offset+len)`
/// slice of the flat vector.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupSpec {
    pub name: String,
    pub offset: usize,
    pub len: usize,
}

/// Named partition of a flat parameter vector into contiguous groups.
/// Groups are ordered by offset, non-empty, and cover `0..total`
/// exactly (enforced at construction).
#[derive(Clone, Debug, PartialEq)]
pub struct GradLayout {
    groups: Vec<GroupSpec>,
    total: usize,
}

impl GradLayout {
    /// The degenerate flat layout: one group "all" covering the whole
    /// vector.  This is the seed API's implicit layout; every flat
    /// entry point routes through it.
    pub fn single(dim: usize) -> Self {
        GradLayout::from_sizes([("all".to_string(), dim)])
    }

    /// Build from `(name, len)` pairs; offsets are cumulative in
    /// iteration order.  Panics on empty input or an empty group.
    pub fn from_sizes<I: IntoIterator<Item = (String, usize)>>(sizes: I) -> Self {
        let mut groups = Vec::new();
        let mut offset = 0usize;
        for (name, len) in sizes {
            assert!(len > 0, "group '{name}' must be non-empty");
            groups.push(GroupSpec { name, offset, len });
            offset += len;
        }
        assert!(!groups.is_empty(), "a layout needs at least one group");
        GradLayout { groups, total: offset }
    }

    /// Adopt the layer structure of an artifact model's [`FlatLayout`]
    /// (one group per layer).  Errors when the manifest's layers are
    /// not a contiguous cover of `[0, total)` — gaps, overlaps, empty
    /// layers or a size/param-count mismatch all mean the layout cannot
    /// drive the bucketed wire format (formerly a `debug_assert`, which
    /// silently produced wrong group offsets in release builds).
    pub fn from_flat(flat: &super::FlatLayout) -> Result<Self, String> {
        if flat.layers.is_empty() {
            return Err("FlatLayout has no layers".to_string());
        }
        let mut offset = 0usize;
        for l in &flat.layers {
            if l.size == 0 {
                return Err(format!("layer '{}' is empty", l.name));
            }
            if l.offset != offset {
                return Err(format!(
                    "layer '{}' offset {} != expected {offset} (non-contiguous FlatLayout)",
                    l.name, l.offset
                ));
            }
            offset += l.size;
        }
        if offset != flat.total {
            return Err(format!(
                "layer sizes sum to {offset} but FlatLayout total is {}",
                flat.total
            ));
        }
        Ok(Self::from_sizes(flat.layers.iter().map(|l| (l.name.clone(), l.size))))
    }

    /// Parse a CLI group spec: `"conv:800,fc:200"` (named) or
    /// `"800,200"` (auto-named `g0`, `g1`, ...).
    pub fn parse_spec(spec: &str) -> Result<Self, String> {
        let mut sizes = Vec::new();
        for (i, part) in spec.split(',').map(str::trim).filter(|s| !s.is_empty()).enumerate() {
            let (name, len_str) = match part.split_once(':') {
                Some((n, l)) => (n.trim().to_string(), l.trim()),
                None => (format!("g{i}"), part),
            };
            let len: usize = len_str
                .parse()
                .map_err(|_| format!("bad group length '{len_str}' in spec '{spec}'"))?;
            if len == 0 {
                return Err(format!("group '{name}' has zero length in spec '{spec}'"));
            }
            sizes.push((name, len));
        }
        if sizes.is_empty() {
            return Err(format!("empty group spec '{spec}'"));
        }
        Ok(Self::from_sizes(sizes))
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn groups(&self) -> &[GroupSpec] {
        &self.groups
    }

    pub fn group(&self, g: usize) -> &GroupSpec {
        &self.groups[g]
    }

    /// Whether this is the degenerate flat layout (one group).
    pub fn is_single(&self) -> bool {
        self.groups.len() == 1
    }

    /// Group index containing flat index `i` (binary search; `i` must
    /// be in range).
    pub fn group_of(&self, i: usize) -> usize {
        debug_assert!(i < self.total, "index {i} out of layout total {}", self.total);
        match self.groups.binary_search_by(|g| g.offset.cmp(&i)) {
            Ok(exact) => exact,
            Err(ins) => ins - 1,
        }
    }

    /// The `[offset, offset+len)` slice of group `g` in `flat`.
    pub fn slice<'a>(&self, g: usize, flat: &'a [f32]) -> &'a [f32] {
        let s = &self.groups[g];
        &flat[s.offset..s.offset + s.len]
    }

    /// Serialize as `[{"name": .., "len": ..}, ...]` (offsets are
    /// derived, so they are not stored).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.groups
                .iter()
                .map(|g| obj([("name", g.name.as_str().into()), ("len", g.len.into())]))
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let arr = j.as_arr().ok_or("groups must be a JSON array")?;
        let mut sizes = Vec::new();
        for (i, entry) in arr.iter().enumerate() {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_else(|| format!("g{i}"));
            let len = entry
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("groups[{i}] missing 'len'"))?;
            if len == 0 {
                return Err(format!("groups[{i}] ('{name}') has zero length"));
            }
            sizes.push((name, len));
        }
        if sizes.is_empty() {
            return Err("groups array is empty".to_string());
        }
        Ok(Self::from_sizes(sizes))
    }
}

/// `GradLayout` is the canonical shape provider for the wire format:
/// `comm::SparseUpdate::conform_to` and `comm::Ledger::set_layout`
/// see it only through this trait.
impl crate::comm::BucketLayout for GradLayout {
    fn total(&self) -> usize {
        self.total
    }

    fn num_buckets(&self) -> usize {
        self.groups.len()
    }

    fn bucket_name(&self, g: usize) -> &str {
        &self.groups[g].name
    }

    fn bucket_offset(&self, g: usize) -> usize {
        self.groups[g].offset
    }

    fn bucket_len(&self, g: usize) -> usize {
        self.groups[g].len
    }
}

/// A layout-aware immutable view of one flat dense gradient — the
/// group-aware replacement for raw `&[f32]` in the public sparsifier
/// surface.
pub struct GradView<'a> {
    layout: &'a GradLayout,
    flat: &'a [f32],
}

impl<'a> GradView<'a> {
    pub fn new(layout: &'a GradLayout, flat: &'a [f32]) -> Self {
        assert_eq!(
            flat.len(),
            layout.total(),
            "gradient length {} != layout total {}",
            flat.len(),
            layout.total()
        );
        GradView { layout, flat }
    }

    pub fn layout(&self) -> &'a GradLayout {
        self.layout
    }

    /// The whole flat vector.
    pub fn flat(&self) -> &'a [f32] {
        self.flat
    }

    /// Group `g`'s slice.
    pub fn group(&self, g: usize) -> &'a [f32] {
        self.layout.slice(g, self.flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_layout_covers_everything() {
        let l = GradLayout::single(10);
        assert!(l.is_single());
        assert_eq!(l.total(), 10);
        assert_eq!(l.num_groups(), 1);
        assert_eq!(l.group(0).offset, 0);
        assert_eq!(l.group(0).len, 10);
        assert_eq!(l.group(0).name, "all");
    }

    #[test]
    fn from_sizes_computes_offsets() {
        let l = GradLayout::from_sizes([("a".to_string(), 3), ("b".to_string(), 5)]);
        assert_eq!(l.total(), 8);
        assert_eq!(l.group(0).offset, 0);
        assert_eq!(l.group(1).offset, 3);
        assert_eq!(l.group_of(0), 0);
        assert_eq!(l.group_of(2), 0);
        assert_eq!(l.group_of(3), 1);
        assert_eq!(l.group_of(7), 1);
    }

    #[test]
    #[should_panic]
    fn empty_group_rejected() {
        GradLayout::from_sizes([("a".to_string(), 0)]);
    }

    #[test]
    fn parse_spec_named_and_bare() {
        let l = GradLayout::parse_spec("conv:6,fc:4").unwrap();
        assert_eq!(l.group(0).name, "conv");
        assert_eq!(l.group(1).len, 4);
        let l = GradLayout::parse_spec("6, 4").unwrap();
        assert_eq!(l.group(0).name, "g0");
        assert_eq!(l.group(1).name, "g1");
        assert_eq!(l.total(), 10);
        assert!(GradLayout::parse_spec("").is_err());
        assert!(GradLayout::parse_spec("a:0").is_err());
        assert!(GradLayout::parse_spec("x:y").is_err());
    }

    #[test]
    fn from_flat_requires_contiguity() {
        use crate::grad::{FlatLayout, LayerSlice};
        let ls = |name: &str, offset: usize, size: usize| LayerSlice {
            name: name.to_string(),
            offset,
            size,
            shape: vec![size],
        };
        let good = FlatLayout { layers: vec![ls("a", 0, 3), ls("b", 3, 5)], total: 8 };
        let l = GradLayout::from_flat(&good).unwrap();
        assert_eq!(l.total(), 8);
        assert_eq!(l.group(1).name, "b");
        assert_eq!(l.group(1).offset, 3);
        // gap between layers
        let gap = FlatLayout { layers: vec![ls("a", 0, 3), ls("b", 4, 4)], total: 8 };
        assert!(GradLayout::from_flat(&gap).is_err());
        // total disagrees with the layer sum
        let short = FlatLayout { layers: vec![ls("a", 0, 3)], total: 8 };
        assert!(GradLayout::from_flat(&short).is_err());
        // first layer does not start at 0
        let late = FlatLayout { layers: vec![ls("a", 2, 6)], total: 8 };
        assert!(GradLayout::from_flat(&late).is_err());
        // empty layer / empty layout
        let empty = FlatLayout { layers: vec![ls("a", 0, 0)], total: 0 };
        assert!(GradLayout::from_flat(&empty).is_err());
        assert!(GradLayout::from_flat(&FlatLayout { layers: vec![], total: 0 }).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let l = GradLayout::from_sizes([("conv".to_string(), 7), ("fc".to_string(), 2)]);
        let j = l.to_json();
        let l2 = GradLayout::from_json(&j).unwrap();
        assert_eq!(l, l2);
        assert!(GradLayout::from_json(&Json::Arr(vec![])).is_err());
    }

    #[test]
    fn view_slices_groups() {
        let l = GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 3)]);
        let flat = [1.0, 2.0, 3.0, 4.0, 5.0];
        let v = GradView::new(&l, &flat);
        assert_eq!(v.group(0), &[1.0, 2.0]);
        assert_eq!(v.group(1), &[3.0, 4.0, 5.0]);
        assert_eq!(v.flat().len(), 5);
    }

    #[test]
    #[should_panic]
    fn view_rejects_length_mismatch() {
        let l = GradLayout::single(3);
        let flat = [0.0; 4];
        GradView::new(&l, &flat);
    }
}
