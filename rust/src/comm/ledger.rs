//! Per-round traffic accounting: every byte that crosses the simulated
//! network is recorded here; EXPERIMENTS.md's communication tables are
//! produced from these counters (DESIGN.md invariant 5).
//!
//! With the layer-wise API the ledger also accounts upload bytes *per
//! parameter group* ([`Ledger::set_layout`] + [`Ledger::record_update`]),
//! so a grouped run can report where the budget — and the wire saving
//! from per-group index widths — actually lands.

use crate::comm::update::{BucketLayout, SparseUpdate};
use crate::comm::CostModel;
use crate::sparse::SparseVec;

/// Traffic observed in one synchronous round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTraffic {
    pub round: usize,
    /// sum over workers of sparse-update bytes
    pub upload_bytes: usize,
    /// broadcast bytes * n_workers
    pub download_bytes: usize,
    /// total entries transmitted upward
    pub upload_entries: usize,
    /// simulated wall-clock for the round's communication
    pub sim_time_s: f64,
}

/// Append-only ledger; one entry per round.
#[derive(Debug, Default)]
pub struct Ledger {
    pub cost: CostModel,
    rounds: Vec<RoundTraffic>,
    current: RoundTraffic,
    upload_sizes: Vec<usize>,
    /// group names (set by [`Self::set_layout`]; empty = per-group
    /// accounting off)
    group_names: Vec<String>,
    /// cumulative upload bytes per group, aligned with `group_names`
    group_bytes: Vec<usize>,
    /// cumulative transmitted entries per group, aligned with
    /// `group_names` (heterogeneous runs: shows where the budget lands)
    group_entries: Vec<usize>,
}

impl Ledger {
    pub fn new(cost: CostModel) -> Self {
        Ledger { cost, ..Ledger::default() }
    }

    /// Enable per-group accounting for `layout` (called by the trainer
    /// once the worker layout is known).
    pub fn set_layout(&mut self, layout: &impl BucketLayout) {
        let n = layout.num_buckets();
        self.group_names = (0..n).map(|g| layout.bucket_name(g).to_string()).collect();
        self.group_bytes = vec![0; n];
        self.group_entries = vec![0; n];
    }

    /// Record one worker's bucketed upload for the current round.
    /// Every bucket is charged by `codec::WireCost` — the one wire
    /// accountant — so encoded buckets (packed values, Rice-coded
    /// indices) report honest post-encoding upload volume.
    pub fn record_update(&mut self, up: &SparseUpdate) {
        let wire = self.cost.wire();
        let mut total = 0usize;
        for (g, bucket) in up.buckets().iter().enumerate() {
            let bytes = wire.bucket(up, g);
            total += bytes;
            if let Some(acc) = self.group_bytes.get_mut(g) {
                *acc += bytes;
            }
            if let Some(acc) = self.group_entries.get_mut(g) {
                *acc += bucket.nnz();
            }
            self.current.upload_entries += bucket.nnz();
        }
        self.current.upload_bytes += total;
        self.upload_sizes.push(total);
    }

    /// Record one worker's flat upload for the current round (the
    /// pre-bucketing entry point, kept for flat callers and tests).
    /// A flat upload carries no group attribution, so it only feeds
    /// the per-group table when the installed layout is single-group
    /// (everything IS that group); under a multi-group layout the
    /// round totals still count but no group is credited.
    pub fn record_upload(&mut self, sv: &SparseVec) {
        let bytes = self.cost.update_bytes(sv);
        self.current.upload_bytes += bytes;
        self.current.upload_entries += sv.nnz();
        if self.group_bytes.len() == 1 {
            self.group_bytes[0] += bytes;
            self.group_entries[0] += sv.nnz();
        }
        self.upload_sizes.push(bytes);
    }

    /// Record the server broadcast and close the round.
    pub fn close_round(&mut self, round: usize, dim: usize, n_workers: usize) {
        let bt = self.cost.broadcast_bytes(dim);
        self.current.download_bytes = bt * n_workers;
        self.current.round = round;
        self.current.sim_time_s = self.cost.round_time(&self.upload_sizes, bt, n_workers);
        self.rounds.push(self.current);
        self.current = RoundTraffic::default();
        self.upload_sizes.clear();
    }

    /// Record a SPARSE server broadcast and close the round: the
    /// downlink is charged through `codec::WireCost::update` on the
    /// encoded aggregate — indices, packed value codes, Rice streams
    /// and all — instead of the dense `32·J`-bit formula.  Only active
    /// when a downlink codec is configured; [`Self::close_round`]
    /// stays the dense-broadcast path, untouched.
    pub fn close_round_sparse(&mut self, round: usize, gagg: &SparseUpdate, n_workers: usize) {
        let bt = self.cost.wire().update(gagg);
        self.current.download_bytes = bt * n_workers;
        self.current.round = round;
        self.current.sim_time_s = self.cost.round_time(&self.upload_sizes, bt, n_workers);
        self.rounds.push(self.current);
        self.current = RoundTraffic::default();
        self.upload_sizes.clear();
    }

    pub fn rounds(&self) -> &[RoundTraffic] {
        &self.rounds
    }

    pub fn total_upload_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.upload_bytes).sum()
    }

    pub fn total_download_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.download_bytes).sum()
    }

    pub fn total_sim_time(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_time_s).sum()
    }

    /// Cumulative upload bytes per parameter group `(name, bytes)`.
    /// Empty unless [`Self::set_layout`] was called.
    pub fn group_upload_totals(&self) -> Vec<(String, usize)> {
        self.group_names
            .iter()
            .cloned()
            .zip(self.group_bytes.iter().copied())
            .collect()
    }

    /// Cumulative transmitted entries per parameter group
    /// `(name, entries)`.  Empty unless [`Self::set_layout`] was called.
    pub fn group_upload_entries(&self) -> Vec<(String, usize)> {
        self.group_names
            .iter()
            .cloned()
            .zip(self.group_entries.iter().copied())
            .collect()
    }

    /// Upload compression ratio vs dense (dense = J values per worker
    /// per round, no indices).
    pub fn upload_compression_vs_dense(&self, dim: usize, n_workers: usize) -> f64 {
        let dense = self.rounds.len() * n_workers * self.cost.broadcast_bytes(dim);
        if dense == 0 {
            return 1.0;
        }
        self.total_upload_bytes() as f64 / dense as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::GradLayout;

    #[test]
    fn ledger_sums_per_round() {
        let mut l = Ledger::new(CostModel::default());
        let sv = SparseVec::new(100, vec![0, 1], vec![1.0, 2.0]);
        l.record_upload(&sv);
        l.record_upload(&sv);
        l.close_round(0, 100, 2);
        assert_eq!(l.rounds().len(), 1);
        let r = l.rounds()[0];
        assert_eq!(r.upload_entries, 4);
        assert_eq!(r.upload_bytes, 2 * l.cost.update_bytes(&sv));
        assert_eq!(r.download_bytes, 2 * 400);
        assert!(r.sim_time_s > 0.0);
    }

    #[test]
    fn totals_accumulate_across_rounds() {
        let mut l = Ledger::new(CostModel::default());
        for t in 0..3 {
            l.record_upload(&SparseVec::new(64, vec![1], vec![1.0]));
            l.close_round(t, 64, 1);
        }
        assert_eq!(l.rounds().len(), 3);
        assert_eq!(l.total_upload_bytes(), 3 * l.cost.update_bytes(&SparseVec::new(64, vec![1], vec![1.0])));
        assert_eq!(l.total_download_bytes(), 3 * 256);
    }

    #[test]
    fn sparse_close_charges_wire_bytes_not_dense_formula() {
        let mut l = Ledger::new(CostModel::default());
        let sv = SparseVec::new(1 << 10, vec![3, 700], vec![1.0, -2.0]);
        let gagg = SparseUpdate::single(sv.clone());
        l.record_upload(&sv);
        l.close_round_sparse(0, &gagg, 4);
        let r = l.rounds()[0];
        // 2 entries * (32+10) bits = 84 bits -> 11 bytes, times 4 workers
        assert_eq!(r.download_bytes, 11 * 4);
        assert!(r.download_bytes < l.cost.broadcast_bytes(1 << 10) * 4);
        assert!(r.sim_time_s > 0.0);
        // an encoded aggregate is charged at its measured payload size
        let mut enc = SparseUpdate::single(sv);
        let idx: Vec<u32> = enc.bucket(0).indices().to_vec();
        enc.payload_mut(0).rice.encode_into(&idx);
        let mut l2 = Ledger::new(CostModel::default());
        l2.close_round_sparse(0, &enc, 1);
        assert_eq!(l2.rounds()[0].download_bytes, l2.cost.wire().update(&enc));
    }

    #[test]
    fn compression_ratio_reflects_sparsity() {
        let mut l = Ledger::new(CostModel::default());
        // 1 of 1024 entries -> ratio should be ~ (32+10)/8 / 4096 bytes
        l.record_upload(&SparseVec::new(1024, vec![5], vec![1.0]));
        l.close_round(0, 1024, 1);
        let r = l.upload_compression_vs_dense(1024, 1);
        assert!(r < 0.01, "{r}");
    }

    #[test]
    fn grouped_updates_account_per_group() {
        let layout =
            GradLayout::from_sizes([("conv".to_string(), 64), ("fc".to_string(), 64)]);
        let mut l = Ledger::new(CostModel::default());
        l.set_layout(&layout);
        let mut up = SparseUpdate::zeros(&layout);
        up.bucket_mut(0).push(3, 1.0);
        up.bucket_mut(0).push(9, 1.0);
        up.bucket_mut(1).push(0, -2.0);
        l.record_update(&up);
        l.close_round(0, 128, 1);
        let r = l.rounds()[0];
        assert_eq!(r.upload_entries, 3);
        assert_eq!(r.upload_bytes, l.cost.update_bytes_grouped(&up));
        let totals = l.group_upload_totals();
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[0].0, "conv");
        assert_eq!(totals[0].1, l.cost.update_bytes(up.bucket(0)));
        assert_eq!(totals[1].1, l.cost.update_bytes(up.bucket(1)));
        let entries = l.group_upload_entries();
        assert_eq!(entries[0], ("conv".to_string(), 2));
        assert_eq!(entries[1], ("fc".to_string(), 1));
    }

    #[test]
    fn mixed_bit_widths_account_exact_packed_bytes() {
        use crate::comm::codec::{LevelKind, ValueCodec};
        use crate::util::rng::Rng;
        let layout = GradLayout::from_sizes([
            ("q4".to_string(), 64),
            ("q8".to_string(), 64),
            ("raw".to_string(), 64),
        ]);
        let mut l = Ledger::new(CostModel::default());
        l.set_layout(&layout);
        let mut up = SparseUpdate::zeros(&layout);
        for g in 0..3 {
            for i in 0..4u32 {
                up.bucket_mut(g).push(i * 7, 0.5 + g as f32 + i as f32);
            }
        }
        let mut rng = Rng::seed_from(3);
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        for (g, bits) in [(0usize, 4usize), (1, 8)] {
            let (b, q) = up.bucket_quant_mut(g);
            let vc = ValueCodec { bits, levels: LevelKind::Uniform };
            vc.encode_bucket(b, &mut rng, q, &mut residual, &mut codes);
        }
        l.record_update(&up);
        l.close_round(0, 192, 1);
        // per-group bytes == each payload's own wire accounting, and
        // the raw group keeps the 32-bit cost
        let totals = l.group_upload_totals();
        assert_eq!(totals[0].1, up.quant(0).unwrap().wire_bytes(6));
        assert_eq!(totals[1].1, up.quant(1).unwrap().wire_bytes(6));
        assert_eq!(totals[2].1, l.cost.update_bytes(up.bucket(2)));
        assert!(totals[0].1 < totals[1].1, "4-bit beats 8-bit on the wire");
        assert!(totals[1].1 < totals[2].1, "8-bit beats raw f32 on the wire");
        // the round total is exactly the sum of the parts
        assert_eq!(
            l.rounds()[0].upload_bytes,
            totals.iter().map(|(_, b)| b).sum::<usize>()
        );
        assert_eq!(l.rounds()[0].upload_bytes, l.cost.update_bytes_grouped(&up));
    }

    #[test]
    fn rice_coded_buckets_account_measured_bytes() {
        let layout =
            GradLayout::from_sizes([("conv".to_string(), 1 << 12), ("fc".to_string(), 64)]);
        let mut l = Ledger::new(CostModel::default());
        l.set_layout(&layout);
        let mut up = SparseUpdate::zeros(&layout);
        let idx: Vec<u32> = (0..32u32).map(|i| i * 2).collect();
        for &i in &idx {
            up.bucket_mut(0).push(i, 1.0);
        }
        up.bucket_mut(1).push(9, -1.0);
        up.payload_mut(0).rice.encode_into(&idx);
        l.record_update(&up);
        l.close_round(0, (1 << 12) + 64, 1);
        let totals = l.group_upload_totals();
        // the rice group pays raw values + the measured index stream
        let rp = up.rice(0).unwrap();
        assert_eq!(totals[0].1, 32 * 4 + rp.wire_bytes());
        // clustered indices: the entropy code beats the 12-bit bound
        assert!(totals[0].1 < l.cost.update_bytes(up.bucket(0)), "{totals:?}");
        // the un-coded group keeps the packed log J accounting
        assert_eq!(totals[1].1, l.cost.update_bytes(up.bucket(1)));
        assert_eq!(
            l.rounds()[0].upload_bytes,
            totals.iter().map(|(_, b)| b).sum::<usize>()
        );
    }

    #[test]
    fn single_group_flat_upload_credits_entries() {
        let mut l = Ledger::new(CostModel::default());
        l.set_layout(&GradLayout::single(64));
        l.record_upload(&SparseVec::new(64, vec![1, 2], vec![1.0, 2.0]));
        l.close_round(0, 64, 1);
        assert_eq!(l.group_upload_entries(), vec![("all".to_string(), 2)]);
    }

    #[test]
    fn flat_and_single_bucket_record_identically() {
        let sv = SparseVec::new(256, vec![7, 90], vec![1.0, -1.0]);
        let mut flat = Ledger::new(CostModel::default());
        flat.record_upload(&sv);
        flat.close_round(0, 256, 1);
        let mut grouped = Ledger::new(CostModel::default());
        grouped.record_update(&SparseUpdate::single(sv));
        grouped.close_round(0, 256, 1);
        assert_eq!(flat.rounds()[0].upload_bytes, grouped.rounds()[0].upload_bytes);
        assert_eq!(flat.rounds()[0].upload_entries, grouped.rounds()[0].upload_entries);
        assert_eq!(flat.rounds()[0].sim_time_s, grouped.rounds()[0].sim_time_s);
    }
}
