//! Value quantization composing with sparsification: the transmitted
//! k values are quantized to `bits` via scaled stochastic rounding
//! (unbiased), shrinking the per-entry payload from 32 bits to
//! `bits` + shared 32-bit scale per message.
//!
//! This is the compression axis orthogonal to sparsity (the paper's
//! cost model footnote: value bits + index bits); the `CostModel`
//! `value_bits` field accounts for it, and the quantization error
//! feeds back through the sparsifier's error accumulator when used
//! via [`quantize_update`] at the worker boundary.

use crate::sparse::{quant_levels, QuantPayload, SparseVec};
use crate::util::rng::Rng;

/// Symmetric linear quantizer with stochastic rounding.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    /// bits per value, 1..=16 (32 = passthrough)
    pub bits: usize,
}

impl Quantizer {
    pub fn new(bits: usize) -> Self {
        assert!((1..=32).contains(&bits));
        Quantizer { bits }
    }

    /// Quantize values in place; returns the scale used.  Stochastic
    /// rounding keeps E[q(x)] = x.
    pub fn quantize(&self, values: &mut [f32], rng: &mut Rng) -> f32 {
        if self.bits >= 32 || values.is_empty() {
            return 1.0;
        }
        let levels = ((1usize << (self.bits - 1)) - 1).max(1) as f32;
        let max = values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if max == 0.0 {
            return 1.0;
        }
        let scale = max / levels;
        for v in values.iter_mut() {
            let x = *v / scale; // in [-levels, levels]
            let lo = x.floor();
            let frac = x - lo;
            let q = if (rng.uniform() as f32) < frac { lo + 1.0 } else { lo };
            *v = q * scale;
        }
        scale
    }

    /// The packed-wire path (layer-wise quantized transmission):
    /// stochastically round `bucket`'s values, replace them with their
    /// exact dequantized counterparts, emit the packed codes + scale
    /// into `payload` and the per-entry error into `residual` (aligned
    /// with the bucket's indices, for the error-feedback fold).
    ///
    /// The packed payload is authoritative: every value written back
    /// equals `payload.decode_value(i)` bit-for-bit, so server-side
    /// decode reproduces the aggregation input exactly.  Codes are
    /// clamped into the representable `[-L, L]` level range before
    /// rounding (the scale maps max|v| to L, so only float round-off
    /// at the extremes can touch the clamp).
    ///
    /// Requires `2 <= bits <= 16`; callers gate 32-bit passthrough.
    pub fn quantize_bucket_into(
        &self,
        bucket: &mut SparseVec,
        rng: &mut Rng,
        payload: &mut QuantPayload,
        residual: &mut Vec<f32>,
        codes_scratch: &mut Vec<u32>,
    ) {
        assert!((2..=16).contains(&self.bits), "packed quantization needs 2..=16 bits");
        let levels = quant_levels(self.bits);
        let values = bucket.values_mut();
        residual.clear();
        codes_scratch.clear();
        let max = values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = if max == 0.0 { 1.0 } else { max / levels as f32 };
        for v in values.iter_mut() {
            let x = (*v / scale).clamp(-(levels as f32), levels as f32);
            let lo = x.floor();
            let frac = x - lo;
            let q = if max != 0.0 && (rng.uniform() as f32) < frac { lo + 1.0 } else { lo };
            let code = (q as i64 + levels) as u32;
            let dv = (code as i64 - levels) as f32 * scale;
            residual.push(*v - dv);
            codes_scratch.push(code);
            *v = dv;
        }
        payload.encode_into(self.bits, scale, codes_scratch);
    }

    /// Quantize a sparse update's values; the returned SparseVec holds
    /// the dequantized (lossy) values that the server will see, and
    /// `residual` receives the per-entry quantization error so the
    /// caller can fold it back into the error accumulator.
    pub fn quantize_update(
        &self,
        sv: &SparseVec,
        rng: &mut Rng,
    ) -> (SparseVec, Vec<f32>) {
        let mut vals = sv.values().to_vec();
        self.quantize(&mut vals, rng);
        let residual: Vec<f32> = sv
            .values()
            .iter()
            .zip(&vals)
            .map(|(orig, q)| orig - q)
            .collect();
        (
            SparseVec::new(sv.dim(), sv.indices().to_vec(), vals),
            residual,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn unbiased_in_expectation() {
        let q = Quantizer::new(4);
        let mut rng = Rng::seed_from(1);
        let x = 0.37f32;
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let mut v = [x, 1.0]; // 1.0 sets the scale
            q.quantize(&mut v, &mut rng);
            sum += v[0] as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - x as f64).abs() < 0.01, "{mean}");
    }

    #[test]
    fn error_bounded_by_one_level() {
        check::forall("quant_error_bound", |rng, _| {
            let n = check::arb_len(rng, 100);
            let mut v = check::arb_vec(rng, n);
            let orig = v.clone();
            let bits = 2 + rng.below(7);
            let q = Quantizer::new(bits);
            let scale = q.quantize(&mut v, rng);
            for (a, b) in orig.iter().zip(&v) {
                assert!((a - b).abs() <= scale * 1.0001, "bits={bits}");
            }
        });
    }

    #[test]
    fn passthrough_at_32_bits() {
        let q = Quantizer::new(32);
        let mut rng = Rng::seed_from(2);
        let mut v = vec![0.123, -9.5];
        let orig = v.clone();
        q.quantize(&mut v, &mut rng);
        assert_eq!(v, orig);
    }

    #[test]
    fn update_residual_reconstructs_exactly() {
        let q = Quantizer::new(4);
        let mut rng = Rng::seed_from(3);
        let sv = SparseVec::new(10, vec![1, 4, 7], vec![0.9, -0.2, 0.05]);
        let (qsv, residual) = q.quantize_update(&sv, &mut rng);
        for i in 0..3 {
            assert_eq!(qsv.values()[i] + residual[i], sv.values()[i]);
        }
        assert_eq!(qsv.indices(), sv.indices());
    }

    #[test]
    fn packed_bucket_decode_matches_written_values() {
        check::forall("quant_bucket_decode", |rng, _| {
            let n = check::arb_len(rng, 80);
            let vals = check::arb_vec(rng, n);
            let idx: Vec<u32> = (0..n as u32).collect();
            let mut bucket = SparseVec::new(n.max(1), idx, vals.clone());
            let bits = 2 + rng.below(15);
            let q = Quantizer::new(bits);
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            q.quantize_bucket_into(&mut bucket, rng, &mut payload, &mut residual, &mut codes);
            assert_eq!(payload.bits(), bits);
            assert_eq!(payload.len(), n);
            for i in 0..n {
                // the payload IS the wire format: decode reproduces the
                // bucket's (lossy) values bit-for-bit ...
                assert_eq!(payload.decode_value(i), bucket.values()[i], "bits={bits} i={i}");
                // ... and the residual is exactly orig - dequantized
                // (the same float op the EF fold receives)
                assert_eq!(residual[i], vals[i] - bucket.values()[i], "bits={bits} i={i}");
            }
        });
    }

    #[test]
    fn packed_bucket_error_within_one_level() {
        let q = Quantizer::new(4);
        let mut rng = Rng::seed_from(7);
        let vals = vec![0.9f32, -0.33, 0.05, 1.0, -1.0];
        let mut bucket = SparseVec::new(5, (0..5).collect(), vals.clone());
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        q.quantize_bucket_into(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
        let scale = payload.scale();
        for r in &residual {
            assert!(r.abs() <= scale * 1.0001, "{r} vs scale {scale}");
        }
    }

    #[test]
    fn packed_bucket_all_zero_is_deterministic() {
        let q = Quantizer::new(4);
        let mut rng = Rng::seed_from(8);
        let before = rng.state();
        let mut bucket = SparseVec::new(3, vec![0, 1, 2], vec![0.0; 3]);
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        q.quantize_bucket_into(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
        assert_eq!(rng.state(), before, "zero buckets must not consume the stream");
        assert_eq!(bucket.values(), &[0.0; 3]);
        assert_eq!(payload.decode(), vec![0.0; 3]);
    }

    #[test]
    fn fewer_bits_fewer_distinct_values() {
        let q = Quantizer::new(2); // levels = 1 -> values in {-s, 0, s}
        let mut rng = Rng::seed_from(4);
        let mut v: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        q.quantize(&mut v, &mut rng);
        let mut uniq: Vec<i32> = v.iter().map(|x| (x * 1000.0) as i32).collect();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() <= 3, "{uniq:?}");
    }
}
