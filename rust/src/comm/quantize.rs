//! Flat value quantization composing with sparsification: the
//! transmitted k values are quantized to `bits` via scaled stochastic
//! rounding (unbiased), shrinking the per-entry payload from 32 bits
//! to `bits` + shared 32-bit scale per message.
//!
//! This is the compression axis orthogonal to sparsity (the paper's
//! cost model footnote: value bits + index bits); the `CostModel`
//! `value_bits` field accounts for it, and the quantization error
//! feeds back through the sparsifier's error accumulator when used
//! via [`Quantizer::quantize_update`] at the worker boundary.
//!
//! The PACKED per-bucket path (the layer-wise `bits` policy) lives in
//! `comm::codec` ([`crate::comm::codec::ValueCodec::encode_bucket`]):
//! this module keeps only the flat dequantized-values API used by the
//! baseline ablations.

use crate::sparse::SparseVec;
use crate::util::rng::Rng;

/// Symmetric linear quantizer with stochastic rounding.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    /// bits per value, 1..=16 (32 = passthrough)
    pub bits: usize,
}

impl Quantizer {
    pub fn new(bits: usize) -> Self {
        assert!((1..=32).contains(&bits));
        Quantizer { bits }
    }

    /// Quantize values in place; returns the scale used.  Stochastic
    /// rounding keeps E[q(x)] = x.
    pub fn quantize(&self, values: &mut [f32], rng: &mut Rng) -> f32 {
        if self.bits >= 32 || values.is_empty() {
            return 1.0;
        }
        let levels = ((1usize << (self.bits - 1)) - 1).max(1) as f32;
        let max = values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if max == 0.0 {
            return 1.0;
        }
        let scale = max / levels;
        for v in values.iter_mut() {
            let x = *v / scale; // in [-levels, levels]
            let lo = x.floor();
            let frac = x - lo;
            let q = if (rng.uniform() as f32) < frac { lo + 1.0 } else { lo };
            *v = q * scale;
        }
        scale
    }

    /// Quantize a sparse update's values; the returned SparseVec holds
    /// the dequantized (lossy) values that the server will see, and
    /// `residual` receives the per-entry quantization error so the
    /// caller can fold it back into the error accumulator.
    pub fn quantize_update(
        &self,
        sv: &SparseVec,
        rng: &mut Rng,
    ) -> (SparseVec, Vec<f32>) {
        let mut vals = sv.values().to_vec();
        self.quantize(&mut vals, rng);
        let residual: Vec<f32> = sv
            .values()
            .iter()
            .zip(&vals)
            .map(|(orig, q)| orig - q)
            .collect();
        (
            SparseVec::new(sv.dim(), sv.indices().to_vec(), vals),
            residual,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn unbiased_in_expectation() {
        let q = Quantizer::new(4);
        let mut rng = Rng::seed_from(1);
        let x = 0.37f32;
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let mut v = [x, 1.0]; // 1.0 sets the scale
            q.quantize(&mut v, &mut rng);
            sum += v[0] as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - x as f64).abs() < 0.01, "{mean}");
    }

    #[test]
    fn error_bounded_by_one_level() {
        check::forall("quant_error_bound", |rng, _| {
            let n = check::arb_len(rng, 100);
            let mut v = check::arb_vec(rng, n);
            let orig = v.clone();
            let bits = 2 + rng.below(7);
            let q = Quantizer::new(bits);
            let scale = q.quantize(&mut v, rng);
            for (a, b) in orig.iter().zip(&v) {
                assert!((a - b).abs() <= scale * 1.0001, "bits={bits}");
            }
        });
    }

    #[test]
    fn passthrough_at_32_bits() {
        let q = Quantizer::new(32);
        let mut rng = Rng::seed_from(2);
        let mut v = vec![0.123, -9.5];
        let orig = v.clone();
        q.quantize(&mut v, &mut rng);
        assert_eq!(v, orig);
    }

    #[test]
    fn update_residual_reconstructs_exactly() {
        let q = Quantizer::new(4);
        let mut rng = Rng::seed_from(3);
        let sv = SparseVec::new(10, vec![1, 4, 7], vec![0.9, -0.2, 0.05]);
        let (qsv, residual) = q.quantize_update(&sv, &mut rng);
        for i in 0..3 {
            assert_eq!(qsv.values()[i] + residual[i], sv.values()[i]);
        }
        assert_eq!(qsv.indices(), sv.indices());
    }

    #[test]
    fn fewer_bits_fewer_distinct_values() {
        let q = Quantizer::new(2); // levels = 1 -> values in {-s, 0, s}
        let mut rng = Rng::seed_from(4);
        let mut v: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        q.quantize(&mut v, &mut rng);
        let mut uniq: Vec<i32> = v.iter().map(|x| (x * 1000.0) as i32).collect();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() <= 3, "{uniq:?}");
    }
}
