//! Communication substrate: message types, pluggable transports, the
//! paper's byte cost model, and a per-round traffic ledger.
//!
//! The paper's experiments ran on real multi-GPU links; here the
//! transport is a [`transport::Transport`] trait with two backends —
//! the in-process mpsc star ([`InProc`], threaded driver) and framed
//! bytes over `std::net` sockets ([`Tcp`], workers as threads or
//! separate processes) — plus direct calls for the deterministic
//! driver.  The *accounting* is exact either way: each sparse update
//! costs `32 + ceil(log2 J)` bits per entry (§2: "the index can be
//! losslessly represented by log J bits"), the broadcast costs `32 J`
//! bits dense or the sparse equivalent, and the TCP frames
//! (`codec::frame`, versioned in `SCHEMA.lock` / `docs/WIRE.md`)
//! carry exactly the charged bytes so socket counters and ledger
//! agree byte-for-byte.  A [`CostModel`] converts bytes to simulated
//! wall-clock so the benches can report the paper's motivating
//! traffic arithmetic (1.7e9 symbols/epoch for ResNet-110, §1).

#![forbid(unsafe_code)]

pub mod codec;
mod ledger;
pub mod quantize;
mod transport;
mod update;

pub use codec::WireCost;
pub use ledger::{Ledger, RoundTraffic};
pub use quantize::Quantizer;
pub use transport::{
    kind_of, InProc, InProcLink, SocketCounters, Tcp, TcpLink, Transport, TransportKind,
    WorkerLink,
};
pub use update::{BucketLayout, SparseUpdate};

use crate::sparse::SparseVec;
use crate::util::json::{obj, Json};

/// Messages exchanged between workers and the server.  Updates travel
/// bucketed ([`SparseUpdate`], one bucket per parameter group with
/// group-local indices) so the wire cost of an index is
/// `ceil(log2 group_len)` bits; the flat path is the degenerate
/// single-bucket case and costs exactly what the seed did.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker -> server: bucketed sparsified gradient for round `round`
    Update { worker: usize, round: usize, update: SparseUpdate, loss: f32 },
    /// server -> workers: aggregated gradient for round `round`
    Broadcast { round: usize, gagg: Vec<f32> },
    /// server -> workers: model + sparse aggregate (downlink codec
    /// active); workers reconstruct dense `gagg_prev` from the union
    /// support — exact when the value codec is lossless
    SparseBroadcast { round: usize, w: Vec<f32>, gagg: SparseUpdate },
}

/// Link parameters for simulated transfer-time accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// per-message fixed latency (seconds)
    pub latency_s: f64,
    /// link bandwidth (bytes/second)
    pub bandwidth_bps: f64,
    /// bits per transmitted value (32 for f32; 16 models half-precision
    /// compression ablations)
    pub value_bits: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        // 10 GbE-ish defaults: 50us latency, 1.25 GB/s
        CostModel { latency_s: 50e-6, bandwidth_bps: 1.25e9, value_bits: 32 }
    }
}

impl CostModel {
    /// Serialize for the config echo — replaying a run from its own
    /// manifest must reproduce the same simulated link, not the
    /// default one (ISSUE 3 state-loss fix).
    pub fn to_json(&self) -> Json {
        obj([
            ("latency_s", self.latency_s.into()),
            ("bandwidth_bps", self.bandwidth_bps.into()),
            ("value_bits", self.value_bits.into()),
        ])
    }

    /// Deserialize; missing keys keep the defaults (config style).
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut c = CostModel::default();
        if let Some(v) = j.get("latency_s").and_then(Json::as_f64) {
            c.latency_s = v;
        }
        if let Some(v) = j.get("bandwidth_bps").and_then(Json::as_f64) {
            c.bandwidth_bps = v;
        }
        if let Some(v) = j.get("value_bits").and_then(Json::as_usize) {
            c.value_bits = v;
        }
        if !(c.bandwidth_bps > 0.0) || !(c.latency_s >= 0.0) || c.value_bits == 0 {
            return Err(format!("invalid cost model {c:?}"));
        }
        Ok(c)
    }

    /// This link's byte accountant — `comm::codec::WireCost` is THE
    /// single accountant of the wire-codec stack; every byte figure
    /// (ledger, sweeps, comm table, benches) routes through it.
    pub fn wire(&self) -> codec::WireCost {
        codec::WireCost::new(self.value_bits)
    }

    /// Wire bytes of a flat sparse update:
    /// `ceil(nnz * (value_bits + ceil(log2 J)) / 8)`.
    pub fn update_bytes(&self, sv: &SparseVec) -> usize {
        self.wire().flat(sv)
    }

    /// Wire bytes of a bucketed update: each bucket pays its own
    /// (smaller) per-group index width under whatever codec stack
    /// encoded it (see [`codec::WireCost::bucket`]).  The
    /// single-bucket degenerate case with default codecs equals
    /// [`Self::update_bytes`] on the flat vector.
    pub fn update_bytes_grouped(&self, up: &SparseUpdate) -> usize {
        self.wire().update(up)
    }

    /// Wire bytes of the dense broadcast g^t (no indices needed).
    pub fn broadcast_bytes(&self, dim: usize) -> usize {
        self.wire().broadcast(dim)
    }

    /// Simulated transfer time of a message of `bytes`.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Round time for a synchronous gather of per-worker byte counts
    /// followed by a broadcast: server link is the bottleneck, uploads
    /// serialize on it (parameter-server topology).
    pub fn round_time(&self, upload_bytes: &[usize], broadcast: usize, n_workers: usize) -> f64 {
        let gather: f64 = upload_bytes.iter().map(|&b| self.transfer_time(b)).sum();
        gather + self.transfer_time(broadcast) * n_workers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_json_roundtrip() {
        let c = CostModel { latency_s: 2e-3, bandwidth_bps: 5e8, value_bits: 16 };
        assert_eq!(CostModel::from_json(&c.to_json()).unwrap(), c);
        // defaults round-trip too (latency 50e-6 has a fractional repr)
        let d = CostModel::default();
        assert_eq!(CostModel::from_json(&d.to_json()).unwrap(), d);
        // missing keys keep defaults
        let partial = Json::parse(r#"{"value_bits": 16}"#).unwrap();
        let c = CostModel::from_json(&partial).unwrap();
        assert_eq!(c.value_bits, 16);
        assert_eq!(c.latency_s, CostModel::default().latency_s);
        // degenerate links rejected
        assert!(CostModel::from_json(&Json::parse(r#"{"bandwidth_bps": 0}"#).unwrap()).is_err());
        assert!(CostModel::from_json(&Json::parse(r#"{"value_bits": 0}"#).unwrap()).is_err());
    }

    #[test]
    fn update_bytes_matches_paper_cost() {
        let cm = CostModel::default();
        // J=100 -> 7 index bits; 10 entries * 39 bits = 390 bits -> 49 bytes
        let sv = SparseVec::new(100, (0..10).collect(), vec![1.0; 10]);
        assert_eq!(cm.update_bytes(&sv), 49);
        // dense broadcast of J=100 f32s = 400 bytes
        assert_eq!(cm.broadcast_bytes(100), 400);
    }

    #[test]
    fn grouped_update_bytes_use_per_group_index_width() {
        use crate::grad::GradLayout;
        let cm = CostModel::default();
        // two 2^10 groups inside J=2048: 10 index bits per entry
        let layout =
            GradLayout::from_sizes([("a".to_string(), 1024), ("b".to_string(), 1024)]);
        let mut up = SparseUpdate::zeros(&layout);
        for i in 0..4u32 {
            up.bucket_mut(0).push(i, 1.0);
            up.bucket_mut(1).push(i, 1.0);
        }
        // 8 entries * (32+10) bits = 336 bits -> 42 bytes
        assert_eq!(cm.update_bytes_grouped(&up), 42);
        // the flat equivalent pays 11 bits per index: 344 -> 43 bytes
        assert_eq!(cm.update_bytes(&up.flatten()), 43);
        // single-bucket degenerate case matches the flat cost exactly
        let flat = SparseVec::new(2048, (0..8).collect(), vec![1.0; 8]);
        assert_eq!(
            cm.update_bytes_grouped(&SparseUpdate::single(flat.clone())),
            cm.update_bytes(&flat)
        );
    }

    #[test]
    fn half_precision_halves_value_cost() {
        let cm16 = CostModel { value_bits: 16, ..CostModel::default() };
        let sv = SparseVec::new(1 << 20, vec![0, 1, 2, 3], vec![1.0; 4]);
        // 4 * (16+20) = 144 bits = 18 bytes
        assert_eq!(cm16.update_bytes(&sv), 18);
    }

    #[test]
    fn transfer_time_latency_plus_bandwidth() {
        let cm = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6, value_bits: 32 };
        let t = cm.transfer_time(1000);
        assert!((t - (1e-3 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn sparsification_reduces_round_time() {
        let cm = CostModel::default();
        let dense = vec![cm.broadcast_bytes(1 << 20); 8];
        let sparse = vec![cm.update_bytes(&SparseVec::new(1 << 20, (0..1000).collect(), vec![0.0; 1000])); 8];
        let bt = cm.broadcast_bytes(1 << 20);
        assert!(cm.round_time(&sparse, bt, 8) < cm.round_time(&dense, bt, 8));
    }
}
