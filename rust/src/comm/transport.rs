//! The transport seam: how [`Msg`] values move between the server
//! loop and its workers.
//!
//! PR 9 redesigns this module around two traits instead of concrete
//! channel-bearing structs:
//!
//! ```text
//!            Trainer (server side)              Worker (either side)
//!            ┌─────────────────────┐            ┌────────────────┐
//!            │   dyn Transport     │            │ dyn WorkerLink │
//!            │ broadcast / gather  │            │  send / recv   │
//!            └──────┬───────┬──────┘            └───┬────────┬───┘
//!                   │       │                      │        │
//!              InProc      Tcp ◄── framed bytes ──► TcpLink  InProcLink
//!            (mpsc star) (std::net)                (std::net) (mpsc)
//! ```
//!
//! [`InProc`] is the seed's mpsc star with its channel internals
//! private; since PR 10 its channels carry the same length-framed
//! bytes the socket backends move (encode once per broadcast, decode
//! per receive — bit-identity with the by-value star is pinned in
//! `rust/tests/transport.rs`).  [`Tcp`] moves the SAME `Msg` values as
//! length-framed bytes (`codec::frame`) over `std::net` sockets — TCP
//! loopback or, on unix, a `UnixListener` domain socket — with every
//! worker attached through a [`TcpLink`], possibly from a separate OS
//! process (`repro worker --connect`).  The server side counts socket
//! bytes per direction ([`SocketCounters`]); the framed charged bytes
//! equal `codec::WireCost`'s ledger accounting by construction, which
//! `Trainer::run_transport` asserts every round.
//!
//! This file is the ONLY non-test place allowed to touch `std::net`
//! (analyzer rule `net-outside-transport`): the coordinator reaches
//! sockets strictly through the traits.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use super::codec::{
    decode_header, decode_hello, decode_msg, decode_payload, encode_hello, encode_msg,
    FrameKind, FrameStats, FRAME_HEADER_BYTES, HELLO_BYTES,
};
use super::Msg;

/// Server side of the star: broadcast down, gather a full round up.
/// `gather_round` returns the n messages ordered by worker id, so the
/// aggregation order — and therefore the trajectory — is independent
/// of arrival order (bit-identical across backends).
pub trait Transport {
    /// Deliver `msg` to every worker.
    fn broadcast(&mut self, msg: &Msg);
    /// Collect exactly one `Msg::Update` per worker for `round`,
    /// ordered by worker id.  Panics on protocol violations
    /// (duplicate, out-of-round, or non-update messages) — those are
    /// driver bugs, not recoverable conditions.
    fn gather_round(&mut self, n_workers: usize, round: usize) -> Vec<Msg>;
    /// Bound the per-message wait inside `gather_round` (future
    /// straggler/fault injection hook; `None` = wait forever).
    fn set_gather_timeout(&mut self, timeout: Option<Duration>);
    /// Socket byte counters, if this backend moves real bytes
    /// (`None` for in-process transports).
    fn counters(&self) -> Option<SocketCounters>;
    /// Zero the counters (no-op for in-process transports).  The
    /// server loop calls this after the uncharged bootstrap
    /// broadcast, so the counters cover exactly the ledger-charged
    /// span of the run.
    fn reset_counters(&mut self) {}
}

/// Worker side of the star: send updates up, receive broadcasts down.
pub trait WorkerLink {
    /// Send one message to the server.
    fn send(&mut self, msg: &Msg);
    /// Receive the next broadcast; `None` once the server is gone.
    fn recv(&mut self) -> Option<Msg>;
}

/// Which transport backend a run uses (config/CLI surface).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// The in-process mpsc star (threaded driver).
    #[default]
    InProc,
    /// Length-framed bytes over loopback TCP, workers as threads or
    /// separate processes.
    Tcp,
    /// Length-framed bytes over a unix domain socket (unix only).
    Uds,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
            TransportKind::Uds => "uds",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "inproc" => Ok(TransportKind::InProc),
            "tcp" => Ok(TransportKind::Tcp),
            "uds" => Ok(TransportKind::Uds),
            _ => Err(format!("unknown transport '{s}' (expected inproc, tcp or uds)")),
        }
    }
}

/// Cumulative socket traffic seen by a byte-moving transport, split
/// into raw socket bytes and the `WireCost`-charged subset (frame
/// headers and structural shape bytes are real traffic but not
/// paper-§2 payload, so both views are kept).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SocketCounters {
    pub sent_frames: u64,
    pub recv_frames: u64,
    /// every byte written, frame headers included
    pub sent_bytes: u64,
    /// every byte read, frame headers included
    pub recv_bytes: u64,
    /// charged (ledger-comparable) bytes written
    pub sent_wire: u64,
    /// charged (ledger-comparable) bytes read
    pub recv_wire: u64,
}

impl SocketCounters {
    fn count_sent(&mut self, st: &FrameStats) {
        self.sent_frames += 1;
        self.sent_bytes += st.bytes as u64;
        self.sent_wire += st.wire as u64;
    }

    fn count_recv(&mut self, st: &FrameStats) {
        self.recv_frames += 1;
        self.recv_bytes += st.bytes as u64;
        self.recv_wire += st.wire as u64;
    }
}

// ---------------------------------------------------------------- InProc

/// The in-process star: every worker holds an [`InProcLink`] whose
/// sender feeds one shared server receiver.  Channel ends are private
/// — the ONLY way in is the [`Transport`] / [`WorkerLink`] traits
/// (plus [`InProc::up_sender`] for protocol-violation tests).
///
/// Since PR 10 the channels carry ENCODED FRAME BYTES, not `Msg`
/// values: every message crosses the thread boundary through the same
/// `codec::frame` encode/decode the socket backends use.  The threaded
/// driver therefore exercises the full wire path every round (torn
/// qmeta, half-width payloads, rice streams — all of it), a broadcast
/// encodes ONCE and clones bytes per worker instead of deep-cloning
/// the `Msg`, and the star counts frames/bytes exactly like [`Tcp`],
/// so `counters()` is `Some` here too.
pub struct InProc {
    from_workers: Receiver<Vec<u8>>,
    to_workers: Vec<Sender<Vec<u8>>>,
    up_tx: Sender<Vec<u8>>,
    pending: Vec<Option<InProcLink>>,
    counters: SocketCounters,
    timeout: Option<Duration>,
}

/// One worker's pair of channel ends onto an [`InProc`] star.
pub struct InProcLink {
    up: Sender<Vec<u8>>,
    down: Receiver<Vec<u8>>,
}

impl InProc {
    /// A star with `n` worker links, parked until [`Self::link`]
    /// hands them out.
    pub fn star(n: usize) -> Self {
        let (up_tx, from_workers) = channel();
        let mut to_workers = Vec::with_capacity(n);
        let mut pending = Vec::with_capacity(n);
        for _ in 0..n {
            let (down_tx, down_rx) = channel();
            to_workers.push(down_tx);
            pending.push(Some(InProcLink { up: up_tx.clone(), down: down_rx }));
        }
        InProc {
            from_workers,
            to_workers,
            up_tx,
            pending,
            counters: SocketCounters::default(),
            timeout: None,
        }
    }

    /// Take worker `i`'s link (once).
    pub fn link(&mut self, worker: usize) -> InProcLink {
        self.pending[worker].take().unwrap_or_else(|| panic!("link {worker} already taken"))
    }

    /// A raw sender onto the up channel — for tests that inject
    /// protocol violations the trait API makes unrepresentable.  The
    /// channel carries frame bytes: inject with `encode_msg(&msg).0`.
    pub fn up_sender(&self) -> Sender<Vec<u8>> {
        self.up_tx.clone()
    }

    fn next_up(&mut self) -> Vec<u8> {
        match self.timeout {
            Some(t) => self
                .from_workers
                .recv_timeout(t)
                .unwrap_or_else(|e| panic!("gather timed out / disconnected: {e}")),
            None => self.from_workers.recv().expect("all workers disconnected mid-round"),
        }
    }
}

impl Transport for InProc {
    fn broadcast(&mut self, msg: &Msg) {
        // encode once; per-worker delivery is a byte-buffer clone
        let (bytes, st) = encode_msg(msg);
        for tx in &self.to_workers {
            // a worker that already finished (dropped its link) is
            // fine; count the frame either way — whether the final
            // broadcast races a worker's exit must not change the
            // counters (Tcp's write_all has the same semantics)
            let _ = tx.send(bytes.clone());
            self.counters.count_sent(&st);
        }
    }

    fn gather_round(&mut self, n_workers: usize, round: usize) -> Vec<Msg> {
        let mut slots: Vec<Option<Msg>> = (0..n_workers).map(|_| None).collect();
        for _ in 0..n_workers {
            let bytes = self.next_up();
            // in-process frames come from our own encoder: a decode
            // failure is a driver bug, not a recoverable condition
            let (msg, st) = decode_msg(&bytes).expect("inproc frame decode failed");
            self.counters.count_recv(&st);
            match &msg {
                Msg::Update { worker, round: r, .. } => {
                    assert_eq!(*r, round, "worker {worker}: out-of-round update");
                    assert!(slots[*worker].is_none(), "worker {worker}: duplicate update");
                    let w = *worker;
                    slots[w] = Some(msg);
                }
                Msg::Broadcast { .. } | Msg::SparseBroadcast { .. } => {
                    panic!("broadcast received at the server side")
                }
            }
        }
        slots.into_iter().map(|s| s.expect("gather slot empty")).collect()
    }

    fn set_gather_timeout(&mut self, timeout: Option<Duration>) {
        self.timeout = timeout;
    }

    fn counters(&self) -> Option<SocketCounters> {
        Some(self.counters)
    }

    fn reset_counters(&mut self) {
        self.counters = SocketCounters::default();
    }
}

impl WorkerLink for InProcLink {
    fn send(&mut self, msg: &Msg) {
        // the server dropping its receiver ends the worker loop via
        // recv() -> None; a failed send here is the same shutdown race
        let _ = self.up.send(encode_msg(msg).0);
    }

    fn recv(&mut self) -> Option<Msg> {
        self.down
            .recv()
            .ok()
            .map(|bytes| decode_msg(&bytes).expect("inproc frame decode failed").0)
    }
}

// ------------------------------------------------------------------- Tcp

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(UnixListener),
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(UnixStream),
}

impl Conn {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.write_all(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write_all(buf),
        }
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.read_exact(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read_exact(buf),
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_read_timeout(t),
        }
    }
}

fn write_frame(conn: &mut Conn, msg: &Msg) -> Result<FrameStats, String> {
    let (bytes, st) = encode_msg(msg);
    conn.write_all(&bytes).map_err(|e| format!("frame write failed: {e}"))?;
    Ok(st)
}

fn read_frame(conn: &mut Conn) -> Result<(Msg, FrameStats), String> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    conn.read_exact(&mut hdr).map_err(|e| format!("frame header read failed: {e}"))?;
    let h = decode_header(&hdr)?;
    let mut payload = vec![0u8; h.len as usize];
    conn.read_exact(&mut payload).map_err(|e| format!("frame payload read failed: {e}"))?;
    let (msg, wire) = decode_payload(&h, &payload)?;
    Ok((msg, FrameStats { bytes: FRAME_HEADER_BYTES + payload.len(), wire }))
}

/// The byte-moving server transport: a listening socket, one framed
/// connection per worker (attached via [`Self::accept`] after a
/// versioned handshake), and per-direction [`SocketCounters`].
pub struct Tcp {
    listener: Listener,
    addr: String,
    /// connections indexed by worker id
    conns: Vec<Option<Conn>>,
    counters: SocketCounters,
    timeout: Option<Duration>,
}

impl Tcp {
    /// Bind an ephemeral loopback TCP listener.
    pub fn bind() -> Result<Self, String> {
        let listener =
            TcpListener::bind("127.0.0.1:0").map_err(|e| format!("tcp bind failed: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("tcp local_addr failed: {e}"))?
            .to_string();
        Ok(Tcp {
            listener: Listener::Tcp(listener),
            addr,
            conns: Vec::new(),
            counters: SocketCounters::default(),
            timeout: None,
        })
    }

    /// Bind a unix domain socket at `path` (unix only).
    #[cfg(unix)]
    pub fn bind_uds(path: &str) -> Result<Self, String> {
        let listener =
            UnixListener::bind(path).map_err(|e| format!("uds bind {path} failed: {e}"))?;
        Ok(Tcp {
            listener: Listener::Uds(listener),
            addr: path.to_string(),
            conns: Vec::new(),
            counters: SocketCounters::default(),
            timeout: None,
        })
    }

    /// The address workers connect to (`host:port`, or the socket
    /// path for uds).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Accept exactly `n` worker connections, each opening with a
    /// versioned handshake naming its worker id.  Connections are
    /// stored by id; duplicate or out-of-range ids are errors.
    pub fn accept(&mut self, n: usize) -> Result<(), String> {
        self.conns = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let mut conn = match &self.listener {
                Listener::Tcp(l) => {
                    let (s, _) = l.accept().map_err(|e| format!("tcp accept failed: {e}"))?;
                    s.set_nodelay(true).map_err(|e| format!("set_nodelay failed: {e}"))?;
                    Conn::Tcp(s)
                }
                #[cfg(unix)]
                Listener::Uds(l) => {
                    let (s, _) = l.accept().map_err(|e| format!("uds accept failed: {e}"))?;
                    Conn::Uds(s)
                }
            };
            let mut hello = [0u8; HELLO_BYTES];
            conn.read_exact(&mut hello).map_err(|e| format!("handshake read failed: {e}"))?;
            let worker = decode_hello(&hello)? as usize;
            let slot = self
                .conns
                .get_mut(worker)
                .ok_or_else(|| format!("worker id {worker} out of range (n = {n})"))?;
            if slot.is_some() {
                return Err(format!("worker id {worker} connected twice"));
            }
            *slot = Some(conn);
        }
        Ok(())
    }

    fn conn_mut(&mut self, worker: usize) -> &mut Conn {
        self.conns[worker].as_mut().expect("worker not connected")
    }
}

impl Transport for Tcp {
    fn broadcast(&mut self, msg: &Msg) {
        let (bytes, st) = encode_msg(msg);
        for conn in self.conns.iter_mut().flatten() {
            conn.write_all(&bytes).expect("broadcast write failed");
            self.counters.count_sent(&st);
        }
    }

    fn gather_round(&mut self, n_workers: usize, round: usize) -> Vec<Msg> {
        // read in worker-id order: the aggregation order is fixed by
        // construction, independent of socket arrival interleaving
        let mut out = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let timeout = self.timeout;
            let conn = self.conn_mut(w);
            conn.set_read_timeout(timeout).expect("set_read_timeout failed");
            let (msg, st) = read_frame(conn).unwrap_or_else(|e| panic!("worker {w}: {e}"));
            match &msg {
                Msg::Update { worker, round: r, .. } => {
                    assert_eq!(*worker, w, "frame on worker {w}'s socket names worker {worker}");
                    assert_eq!(*r, round, "worker {w}: out-of-round update");
                }
                Msg::Broadcast { .. } | Msg::SparseBroadcast { .. } => {
                    panic!("broadcast received at the server side")
                }
            }
            self.counters.count_recv(&st);
            out.push(msg);
        }
        out
    }

    fn set_gather_timeout(&mut self, timeout: Option<Duration>) {
        self.timeout = timeout;
    }

    fn counters(&self) -> Option<SocketCounters> {
        Some(self.counters)
    }

    fn reset_counters(&mut self) {
        self.counters = SocketCounters::default();
    }
}

/// Worker side of a [`Tcp`] transport: one framed connection, opened
/// with the handshake, usable from a thread or a separate process.
pub struct TcpLink {
    conn: Conn,
}

impl TcpLink {
    /// Connect to a server at `addr` and introduce ourselves as
    /// `worker`.
    pub fn connect(addr: &str, worker: usize) -> Result<Self, String> {
        let s = TcpStream::connect(addr).map_err(|e| format!("connect {addr} failed: {e}"))?;
        s.set_nodelay(true).map_err(|e| format!("set_nodelay failed: {e}"))?;
        let mut conn = Conn::Tcp(s);
        conn.write_all(&encode_hello(worker as u32))
            .map_err(|e| format!("handshake write failed: {e}"))?;
        Ok(TcpLink { conn })
    }

    /// Connect to a unix-domain-socket server at `path` (unix only).
    #[cfg(unix)]
    pub fn connect_uds(path: &str, worker: usize) -> Result<Self, String> {
        let s = UnixStream::connect(path).map_err(|e| format!("connect {path} failed: {e}"))?;
        let mut conn = Conn::Uds(s);
        conn.write_all(&encode_hello(worker as u32))
            .map_err(|e| format!("handshake write failed: {e}"))?;
        Ok(TcpLink { conn })
    }
}

impl WorkerLink for TcpLink {
    fn send(&mut self, msg: &Msg) {
        write_frame(&mut self.conn, msg).expect("worker frame send failed");
    }

    fn recv(&mut self) -> Option<Msg> {
        read_frame(&mut self.conn).ok().map(|(msg, _)| msg)
    }
}

/// The frame kind a message travels as (shared by the transport's
/// protocol asserts and the comm-table's byte attribution).
pub fn kind_of(msg: &Msg) -> FrameKind {
    match msg {
        Msg::Update { .. } => FrameKind::Update,
        Msg::Broadcast { .. } => FrameKind::Broadcast,
        Msg::SparseBroadcast { .. } => FrameKind::SparseBroadcast,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SparseUpdate;
    use crate::sparse::SparseVec;
    use std::thread;

    fn update_msg(worker: usize, round: usize, v: f32) -> Msg {
        let mut sv = SparseVec::zeros(8);
        sv.push(worker as u32, v);
        Msg::Update { worker, round, update: SparseUpdate::single(sv), loss: v }
    }

    #[test]
    fn inproc_star_roundtrip_two_workers() {
        let mut net = InProc::star(2);
        let mut links: Vec<InProcLink> = (0..2).map(|w| net.link(w)).collect();
        let handles: Vec<_> = links
            .drain(..)
            .enumerate()
            .map(|(w, mut link)| {
                thread::spawn(move || {
                    let got = link.recv().expect("broadcast");
                    match got {
                        Msg::Broadcast { round, gagg } => {
                            assert_eq!(round, 0);
                            link.send(&update_msg(w, 0, gagg[w]));
                        }
                        _ => panic!("expected broadcast"),
                    }
                })
            })
            .collect();
        net.broadcast(&Msg::Broadcast { round: 0, gagg: vec![1.0, 2.0] });
        let msgs = net.gather_round(2, 0);
        assert_eq!(msgs.len(), 2);
        for (w, m) in msgs.iter().enumerate() {
            match m {
                Msg::Update { worker, loss, .. } => {
                    assert_eq!(*worker, w);
                    assert_eq!(*loss, (w + 1) as f32);
                }
                _ => panic!("expected update"),
            }
        }
        for h in handles {
            h.join().expect("worker thread");
        }
        // the byte-shipping star counts frames exactly like Tcp
        let c = net.counters().expect("inproc counts frame bytes since PR 10");
        assert_eq!(c.sent_frames, 2);
        assert_eq!(c.recv_frames, 2);
        assert!(c.sent_bytes > 0 && c.recv_bytes > 0);
        assert_eq!(c.sent_wire, 2 * 4, "1-value gagg half charged per worker");
        net.reset_counters();
        assert_eq!(net.counters(), Some(SocketCounters::default()));
    }

    #[test]
    #[should_panic(expected = "duplicate update")]
    fn inproc_duplicate_update_detected() {
        let mut net = InProc::star(2);
        let tx = net.up_sender();
        tx.send(encode_msg(&update_msg(0, 0, 1.0)).0).unwrap();
        tx.send(encode_msg(&update_msg(0, 0, 2.0)).0).unwrap();
        net.gather_round(2, 0);
    }

    #[test]
    #[should_panic(expected = "out-of-round update")]
    fn inproc_out_of_round_update_detected() {
        let mut net = InProc::star(1);
        let tx = net.up_sender();
        tx.send(encode_msg(&update_msg(0, 3, 1.0)).0).unwrap();
        net.gather_round(1, 0);
    }

    #[test]
    fn tcp_loopback_star_roundtrip_counts_bytes() {
        let mut net = Tcp::bind().expect("bind");
        let addr = net.addr().to_string();
        let handles: Vec<_> = (0..2)
            .map(|w| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut link = TcpLink::connect(&addr, w).expect("connect");
                    let got = link.recv().expect("broadcast");
                    match got {
                        Msg::Broadcast { round, gagg } => {
                            assert_eq!(round, 0);
                            link.send(&update_msg(w, 0, gagg[w]));
                        }
                        _ => panic!("expected broadcast"),
                    }
                })
            })
            .collect();
        net.accept(2).expect("accept");
        net.broadcast(&Msg::Broadcast { round: 0, gagg: vec![4.0, 5.0] });
        let msgs = net.gather_round(2, 0);
        for (w, m) in msgs.iter().enumerate() {
            match m {
                Msg::Update { worker, loss, .. } => {
                    assert_eq!(*worker, w);
                    assert_eq!(*loss, (w + 4) as f32);
                }
                _ => panic!("expected update"),
            }
        }
        for h in handles {
            h.join().expect("worker thread");
        }
        let c = net.counters().expect("tcp counts bytes");
        assert_eq!(c.sent_frames, 2);
        assert_eq!(c.recv_frames, 2);
        assert!(c.sent_bytes > 0 && c.recv_bytes > 0);
        // a 2-value broadcast charges its 1-value gagg half per worker
        assert_eq!(c.sent_wire, 2 * 4);
    }

    #[test]
    fn tcp_rejects_duplicate_worker_ids() {
        let mut net = Tcp::bind().expect("bind");
        let addr = net.addr().to_string();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || TcpLink::connect(&addr, 0))
            })
            .collect();
        let err = net.accept(2).expect_err("duplicate id must fail");
        assert!(err.contains("twice") || err.contains("out of range"), "{err}");
        drop(net);
        for h in handles {
            let _ = h.join();
        }
    }

    #[cfg(unix)]
    #[test]
    fn uds_star_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("regtopk-uds-test-{}", std::process::id()))
            .to_string_lossy()
            .to_string();
        let _ = std::fs::remove_file(&path);
        let mut net = Tcp::bind_uds(&path).expect("bind");
        let addr = net.addr().to_string();
        let h = thread::spawn(move || {
            let mut link = TcpLink::connect_uds(&addr, 0).expect("connect");
            let _ = link.recv().expect("broadcast");
            link.send(&update_msg(0, 0, 7.0));
        });
        net.accept(1).expect("accept");
        net.broadcast(&Msg::Broadcast { round: 0, gagg: vec![0.0, 0.0] });
        let msgs = net.gather_round(1, 0);
        assert_eq!(msgs.len(), 1);
        h.join().expect("worker thread");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transport_kind_names_roundtrip() {
        for k in [TransportKind::InProc, TransportKind::Tcp, TransportKind::Uds] {
            assert_eq!(TransportKind::parse(k.name()), Ok(k));
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::default(), TransportKind::InProc);
    }

    #[test]
    fn kind_of_matches_variants() {
        assert_eq!(kind_of(&update_msg(0, 0, 1.0)), FrameKind::Update);
        assert_eq!(kind_of(&Msg::Broadcast { round: 0, gagg: vec![] }), FrameKind::Broadcast);
    }
}
