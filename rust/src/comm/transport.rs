//! In-process transport: mpsc-based endpoints wiring N workers to one
//! server (parameter-server star topology).
//!
//! The deterministic single-threaded trainer calls sparsifiers
//! directly; this transport backs the *threaded* driver
//! (`coordinator::Trainer::run_threaded`) where each worker's round
//! body runs as a pooled task on the persistent executors, which is
//! how the framework would host real gradient computation.  Message
//! order per link is FIFO (mpsc guarantee); the
//! server gathers exactly one update per worker per round, so the
//! aggregate is order-independent and bit-identical to the
//! deterministic driver (verified in coordinator tests).

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::comm::Msg;

/// One side of the star: the server holds `WorkerHandle`s; each worker
/// thread holds an `Endpoint`.
pub struct Network {
    /// server's receive end (all workers send here)
    pub from_workers: Receiver<Msg>,
    /// per-worker broadcast senders
    to_workers: Vec<Sender<Msg>>,
    /// sender workers clone
    up_tx: Sender<Msg>,
    /// endpoints not yet taken by worker threads
    pending: Vec<Option<Endpoint>>,
}

/// A worker-side endpoint: send updates up, receive broadcasts down.
pub struct Endpoint {
    pub worker: usize,
    pub up: Sender<Msg>,
    pub down: Receiver<Msg>,
}

impl Network {
    pub fn star(n_workers: usize) -> Self {
        let (up_tx, from_workers) = channel();
        let mut to_workers = Vec::with_capacity(n_workers);
        let mut pending = Vec::with_capacity(n_workers);
        for worker in 0..n_workers {
            let (tx, rx) = channel();
            to_workers.push(tx);
            pending.push(Some(Endpoint { worker, up: up_tx.clone(), down: rx }));
        }
        Network { from_workers, to_workers, up_tx, pending }
    }

    /// Take worker `i`'s endpoint (once).
    pub fn endpoint(&mut self, worker: usize) -> Endpoint {
        self.pending[worker].take().expect("endpoint already taken")
    }

    /// Broadcast a message to all workers.
    pub fn broadcast(&self, msg: &Msg) {
        for tx in &self.to_workers {
            // a dropped worker is a shutdown race, not an error
            let _ = tx.send(msg.clone());
        }
    }

    /// Gather exactly one update per worker for `round`; returns them
    /// ordered by worker id (determinism).
    pub fn gather_round(&self, n_workers: usize, round: usize) -> Vec<Msg> {
        let mut slots: Vec<Option<Msg>> = (0..n_workers).map(|_| None).collect();
        let mut got = 0;
        while got < n_workers {
            let msg = self
                .from_workers
                .recv()
                .expect("worker hung up mid-round");
            match msg {
                Msg::Update { worker, round: r, .. } => {
                    assert_eq!(r, round, "out-of-round update");
                    assert!(slots[worker].is_none(), "duplicate update");
                    slots[worker] = Some(msg);
                    got += 1;
                }
                m @ (Msg::Broadcast { .. } | Msg::SparseBroadcast { .. }) => {
                    panic!("unexpected message at server: {m:?}")
                }
            }
        }
        slots.into_iter().map(Option::unwrap).collect()
    }

    /// A sender handle for injecting messages (tests).
    pub fn up_sender(&self) -> Sender<Msg> {
        self.up_tx.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::update::SparseUpdate;
    use crate::sparse::SparseVec;

    fn zero_update(dim: usize) -> SparseUpdate {
        SparseUpdate::single(SparseVec::zeros(dim))
    }

    #[test]
    fn star_roundtrip_two_workers() {
        let mut net = Network::star(2);
        let e0 = net.endpoint(0);
        let e1 = net.endpoint(1);
        let h0 = std::thread::spawn(move || {
            e0.up
                .send(Msg::Update { worker: 0, round: 0, update: zero_update(4), loss: 1.0 })
                .unwrap();
            match e0.down.recv().unwrap() {
                Msg::Broadcast { round, gagg } => (round, gagg),
                _ => panic!(),
            }
        });
        let h1 = std::thread::spawn(move || {
            e1.up
                .send(Msg::Update { worker: 1, round: 0, update: zero_update(4), loss: 2.0 })
                .unwrap();
            match e1.down.recv().unwrap() {
                Msg::Broadcast { round, .. } => round,
                _ => panic!(),
            }
        });
        let msgs = net.gather_round(2, 0);
        assert_eq!(msgs.len(), 2);
        // ordered by worker id regardless of arrival order
        match (&msgs[0], &msgs[1]) {
            (Msg::Update { worker: 0, .. }, Msg::Update { worker: 1, .. }) => {}
            other => panic!("bad order {other:?}"),
        }
        net.broadcast(&Msg::Broadcast { round: 0, gagg: vec![1.0; 4] });
        let (r0, g0) = h0.join().unwrap();
        assert_eq!(r0, 0);
        assert_eq!(g0, vec![1.0; 4]);
        assert_eq!(h1.join().unwrap(), 0);
    }

    #[test]
    #[should_panic]
    fn duplicate_update_detected() {
        let net = Network::star(1);
        let tx = net.up_sender();
        tx.send(Msg::Update { worker: 0, round: 0, update: zero_update(1), loss: 0.0 }).unwrap();
        tx.send(Msg::Update { worker: 0, round: 0, update: zero_update(1), loss: 0.0 }).unwrap();
        // gather for 2 workers so it tries to consume both messages
        net.gather_round(2, 0);
    }

    #[test]
    #[should_panic]
    fn out_of_round_update_detected() {
        let net = Network::star(1);
        net.up_sender()
            .send(Msg::Update { worker: 0, round: 5, update: zero_update(1), loss: 0.0 })
            .unwrap();
        net.gather_round(1, 0);
    }
}
