//! `SparseUpdate`: the bucketed wire format of a sparsified gradient —
//! one [`SparseVec`] per parameter group, with group-LOCAL indices.
//!
//! Bucketing is how real DDP stacks ship gradients (arXiv 1911.08772)
//! and it is cheaper on the wire: an entry's index costs
//! `ceil(log2 group_len)` bits instead of `ceil(log2 J)` (paper §2's
//! "log J bits" argument applied per group).  The degenerate
//! single-bucket update ([`SparseUpdate::single`], or any update
//! conformed to a single-group layout) is byte- and bit-identical to
//! the seed's flat `SparseVec` path.
//!
//! The wire format lives in `comm` (it IS the wire), but the shape it
//! conforms to is owned by higher layers: `grad::GradLayout` carries
//! the model's parameter-group structure.  To keep the module DAG
//! pointing down (`comm` must not import `grad`), shaping goes through
//! the [`BucketLayout`] trait declared here and implemented up-stack
//! by `GradLayout` — the classic dependency inversion.
//!
//! Each bucket carries a [`WirePayload`] slot recording which codecs
//! of the `comm::codec` stack encoded it this round: packed low-bit
//! value codes (a `bits` policy), Golomb–Rice coded indices
//! (`idx=rice`), or the raw-`u32` index marker (`idx=raw`).  The f32
//! values held in the bucket are always the payload's exact decode,
//! kept pre-decoded so the aggregation hot path stays branch-free;
//! `comm::codec::WireCost` reads the same slots to charge the true
//! wire size.  All-inactive slots (the default) mean the bucket
//! travels as raw f32 with bit-packed indices, exactly as before the
//! codec stack existed.

#![forbid(unsafe_code)]

use crate::comm::codec::{QuantPayload, RicePayload, WirePayload};
use crate::sparse::SparseVec;

/// A named partition of a flat parameter vector into contiguous
/// buckets — the shape contract [`SparseUpdate::conform_to`] and the
/// traffic ledger consume.  `grad::GradLayout` is the canonical
/// implementor; `comm` itself never sees the concrete type, keeping
/// the layering DAG acyclic.
pub trait BucketLayout {
    /// Total flat dimension J.
    fn total(&self) -> usize;
    /// Number of buckets (parameter groups).
    fn num_buckets(&self) -> usize;
    /// Bucket `g`'s name (for per-group ledger tables).
    fn bucket_name(&self, g: usize) -> &str;
    /// Bucket `g`'s global offset into the flat vector.
    fn bucket_offset(&self, g: usize) -> usize;
    /// Bucket `g`'s length.
    fn bucket_len(&self, g: usize) -> usize;
}

/// A bucketed sparse update.  Buckets are ordered by group offset;
/// each bucket's `dim` is its group length and its indices are local
/// to the group.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseUpdate {
    /// per-bucket global offset (mirrors the layout's group offsets)
    offsets: Vec<usize>,
    buckets: Vec<SparseVec>,
    /// per-bucket codec state (all-inactive = raw f32 / packed log J)
    payloads: Vec<WirePayload>,
    /// total flat dimension J
    total: usize,
}

impl SparseUpdate {
    /// A shapeless update; [`Self::conform_to`] (called by every
    /// `Sparsifier::step_group_into`) gives it its buckets.
    pub fn empty() -> Self {
        SparseUpdate::default()
    }

    /// An all-zero update shaped by `layout`.
    pub fn zeros(layout: &impl BucketLayout) -> Self {
        let mut u = SparseUpdate::empty();
        u.conform_to(layout);
        u
    }

    /// Wrap a flat [`SparseVec`] as the degenerate single-bucket
    /// update (the seed wire format).
    pub fn single(sv: SparseVec) -> Self {
        SparseUpdate {
            offsets: vec![0],
            total: sv.dim(),
            payloads: vec![WirePayload::default()],
            buckets: vec![sv],
        }
    }

    /// Reshape to `layout`, recycling bucket buffers (no allocation at
    /// steady state).  All buckets come back empty with their group's
    /// dimension and their codec slots inactive (payload word buffers
    /// keep their capacity for the next encoded round).
    pub fn conform_to(&mut self, layout: &impl BucketLayout) {
        let n = layout.num_buckets();
        self.total = layout.total();
        self.offsets.clear();
        self.offsets.extend((0..n).map(|g| layout.bucket_offset(g)));
        self.buckets.resize_with(n, || SparseVec::zeros(0));
        self.payloads.resize_with(n, WirePayload::default);
        for (g, b) in self.buckets.iter_mut().enumerate() {
            b.reset(layout.bucket_len(g));
        }
        for p in &mut self.payloads {
            p.clear();
        }
    }

    /// Reshape to mirror `other`'s bucket structure (offsets, bucket
    /// dims, total J) with every bucket empty and every codec slot
    /// inactive.  The server-side merge uses this to shape its output
    /// from the incoming worker updates — the server holds no
    /// layout of its own.
    pub fn conform_like(&mut self, other: &SparseUpdate) {
        self.total = other.total;
        self.offsets.clear();
        self.offsets.extend_from_slice(&other.offsets);
        self.buckets.resize_with(other.buckets.len(), || SparseVec::zeros(0));
        self.payloads.resize_with(other.buckets.len(), WirePayload::default);
        for (b, ob) in self.buckets.iter_mut().zip(&other.buckets) {
            b.reset(ob.dim());
        }
        for p in &mut self.payloads {
            p.clear();
        }
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn buckets(&self) -> &[SparseVec] {
        &self.buckets
    }

    pub fn bucket(&self, g: usize) -> &SparseVec {
        &self.buckets[g]
    }

    pub fn bucket_mut(&mut self, g: usize) -> &mut SparseVec {
        &mut self.buckets[g]
    }

    /// Bucket `g`'s packed value payload, if one is active.
    pub fn quant(&self, g: usize) -> Option<&QuantPayload> {
        self.payloads.get(g).map(|p| &p.value).filter(|q| q.is_active())
    }

    /// Bucket `g`'s Golomb–Rice index payload, if one is active.
    pub fn rice(&self, g: usize) -> Option<&RicePayload> {
        self.payloads.get(g).map(|p| &p.rice).filter(|r| r.is_active())
    }

    /// Whether bucket `g` is marked for raw-`u32` index accounting
    /// (`idx=raw`).
    pub fn raw_index(&self, g: usize) -> bool {
        self.payloads.get(g).is_some_and(|p| p.raw_index)
    }

    /// Mutable access to bucket `g`'s codec slot.
    pub fn payload_mut(&mut self, g: usize) -> &mut WirePayload {
        &mut self.payloads[g]
    }

    /// Disjoint mutable borrows of bucket `g` and its codec slot — the
    /// worker-boundary encode writes both in one pass (decoded values
    /// into the bucket, packed codes into the slot).
    pub fn bucket_payload_mut(&mut self, g: usize) -> (&mut SparseVec, &mut WirePayload) {
        (&mut self.buckets[g], &mut self.payloads[g])
    }

    /// Disjoint mutable borrows of bucket `g` and its value-payload
    /// slot (the PR 4 entry point, kept for value-only encoders).
    pub fn bucket_quant_mut(&mut self, g: usize) -> (&mut SparseVec, &mut QuantPayload) {
        (&mut self.buckets[g], &mut self.payloads[g].value)
    }

    /// Global offset of bucket `g`.
    pub fn offset(&self, g: usize) -> usize {
        self.offsets[g]
    }

    /// Total flat dimension J.
    pub fn total_dim(&self) -> usize {
        self.total
    }

    /// Total transmitted entries across buckets.
    pub fn nnz(&self) -> usize {
        self.buckets.iter().map(SparseVec::nnz).sum()
    }

    /// `out += scale * self` over the full flat vector (server-side
    /// aggregation hot path).  Buckets apply in offset order, so the
    /// float-add order matches the flat path bit-for-bit.
    pub fn axpy_into(&self, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.total);
        for (&off, b) in self.offsets.iter().zip(&self.buckets) {
            b.axpy_into(scale, &mut out[off..off + b.dim()]);
        }
    }

    /// Densify into a fresh flat vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.total];
        self.axpy_into(1.0, &mut out);
        out
    }

    /// Flatten to a single global-index [`SparseVec`] into a recycled
    /// buffer.  Bucket-local indices shift by their group offset;
    /// bucket order == ascending global order, so the result satisfies
    /// the wire invariant by construction.
    pub fn flatten_into(&self, out: &mut SparseVec) {
        out.reset(self.total);
        for (&off, b) in self.offsets.iter().zip(&self.buckets) {
            for (&i, &v) in b.indices().iter().zip(b.values()) {
                out.push(off as u32 + i, v);
            }
        }
    }

    /// Allocating variant of [`Self::flatten_into`].
    pub fn flatten(&self) -> SparseVec {
        let mut out = SparseVec::zeros(self.total);
        self.flatten_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::WireCost;
    use crate::grad::GradLayout;

    fn two_group_layout() -> GradLayout {
        GradLayout::from_sizes([("a".to_string(), 4), ("b".to_string(), 6)])
    }

    #[test]
    fn conform_shapes_buckets_and_recycles() {
        let layout = two_group_layout();
        let mut u = SparseUpdate::empty();
        u.conform_to(&layout);
        assert_eq!(u.num_buckets(), 2);
        assert_eq!(u.bucket(0).dim(), 4);
        assert_eq!(u.bucket(1).dim(), 6);
        assert_eq!(u.offset(1), 4);
        assert_eq!(u.total_dim(), 10);
        // reshaping to a different layout reuses the bucket vec
        u.conform_to(&GradLayout::single(7));
        assert_eq!(u.num_buckets(), 1);
        assert_eq!(u.bucket(0).dim(), 7);
    }

    #[test]
    fn bucket_layout_trait_mirrors_grad_layout() {
        let layout = two_group_layout();
        let bl: &dyn BucketLayout = &layout;
        assert_eq!(bl.total(), 10);
        assert_eq!(bl.num_buckets(), 2);
        assert_eq!(bl.bucket_name(0), "a");
        assert_eq!(bl.bucket_offset(1), 4);
        assert_eq!(bl.bucket_len(1), 6);
    }

    #[test]
    fn conform_like_mirrors_shape_without_entries() {
        let layout = two_group_layout();
        let mut src = SparseUpdate::zeros(&layout);
        src.bucket_mut(0).push(2, 1.0);
        src.bucket_mut(1).push(3, -4.0);
        let mut dst = SparseUpdate::single(SparseVec::new(3, vec![0], vec![9.0]));
        dst.conform_like(&src);
        assert_eq!(dst.num_buckets(), 2);
        assert_eq!(dst.offset(1), src.offset(1));
        assert_eq!(dst.bucket(0).dim(), 4);
        assert_eq!(dst.bucket(1).dim(), 6);
        assert_eq!(dst.total_dim(), 10);
        assert_eq!(dst.nnz(), 0, "conform_like must not copy entries");
        assert!(dst.quant(0).is_none() && dst.rice(0).is_none());
    }

    #[test]
    fn single_matches_flat_sparsevec() {
        let sv = SparseVec::new(100, vec![3, 50], vec![1.0, -2.0]);
        let flat_bytes = WireCost::paper().flat(&sv);
        let u = SparseUpdate::single(sv.clone());
        assert_eq!(u.nnz(), 2);
        assert_eq!(WireCost::paper().update(&u), flat_bytes);
        assert_eq!(u.flatten(), sv);
        assert_eq!(u.to_dense(), sv.to_dense());
    }

    #[test]
    fn flatten_shifts_local_indices() {
        let layout = two_group_layout();
        let mut u = SparseUpdate::zeros(&layout);
        u.bucket_mut(0).push(1, 5.0);
        u.bucket_mut(1).push(0, -1.0);
        u.bucket_mut(1).push(5, 2.0);
        let flat = u.flatten();
        assert_eq!(flat.indices(), &[1, 4, 9]);
        assert_eq!(flat.values(), &[5.0, -1.0, 2.0]);
        assert_eq!(u.nnz(), 3);
        let mut dense = vec![0.0f32; 10];
        u.axpy_into(2.0, &mut dense);
        assert_eq!(dense[1], 10.0);
        assert_eq!(dense[4], -2.0);
        assert_eq!(dense[9], 4.0);
    }

    #[test]
    fn codec_slots_follow_conform_and_shrink_wire_bytes() {
        let wc = WireCost::paper();
        let layout = two_group_layout();
        let mut u = SparseUpdate::zeros(&layout);
        u.bucket_mut(0).push(1, 0.5);
        u.bucket_mut(0).push(3, -0.25);
        assert!(u.quant(0).is_none(), "slots start inactive");
        assert!(u.rice(0).is_none() && !u.raw_index(0));
        let raw = wc.update(&u);
        let (b, q) = u.bucket_quant_mut(0);
        // 4-bit codes for the two entries (values already "quantized")
        q.encode_into(4, 0.25, &[9, 6]);
        b.values_mut().copy_from_slice(&[0.5, -0.25]);
        assert!(u.quant(0).is_some());
        assert!(wc.update(&u) < raw, "{} !< {raw}", wc.update(&u));
        // reconforming deactivates every slot again
        u.conform_to(&layout);
        assert!(u.quant(0).is_none());
        assert_eq!(wc.update(&u), 0);
    }

    #[test]
    fn bucketed_indices_are_cheaper_on_the_wire() {
        // 2^20 flat dim -> 20 index bits; two 2^10 groups -> 10 bits.
        let layout = GradLayout::from_sizes([
            ("a".to_string(), 1 << 10),
            ("b".to_string(), (1 << 20) - (1 << 10)),
        ]);
        let mut grouped = SparseUpdate::zeros(&layout);
        for i in 0..8u32 {
            grouped.bucket_mut(0).push(i, 1.0);
        }
        let flat = grouped.flatten();
        assert!(flat.dim() == 1 << 20);
        let wc = WireCost::paper();
        assert!(
            wc.update(&grouped) < wc.flat(&flat),
            "grouped {} !< flat {}",
            wc.update(&grouped),
            wc.flat(&flat)
        );
    }
}
