//! Versioned byte-level wire framing for [`Msg`] — the serialization
//! seam of the networked transport (`comm::transport::Tcp`).
//!
//! Every frame is a fixed 20-byte header followed by a length-prefixed
//! payload:
//!
//! ```text
//! magic "RTKW" (4) | version u16 LE (2) | kind u8 | pad u8 = 0
//! round u32 LE (4) | worker u32 LE (4)  | payload len u32 LE (4)
//! ```
//!
//! The payload splits into a STRUCTURAL part (shape: bucket offsets,
//! dims, nnz counts, codec flags — bytes a real system would fold into
//! its session state) and a CHARGED part that mirrors
//! [`WireCost`]'s accounting byte-for-byte: for every bucket the
//! charged segment's length equals `WireCost::paper().bucket(..)`
//! exactly, so socket byte counters and the traffic [`Ledger`] agree
//! by construction (ISSUE 9 acceptance criterion).  `encode`
//! debug-asserts that equality on every bucket.
//!
//! Bit-level layouts reuse the codec stack's LSB-first convention
//! (`rice::put_bits`): packed value codes are the [`QuantPayload`]
//! stream verbatim, Rice index streams are the [`RicePayload`] words
//! re-emitted as little-endian bytes.  Decode is lossless — a decoded
//! update re-encodes to identical bytes — and returns `Err` (never
//! panics) on torn frames, short reads, or corrupt streams.
//!
//! [`Ledger`]: crate::comm::Ledger

#![forbid(unsafe_code)]

use super::{index_bits, LevelKind, QuantPayload, WireCost};
use crate::comm::update::{BucketLayout, SparseUpdate};
use crate::comm::Msg;

/// Frame magic: "RegTopK Wire".
pub const FRAME_MAGIC: &[u8; 4] = b"RTKW";
/// Handshake magic: "RegTopK Hello" (sent once per connection, before
/// any frame; not itself a frame).
pub const HELLO_MAGIC: &[u8; 4] = b"RTKH";
/// Wire schema version carried by every frame header (v1 was the
/// in-process era with no byte framing; see docs/WIRE.md).
pub const WIRE_VERSION: u16 = 2;
/// Fixed frame-header size in bytes.
pub const FRAME_HEADER_BYTES: usize = 20;
/// Fixed handshake size in bytes: magic + version u16 + worker u32.
pub const HELLO_BYTES: usize = 10;

/// Payload kind carried in the frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker → server sparsified update (`Msg::Update`).
    Update,
    /// Server → worker dense broadcast (`Msg::Broadcast`).
    Broadcast,
    /// Server → worker downlink-coded broadcast (`Msg::SparseBroadcast`).
    SparseBroadcast,
}

impl FrameKind {
    fn as_byte(self) -> u8 {
        match self {
            FrameKind::Update => 0,
            FrameKind::Broadcast => 1,
            FrameKind::SparseBroadcast => 2,
        }
    }

    fn from_byte(b: u8) -> Result<Self, String> {
        match b {
            0 => Ok(FrameKind::Update),
            1 => Ok(FrameKind::Broadcast),
            2 => Ok(FrameKind::SparseBroadcast),
            _ => Err(format!("unknown frame kind byte {b}")),
        }
    }
}

/// Decoded frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    pub round: u32,
    pub worker: u32,
    /// Payload length in bytes (the header's own 20 bytes excluded).
    pub len: u32,
}

/// Byte accounting of one encoded/decoded frame: `bytes` is the full
/// frame size on the socket, `wire` the [`WireCost`]-charged subset.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrameStats {
    pub bytes: usize,
    pub wire: usize,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// LSB-first bit appender over a byte buffer (same bit order as the
/// codec stack's `put_bits`, so packed streams re-emit verbatim).
#[derive(Default)]
struct BitWriter {
    bytes: Vec<u8>,
    bits: usize,
}

impl BitWriter {
    fn put(&mut self, value: u32, bits: usize) {
        debug_assert!(bits <= 32);
        for k in 0..bits {
            let pos = self.bits + k;
            if pos / 8 == self.bytes.len() {
                self.bytes.push(0);
            }
            if (value >> k) & 1 == 1 {
                self.bytes[pos / 8] |= 1 << (pos % 8);
            }
        }
        self.bits += bits;
    }
}

/// LSB-first bit reader over a byte slice; every read is bounds
/// checked so torn frames surface as `Err`, not panics.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    fn get(&mut self, bits: usize) -> Result<u32, String> {
        debug_assert!(bits <= 32);
        let mut v = 0u32;
        for k in 0..bits {
            let p = self.pos + k;
            if p / 8 >= self.bytes.len() {
                return Err("torn frame: bit stream truncated".to_string());
            }
            v |= (((self.bytes[p / 8] >> (p % 8)) & 1) as u32) << k;
        }
        self.pos += bits;
        Ok(v)
    }

    /// Bytes consumed so far, rounded up to whole bytes.
    fn consumed_bytes(&self) -> usize {
        self.pos.div_ceil(8)
    }
}

/// Bounds-checked sequential reader over a payload slice.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "torn frame: need {n} bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32(&mut self) -> Result<f32, String> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn rest(&self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    fn advance(&mut self, n: usize) -> Result<(), String> {
        self.take(n).map(|_| ())
    }
}

/// A frame-local [`BucketLayout`] rebuilt from the structural section;
/// buckets are nameless on the wire (names are config-side metadata).
struct WireShape {
    offsets: Vec<usize>,
    dims: Vec<usize>,
    total: usize,
}

impl BucketLayout for WireShape {
    fn total(&self) -> usize {
        self.total
    }

    fn num_buckets(&self) -> usize {
        self.offsets.len()
    }

    fn bucket_name(&self, _g: usize) -> &str {
        ""
    }

    fn bucket_offset(&self, g: usize) -> usize {
        self.offsets[g]
    }

    fn bucket_len(&self, g: usize) -> usize {
        self.dims[g]
    }
}

/// Encode `msg` as one framed byte vector.  Returns the bytes plus
/// their [`FrameStats`]; the `wire` component equals what the traffic
/// ledger charges for the same message (`WireCost::paper()`
/// accounting; model-weight halves of broadcasts are structural).
pub fn encode_msg(msg: &Msg) -> (Vec<u8>, FrameStats) {
    let (kind, round, worker) = match msg {
        Msg::Update { worker, round, .. } => (FrameKind::Update, *round, *worker),
        Msg::Broadcast { round, .. } => (FrameKind::Broadcast, *round, 0),
        Msg::SparseBroadcast { round, .. } => (FrameKind::SparseBroadcast, *round, 0),
    };
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + 64);
    out.extend_from_slice(FRAME_MAGIC);
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.push(kind.as_byte());
    out.push(0);
    put_u32(&mut out, round as u32);
    put_u32(&mut out, worker as u32);
    put_u32(&mut out, 0); // payload length, patched below
    let wire = match msg {
        Msg::Update { update, loss, .. } => {
            put_f32(&mut out, *loss);
            encode_update(update, &mut out)
        }
        Msg::Broadcast { gagg, .. } => {
            put_u32(&mut out, gagg.len() as u32);
            for &v in gagg {
                put_f32(&mut out, v);
            }
            // the broadcast vector is [w | gagg_prev]: the model half
            // is session state, only the aggregate half is charged
            4 * (gagg.len() / 2)
        }
        Msg::SparseBroadcast { w, gagg, .. } => {
            put_u32(&mut out, w.len() as u32);
            for &v in w {
                put_f32(&mut out, v);
            }
            encode_update(gagg, &mut out)
        }
    };
    let len = (out.len() - FRAME_HEADER_BYTES) as u32;
    out[16..20].copy_from_slice(&len.to_le_bytes());
    let stats = FrameStats { bytes: out.len(), wire };
    (out, stats)
}

/// Append `up`'s wire encoding; returns the charged byte count
/// (defined equal to `WireCost::paper().update(up)`).
fn encode_update(up: &SparseUpdate, out: &mut Vec<u8>) -> usize {
    let wc = WireCost::paper();
    put_u32(out, up.total_dim() as u32);
    put_u32(out, up.num_buckets() as u32);
    let mut charged = 0usize;
    for g in 0..up.num_buckets() {
        let b = up.bucket(g);
        put_u32(out, up.offset(g) as u32);
        put_u32(out, b.dim() as u32);
        put_u32(out, b.nnz() as u32);
        if b.nnz() == 0 {
            // empty buckets carry no codec state: WireCost charges 0
            // with or without active slots, and an empty payload's
            // scale/param header cannot ride for free
            out.push(0);
            continue;
        }
        let quant = up.quant(g);
        let rice = up.rice(g);
        let raw = up.raw_index(g);
        let mut flags = 0u8;
        if quant.is_some() {
            flags |= 1;
        }
        if rice.is_some() {
            flags |= 2;
        }
        if raw {
            flags |= 4;
        }
        out.push(flags);
        if let Some(q) = quant {
            out.push(q.bits() as u8);
            out.push(match q.level_kind() {
                LevelKind::Uniform => 0,
                LevelKind::Nuq => 1,
                LevelKind::Fp16 => 2,
                LevelKind::Bf16 => 3,
            });
        }
        let start = out.len();
        if let Some(rp) = rice {
            // values first (codes or raw f32), then the Rice stream;
            // half-width kinds are scale-free (the code IS the value)
            if let Some(q) = quant {
                if !q.level_kind().is_half() {
                    put_f32(out, q.scale());
                }
                let mut bw = BitWriter::default();
                for i in 0..b.nnz() {
                    bw.put(q.code(i), q.bits());
                }
                out.extend_from_slice(&bw.bytes);
            } else {
                for &v in b.values() {
                    put_f32(out, v);
                }
            }
            out.push(rp.param() as u8);
            let nbytes = rp.bit_len().div_ceil(8);
            let words = rp.words();
            for j in 0..nbytes {
                out.push(((words[j / 4] >> (8 * (j % 4))) & 0xFF) as u8);
            }
        } else {
            let ib = if raw { 32 } else { index_bits(b.dim()) };
            let mut bw = BitWriter::default();
            if let Some(q) = quant {
                if !q.level_kind().is_half() {
                    put_f32(out, q.scale());
                }
                for (i, &idx) in b.indices().iter().enumerate() {
                    bw.put(q.code(i), q.bits());
                    bw.put(idx, ib);
                }
            } else {
                for (&idx, &v) in b.indices().iter().zip(b.values()) {
                    // repro-lint: allow(bit-kernels-outside-kernels)
                    bw.put(v.to_bits(), 32);
                    bw.put(idx, ib);
                }
            }
            out.extend_from_slice(&bw.bytes);
        }
        let seg = out.len() - start;
        debug_assert_eq!(
            seg,
            wc.bucket(up, g),
            "bucket {g}: charged frame bytes disagree with WireCost"
        );
        charged += seg;
    }
    debug_assert_eq!(charged, wc.update(up));
    charged
}

/// Parse and validate a frame header (exactly
/// [`FRAME_HEADER_BYTES`] bytes).
pub fn decode_header(buf: &[u8]) -> Result<FrameHeader, String> {
    if buf.len() != FRAME_HEADER_BYTES {
        return Err(format!("frame header needs {FRAME_HEADER_BYTES} bytes, got {}", buf.len()));
    }
    if &buf[0..4] != FRAME_MAGIC {
        return Err(format!("bad frame magic {:?}", &buf[0..4]));
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != WIRE_VERSION {
        return Err(format!("wire version {version} != supported {WIRE_VERSION}"));
    }
    let kind = FrameKind::from_byte(buf[6])?;
    if buf[7] != 0 {
        return Err(format!("nonzero header pad byte {}", buf[7]));
    }
    Ok(FrameHeader {
        kind,
        round: u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]),
        worker: u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]),
        len: u32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]),
    })
}

/// Decode a payload under its header into a [`Msg`], returning the
/// charged wire bytes alongside.  Lossless: re-encoding the result
/// reproduces the input frame byte-for-byte.
pub fn decode_payload(h: &FrameHeader, payload: &[u8]) -> Result<(Msg, usize), String> {
    if payload.len() != h.len as usize {
        return Err(format!("payload is {} bytes, header says {}", payload.len(), h.len));
    }
    let mut cur = Cursor::new(payload);
    let (msg, wire) = match h.kind {
        FrameKind::Update => {
            let loss = cur.f32()?;
            let (update, wire) = decode_update(&mut cur)?;
            (
                Msg::Update {
                    worker: h.worker as usize,
                    round: h.round as usize,
                    update,
                    loss,
                },
                wire,
            )
        }
        FrameKind::Broadcast => {
            let n = cur.u32()? as usize;
            let gagg = decode_f32s(&mut cur, n)?;
            (Msg::Broadcast { round: h.round as usize, gagg }, 4 * (n / 2))
        }
        FrameKind::SparseBroadcast => {
            let n = cur.u32()? as usize;
            let w = decode_f32s(&mut cur, n)?;
            let (gagg, wire) = decode_update(&mut cur)?;
            (Msg::SparseBroadcast { round: h.round as usize, w, gagg }, wire)
        }
    };
    if cur.remaining() != 0 {
        return Err(format!("{} trailing bytes after payload", cur.remaining()));
    }
    Ok((msg, wire))
}

/// Decode a whole frame (header + payload) in one call.
pub fn decode_msg(frame: &[u8]) -> Result<(Msg, FrameStats), String> {
    if frame.len() < FRAME_HEADER_BYTES {
        return Err(format!("short frame: {} bytes", frame.len()));
    }
    let h = decode_header(&frame[..FRAME_HEADER_BYTES])?;
    let (msg, wire) = decode_payload(&h, &frame[FRAME_HEADER_BYTES..])?;
    Ok((msg, FrameStats { bytes: frame.len(), wire }))
}

fn decode_f32s(cur: &mut Cursor, n: usize) -> Result<Vec<f32>, String> {
    if cur.remaining() < n * 4 {
        return Err(format!("torn frame: {n} f32s need {} bytes", n * 4));
    }
    (0..n).map(|_| cur.f32()).collect()
}

fn decode_update(cur: &mut Cursor) -> Result<(SparseUpdate, usize), String> {
    let total = cur.u32()? as usize;
    let n_buckets = cur.u32()? as usize;
    // 13 bytes is the smallest possible bucket record
    if n_buckets * 13 > cur.remaining() + 13 {
        return Err(format!("torn frame: {n_buckets} buckets cannot fit"));
    }
    struct DecBucket {
        indices: Vec<u32>,
        values: Vec<f32>,
        quant: Option<(usize, f32, LevelKind, Vec<u32>)>,
        rice: bool,
        raw: bool,
    }
    let mut shape = WireShape { offsets: Vec::new(), dims: Vec::new(), total };
    let mut dec: Vec<DecBucket> = Vec::new();
    let mut prev_end = 0usize;
    let mut charged = 0usize;
    for g in 0..n_buckets {
        let off = cur.u32()? as usize;
        let dim = cur.u32()? as usize;
        let nnz = cur.u32()? as usize;
        if off < prev_end || off + dim > total {
            return Err(format!("bucket {g}: span {off}+{dim} outside [{prev_end}, {total}]"));
        }
        prev_end = off + dim;
        if nnz > dim {
            return Err(format!("bucket {g}: nnz {nnz} > dim {dim}"));
        }
        let flags = cur.u8()?;
        if flags & !0b111 != 0 {
            return Err(format!("bucket {g}: unknown flag bits {flags:#x}"));
        }
        if nnz == 0 && flags != 0 {
            return Err(format!("bucket {g}: empty bucket with codec flags {flags:#x}"));
        }
        shape.offsets.push(off);
        shape.dims.push(dim);
        let (has_quant, has_rice, raw) = (flags & 1 != 0, flags & 2 != 0, flags & 4 != 0);
        if nnz == 0 {
            dec.push(DecBucket {
                indices: Vec::new(),
                values: Vec::new(),
                quant: None,
                rice: false,
                raw: false,
            });
            continue;
        }
        let qmeta = if has_quant {
            let bits = cur.u8()? as usize;
            if !(2..=16).contains(&bits) {
                return Err(format!("bucket {g}: quant bit width {bits} outside 2..=16"));
            }
            let levels = match cur.u8()? {
                0 => LevelKind::Uniform,
                1 => LevelKind::Nuq,
                2 => LevelKind::Fp16,
                3 => LevelKind::Bf16,
                b => return Err(format!("bucket {g}: unknown level-family byte {b}")),
            };
            if levels.is_half() && bits != 16 {
                return Err(format!("bucket {g}: half-width family requires 16 bits, got {bits}"));
            }
            Some((bits, levels))
        } else {
            None
        };
        let start = cur.pos;
        let (indices, values, quant) = if has_rice {
            let (values, quant) = match qmeta {
                Some((bits, levels)) => {
                    // half-width kinds carry no scale on the wire
                    let scale = if levels.is_half() { 0.0 } else { cur.f32()? };
                    let mut br = BitReader::new(cur.rest());
                    let mut codes = Vec::with_capacity(nnz);
                    for _ in 0..nnz {
                        codes.push(br.get(bits)?);
                    }
                    cur.advance((nnz * bits).div_ceil(8))?;
                    (Vec::new(), Some((bits, scale, levels, codes)))
                }
                None => (decode_f32s(cur, nnz)?, None),
            };
            let indices = decode_rice_stream(cur, nnz, dim, g)?;
            (indices, values, quant)
        } else {
            let ib = if raw { 32 } else { index_bits(dim) };
            let mut br = BitReader::new(cur.rest());
            let mut indices = Vec::with_capacity(nnz);
            let (values, quant) = match qmeta {
                Some((bits, levels)) => {
                    // half-width kinds carry no scale on the wire
                    let scale = if levels.is_half() { 0.0 } else { cur.f32()? };
                    let mut br = BitReader::new(cur.rest());
                    let mut codes = Vec::with_capacity(nnz);
                    for _ in 0..nnz {
                        codes.push(br.get(bits)?);
                        indices.push(br.get(ib)?);
                    }
                    cur.advance((nnz * (bits + ib)).div_ceil(8))?;
                    (Vec::new(), Some((bits, scale, levels, codes)))
                }
                None => {
                    let mut values = Vec::with_capacity(nnz);
                    for _ in 0..nnz {
                        // repro-lint: allow(bit-kernels-outside-kernels)
                        values.push(f32::from_bits(br.get(32)?));
                        indices.push(br.get(ib)?);
                    }
                    cur.advance((nnz * (32 + ib)).div_ceil(8))?;
                    (values, None)
                }
            };
            (indices, values, quant)
        };
        for (j, &i) in indices.iter().enumerate() {
            let ok = (i as usize) < dim && (j == 0 || indices[j - 1] < i);
            if !ok {
                return Err(format!("bucket {g}: index stream not strictly increasing in-range"));
            }
        }
        charged += cur.pos - start;
        dec.push(DecBucket { indices, values, quant, rice: has_rice, raw });
    }
    let mut up = SparseUpdate::empty();
    up.conform_to(&shape);
    for (g, db) in dec.iter().enumerate() {
        match &db.quant {
            Some((bits, scale, levels, codes)) => {
                let (b, q) = up.bucket_quant_mut(g);
                q.encode_with_levels(*bits, *scale, codes, *levels);
                for (j, &i) in db.indices.iter().enumerate() {
                    b.push(i, q.decode_value(j));
                }
            }
            None => {
                let b = up.bucket_mut(g);
                for (&i, &v) in db.indices.iter().zip(&db.values) {
                    b.push(i, v);
                }
            }
        }
        if db.rice {
            // deterministic re-encode: best_param is a pure function
            // of the index list, so the payload matches the sender's
            up.payload_mut(g).rice.encode_into(&db.indices);
        }
        up.payload_mut(g).raw_index = db.raw;
    }
    debug_assert_eq!(charged, WireCost::paper().update(&up));
    Ok((up, charged))
}

/// Decode one bucket's Rice stream (param byte + bit-packed gaps) and
/// advance the cursor past exactly the bytes the encoder emitted.
fn decode_rice_stream(
    cur: &mut Cursor,
    nnz: usize,
    dim: usize,
    g: usize,
) -> Result<Vec<u32>, String> {
    let r = cur.u8()? as usize;
    if r >= 32 {
        return Err(format!("bucket {g}: rice parameter {r} out of range"));
    }
    let mut br = BitReader::new(cur.rest());
    let mut indices = Vec::with_capacity(nnz);
    let mut prev: u64 = 0;
    for j in 0..nnz {
        let mut q: u64 = 0;
        while br.get(1)? == 1 {
            q += 1;
            if q as usize > dim {
                return Err(format!("bucket {g}: runaway rice quotient"));
            }
        }
        let rem = br.get(r)? as u64;
        let d = (q << r) | rem;
        prev = if j == 0 { d } else { prev + d + 1 };
        if prev as usize >= dim {
            return Err(format!("bucket {g}: rice index {prev} >= dim {dim}"));
        }
        indices.push(prev as u32);
    }
    let consumed = br.consumed_bytes();
    cur.advance(consumed)?;
    Ok(indices)
}

/// The per-connection handshake a worker sends before its first
/// frame: magic + wire version + worker id.
pub fn encode_hello(worker: u32) -> [u8; HELLO_BYTES] {
    let mut out = [0u8; HELLO_BYTES];
    out[0..4].copy_from_slice(HELLO_MAGIC);
    out[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    out[6..10].copy_from_slice(&worker.to_le_bytes());
    out
}

/// Parse and validate a handshake, returning the worker id.
pub fn decode_hello(buf: &[u8]) -> Result<u32, String> {
    if buf.len() != HELLO_BYTES {
        return Err(format!("handshake needs {HELLO_BYTES} bytes, got {}", buf.len()));
    }
    if &buf[0..4] != HELLO_MAGIC {
        return Err(format!("bad handshake magic {:?}", &buf[0..4]));
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != WIRE_VERSION {
        return Err(format!("handshake version {version} != supported {WIRE_VERSION}"));
    }
    Ok(u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::ValueCodec;
    use crate::grad::GradLayout;
    use crate::sparse::SparseVec;
    use crate::util::rng::Rng;

    fn roundtrip(msg: &Msg) -> (Msg, FrameStats) {
        let (bytes, st) = encode_msg(msg);
        let (back, st2) = decode_msg(&bytes).expect("decode");
        assert_eq!(st, st2, "encode/decode stats disagree");
        // losslessness at the byte level: re-encode reproduces the frame
        let (bytes2, _) = encode_msg(&back);
        assert_eq!(bytes, bytes2, "re-encode is not byte-identical");
        (back, st)
    }

    fn grouped_update() -> SparseUpdate {
        let layout =
            GradLayout::from_sizes([("conv".to_string(), 1 << 10), ("fc".to_string(), 40)]);
        let mut up = SparseUpdate::zeros(&layout);
        for i in 0..16u32 {
            up.bucket_mut(0).push(i * 11, 0.25 * (i as f32 + 1.0));
        }
        up.bucket_mut(1).push(3, -1.5);
        up.bucket_mut(1).push(39, 2.0);
        up
    }

    #[test]
    fn raw_update_roundtrips_and_charges_wirecost() {
        let up = grouped_update();
        let expect = WireCost::paper().update(&up);
        let msg = Msg::Update { worker: 3, round: 7, update: up, loss: 0.625 };
        let (back, st) = roundtrip(&msg);
        assert_eq!(st.wire, expect);
        assert_eq!(back, msg);
    }

    #[test]
    fn rice_and_quant_buckets_roundtrip() {
        let mut up = grouped_update();
        let idx: Vec<u32> = up.bucket(0).indices().to_vec();
        up.payload_mut(0).rice.encode_into(&idx);
        let mut rng = Rng::seed_from(9);
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        let (b, q) = up.bucket_quant_mut(1);
        let vc = ValueCodec { bits: 4, levels: LevelKind::Uniform };
        vc.encode_bucket(b, &mut rng, q, &mut residual, &mut codes);
        let expect = WireCost::paper().update(&up);
        let msg = Msg::Update { worker: 0, round: 2, update: up, loss: 1.0 };
        let (back, st) = roundtrip(&msg);
        assert_eq!(st.wire, expect);
        assert_eq!(back, msg);
    }

    #[test]
    fn raw_index_and_rice_plus_quant_roundtrip() {
        let mut up = grouped_update();
        up.payload_mut(0).raw_index = true;
        let mut rng = Rng::seed_from(4);
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        {
            let (b, q) = up.bucket_quant_mut(1);
            let vc = ValueCodec { bits: 8, levels: LevelKind::Nuq };
            vc.encode_bucket(b, &mut rng, q, &mut residual, &mut codes);
        }
        let idx: Vec<u32> = up.bucket(1).indices().to_vec();
        up.payload_mut(1).rice.encode_into(&idx);
        let expect = WireCost::paper().update(&up);
        let msg = Msg::Update { worker: 1, round: 0, update: up, loss: -0.5 };
        let (back, st) = roundtrip(&msg);
        assert_eq!(st.wire, expect);
        assert_eq!(back, msg);
    }

    #[test]
    fn half_width_buckets_roundtrip_scale_free() {
        for levels in [LevelKind::Fp16, LevelKind::Bf16] {
            let mut up = grouped_update();
            let mut rng = Rng::seed_from(11);
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            {
                let (b, q) = up.bucket_quant_mut(0);
                let vc = ValueCodec { bits: 16, levels };
                vc.encode_bucket(b, &mut rng, q, &mut residual, &mut codes);
            }
            // bucket 0 also exercises the rice index path with half values
            let idx: Vec<u32> = up.bucket(0).indices().to_vec();
            up.payload_mut(0).rice.encode_into(&idx);
            {
                let (b, q) = up.bucket_quant_mut(1);
                let vc = ValueCodec { bits: 16, levels };
                vc.encode_bucket(b, &mut rng, q, &mut residual, &mut codes);
            }
            let expect = WireCost::paper().update(&up);
            let msg = Msg::Update { worker: 2, round: 5, update: up, loss: 0.125 };
            let (back, st) = roundtrip(&msg);
            assert_eq!(st.wire, expect, "{levels:?}: half payloads charge 16 bits/value");
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn half_width_family_byte_requires_sixteen_bits() {
        let mut sv = SparseVec::zeros(64);
        sv.push(17, -3.25);
        let mut up = SparseUpdate::single(sv);
        let mut rng = Rng::seed_from(3);
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        let (b, q) = up.bucket_quant_mut(0);
        let vc = ValueCodec { bits: 16, levels: LevelKind::Fp16 };
        vc.encode_bucket(b, &mut rng, q, &mut residual, &mut codes);
        let msg = Msg::Update { worker: 0, round: 0, update: up, loss: 0.0 };
        let (bytes, _) = encode_msg(&msg);
        // the bucket preamble is flags=1 (quant only), bits=16, family=2;
        // that window is unique in this minimal frame by construction
        let pat: Vec<usize> = bytes
            .windows(3)
            .enumerate()
            .filter(|(_, w)| w == &[1u8, 16, 2])
            .map(|(i, _)| i)
            .collect();
        assert_eq!(pat.len(), 1, "qmeta window must be unique");
        let mut bad = bytes.clone();
        bad[pat[0] + 1] = 8; // claims 8-bit codes with a half family
        assert!(decode_msg(&bad).is_err(), "half family with bits != 16 must not decode");
    }

    #[test]
    fn empty_and_single_entry_updates_roundtrip() {
        for nnz in [0usize, 1] {
            let mut sv = SparseVec::zeros(64);
            if nnz == 1 {
                sv.push(17, -3.25);
            }
            let up = SparseUpdate::single(sv);
            let expect = WireCost::paper().update(&up);
            let msg = Msg::Update { worker: 0, round: 0, update: up, loss: 0.0 };
            let (back, st) = roundtrip(&msg);
            assert_eq!(st.wire, expect);
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn broadcast_charges_aggregate_half_only() {
        let dim = 6;
        let bcast: Vec<f32> = (0..2 * dim).map(|i| i as f32 * 0.5).collect();
        let msg = Msg::Broadcast { round: 4, gagg: bcast };
        let (back, st) = roundtrip(&msg);
        assert_eq!(st.wire, 4 * dim, "only the gagg half is charged");
        assert_eq!(back, msg);
    }

    #[test]
    fn sparse_broadcast_roundtrips() {
        let up = grouped_update();
        let expect = WireCost::paper().update(&up);
        let w: Vec<f32> = (0..up.total_dim()).map(|i| (i % 7) as f32).collect();
        let msg = Msg::SparseBroadcast { round: 1, w, gagg: up };
        let (back, st) = roundtrip(&msg);
        assert_eq!(st.wire, expect, "model weights are structural, not charged");
        assert_eq!(back, msg);
    }

    #[test]
    fn header_rejects_corruption() {
        let msg = Msg::Broadcast { round: 0, gagg: vec![1.0, 2.0] };
        let (bytes, _) = encode_msg(&msg);
        let h = decode_header(&bytes[..FRAME_HEADER_BYTES]).expect("good header");
        assert_eq!(h.kind, FrameKind::Broadcast);
        assert_eq!(h.len as usize, bytes.len() - FRAME_HEADER_BYTES);
        for (at, label) in [(0, "magic"), (4, "version"), (6, "kind"), (7, "pad")] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x55;
            assert!(decode_msg(&bad).is_err(), "corrupt {label} must not decode");
        }
    }

    #[test]
    fn torn_frames_error_not_panic() {
        let mut up = grouped_update();
        let idx: Vec<u32> = up.bucket(0).indices().to_vec();
        up.payload_mut(0).rice.encode_into(&idx);
        let msg = Msg::Update { worker: 0, round: 0, update: up, loss: 0.5 };
        let (bytes, _) = encode_msg(&msg);
        // every strict prefix of the payload must fail cleanly
        for cut in FRAME_HEADER_BYTES..bytes.len() {
            let h = decode_header(&bytes[..FRAME_HEADER_BYTES]).expect("header");
            assert!(
                decode_payload(&h, &bytes[FRAME_HEADER_BYTES..cut]).is_err(),
                "cut at {cut} decoded"
            );
        }
    }

    #[test]
    fn hello_roundtrips_and_validates() {
        let hb = encode_hello(5);
        assert_eq!(decode_hello(&hb), Ok(5));
        let mut bad = hb;
        bad[0] = b'X';
        assert!(decode_hello(&bad).is_err());
        assert!(decode_hello(&hb[..6]).is_err());
    }
}
