//! `comm::codec` — the pluggable wire-codec stack (ISSUE 5 tentpole).
//!
//! Everything that turns a sparsified bucket into bytes-on-the-wire
//! lives here, as two composable axes selected per parameter group by
//! the policy keys `idx=` and `levels=` (plus the existing `bits=`
//! width knob):
//!
//! | axis    | codec    | per-entry cost                 | notes |
//! |---------|----------|--------------------------------|-------|
//! | index   | `packed` | `ceil(log2 group_len)` bits    | default; the paper's §2 accounting |
//! | index   | `raw`    | 32 bits (`u32`)                | the naive wire format (ablation) |
//! | index   | `rice`   | measured Golomb–Rice bits      | delta-sorted gaps, per-bucket Rice parameter |
//! | value   | f32      | 32 bits (or the link's width)  | default when `bits` is unset |
//! | value   | `uniform`| `bits` bits + 4 B scale/bucket | offset-binary stochastic rounding (PR 4) |
//! | value   | `nuq`    | `bits` bits + 4 B scale/bucket | NUQSGD-style exponential table, histogram-fit scale (PR 10) |
//! | value   | `fp16`   | 16 bits, no scale header       | real IEEE binary16 words (RNE encode, exact widen) |
//! | value   | `bf16`   | 16 bits, no scale header       | real bfloat16 words (RNE encode, exact widen) |
//!
//! The paper charges each transmitted entry "log J bits" for its index
//! (§2) — an information bound, not a code.  "Understanding Top-k
//! Sparsification" (arXiv 1911.08772) shows index bits dominate the
//! payload at the paper's 0.1% sparsity regime, which is exactly where
//! an entropy code beats the bound: top-k indices cluster (persistent
//! coordinates under error feedback), so the delta-gap distribution is
//! far from uniform and Golomb–Rice closes much of the gap.
//!
//! [`WireCost`] (see `cost`) is the ONE byte accountant: the ledger,
//! the sweeps, `repro comm`, the benches and the packing-must-pay
//! guard all route through it, so reported bytes are the bytes on the
//! wire by construction.  With `idx`/`levels` unset everywhere the
//! stack reproduces the PR 4 tree bit-for-bit — trajectories AND byte
//! totals (pinned by `rust/tests/codec.rs`).

mod cost;
mod frame;
mod packed;
mod rice;

pub use cost::WireCost;
pub use frame::{
    decode_header, decode_hello, decode_msg, decode_payload, encode_hello, encode_msg,
    FrameHeader, FrameKind, FrameStats, FRAME_HEADER_BYTES, FRAME_MAGIC, HELLO_BYTES,
    HELLO_MAGIC, WIRE_VERSION,
};
pub use packed::{quant_levels, LevelKind, QuantPayload};
pub use rice::RicePayload;

use crate::sparse::SparseVec;
use crate::util::rng::Rng;

/// Per-entry index cost in bits under the bit-packed code:
/// `ceil(log2 dim)` with the `dim >= 2` clamp (paper §2: "the index
/// can be losslessly represented by log J bits").  The single source
/// for every place the cost model meets the wire.
pub fn index_bits(dim: usize) -> usize {
    (usize::BITS - (dim.max(2) - 1).leading_zeros()) as usize
}

/// The index-codec axis of a group's wire stack.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IndexCodec {
    /// Bit-packed `ceil(log2 group_len)` bits per index — the paper's
    /// §2 accounting and the default (bit-identical to the PR 4 tree).
    #[default]
    Packed,
    /// Raw `u32` per index (32 bits) — the naive format, kept as an
    /// ablation endpoint so the sweep can show what packing buys.
    Raw,
    /// Delta-sorted Golomb–Rice entropy code with a per-bucket Rice
    /// parameter chosen from the gap distribution ([`RicePayload`]).
    Rice,
}

impl IndexCodec {
    pub fn name(&self) -> &'static str {
        match self {
            IndexCodec::Packed => "packed",
            IndexCodec::Raw => "raw",
            IndexCodec::Rice => "rice",
        }
    }

    /// Parse the `idx=` policy value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "packed" => Ok(IndexCodec::Packed),
            "raw" => Ok(IndexCodec::Raw),
            "rice" => Ok(IndexCodec::Rice),
            other => Err(format!("unknown index codec '{other}' (packed|raw|rice)")),
        }
    }
}

/// The per-bucket wire state a [`crate::comm::SparseUpdate`] carries:
/// which codecs actually encoded this bucket this round.  Default
/// (inactive value payload, inactive rice payload, packed indexing) is
/// the raw-f32 / `log J` wire format — exactly the PR 4 bucket.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WirePayload {
    /// packed low-bit value codes; inactive = raw f32 values
    pub value: QuantPayload,
    /// Golomb–Rice coded indices; inactive = no entropy code
    pub rice: RicePayload,
    /// raw-`u32` index accounting marker (`idx=raw`)
    pub raw_index: bool,
}

impl WirePayload {
    /// Deactivate everything, keeping buffer capacity (per-round
    /// recycling in the trainer's update buffers).
    pub fn clear(&mut self) {
        self.value.clear();
        self.rice.clear();
        self.raw_index = false;
    }

    /// Whether any codec beyond the default raw-f32/`log J` format is
    /// engaged on this bucket.
    pub fn is_default(&self) -> bool {
        !self.value.is_active() && !self.rice.is_active() && !self.raw_index
    }
}

/// The value-codec axis: a bit width plus a level family.  Stateless —
/// per-group schedule/RNG state lives with the caller (the layerwise
/// wrapper), which hands in the rounding stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ValueCodec {
    /// bits per transmitted value, 2..=16 (the packable range)
    pub bits: usize,
    /// level-table family (`levels=` policy key)
    pub levels: LevelKind,
}

impl ValueCodec {
    /// Stochastically round `bucket`'s values onto the codec's level
    /// grid, replace them with their exact dequantized counterparts,
    /// emit the packed codes + scale into `payload` and the per-entry
    /// error into `residual` (aligned with the bucket's indices, for
    /// the error-feedback fold).
    ///
    /// The packed payload is authoritative: every value written back
    /// equals `payload.decode_value(i)` bit-for-bit, so server-side
    /// decode reproduces the aggregation input exactly.  The uniform
    /// family is the PR 4 `Quantizer::quantize_bucket_into` path moved
    /// here unchanged (same float ops, same RNG draw discipline — one
    /// uniform per entry unless the bucket is all-zero); the NUQ
    /// family rounds between adjacent exponential levels
    /// `scale * 2^(q - L)` instead of the linear grid.
    pub fn encode_bucket(
        &self,
        bucket: &mut SparseVec,
        rng: &mut Rng,
        payload: &mut QuantPayload,
        residual: &mut Vec<f32>,
        codes_scratch: &mut Vec<u32>,
    ) {
        assert!((2..=16).contains(&self.bits), "packable bit width is 2..=16, got {}", self.bits);
        let levels = quant_levels(self.bits);
        let values = bucket.values_mut();
        residual.clear();
        codes_scratch.clear();
        let max = values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        match self.levels {
            LevelKind::Uniform => {
                let scale = if max == 0.0 { 1.0 } else { max / levels as f32 };
                for v in values.iter_mut() {
                    let x = (*v / scale).clamp(-(levels as f32), levels as f32);
                    let lo = x.floor();
                    let frac = x - lo;
                    let q = if max != 0.0 && (rng.uniform() as f32) < frac { lo + 1.0 } else { lo };
                    let code = (q as i64 + levels) as u32;
                    let dv = (code as i64 - levels) as f32 * scale;
                    residual.push(*v - dv);
                    codes_scratch.push(code);
                    *v = dv;
                }
                payload.encode_into(self.bits, scale, codes_scratch);
            }
            LevelKind::Nuq => {
                // NUQSGD-style grid: magnitudes {0} ∪ {scale * 2^(q-L)
                // for q in 1..=L}, stochastic rounding between adjacent
                // levels (unbiased), sign folded offset-binary exactly
                // like the uniform code space.  The scale is fit from
                // the bucket's magnitude histogram (PR 10) instead of
                // the outlier-sensitive max; entries above it clamp to
                // the top level with exactly one draw, error folded
                // into feedback like any other rounding.
                let scale = if max == 0.0 { 1.0 } else { nuq_fit_scale(values, max) };
                for v in values.iter_mut() {
                    let q_mag: i64 = if max == 0.0 {
                        0
                    } else {
                        let x = (v.abs() / scale) as f64; // in [0, 1]
                        if x <= 0.0 {
                            // keep one draw per entry: the stream
                            // position must not depend on zero values
                            let _ = rng.uniform();
                            0
                        } else {
                            let e = x.log2().floor();
                            let (qlo, lo, hi) = if e <= -(levels as f64) {
                                // below the smallest nonzero level
                                (0i64, 0.0f64, exp2i(1 - levels))
                            } else {
                                let qlo = ((levels as f64 + e) as i64).min(levels);
                                (qlo, exp2i(qlo - levels), exp2i((qlo + 1 - levels).min(0)))
                            };
                            // hi == lo at the bucket max (x == 1) and
                            // when both underflow: round down, but
                            // still draw — the stream position must
                            // not depend on the values
                            let p = if hi > lo { (x - lo) / (hi - lo) } else { 0.0 };
                            if rng.uniform() < p { (qlo + 1).min(levels) } else { qlo }
                        }
                    };
                    let q = if *v < 0.0 { -q_mag } else { q_mag };
                    let code = (q + levels) as u32;
                    let dv = LevelKind::Nuq.decode(code, self.bits, scale);
                    residual.push(*v - dv);
                    codes_scratch.push(code);
                    *v = dv;
                }
                payload.encode_with_levels(self.bits, scale, codes_scratch, LevelKind::Nuq);
            }
            LevelKind::Fp16 | LevelKind::Bf16 => {
                // true half-width wire values: deterministic RNE
                // narrowing (consumes NO rounding stream — the stream
                // position is as if the bucket were never quantized),
                // exact widening decode, narrowing error folded into
                // error feedback exactly like the stochastic families.
                debug_assert_eq!(self.bits, 16, "half-width kinds are fixed at 16 bits");
                let half = self.levels;
                if half == LevelKind::Fp16 {
                    crate::util::kernels::f32_to_f16_codes(values, codes_scratch);
                } else {
                    crate::util::kernels::f32_to_bf16_codes(values, codes_scratch);
                }
                for (v, &code) in values.iter_mut().zip(codes_scratch.iter()) {
                    let dv = half.decode(code, 16, 0.0);
                    residual.push(*v - dv);
                    *v = dv;
                }
                payload.encode_with_levels(16, 0.0, codes_scratch, half);
            }
        }
    }
}

/// Histogram-fit NUQ scale (ROADMAP codec follow-up): the smallest
/// power-of-two bin edge covering all but at most `n/16` entries —
/// instead of the max, a single outlier of which drags the whole
/// exponential table up and wastes its resolution on empty range.
/// Entries above the fitted scale clamp to the top level; their
/// (possibly large) error rides error feedback, bounded in count by
/// the 1/16 budget.  Power-of-two scales also make the level grid
/// exact under the `scale * 2^(q-L)` decode.
fn nuq_fit_scale(values: &[f32], max: f32) -> f32 {
    let mut h = [0u32; 256];
    crate::util::kernels::abs_hist(values, &mut h);
    let budget = values.len() / 16;
    let (mut above, mut b) = (0usize, 255usize);
    while b > 0 && above + h[b] as usize <= budget {
        above += h[b] as usize;
        b -= 1;
    }
    let edge = crate::util::kernels::hist_bin_edge(b);
    if edge.is_finite() {
        edge
    } else if max.is_finite() {
        // bin 127 (huge magnitudes) has no representable upper edge
        max
    } else {
        f32::MAX
    }
}

/// `2^e` as f64 for (possibly very negative) integer exponents.
fn exp2i(e: i64) -> f64 {
    (2.0f64).powi(e.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn index_bits_clamps_and_rounds_up() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(1), 1, "dim < 2 clamps");
        assert_eq!(index_bits(100), 7);
        assert_eq!(index_bits(1 << 20), 20);
        assert_eq!(index_bits((1 << 20) + 1), 21);
    }

    #[test]
    fn index_codec_parse_roundtrip() {
        for c in [IndexCodec::Packed, IndexCodec::Raw, IndexCodec::Rice] {
            assert_eq!(IndexCodec::parse(c.name()).unwrap(), c);
        }
        assert!(IndexCodec::parse("huffman").is_err());
        assert_eq!(IndexCodec::default(), IndexCodec::Packed);
    }

    #[test]
    fn wire_payload_default_is_the_pr4_bucket() {
        let p = WirePayload::default();
        assert!(p.is_default());
        assert!(!p.value.is_active());
        assert!(!p.rice.is_active());
        assert!(!p.raw_index);
    }

    #[test]
    fn uniform_encode_decodes_bit_exact() {
        check::forall("codec_uniform_decode", |rng, _| {
            let n = check::arb_len(rng, 80);
            let vals = check::arb_vec(rng, n);
            let idx: Vec<u32> = (0..n as u32).collect();
            let mut bucket = SparseVec::new(n.max(1), idx, vals.clone());
            let bits = 2 + rng.below(15);
            let vc = ValueCodec { bits, levels: LevelKind::Uniform };
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            vc.encode_bucket(&mut bucket, rng, &mut payload, &mut residual, &mut codes);
            assert_eq!(payload.level_kind(), LevelKind::Uniform);
            for i in 0..n {
                assert_eq!(payload.decode_value(i), bucket.values()[i], "bits={bits} i={i}");
                assert_eq!(residual[i], vals[i] - bucket.values()[i], "bits={bits} i={i}");
            }
        });
    }

    #[test]
    fn nuq_encode_decodes_bit_exact_and_bounds_error() {
        check::forall("codec_nuq_decode", |rng, _| {
            let n = check::arb_len(rng, 80);
            let vals = check::arb_vec(rng, n);
            let idx: Vec<u32> = (0..n as u32).collect();
            let mut bucket = SparseVec::new(n.max(1), idx, vals.clone());
            let bits = 2 + rng.below(7); // NUQ's useful range
            let vc = ValueCodec { bits, levels: LevelKind::Nuq };
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            vc.encode_bucket(&mut bucket, rng, &mut payload, &mut residual, &mut codes);
            assert_eq!(payload.level_kind(), LevelKind::Nuq);
            let scale = payload.scale();
            for i in 0..n {
                let dv = payload.decode_value(i);
                assert_eq!(dv, bucket.values()[i], "bits={bits} i={i}");
                assert_eq!(residual[i], vals[i] - dv, "bits={bits} i={i}");
                // a decoded magnitude never exceeds the fitted scale
                // and the sign survives (or the value rounded to zero)
                assert!(dv.abs() <= scale * 1.0001, "bits={bits} i={i}");
                assert!(dv == 0.0 || dv.signum() == vals[i].signum(), "bits={bits} i={i}");
                // within the fitted range, rounding moves at most one
                // grid step (no step spans more than the full scale);
                // budgeted outliers clamp, so their residual is
                // bounded by their own magnitude instead
                assert!(
                    residual[i].abs() <= scale.max(vals[i].abs()) * 1.0001,
                    "bits={bits} i={i}"
                );
            }
        });
    }

    #[test]
    fn uniform_zero_bucket_is_deterministic() {
        // the documented stream contract the resume tests rely on:
        // all-zero buckets must not consume the rounding stream
        let vc = ValueCodec { bits: 4, levels: LevelKind::Uniform };
        let mut rng = Rng::seed_from(8);
        let before = rng.state();
        let mut bucket = SparseVec::new(3, vec![0, 1, 2], vec![0.0; 3]);
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
        assert_eq!(rng.state(), before, "zero buckets must not consume the stream");
        assert_eq!(bucket.values(), &[0.0; 3]);
        assert_eq!(payload.decode(), vec![0.0; 3]);
    }

    #[test]
    fn uniform_residual_within_one_level() {
        let vc = ValueCodec { bits: 4, levels: LevelKind::Uniform };
        let mut rng = Rng::seed_from(7);
        let vals = vec![0.9f32, -0.33, 0.05, 1.0, -1.0];
        let mut bucket = SparseVec::new(5, (0..5).collect(), vals.clone());
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
        let scale = payload.scale();
        for r in &residual {
            assert!(r.abs() <= scale * 1.0001, "{r} vs scale {scale}");
        }
    }

    #[test]
    fn nuq_zero_bucket_is_deterministic() {
        let vc = ValueCodec { bits: 4, levels: LevelKind::Nuq };
        let mut rng = Rng::seed_from(8);
        let before = rng.state();
        let mut bucket = SparseVec::new(3, vec![0, 1, 2], vec![0.0; 3]);
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
        assert_eq!(rng.state(), before, "zero buckets must not consume the stream");
        assert_eq!(bucket.values(), &[0.0; 3]);
        assert_eq!(payload.decode(), vec![0.0; 3]);
    }

    #[test]
    fn half_encode_is_deterministic_and_decodes_bit_exact() {
        for levels in [LevelKind::Fp16, LevelKind::Bf16] {
            let vc = ValueCodec { bits: 16, levels };
            let mut rng = Rng::seed_from(11);
            let before = rng.state();
            let vals = vec![1.0f32, -0.333, 6.1e-5, -0.0, 65519.0, 1.0e-40];
            let n = vals.len();
            let mut bucket = SparseVec::new(n, (0..n as u32).collect(), vals.clone());
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
            assert_eq!(rng.state(), before, "half narrowing must not consume the stream");
            for i in 0..n {
                assert_eq!(payload.decode_value(i), bucket.values()[i], "{levels:?} i={i}");
                assert_eq!(residual[i], vals[i] - bucket.values()[i], "{levels:?} i={i}");
            }
            assert_eq!(payload.bits(), 16);
            assert_eq!(payload.level_kind(), levels);
            assert_eq!(payload.scale(), 0.0, "half payloads are scale-free");
        }
    }

    #[test]
    fn nuq_scale_is_histogram_fit_not_max() {
        // 32 entries: 31 at 1.0 plus one huge outlier.  The fit covers
        // the bulk (power-of-two edge 2.0) and clamps the outlier to
        // the top level, its error riding error feedback.
        let mut vals = vec![1.0f32; 32];
        vals[7] = 1000.0;
        let mut bucket = SparseVec::new(32, (0..32).collect(), vals.clone());
        let mut rng = Rng::seed_from(5);
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        ValueCodec { bits: 8, levels: LevelKind::Nuq }.encode_bucket(
            &mut bucket,
            &mut rng,
            &mut payload,
            &mut residual,
            &mut codes,
        );
        assert_eq!(payload.scale(), 2.0, "fit covers the bulk, not the outlier");
        assert_eq!(bucket.values()[7], 2.0, "outlier clamps to the top level");
        assert_eq!(residual[7], 1000.0 - 2.0);
        assert_eq!(bucket.values()[0], 1.0, "bulk lands exactly on a grid level");
        for i in 0..32 {
            assert_eq!(payload.decode_value(i), bucket.values()[i], "i={i}");
        }
    }

    #[test]
    fn nuq_is_roughly_unbiased() {
        let vc = ValueCodec { bits: 4, levels: LevelKind::Nuq };
        let mut rng = Rng::seed_from(1);
        let x = 0.37f32;
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let mut bucket = SparseVec::new(2, vec![0, 1], vec![x, 1.0]); // 1.0 sets the scale
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);
            sum += bucket.values()[0] as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - x as f64).abs() < 0.01, "{mean}");
    }

    #[test]
    fn nuq_resolves_small_values_better_than_uniform() {
        // the point of the exponential grid: a value 1000x smaller
        // than the max lands within one exponential step (< 100%
        // relative error), while the 8-bit uniform grid can only round
        // it to 0 (100% error) or a whole linear level (~690%)
        let vals = vec![1.0f32, 0.001];
        let mk = |levels| {
            let mut bucket = SparseVec::new(2, vec![0, 1], vals.clone());
            let mut rng = Rng::seed_from(3);
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            ValueCodec { bits: 8, levels }.encode_bucket(
                &mut bucket,
                &mut rng,
                &mut payload,
                &mut residual,
                &mut codes,
            );
            bucket.values()[1]
        };
        let nuq = mk(LevelKind::Nuq);
        let uni = mk(LevelKind::Uniform);
        let rel = |v: f32| (v - 0.001).abs() / 0.001;
        assert!(rel(nuq) < rel(uni), "nuq {nuq} vs uniform {uni}");
        assert!(rel(nuq) < 1.0, "{nuq}");
    }
}
