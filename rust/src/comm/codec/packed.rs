//! `QuantPayload`: the packed low-bit value payload of one quantized
//! bucket — what actually crosses the wire when a group's policy sets
//! a `bits` override.  Rehomed from `sparse/packed.rs` into the codec
//! stack (ISSUE 5); the packing itself is unchanged, but the payload
//! now carries its level family ([`LevelKind`], the `levels=` policy
//! axis) so decode can dispatch between the uniform offset-binary grid
//! and the NUQSGD-style exponential grid.
//!
//! Codes are offset-binary: a level index `q` in `[-L, +L]` (with
//! `L = 2^(bits-1) - 1`) is stored as `q + L`, which spans `[0, 2L]`
//! and always fits in `bits` bits (2 <= bits <= 16).  Codes are
//! bit-packed LSB-first into `u32` words; the shared `f32` scale
//! travels once per bucket.  Dequantization is exact and deterministic
//! — the level map reproduces the worker-side lossy values
//! bit-for-bit, so the server can aggregate from the packed payload
//! alone (pinned by `rust/tests/quantized.rs` + `rust/tests/codec.rs`).
//!
//! Wire accounting: [`QuantPayload::wire_bytes`] =
//! `ceil(n*(bits + index_bits)/8)` plus the 4-byte scale header — the
//! value-side term [`super::WireCost`] charges.  The level family
//! travels in the run manifest (it is per-group configuration, not
//! per-message data), so it adds no bytes.
//!
//! PR 10 adds the half-width float kinds [`LevelKind::Fp16`] /
//! [`LevelKind::Bf16`]: the 16-bit code IS the value (round-to-
//! nearest-even narrowing on encode, exact widening on decode — see
//! `util::kernels`), so no scale header travels and the payload
//! charges exactly 16 bits per value — the width `CostModel` has
//! modeled all along, now carried for real.  Deterministic: half
//! encodes consume no rounding stream.

/// The value level-table family (`levels=` policy key).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LevelKind {
    /// Linear grid `q * scale` for `q` in `[-L, L]` — the PR 4
    /// offset-binary format and the default (bit-identical).
    #[default]
    Uniform,
    /// NUQSGD-style exponential grid: magnitudes
    /// `{0} ∪ {scale * 2^(q - L) : q in 1..=L}` — spends the level
    /// budget logarithmically, resolving small values a uniform grid
    /// rounds to zero (arXiv 1908.06077's argument for nonuniform
    /// levels under heavy-tailed gradient magnitudes).
    Nuq,
    /// IEEE binary16 on the wire: each 16-bit code is the value
    /// itself (RNE narrowing encode, exact widening decode).  Fixed
    /// at `bits = 16`, scale-free, deterministic.
    Fp16,
    /// bfloat16 on the wire (the top half of the f32 layout): f32's
    /// full exponent range at 8 mantissa bits.  Same contract as
    /// [`LevelKind::Fp16`].
    Bf16,
}

impl LevelKind {
    pub fn name(&self) -> &'static str {
        match self {
            LevelKind::Uniform => "uniform",
            LevelKind::Nuq => "nuq",
            LevelKind::Fp16 => "fp16",
            LevelKind::Bf16 => "bf16",
        }
    }

    /// Parse the `levels=` policy value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "uniform" => Ok(LevelKind::Uniform),
            "nuq" => Ok(LevelKind::Nuq),
            "fp16" => Ok(LevelKind::Fp16),
            "bf16" => Ok(LevelKind::Bf16),
            other => Err(format!("unknown value levels '{other}' (uniform|nuq|fp16|bf16)")),
        }
    }

    /// Whether this family is a half-width float kind: fixed 16-bit
    /// codes that ARE the values — no scale header, no rounding
    /// stream, no level grid.
    pub fn is_half(&self) -> bool {
        match self {
            LevelKind::Uniform | LevelKind::Nuq => false,
            LevelKind::Fp16 | LevelKind::Bf16 => true,
        }
    }

    /// Dequantize one offset-binary `code` at `bits`/`scale` under
    /// this level family.  This is THE level map: both the encoder
    /// (writing back lossy values) and the payload decode route
    /// through it, so they cannot disagree.
    pub fn decode(&self, code: u32, bits: usize, scale: f32) -> f32 {
        match self {
            LevelKind::Uniform => {
                let levels = quant_levels(bits);
                (code as i64 - levels) as f32 * scale
            }
            LevelKind::Nuq => {
                let levels = quant_levels(bits);
                let q = code as i64 - levels;
                if q == 0 {
                    0.0
                } else {
                    let mag = scale * (2.0f32).powi((q.abs() - levels) as i32);
                    if q < 0 { -mag } else { mag }
                }
            }
            // half kinds ignore bits/scale: the code is the value
            LevelKind::Fp16 => crate::util::kernels::f16_to_f32(code as u16),
            LevelKind::Bf16 => crate::util::kernels::bf16_to_f32(code as u16),
        }
    }
}

/// Packed quantized values for one bucket.  `bits == 0` means the slot
/// is inactive (the bucket travels as raw f32, the pre-quantization
/// wire format).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantPayload {
    bits: usize,
    scale: f32,
    len: usize,
    levels: LevelKind,
    words: Vec<u32>,
}

/// Quantization levels per side for a bit width: `2^(bits-1) - 1`.
pub fn quant_levels(bits: usize) -> i64 {
    debug_assert!((2..=16).contains(&bits));
    (1i64 << (bits - 1)) - 1
}

impl QuantPayload {
    /// Whether this slot carries a packed payload.
    pub fn is_active(&self) -> bool {
        self.bits != 0
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// The level family this payload's codes decode under.
    pub fn level_kind(&self) -> LevelKind {
        self.levels
    }

    /// Number of packed codes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Deactivate, keeping the word buffer's capacity (per-round
    /// recycling in the trainer's update buffers).
    pub fn clear(&mut self) {
        self.bits = 0;
        self.scale = 0.0;
        self.len = 0;
        self.levels = LevelKind::Uniform;
        self.words.clear();
    }

    /// Pack `codes` at `bits` per code with the shared `scale` under
    /// the uniform level family, recycling the word buffer.  Every
    /// code must fit in `bits` bits.
    pub fn encode_into(&mut self, bits: usize, scale: f32, codes: &[u32]) {
        self.encode_with_levels(bits, scale, codes, LevelKind::Uniform);
    }

    /// [`Self::encode_into`] with an explicit level family.
    pub fn encode_with_levels(
        &mut self,
        bits: usize,
        scale: f32,
        codes: &[u32],
        levels: LevelKind,
    ) {
        assert!((2..=16).contains(&bits), "packable bit width is 2..=16, got {bits}");
        assert!(!levels.is_half() || bits == 16, "half-width kinds are fixed at 16 bits");
        self.bits = bits;
        self.scale = scale;
        self.len = codes.len();
        self.levels = levels;
        // chunked accumulator packer, bit-identical to the historical
        // positioned put_bits loop (pinned in rust/tests/kernels.rs)
        crate::util::kernels::pack_fixed(codes, bits, &mut self.words);
    }

    /// Extract code `i`.
    pub fn code(&self, i: usize) -> u32 {
        assert!(i < self.len, "code index {i} out of {}", self.len);
        super::rice::get_bits(&self.words, i * self.bits, self.bits)
    }

    /// Dequantize code `i` under the payload's level family.  This is
    /// exactly the f32 the worker wrote into the bucket, so
    /// server-side decode reproduces the transmitted values
    /// bit-for-bit.
    pub fn decode_value(&self, i: usize) -> f32 {
        self.levels.decode(self.code(i), self.bits, self.scale)
    }

    /// Dequantize the whole payload into a fresh vector.
    pub fn decode(&self) -> Vec<f32> {
        (0..self.len).map(|i| self.decode_value(i)).collect()
    }

    /// Wire bytes of `len` entries packed at `bits` per value with
    /// `index_bits` per index, plus the 4-byte scale header (empty
    /// payloads cost nothing).  Exposed as an associated fn so the
    /// worker can decide BEFORE packing whether quantization pays for
    /// a bucket at all (for tiny buckets the scale header can exceed
    /// the value-bit saving).
    pub fn bytes_for(len: usize, bits: usize, index_bits: usize) -> usize {
        Self::bytes_for_levels(len, bits, index_bits, LevelKind::Uniform)
    }

    /// [`Self::bytes_for`] with an explicit level family: half-width
    /// kinds carry no scale header (the 16-bit code IS the value), so
    /// they charge exactly `len * (16 + index_bits)` bits — the link
    /// value width the cost model has always advertised.
    pub fn bytes_for_levels(
        len: usize,
        bits: usize,
        index_bits: usize,
        levels: LevelKind,
    ) -> usize {
        if len == 0 {
            return 0;
        }
        let packed = (len * (bits + index_bits)).div_ceil(8);
        if levels.is_half() { packed } else { packed + 4 }
    }

    /// Wire bytes of this payload for a bucket whose index costs
    /// `index_bits` bits per entry.
    pub fn wire_bytes(&self, index_bits: usize) -> usize {
        Self::bytes_for_levels(self.len, self.bits, index_bits, self.levels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn pack_unpack_roundtrips_across_widths() {
        check::forall("quant_pack_roundtrip", |rng, _| {
            let bits = 2 + rng.below(15); // 2..=16
            let n = check::arb_len(rng, 200);
            let max_code = (1u32 << bits) - 1;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max_code as usize + 1) as u32).collect();
            let mut p = QuantPayload::default();
            p.encode_into(bits, 0.5, &codes);
            assert_eq!(p.len(), n);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(p.code(i), c, "bits={bits} i={i}");
            }
        });
    }

    #[test]
    fn decode_is_offset_binary() {
        let mut p = QuantPayload::default();
        // bits=4 -> L=7; codes 0, 7, 14 -> -7, 0, +7 levels
        p.encode_into(4, 0.25, &[0, 7, 14]);
        assert_eq!(p.decode(), vec![-7.0 * 0.25, 0.0, 7.0 * 0.25]);
        assert_eq!(p.level_kind(), LevelKind::Uniform);
    }

    #[test]
    fn nuq_decode_is_exponential() {
        let mut p = QuantPayload::default();
        // bits=4 -> L=7; codes 7 -> 0, 14 -> +scale*2^0, 13 -> +scale/2,
        // 8 -> +scale*2^-6, 0 -> -scale*2^0
        p.encode_with_levels(4, 2.0, &[7, 14, 13, 8, 0], LevelKind::Nuq);
        assert_eq!(p.level_kind(), LevelKind::Nuq);
        assert_eq!(p.decode(), vec![0.0, 2.0, 1.0, 2.0 * (0.5f32).powi(6), -2.0]);
    }

    #[test]
    fn clear_deactivates_and_recycles() {
        let mut p = QuantPayload::default();
        assert!(!p.is_active());
        p.encode_with_levels(8, 1.0, &[1, 2, 3], LevelKind::Nuq);
        assert!(p.is_active());
        let cap = p.words.capacity();
        p.clear();
        assert!(!p.is_active());
        assert_eq!(p.len(), 0);
        assert_eq!(p.level_kind(), LevelKind::Uniform, "levels reset with the slot");
        assert_eq!(p.words.capacity(), cap, "buffer capacity survives clear");
    }

    #[test]
    fn wire_bytes_packs_tight() {
        let mut p = QuantPayload::default();
        // 10 codes at 4 bits + 10 index bits each = 140 bits -> 18 B + 4 B scale
        p.encode_into(4, 1.0, &[0; 10]);
        assert_eq!(p.wire_bytes(10), 22);
        // empty payload: nothing on the wire
        p.encode_into(4, 1.0, &[]);
        assert_eq!(p.wire_bytes(10), 0);
    }

    #[test]
    fn levels_per_width() {
        assert_eq!(quant_levels(2), 1);
        assert_eq!(quant_levels(4), 7);
        assert_eq!(quant_levels(8), 127);
        assert_eq!(quant_levels(16), 32767);
    }

    #[test]
    fn level_kind_parse_roundtrip() {
        for k in [LevelKind::Uniform, LevelKind::Nuq, LevelKind::Fp16, LevelKind::Bf16] {
            assert_eq!(LevelKind::parse(k.name()).unwrap(), k);
        }
        assert!(LevelKind::parse("log").is_err());
        assert_eq!(LevelKind::default(), LevelKind::Uniform);
        assert!(!LevelKind::Uniform.is_half());
        assert!(!LevelKind::Nuq.is_half());
        assert!(LevelKind::Fp16.is_half());
        assert!(LevelKind::Bf16.is_half());
    }

    #[test]
    fn half_kinds_charge_sixteen_bits_and_no_scale_header() {
        // 10 values at 16 bits + 10 index bits = 260 bits -> 33 B, no +4
        for k in [LevelKind::Fp16, LevelKind::Bf16] {
            assert_eq!(QuantPayload::bytes_for_levels(10, 16, 10, k), 33);
            assert_eq!(QuantPayload::bytes_for_levels(0, 16, 10, k), 0);
        }
        // the uniform family at the same width still pays the header
        assert_eq!(QuantPayload::bytes_for_levels(10, 16, 10, LevelKind::Uniform), 37);
        let mut p = QuantPayload::default();
        p.encode_with_levels(16, 0.0, &[0x3C00, 0x8000], LevelKind::Fp16);
        assert_eq!(p.wire_bytes(10), (2 * 26usize).div_ceil(8));
    }

    #[test]
    fn half_decode_is_the_code_itself() {
        let mut p = QuantPayload::default();
        // fp16: 1.0, -2.0, min subnormal, -0.0
        p.encode_with_levels(16, 0.0, &[0x3C00, 0xC000, 0x0001, 0x8000], LevelKind::Fp16);
        assert_eq!(p.decode(), vec![1.0, -2.0, 2.0f32.powi(-24), -0.0]);
        // bf16: 1.0, -2.0 (top half of the f32 layout)
        p.encode_with_levels(16, 0.0, &[0x3F80, 0xC000], LevelKind::Bf16);
        assert_eq!(p.decode(), vec![1.0, -2.0]);
        assert_eq!(p.level_kind(), LevelKind::Bf16);
    }

    #[test]
    fn codes_straddling_word_boundaries() {
        // 7-bit codes hit every 32-bit boundary misalignment
        let codes: Vec<u32> = (0..64).map(|i| (i * 2 + 1) % 128).collect();
        let mut p = QuantPayload::default();
        p.encode_into(7, 2.0, &codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(p.code(i), c, "i={i}");
        }
    }
}
