//! `WireCost` — the ONE byte accountant of the wire-codec stack.
//!
//! PR 4 left two parallel accountants (`SparseUpdate::wire_bytes` with
//! a hardwired 32-bit value width, and `CostModel::bucket_bytes` with
//! the link model's width); both are folded into this struct.  Every
//! caller — the ledger, the sweeps, `repro comm`, the benches and the
//! packing-must-pay guard — routes through [`WireCost::bucket`], so
//! reported bytes are the bytes on the wire by construction: the
//! dispatch reads the SAME per-bucket payload state the encoders
//! wrote, and the accountant and the payloads can never disagree.
//!
//! With every codec at its default (raw f32 values, bit-packed `log J`
//! indices) the formulas reproduce the PR 4 accounting bit-for-bit:
//! `ceil(nnz * (value_bits + ceil(log2 dim)) / 8)` raw, and the packed
//! payload's `ceil(nnz * (bits + ceil(log2 dim)) / 8) + 4` when a
//! `bits` policy engaged (pinned by `rust/tests/codec.rs`).

use super::index_bits;
use crate::comm::update::SparseUpdate;
use crate::sparse::SparseVec;

/// Byte accountant parameterized by the link's raw value width
/// (`CostModel::value_bits`; 32 for f32, 16 models half-precision
/// links).  Construct via [`crate::comm::CostModel::wire`] for a run's
/// configured link, or [`WireCost::paper`] for the paper's fixed §2
/// format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireCost {
    /// bits per un-quantized transmitted value
    pub value_bits: usize,
}

impl WireCost {
    pub fn new(value_bits: usize) -> Self {
        assert!(value_bits > 0, "raw value bits must be positive");
        WireCost { value_bits }
    }

    /// The paper's FIXED §2 format: 32-bit f32 values (what the bench
    /// wire points and `SparseVec::wire_bytes` report, independent of
    /// any configured link model).
    pub fn paper() -> Self {
        WireCost { value_bits: 32 }
    }

    /// Bytes of a raw-f32 bucket under bit-packed `log J` indexing —
    /// the paper's §2 formula with this accountant's value width.
    pub fn raw_bucket(&self, nnz: usize, dim: usize) -> usize {
        (nnz * (self.value_bits + index_bits(dim))).div_ceil(8)
    }

    /// Bytes of a flat [`SparseVec`] upload (the pre-bucketing wire
    /// format; also the degenerate single-bucket case).
    pub fn flat(&self, sv: &SparseVec) -> usize {
        self.raw_bucket(sv.nnz(), sv.dim())
    }

    /// Bytes of bucket `g` of a bucketed update: the single dispatch
    /// point over the bucket's actual codec state.
    ///
    /// - value axis: the packed payload's own accounting when one is
    ///   active (`bits` value bits + 4-byte scale header), raw
    ///   `value_bits` otherwise;
    /// - index axis: the Rice payload's measured bytes when one is
    ///   active, 32 bits per index under `idx=raw`, bit-packed
    ///   `ceil(log2 dim)` bits otherwise.
    ///
    /// Non-Rice paths keep the PR 4 combined-ceil formulas exactly
    /// (value and index bits share one `div_ceil(8)`), so codec-unset
    /// byte totals are bit-identical to the pre-codec tree.
    pub fn bucket(&self, up: &SparseUpdate, g: usize) -> usize {
        let b = up.bucket(g);
        let quant = up.quant(g);
        if let Some(rp) = up.rice(g) {
            // entropy-coded indices travel as their own byte stream;
            // values pack separately (index_bits = 0 in the payload's
            // accounting keeps the 4-byte scale header)
            let vbytes = match quant {
                Some(q) => {
                    debug_assert_eq!(b.nnz(), q.len(), "payload/bucket entry mismatch");
                    q.wire_bytes(0)
                }
                None => (b.nnz() * self.value_bits).div_ceil(8),
            };
            return vbytes + rp.wire_bytes();
        }
        let ib = if up.raw_index(g) { 32 } else { index_bits(b.dim()) };
        match quant {
            Some(q) => {
                debug_assert_eq!(b.nnz(), q.len(), "payload/bucket entry mismatch");
                q.wire_bytes(ib)
            }
            None => (b.nnz() * (self.value_bits + ib)).div_ceil(8),
        }
    }

    /// Bytes of a whole bucketed update: each bucket pays its own
    /// codec stack.  The single-bucket degenerate case with default
    /// codecs equals [`Self::flat`] on the flattened vector.
    pub fn update(&self, up: &SparseUpdate) -> usize {
        (0..up.num_buckets()).map(|g| self.bucket(up, g)).sum()
    }

    /// Bytes of the dense broadcast g^t (no indices needed).
    pub fn broadcast(&self, dim: usize) -> usize {
        (dim * self.value_bits).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::{LevelKind, ValueCodec};
    use crate::grad::GradLayout;
    use crate::util::rng::Rng;

    #[test]
    fn raw_formula_matches_the_paper_cost() {
        let wc = WireCost::paper();
        // J=100 -> 7 index bits; 10 entries * 39 bits = 390 bits -> 49 bytes
        assert_eq!(wc.raw_bucket(10, 100), 49);
        let sv = SparseVec::new(100, (0..10).collect(), vec![1.0; 10]);
        assert_eq!(wc.flat(&sv), 49);
        assert_eq!(wc.broadcast(100), 400);
        // half-precision link halves the value term
        let wc16 = WireCost::new(16);
        // 4 * (16+20) = 144 bits = 18 bytes
        assert_eq!(wc16.raw_bucket(4, 1 << 20), 18);
    }

    #[test]
    fn default_codecs_reproduce_pr4_bucket_accounting() {
        let layout = GradLayout::from_sizes([("a".to_string(), 1024), ("b".to_string(), 1024)]);
        let mut up = SparseUpdate::zeros(&layout);
        for i in 0..4u32 {
            up.bucket_mut(0).push(i, 1.0);
            up.bucket_mut(1).push(i, 1.0);
        }
        let wc = WireCost::paper();
        // 8 entries * (32+10) bits = 336 bits -> 42 bytes
        assert_eq!(wc.update(&up), 42);
        // the flat equivalent pays 11 bits per index: 344 -> 43 bytes
        assert_eq!(wc.flat(&up.flatten()), 43);
        // single-bucket degenerate case matches the flat cost exactly
        let flat = SparseVec::new(2048, (0..8).collect(), vec![1.0; 8]);
        assert_eq!(wc.update(&SparseUpdate::single(flat.clone())), wc.flat(&flat));
    }

    #[test]
    fn quantized_bucket_charges_the_packed_payload() {
        let layout = GradLayout::from_sizes([("a".to_string(), 1024)]);
        let mut up = SparseUpdate::zeros(&layout);
        for i in 0..10u32 {
            up.bucket_mut(0).push(i * 7, 0.1 * i as f32);
        }
        let mut rng = Rng::seed_from(1);
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        let (b, p) = up.bucket_payload_mut(0);
        ValueCodec { bits: 4, levels: LevelKind::Uniform }.encode_bucket(
            b,
            &mut rng,
            &mut p.value,
            &mut residual,
            &mut codes,
        );
        let wc = WireCost::paper();
        // 10 entries * (4+10) bits = 140 -> 18 B, + 4 B scale header
        assert_eq!(wc.update(&up), 22);
        assert_eq!(wc.bucket(&up, 0), up.quant(0).unwrap().wire_bytes(10));
    }

    #[test]
    fn raw_index_marker_charges_32_bits() {
        let layout = GradLayout::from_sizes([("a".to_string(), 1024)]);
        let mut up = SparseUpdate::zeros(&layout);
        for i in 0..4u32 {
            up.bucket_mut(0).push(i, 1.0);
        }
        let wc = WireCost::paper();
        let packed = wc.update(&up); // 4 * 42 bits -> 21 bytes
        assert_eq!(packed, 21);
        up.payload_mut(0).raw_index = true;
        // 4 * (32+32) bits -> 32 bytes
        assert_eq!(wc.update(&up), 32);
    }

    #[test]
    fn rice_bucket_pays_measured_bytes() {
        let layout = GradLayout::from_sizes([("a".to_string(), 1 << 20)]);
        let mut up = SparseUpdate::zeros(&layout);
        let idx: Vec<u32> = (0..256u32).map(|i| i * 3).collect();
        for &i in &idx {
            up.bucket_mut(0).push(i, 1.0);
        }
        let wc = WireCost::paper();
        let packed = wc.update(&up); // 256 * (32+20) bits
        up.payload_mut(0).rice.encode_into(&idx);
        let riced = wc.update(&up);
        let rp = up.rice(0).unwrap();
        assert_eq!(riced, 256 * 4 + rp.wire_bytes());
        assert!(riced < packed, "clustered rice {riced} !< packed {packed}");
        // empty bucket costs nothing under every codec
        up.conform_to(&layout);
        assert_eq!(wc.update(&up), 0);
    }
}
