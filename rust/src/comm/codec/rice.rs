//! `RicePayload`: delta-sorted Golomb–Rice entropy-coded indices —
//! the `idx=rice` axis of the codec stack.
//!
//! The paper charges each transmitted entry `ceil(log2 J)` bits for
//! its index (§2) — the cost of addressing a uniformly random
//! coordinate.  Real top-k index sets are nothing like uniform: error
//! feedback keeps coordinates persistent and layer structure clusters
//! them, so the sorted-index *gap* distribution is heavily skewed
//! toward small gaps.  A Golomb–Rice code with parameter `r` spends
//! `(d >> r) + 1 + r` bits on a gap `d` — near-optimal for geometric
//! gaps when `2^r` is near the mean gap — and therefore beats the
//! `log J` bound whenever indices cluster (pinned by
//! `rust/tests/codec.rs` and measured in BENCH_PR5.json).
//!
//! Encoding: strictly-increasing indices become gaps
//! `d_0 = i_0, d_j = i_j - i_{j-1} - 1`; each gap is written as a
//! unary quotient (`d >> r` one-bits then a zero-bit) followed by the
//! `r` low remainder bits, LSB-first into `u32` words.  The per-bucket
//! parameter `r` is chosen by exact minimization of the encoded length
//! over all candidate shifts — cheap (O(32 n)) and deterministic.
//! Decode is lossless and reproduces the index list bit-for-bit.
//!
//! Wire accounting ([`RicePayload::wire_bytes`]): a 1-byte header
//! carrying `r` plus `ceil(bitlen/8)` payload bytes; empty buckets
//! cost nothing (matching the raw/packed accountants).

/// Golomb–Rice coded index payload of one bucket.  Inactive (default)
/// means the bucket keeps the bit-packed `log J` accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RicePayload {
    active: bool,
    r: u32,
    len: usize,
    bitlen: usize,
    words: Vec<u32>,
    /// gap scratch recycled across encodes (per-round hot path —
    /// zero allocation at steady state, like the packed word buffer).
    /// Deterministically refilled by every encode, so derived
    /// equality still compares logical content.
    gaps: Vec<u32>,
}

/// Append `bits` low bits of `value` at bit position `pos`, LSB-first
/// (shared with the packed value payload — ONE copy of the
/// word-straddling logic per direction in this subsystem).
pub(super) fn put_bits(words: &mut Vec<u32>, pos: usize, value: u64, bits: usize) {
    debug_assert!(bits <= 32);
    if bits == 0 {
        return; // r = 0 remainders write nothing (and must not index)
    }
    let need = (pos + bits).div_ceil(32);
    if words.len() < need {
        words.resize(need, 0);
    }
    let (w, off) = (pos / 32, pos % 32);
    words[w] |= (value << off) as u32;
    if off + bits > 32 {
        words[w + 1] |= (value >> (32 - off)) as u32;
    }
}

/// Read one bit at `pos`.
fn get_bit(words: &[u32], pos: usize) -> u32 {
    (words[pos / 32] >> (pos % 32)) & 1
}

/// Read `bits` bits at `pos`, LSB-first (shared with the packed value
/// payload).
pub(super) fn get_bits(words: &[u32], pos: usize, bits: usize) -> u32 {
    if bits == 0 {
        return 0;
    }
    let (w, off) = (pos / 32, pos % 32);
    let mut v = (words[w] >> off) as u64;
    if off + bits > 32 {
        v |= (words[w + 1] as u64) << (32 - off);
    }
    (v & ((1u64 << bits) - 1)) as u32
}

impl RicePayload {
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// The per-bucket Rice parameter chosen at encode time.
    pub fn param(&self) -> u32 {
        self.r
    }

    /// Number of encoded indices.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Encoded payload length in bits (excluding the parameter header).
    pub fn bit_len(&self) -> usize {
        self.bitlen
    }

    /// The packed bitstream words (LSB-first), for the byte-level
    /// frame emitter.
    pub(super) fn words(&self) -> &[u32] {
        &self.words
    }

    /// Deactivate, keeping the buffers' capacity.
    pub fn clear(&mut self) {
        self.active = false;
        self.r = 0;
        self.len = 0;
        self.bitlen = 0;
        self.words.clear();
        self.gaps.clear();
    }

    /// The optimal Rice parameter and resulting payload bit length for
    /// a gap sequence: exact minimization of
    /// `sum(d >> r) + n*(1 + r)` over `r` in `0..=31`.
    fn best_param(gaps: &[u32]) -> (u32, usize) {
        let n = gaps.len();
        let mut best = (0u32, usize::MAX);
        for r in 0..32u32 {
            let quot: usize = gaps.iter().map(|&d| (d >> r) as usize).sum();
            let cost = quot + n * (1 + r as usize);
            if cost < best.1 {
                best = (r, cost);
            }
            // once the remainder term alone exceeds the best cost no
            // larger r can win (quot only shrinks toward 0)
            if n * (1 + r as usize) > best.1 {
                break;
            }
        }
        best
    }

    /// Encode a strictly-increasing index list, recycling the word
    /// and gap buffers (zero allocation at steady state).  An empty
    /// list produces an active-but-empty payload that costs nothing
    /// on the wire.
    pub fn encode_into(&mut self, indices: &[u32]) {
        self.active = true;
        self.len = indices.len();
        self.words.clear();
        self.gaps.clear();
        if indices.is_empty() {
            self.r = 0;
            self.bitlen = 0;
            return;
        }
        // delta-sorted gaps: d0 = i0, dj = ij - i(j-1) - 1
        self.gaps.extend((0..indices.len()).map(|j| {
            if j == 0 { indices[0] } else { indices[j] - indices[j - 1] - 1 }
        }));
        let (r, bitlen) = Self::best_param(&self.gaps);
        self.r = r;
        self.bitlen = bitlen;
        let mut pos = 0usize;
        for &d in &self.gaps {
            let q = (d >> r) as usize;
            // unary quotient: q one-bits, then a terminating zero
            let mut left = q;
            while left > 0 {
                let chunk = left.min(32);
                put_bits(&mut self.words, pos, ((1u64 << chunk) - 1) as u64, chunk);
                pos += chunk;
                left -= chunk;
            }
            put_bits(&mut self.words, pos, 0, 1);
            pos += 1;
            // remainder: r low bits
            put_bits(&mut self.words, pos, (d & ((1u64 << r) - 1) as u32) as u64, r as usize);
            pos += r as usize;
        }
        debug_assert_eq!(pos, bitlen, "encoded length disagrees with the cost scan");
    }

    /// Decode the index list into a recycled buffer (lossless: exactly
    /// the list given to [`Self::encode_into`]).
    pub fn decode_into(&self, out: &mut Vec<u32>) {
        out.clear();
        let mut pos = 0usize;
        let mut prev: u64 = 0;
        for j in 0..self.len {
            let mut q = 0u64;
            while get_bit(&self.words, pos) == 1 {
                q += 1;
                pos += 1;
            }
            pos += 1; // terminator
            let rem = get_bits(&self.words, pos, self.r as usize) as u64;
            pos += self.r as usize;
            let d = (q << self.r) | rem;
            prev = if j == 0 { d } else { prev + d + 1 };
            out.push(prev as u32);
        }
    }

    /// Allocating variant of [`Self::decode_into`].
    pub fn decode(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.len);
        self.decode_into(&mut out);
        out
    }

    /// Wire bytes: 1-byte Rice-parameter header + the packed bitstream
    /// (empty payloads cost nothing, matching the other accountants).
    pub fn wire_bytes(&self) -> usize {
        if self.len == 0 {
            return 0;
        }
        1 + self.bitlen.div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::index_bits;
    use crate::util::check;
    use crate::util::rng::Rng;

    fn arb_indices(rng: &mut Rng, dim: usize, n: usize) -> Vec<u32> {
        let mut idx = rng.sample_indices(dim, n.min(dim));
        idx.sort_unstable();
        idx.into_iter().map(|i| i as u32).collect()
    }

    #[test]
    fn roundtrip_is_lossless() {
        check::forall("rice_roundtrip", |rng, _| {
            let dim = [1usize, 2, 17, 1000, 1 << 20][rng.below(5)];
            let n = rng.below(check::arb_len(rng, 200).min(dim) + 1);
            let idx = arb_indices(rng, dim, n);
            let mut p = RicePayload::default();
            p.encode_into(&idx);
            assert!(p.is_active());
            assert_eq!(p.len(), idx.len());
            assert_eq!(p.decode(), idx, "dim={dim} r={}", p.param());
        });
    }

    #[test]
    fn boundary_sizes_roundtrip() {
        let mut p = RicePayload::default();
        // empty
        p.encode_into(&[]);
        assert!(p.is_active() && p.is_empty());
        assert_eq!(p.wire_bytes(), 0);
        assert_eq!(p.decode(), Vec::<u32>::new());
        // single index, including the extremes
        for idx in [0u32, 1, (1 << 20) - 1] {
            p.encode_into(&[idx]);
            assert_eq!(p.decode(), vec![idx], "idx={idx}");
            assert!(p.wire_bytes() >= 2);
        }
        // dense run 0..n (gaps all zero -> ~1 bit/index at r=0)
        let dense: Vec<u32> = (0..64).collect();
        p.encode_into(&dense);
        assert_eq!(p.param(), 0);
        assert_eq!(p.decode(), dense);
        assert_eq!(p.bit_len(), 64, "zero gaps cost exactly the terminator bit");
    }

    #[test]
    fn clear_deactivates_and_recycles() {
        let mut p = RicePayload::default();
        assert!(!p.is_active());
        p.encode_into(&[3, 9, 1000]);
        assert!(p.is_active());
        let cap = p.words.capacity();
        p.clear();
        assert!(!p.is_active());
        assert_eq!(p.len(), 0);
        assert_eq!(p.words.capacity(), cap, "buffer capacity survives clear");
    }

    #[test]
    fn clustered_indices_beat_the_log_j_bound() {
        // 256 indices inside a 4096-wide window of a 2^20-dim group:
        // mean gap ~16 -> ~ (1 + 4 + eps) bits/index vs the 20-bit
        // bound the paper charges
        let mut rng = Rng::seed_from(9);
        let dim = 1 << 20;
        let mut idx: Vec<u32> =
            rng.sample_indices(4096, 256).into_iter().map(|i| i as u32).collect();
        idx.sort_unstable();
        let mut p = RicePayload::default();
        p.encode_into(&idx);
        assert_eq!(p.decode(), idx);
        let packed_bits = idx.len() * index_bits(dim);
        assert!(
            p.bit_len() + 8 < packed_bits,
            "rice {} + header vs packed {packed_bits}",
            p.bit_len()
        );
    }

    #[test]
    fn uniform_indices_stay_near_the_entropy_rate() {
        // uniformly random k-of-J: gaps are geometric with mean J/k;
        // rice spends ~log2(J/k) + 1.5 bits/index, well under log2 J
        let mut rng = Rng::seed_from(11);
        let (dim, k) = (1 << 20, 1024);
        let mut idx: Vec<u32> =
            rng.sample_indices(dim, k).into_iter().map(|i| i as u32).collect();
        idx.sort_unstable();
        let mut p = RicePayload::default();
        p.encode_into(&idx);
        assert_eq!(p.decode(), idx);
        let bits_per_idx = p.bit_len() as f64 / k as f64;
        assert!(bits_per_idx < 13.0, "{bits_per_idx}");
        assert!(bits_per_idx > 9.0, "{bits_per_idx} suspiciously small");
    }

    #[test]
    fn worst_case_single_huge_gap_still_decodes() {
        // one index at the far end: the cost scan picks a large r so
        // the unary part stays bounded
        let mut p = RicePayload::default();
        p.encode_into(&[u32::MAX - 1]);
        assert_eq!(p.decode(), vec![u32::MAX - 1]);
        assert!(p.wire_bytes() <= 6, "{} bytes", p.wire_bytes());
    }
}
