//! Declarative flag parser for the `repro` binary and the examples.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; generates `--help` text from declarations.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

/// One declared flag.
struct FlagDef {
    name: &'static str,
    default: Option<String>,
    help: &'static str,
    boolean: bool,
}

/// Declarative CLI: declare flags, then parse `std::env::args`.
pub struct Cli {
    about: &'static str,
    flags: Vec<FlagDef>,
    values: BTreeMap<&'static str, String>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Cli { about, flags: Vec::new(), values: BTreeMap::new(), positional: Vec::new() }
    }

    /// Declare a value flag with a default.
    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagDef { name, default: Some(default.to_string()), help, boolean: false });
        self
    }

    /// Declare a required value flag (no default).
    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagDef { name, default: None, help, boolean: false });
        self
    }

    /// Declare a boolean switch (default false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagDef { name, default: Some("false".to_string()), help, boolean: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nFlags:\n", self.about);
        for f in &self.flags {
            let d = match &f.default {
                Some(d) if f.boolean => format!(" [switch, default {d}]"),
                Some(d) => format!(" [default: {d}]"),
                None => " [required]".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse an explicit argv (no program name). Returns Err(help) on
    /// `--help` or malformed input.
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, args: I) -> Result<Parsed, String> {
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = arg.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let def = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                let val = if def.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    }
                };
                self.values.insert(def.name, val);
            } else {
                self.positional.push(arg);
            }
        }
        // fill defaults / check required
        let mut out = BTreeMap::new();
        let mut explicit = std::collections::BTreeSet::new();
        for f in &self.flags {
            match self.values.get(f.name) {
                Some(v) => {
                    out.insert(f.name, v.clone());
                    explicit.insert(f.name.to_string());
                }
                None => match &f.default {
                    Some(d) => {
                        out.insert(f.name, d.clone());
                    }
                    None => return Err(format!("missing required --{}\n\n{}", f.name, self.usage())),
                },
            }
        }
        Ok(Parsed { values: out, explicit, positional: self.positional })
    }

    /// Parse the process args (skipping argv[0]); print help and exit on error.
    pub fn parse(self) -> Parsed {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

/// Parsed flag values with typed getters (panic on type error — flags
/// are developer-declared, so a bad parse is a bug in the caller).
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<&'static str, String>,
    /// flags the user actually passed (vs. filled-in defaults)
    explicit: std::collections::BTreeSet<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }

    /// Whether the user passed `--name` explicitly (false = the value
    /// came from the declared default).  Lets override-style commands
    /// distinguish "tweak this one parameter" from "leave config as is".
    pub fn provided(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }
    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{}'", self.get(name)))
    }
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number, got '{}'", self.get(name)))
    }
    pub fn get_f32(&self, name: &str) -> f32 {
        self.get_f64(name) as f32
    }
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }
    /// Comma-separated list of numbers, e.g. `--s 0.4,0.5,0.6`.
    pub fn get_f64_list(&self, name: &str) -> Vec<f64> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad number '{s}'"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    fn cli() -> Cli {
        Cli::new("test")
            .flag("iters", "100", "iterations")
            .flag("eta", "0.01", "learning rate")
            .switch("verbose", "chatty")
            .required("name", "run name")
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cli().parse_from(argv("--name run1 --iters 5")).unwrap();
        assert_eq!(p.get_usize("iters"), 5);
        assert_eq!(p.get_f64("eta"), 0.01);
        assert!(!p.get_bool("verbose"));
        assert!(p.provided("iters"));
        assert!(!p.provided("eta"), "default fill is not 'provided'");
    }

    #[test]
    fn equals_syntax_and_switch() {
        let p = cli().parse_from(argv("--name=x --eta=0.5 --verbose")).unwrap();
        assert_eq!(p.get_f64("eta"), 0.5);
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(cli().parse_from(argv("--iters 5")).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        assert!(cli().parse_from(argv("--name x --bogus 1")).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = cli().parse_from(argv("fig2 --name x")).unwrap();
        assert_eq!(p.positional, vec!["fig2".to_string()]);
    }

    #[test]
    fn list_parsing() {
        let p = Cli::new("t")
            .flag("s", "0.4,0.5,0.6", "sparsities")
            .parse_from(argv(""))
            .unwrap();
        assert_eq!(p.get_f64_list("s"), vec![0.4, 0.5, 0.6]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cli().parse_from(argv("--help")).unwrap_err();
        assert!(err.contains("--iters"));
    }
}
