//! Deterministic PRNG substrate: SplitMix64 seeding + Xoshiro256++ core
//! with uniform / Gaussian / permutation sampling.
//!
//! Every stochastic component in the repo (dataset generators, batch
//! samplers, RandK sparsifier, property tests) draws from this module,
//! so a run is bit-reproducible from its config seed (invariant #6 in
//! DESIGN.md).  Gaussian variates use Box–Muller on 53-bit uniforms —
//! exactness is irrelevant, determinism is what matters.

#![forbid(unsafe_code)]

/// SplitMix64: used to expand a `u64` seed into Xoshiro state (the
/// construction recommended by the Xoshiro authors).
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed from a single u64 (SplitMix64-expanded).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Snapshot the full generator state (checkpoint/resume: a
    /// restored stream continues exactly where the original left off).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from a [`Self::state`] snapshot.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Rng { s, gauss_spare }
    }

    /// Derive an independent stream for a sub-component (worker id,
    /// epoch, ...) without correlating with the parent stream.
    pub fn derive(&self, stream: u64) -> Self {
        let mut sm = SplitMix64(self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire rejection-free approximation is
    /// overkill here; modulo bias is < 2^-32 for our n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (sin, cos) = (std::f64::consts::TAU * u2).sin_cos();
        self.gauss_spare = Some(r * sin);
        r * cos
    }

    /// N(mean, std^2) as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f64, std: f64) -> f32 {
        (mean + std * self.gaussian()) as f32
    }

    /// Fill a vector with i.i.d. N(0, scale^2) f32s.
    pub fn gaussian_vec(&mut self, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(0.0, scale)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn derive_is_independent_of_parent_consumption() {
        let parent = Rng::seed_from(7);
        let mut c1 = parent.derive(3);
        let mut parent2 = Rng::seed_from(7);
        parent2.next_u64();
        // derive() reads only the seed state captured at construction
        let mut c2 = parent.derive(3);
        assert_eq!(c1.next_u64(), c2.next_u64());
        let _ = parent2;
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(9);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from(1234);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::seed_from(8);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::seed_from(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
