//! Chunked, autovectorization-friendly kernels for the per-round hot
//! paths (ISSUE 10 tentpole), on stable Rust: fixed-width lane blocks
//! over `chunks_exact`, no `std::simd`, no unsafe.
//!
//! Every kernel comes in two forms:
//!
//! - the **chunked** form (`abs_hist`, `boundary_collect`, ...): the
//!   production entry point, written so the loop body is branch-light
//!   and lane-shaped ([`LANES`]-wide blocks) and the compiler's
//!   autovectorizer can do the rest;
//! - a **scalar referee** (`*_ref`): the obviously-correct
//!   element-at-a-time loop.
//!
//! The contract between the two is **bit-identity**, not approximate
//! equality: for every input — including NaN, infinities, `-0.0`,
//! denormals, and misaligned tail lengths — the chunked kernel must
//! produce exactly the referee's output (`rust/tests/kernels.rs`
//! property-tests this at sizes 0, 1, LANES±1 and large, and
//! `benches/kernels.rs` re-asserts it on every timed point).  That is
//! what lets the sharded select engine, the merge and the codec ride
//! these kernels without disturbing any trajectory pin in the repo.
//!
//! Adding a kernel: write the referee first, write the chunked form
//! so every float op happens in the same order per element (vectorize
//! ACROSS independent elements, never reassociate within one), then
//! pin the pair in `rust/tests/kernels.rs` and add a throughput point
//! to `benches/kernels.rs`.
//!
//! Float bit-twiddling (`to_bits`/`from_bits` masks and the
//! [`mag_bits`] order trick) is confined to this file plus
//! `sparse/topk.rs` by the `bit-kernels-outside-kernels` analyzer
//! rule, so there is exactly one place such tricks can drift.

#![forbid(unsafe_code)]

/// Lane-block width of the chunked kernels.  8 f32 lanes = one AVX2
/// register (or two NEON registers); wide enough to expose ILP even
/// when the target autovectorizes at 4.
pub const LANES: usize = 8;

/// Block length of the fused fill+histogram pass: 4096 f32 = 16 KiB,
/// so the freshly-filled block is still in L1 when it is histogrammed.
pub const FUSE_BLOCK: usize = 4096;

/// Magnitude as order-preserving u32 bits (IEEE-754 non-negative
/// floats compare like their bit patterns); NaN maps to 0 (never
/// preferred).  THE shared bucketing map of the selection paths —
/// `sparse/topk.rs` re-exports it so the serial radix path, the
/// sharded engine and these kernels cannot disagree.
#[inline]
pub fn mag_bits(v: f32) -> u32 {
    let m = v.abs();
    if m.is_nan() {
        0
    } else {
        m.to_bits()
    }
}

// ---------------------------------------------------------------------
// histogram
// ---------------------------------------------------------------------

/// Accumulate the 256-bucket histogram of the magnitude high byte of
/// `x` into `h` (adds; the caller zeroes).  Four interleaved
/// sub-histograms break the store-to-load dependency a single counter
/// array serializes on; the per-lane `mag_bits` computation
/// vectorizes.
pub fn abs_hist(x: &[f32], h: &mut [u32; 256]) {
    let mut sub = [[0u32; 256]; 4];
    let mut chunks = x.chunks_exact(LANES);
    for c in chunks.by_ref() {
        let mut bucket = [0usize; LANES];
        for (b, &v) in bucket.iter_mut().zip(c) {
            *b = (mag_bits(v) >> 24) as usize;
        }
        for (lane, &b) in bucket.iter().enumerate() {
            sub[lane & 3][b] += 1;
        }
    }
    for &v in chunks.remainder() {
        sub[0][(mag_bits(v) >> 24) as usize] += 1;
    }
    for (i, dst) in h.iter_mut().enumerate() {
        *dst += sub[0][i] + sub[1][i] + sub[2][i] + sub[3][i];
    }
}

/// Scalar referee of [`abs_hist`].
pub fn abs_hist_ref(x: &[f32], h: &mut [u32; 256]) {
    for &v in x {
        h[(mag_bits(v) >> 24) as usize] += 1;
    }
}

/// Upper edge of [`abs_hist`] bin `b`: the smallest magnitude landing
/// in bin `b + 1`, always an exact power of two.  Finite magnitudes
/// occupy bins 0..=127 (the top byte of the magnitude bits is
/// sign-free), and bin 127 — which also holds the infinities — has no
/// representable upper edge, so `b >= 127` returns +inf.
pub fn hist_bin_edge(b: usize) -> f32 {
    if b >= 127 {
        f32::INFINITY
    } else {
        f32::from_bits(((b as u32) + 1) << 24)
    }
}

/// Fused fill + histogram over one shard: `fill(lo + off, block)`
/// writes the scores for the global range the block covers, and the
/// same block is histogrammed while still hot in L1 ([`FUSE_BLOCK`]
/// granularity).  `h` is overwritten.
///
/// `fill` MUST be position-pure — writing element `lo + i` must
/// depend only on `lo + i`, never on how the range is blocked —
/// because it is invoked once per block, on consecutive sub-ranges.
/// That is already the sharded engine's closure contract (shard
/// boundaries are arbitrary); this merely blocks finer.
pub fn fill_abs_hist<F: FnMut(usize, &mut [f32])>(
    lo: usize,
    dst: &mut [f32],
    h: &mut [u32; 256],
    mut fill: F,
) {
    h.fill(0);
    let mut off = 0usize;
    while off < dst.len() {
        let end = (off + FUSE_BLOCK).min(dst.len());
        let block = &mut dst[off..end];
        fill(lo + off, block);
        abs_hist(block, h);
        off = end;
    }
}

/// Scalar referee of [`fill_abs_hist`]: one fill call over the whole
/// slice, then the scalar histogram.
pub fn fill_abs_hist_ref<F: FnMut(usize, &mut [f32])>(
    lo: usize,
    dst: &mut [f32],
    h: &mut [u32; 256],
    mut fill: F,
) {
    h.fill(0);
    fill(lo, dst);
    abs_hist_ref(dst, h);
}

// ---------------------------------------------------------------------
// boundary scan / collect (pass 2 of the radix select)
// ---------------------------------------------------------------------

#[inline]
fn classify(
    m: u32,
    v: f32,
    i: u32,
    b: usize,
    hi_floor: u64,
    winners: &mut Vec<u32>,
    cand_idx: &mut Vec<u32>,
    cand_val: &mut Vec<f32>,
) {
    if (m as u64) >= hi_floor {
        winners.push(i);
    } else if (m >> 24) as usize == b {
        cand_idx.push(i);
        cand_val.push(v);
    }
}

/// Pass-2 collect of the radix select: append global indices
/// (`base + offset`) of entries strictly above the boundary bucket to
/// `winners`, and boundary-bucket (`b`) candidates to
/// `cand_idx`/`cand_val`.  `hi_floor` is `((b as u64) + 1) << 24`
/// (u64 so bucket 255 cannot overflow).  Appends in ascending index
/// order — the tie-break the sort oracle relies on.  The magnitude
/// computation runs a lane block ahead of the (inherently branchy)
/// pushes.
pub fn boundary_collect(
    base: u32,
    x: &[f32],
    b: usize,
    hi_floor: u64,
    winners: &mut Vec<u32>,
    cand_idx: &mut Vec<u32>,
    cand_val: &mut Vec<f32>,
) {
    let mut chunks = x.chunks_exact(LANES);
    let mut off = 0u32;
    for c in chunks.by_ref() {
        let mut mags = [0u32; LANES];
        for (m, &v) in mags.iter_mut().zip(c) {
            *m = mag_bits(v);
        }
        for (lane, (&m, &v)) in mags.iter().zip(c).enumerate() {
            classify(m, v, base + off + lane as u32, b, hi_floor, winners, cand_idx, cand_val);
        }
        off += LANES as u32;
    }
    for (lane, &v) in chunks.remainder().iter().enumerate() {
        let i = base + off + lane as u32;
        classify(mag_bits(v), v, i, b, hi_floor, winners, cand_idx, cand_val);
    }
}

/// Scalar referee of [`boundary_collect`].
pub fn boundary_collect_ref(
    base: u32,
    x: &[f32],
    b: usize,
    hi_floor: u64,
    winners: &mut Vec<u32>,
    cand_idx: &mut Vec<u32>,
    cand_val: &mut Vec<f32>,
) {
    for (off, &v) in x.iter().enumerate() {
        classify(mag_bits(v), v, base + off as u32, b, hi_floor, winners, cand_idx, cand_val);
    }
}

// ---------------------------------------------------------------------
// merge: scatter-add / scaled copy
// ---------------------------------------------------------------------

/// `out[idx[j]] += c * val[j]` for every entry, in entry order (so
/// the result is bit-identical to the scalar loop even with repeated
/// indices).  Random stores cannot vectorize, but the 4-wide unroll
/// keeps the address computation and multiply off the store's
/// critical path.
pub fn scatter_add(out: &mut [f32], idx: &[u32], val: &[f32], c: f32) {
    assert_eq!(idx.len(), val.len(), "scatter_add: index/value length mismatch");
    let mut ic = idx.chunks_exact(4);
    let mut vc = val.chunks_exact(4);
    for (i4, v4) in ic.by_ref().zip(vc.by_ref()) {
        out[i4[0] as usize] += c * v4[0];
        out[i4[1] as usize] += c * v4[1];
        out[i4[2] as usize] += c * v4[2];
        out[i4[3] as usize] += c * v4[3];
    }
    for (&i, &v) in ic.remainder().iter().zip(vc.remainder()) {
        out[i as usize] += c * v;
    }
}

/// Scalar referee of [`scatter_add`].
pub fn scatter_add_ref(out: &mut [f32], idx: &[u32], val: &[f32], c: f32) {
    assert_eq!(idx.len(), val.len(), "scatter_add_ref: index/value length mismatch");
    for (&i, &v) in idx.iter().zip(val) {
        out[i as usize] += c * v;
    }
}

/// `out[idx[j]] = val[j]` for every entry, in entry order — the
/// dense-mirror refresh behind the sparse aggregate (assignment, so
/// later duplicates win exactly as in the scalar loop).
pub fn scatter_assign(out: &mut [f32], idx: &[u32], val: &[f32]) {
    assert_eq!(idx.len(), val.len(), "scatter_assign: index/value length mismatch");
    let mut ic = idx.chunks_exact(4);
    let mut vc = val.chunks_exact(4);
    for (i4, v4) in ic.by_ref().zip(vc.by_ref()) {
        out[i4[0] as usize] = v4[0];
        out[i4[1] as usize] = v4[1];
        out[i4[2] as usize] = v4[2];
        out[i4[3] as usize] = v4[3];
    }
    for (&i, &v) in ic.remainder().iter().zip(vc.remainder()) {
        out[i as usize] = v;
    }
}

/// Scalar referee of [`scatter_assign`].
pub fn scatter_assign_ref(out: &mut [f32], idx: &[u32], val: &[f32]) {
    assert_eq!(idx.len(), val.len(), "scatter_assign_ref: index/value length mismatch");
    for (&i, &v) in idx.iter().zip(val) {
        out[i as usize] = v;
    }
}

/// `dst[j] = c * src[j]` — the bulk scaled copy behind the
/// single-contributor fast path of the sparse merge.
pub fn scale_into(dst: &mut [f32], src: &[f32], c: f32) {
    assert_eq!(dst.len(), src.len(), "scale_into: length mismatch");
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (d8, s8) in dc.by_ref().zip(sc.by_ref()) {
        for (d, &s) in d8.iter_mut().zip(s8) {
            *d = c * s;
        }
    }
    for (d, &s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *d = c * s;
    }
}

/// Scalar referee of [`scale_into`].
pub fn scale_into_ref(dst: &mut [f32], src: &[f32], c: f32) {
    assert_eq!(dst.len(), src.len(), "scale_into_ref: length mismatch");
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = c * s;
    }
}

// ---------------------------------------------------------------------
// fixed-width bit pack / unpack (LSB-first, the codec word layout)
// ---------------------------------------------------------------------

#[inline]
fn width_mask(bits: usize) -> u64 {
    if bits == 32 {
        u32::MAX as u64
    } else {
        (1u64 << bits) - 1
    }
}

/// Pack `codes` at `bits` per code, LSB-first into `u32` words —
/// exactly the layout the codec stack's positioned `put_bits` loop
/// produces (`words.len() == (codes.len() * bits).div_ceil(32)`,
/// trailing bits zero), via a single u64 accumulator instead of one
/// read-modify-write per code.  Every code must fit in `bits` bits.
pub fn pack_fixed(codes: &[u32], bits: usize, words: &mut Vec<u32>) {
    assert!((1..=32).contains(&bits), "packable width is 1..=32, got {bits}");
    words.clear();
    words.resize((codes.len() * bits).div_ceil(32), 0);
    let mask = width_mask(bits);
    let mut acc = 0u64;
    let mut nbits = 0usize;
    let mut w = 0usize;
    for &code in codes {
        debug_assert_eq!(code as u64 & mask, code as u64, "code {code} exceeds {bits} bits");
        acc |= (code as u64 & mask) << nbits;
        nbits += bits;
        if nbits >= 32 {
            words[w] = acc as u32;
            w += 1;
            acc >>= 32;
            nbits -= 32;
        }
    }
    if nbits > 0 {
        words[w] = acc as u32;
    }
}

/// Scalar referee of [`pack_fixed`]: one positioned word-straddling
/// write per code (the historical codec loop).
pub fn pack_fixed_ref(codes: &[u32], bits: usize, words: &mut Vec<u32>) {
    assert!((1..=32).contains(&bits), "packable width is 1..=32, got {bits}");
    words.clear();
    words.resize((codes.len() * bits).div_ceil(32), 0);
    for (i, &code) in codes.iter().enumerate() {
        let pos = i * bits;
        let (w, off) = (pos / 32, pos % 32);
        words[w] |= ((code as u64) << off) as u32;
        if off + bits > 32 {
            words[w + 1] |= ((code as u64) >> (32 - off)) as u32;
        }
    }
}

/// Unpack `len` codes of `bits` each from LSB-first `words` into
/// `out` (cleared first) — the inverse of [`pack_fixed`].
pub fn unpack_fixed(words: &[u32], bits: usize, len: usize, out: &mut Vec<u32>) {
    assert!((1..=32).contains(&bits), "packable width is 1..=32, got {bits}");
    assert!(len * bits <= words.len() * 32, "unpack_fixed: {len} codes of {bits}b overrun");
    out.clear();
    out.reserve(len);
    let mask = width_mask(bits);
    let mut acc = 0u64;
    let mut nbits = 0usize;
    let mut w = 0usize;
    for _ in 0..len {
        if nbits < bits {
            acc |= (words[w] as u64) << nbits;
            w += 1;
            nbits += 32;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        nbits -= bits;
    }
}

/// Scalar referee of [`unpack_fixed`]: one positioned read per code.
pub fn unpack_fixed_ref(words: &[u32], bits: usize, len: usize, out: &mut Vec<u32>) {
    assert!((1..=32).contains(&bits), "packable width is 1..=32, got {bits}");
    assert!(len * bits <= words.len() * 32, "unpack_fixed_ref: {len} codes of {bits}b overrun");
    out.clear();
    out.reserve(len);
    let mask = width_mask(bits);
    for i in 0..len {
        let pos = i * bits;
        let (w, off) = (pos / 32, pos % 32);
        let mut v = (words[w] >> off) as u64;
        if off + bits > 32 {
            v |= (words[w + 1] as u64) << (32 - off);
        }
        out.push((v & mask) as u32);
    }
}

// ---------------------------------------------------------------------
// f32 <-> bf16 / f16 (round-to-nearest-even encode, exact widen)
// ---------------------------------------------------------------------

/// Round-to-nearest-even f32 → bf16 (top 16 bits of the f32 layout).
/// NaNs keep their high payload bits and are quieted (the narrowed
/// value must stay a NaN); overflow past the largest finite bf16
/// rounds to the signed infinity, exactly as hardware RNE does.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let b = x.to_bits();
    if x.is_nan() {
        return ((b >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((b >> 16) & 1);
    ((b + round) >> 16) as u16
}

/// Exact bf16 → f32 widening (bf16 is the f32 prefix: shift only).
#[inline]
pub fn bf16_to_f32(u: u16) -> f32 {
    f32::from_bits((u as u32) << 16)
}

/// Shift `v` right by `s` (1..=31) rounding to nearest, ties to even.
#[inline]
fn rne_shift(v: u32, s: u32) -> u32 {
    let down = v >> s;
    let rem = v & ((1u32 << s) - 1);
    let half = 1u32 << (s - 1);
    if rem > half || (rem == half && down & 1 == 1) {
        down + 1
    } else {
        down
    }
}

/// Round-to-nearest-even f32 → IEEE binary16.  Handles the full
/// range: quiet-NaN passthrough (top 10 payload bits), infinities,
/// overflow-to-inf at ±65520, the normal range, gradual underflow to
/// f16 subnormals, and underflow-to-signed-zero below 2^-25.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let abs = b & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // inf or NaN; NaN keeps its top payload bits and is quieted
        return if abs > 0x7F80_0000 {
            sign | 0x7E00 | ((abs >> 13) & 0x03FF) as u16
        } else {
            sign | 0x7C00
        };
    }
    let e = (abs >> 23) as i32 - 127;
    if e > 15 {
        return sign | 0x7C00; // above the f16 range entirely
    }
    if e < -25 {
        return sign; // rounds to signed zero
    }
    if e < -14 {
        // subnormal result: value = mant * 2^(e-23), f16 unit 2^-24
        let mant = 0x0080_0000 | (abs & 0x007F_FFFF);
        return sign | rne_shift(mant, (-(e + 1)) as u32) as u16;
    }
    // normal: 10-bit mantissa by RNE on the low 13 bits; a mantissa
    // carry rolls into the exponent (and e == 15 overflow lands on
    // the infinity encoding 0x7C00 by the same carry)
    let r = (((e + 15) as u32) << 10) + rne_shift(abs & 0x007F_FFFF, 13);
    sign | r as u16
}

/// Exact IEEE binary16 → f32 widening (subnormals included).
#[inline]
pub fn f16_to_f32(u: u16) -> f32 {
    let sign = ((u as u32) & 0x8000) << 16;
    let exp = (u >> 10) & 0x1F;
    let man = (u & 0x03FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0.0
        }
        // subnormal: man * 2^-24, exact in f32
        let v = man as f32 * f32::from_bits(0x3380_0000);
        return if sign != 0 { -v } else { v };
    }
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp as u32 + 112) << 23) | (man << 13))
}

macro_rules! encode_codes {
    ($name:ident, $ref_name:ident, $scalar:ident, $doc:literal) => {
        #[doc = $doc]
        pub fn $name(src: &[f32], out: &mut Vec<u32>) {
            out.clear();
            out.reserve(src.len());
            let mut chunks = src.chunks_exact(LANES);
            for c in chunks.by_ref() {
                let mut lane = [0u32; LANES];
                for (l, &v) in lane.iter_mut().zip(c) {
                    *l = $scalar(v) as u32;
                }
                out.extend_from_slice(&lane);
            }
            for &v in chunks.remainder() {
                out.push($scalar(v) as u32);
            }
        }

        #[doc = concat!("Scalar referee of [`", stringify!($name), "`].")]
        pub fn $ref_name(src: &[f32], out: &mut Vec<u32>) {
            out.clear();
            out.extend(src.iter().map(|&v| $scalar(v) as u32));
        }
    };
}

macro_rules! decode_codes {
    ($name:ident, $ref_name:ident, $scalar:ident, $doc:literal) => {
        #[doc = $doc]
        pub fn $name(codes: &[u32], out: &mut Vec<f32>) {
            out.clear();
            out.reserve(codes.len());
            let mut chunks = codes.chunks_exact(LANES);
            for c in chunks.by_ref() {
                let mut lane = [0f32; LANES];
                for (l, &u) in lane.iter_mut().zip(c) {
                    *l = $scalar(u as u16);
                }
                out.extend_from_slice(&lane);
            }
            for &u in chunks.remainder() {
                out.push($scalar(u as u16));
            }
        }

        #[doc = concat!("Scalar referee of [`", stringify!($name), "`].")]
        pub fn $ref_name(codes: &[u32], out: &mut Vec<f32>) {
            out.clear();
            out.extend(codes.iter().map(|&u| $scalar(u as u16)));
        }
    };
}

encode_codes!(
    f32_to_bf16_codes,
    f32_to_bf16_codes_ref,
    f32_to_bf16,
    "Chunked slice form of [`f32_to_bf16`]: each code is the 16-bit \
     bf16 word, widened to `u32` for the codec's packing stage."
);
encode_codes!(
    f32_to_f16_codes,
    f32_to_f16_codes_ref,
    f32_to_f16,
    "Chunked slice form of [`f32_to_f16`]: each code is the 16-bit \
     binary16 word, widened to `u32` for the codec's packing stage."
);
decode_codes!(
    bf16_to_f32_slice,
    bf16_to_f32_slice_ref,
    bf16_to_f32,
    "Chunked slice form of [`bf16_to_f32`] over 16-bit codes in `u32`."
);
decode_codes!(
    f16_to_f32_slice,
    f16_to_f32_slice_ref,
    f16_to_f32,
    "Chunked slice form of [`f16_to_f32`] over 16-bit codes in `u32`."
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_matches_referee_across_tails() {
        for n in [0usize, 1, LANES - 1, LANES, LANES + 1, 1000] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 - 3.5) * 1.7).collect();
            let (mut a, mut b) = ([0u32; 256], [0u32; 256]);
            abs_hist(&x, &mut a);
            abs_hist_ref(&x, &mut b);
            assert_eq!(a, b, "n={n}");
            assert_eq!(a.iter().sum::<u32>() as usize, n);
        }
    }

    #[test]
    fn pack_unpack_roundtrip_matches_referee() {
        for bits in [1usize, 5, 16, 31, 32] {
            let mask = width_mask(bits);
            let codes: Vec<u32> =
                (0..67u64).map(|i| ((i.wrapping_mul(0x9E37_79B9) ) & mask) as u32).collect();
            let (mut w1, mut w2) = (Vec::new(), Vec::new());
            pack_fixed(&codes, bits, &mut w1);
            pack_fixed_ref(&codes, bits, &mut w2);
            assert_eq!(w1, w2, "bits={bits}");
            let (mut o1, mut o2) = (Vec::new(), Vec::new());
            unpack_fixed(&w1, bits, codes.len(), &mut o1);
            unpack_fixed_ref(&w1, bits, codes.len(), &mut o2);
            assert_eq!(o1, codes, "bits={bits}");
            assert_eq!(o2, codes, "bits={bits}");
        }
    }

    #[test]
    fn bf16_golden_values() {
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        assert_eq!(f32_to_bf16(-2.0), 0xC000);
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        assert_eq!(bf16_to_f32(0x3F80), 1.0);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_golden_values() {
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF, "max finite f16");
        assert_eq!(f32_to_f16(65520.0), 0x7C00, "ties up to inf");
        assert_eq!(f32_to_f16(65519.9), 0x7BFF, "below the tie stays finite");
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001, "min subnormal");
        assert_eq!(f32_to_f16(2.0f32.powi(-26)), 0x0000, "underflow to zero");
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_to_f32(0xFC00), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }
}
