//! Seeded property-test driver (a shrinking-free proptest-alike).
//!
//! Runs a property over `n` random cases drawn from a deterministic
//! seed; on failure it reports the case index and seed so the exact
//! case replays.  Used by the invariant suites in `sparse`, `sparsify`,
//! `grad` and `comm` (DESIGN.md §6).

#![forbid(unsafe_code)]

use crate::util::rng::Rng;

/// Number of cases per property (kept moderate; the suites cover many
/// properties).  Override with env `REGTOPK_PROP_CASES`.
pub fn default_cases() -> usize {
    std::env::var("REGTOPK_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `default_cases()` random cases.  `prop` gets a
/// per-case RNG and the case index; it should panic/assert on failure.
pub fn forall<F: FnMut(&mut Rng, usize)>(name: &str, mut prop: F) {
    let seed = 0xC0FFEE ^ fxhash(name);
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Rng::seed_from(seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15)));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector length biased toward small + boundary sizes.
pub fn arb_len(rng: &mut Rng, max: usize) -> usize {
    match rng.below(10) {
        0 => 1,
        1 => 2,
        2 => rng.below(8) + 1,
        _ => rng.below(max.max(2) - 1) + 1,
    }
}

/// Random f32 vector with occasional adversarial values (zeros, huge,
/// tiny, exact duplicates).
pub fn arb_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mode = rng.below(5);
    let mut v: Vec<f32> = (0..len)
        .map(|_| match mode {
            0 => rng.normal_f32(0.0, 1.0),
            1 => rng.normal_f32(0.0, 1e4),
            2 => rng.normal_f32(0.0, 1e-4),
            _ => rng.normal_f32(0.0, 1.0),
        })
        .collect();
    // sprinkle zeros and duplicates
    if len > 2 && mode == 3 {
        for _ in 0..(len / 4).max(1) {
            let i = rng.below(len);
            v[i] = 0.0;
        }
    }
    if len > 2 && mode == 4 {
        let src = rng.below(len);
        for _ in 0..(len / 4).max(1) {
            let dst = rng.below(len);
            v[dst] = v[src];
        }
    }
    v
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counter", |_rng, _case| {
            count += 1;
        });
        assert_eq!(count, default_cases());
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall("fails", |rng, _case| {
            assert!(rng.uniform() < 0.5, "expected failure");
        });
    }

    #[test]
    fn arb_vec_has_requested_length() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..50 {
            let n = arb_len(&mut rng, 100);
            assert_eq!(arb_vec(&mut rng, n).len(), n);
        }
    }
}
