//! In-tree substrates replacing unavailable third-party crates.
//!
//! This workspace builds fully offline: the only external dependency is
//! the `xla` PJRT binding.  Everything a framework normally pulls from
//! crates.io is implemented (and tested) here:
//!
//! - [`rng`]    — deterministic PRNG (SplitMix64 / Xoshiro256++) with
//!               Gaussian sampling; seeds every dataset, sampler and
//!               property test in the repo.
//! - [`json`]   — minimal JSON parser/serializer for
//!               `artifacts/manifest.json`, metrics output and configs.
//! - [`cli`]    — declarative flag parser for the `repro` binary and
//!               the examples.
//! - [`bench`]  — micro-benchmark harness (warmup + median/MAD) used by
//!               every `cargo bench` target (criterion is unavailable
//!               offline).
//! - [`check`]  — seeded property-test driver (shrinking-free
//!               proptest-alike) used by the invariant suites.
//! - [`pool`]   — persistent sharded thread pool (+ deterministic
//!               shard->range mapping) shared by the trainer fan-out
//!               and the sparsification engine.
//! - [`kernels`] — chunked, autovectorization-friendly hot-path
//!               primitives (fused fill+histogram, boundary collect,
//!               scatter-add, fixed-width bit pack, f32↔bf16/f16),
//!               each pinned bit-identical to a scalar referee.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod kernels;
pub mod pool;
pub mod rng;
