//! Persistent sharded thread pool — the execution substrate of the
//! sparsification engine (EXPERIMENTS.md §Perf).
//!
//! Design goals, in order:
//!
//! 1. **Determinism.** Work is split into *indexed tasks*; which OS
//!    thread runs a task never affects results because every consumer
//!    writes only its own disjoint output (see [`SharedSlice`]) and
//!    merges happen in task order on the caller.  [`shard_range`] is
//!    the single source of truth for the shard -> index-range mapping.
//! 2. **Zero per-round setup.** Threads are spawned once and parked on
//!    a condvar between jobs — no `thread::spawn` in any hot path
//!    (the seed trainer spawned N threads per round).
//! 3. **std-only.** No crossbeam/rayon; one `Mutex<State>` + two
//!    condvars.  Work-stealing is deliberately absent: shards are
//!    claimed from a shared counter, which is enough because shard
//!    costs are uniform (contiguous equal ranges of the same kernel).
//!
//! The caller of [`ThreadPool::run`] participates in execution, so a
//! pool with `t` worker threads uses `t + 1` executors.  Nested `run`
//! calls (a pooled task itself calling `run`) execute inline serially
//! instead of deadlocking on the job slot.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Deterministic contiguous shard -> range mapping: shard `s` of
/// `shards` over `len` elements covers `[s*len/shards, (s+1)*len/shards)`.
/// Ranges are disjoint, cover `0..len`, and differ in size by at most 1.
#[inline]
pub fn shard_range(len: usize, shards: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < shards);
    (s * len / shards, (s + 1) * len / shards)
}

/// Pointer-with-length wrapper that lets pooled tasks write **disjoint**
/// ranges of one slice in parallel.  The type is `Copy` so a `Fn`
/// closure can hand it to every shard.
///
/// Safety contract (bounds are checked in debug builds): concurrent
/// [`Self::range`] calls must use non-overlapping ranges, and the
/// backing slice must outlive the pool job — which
/// [`ThreadPool::run`] guarantees by blocking until every task is done.
pub struct SharedSlice<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for SharedSlice<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SharedSlice<T> {}

unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub fn new(slice: &mut [T]) -> Self {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[lo, hi)`.
    ///
    /// # Safety
    /// Concurrent callers must use disjoint ranges; the backing slice
    /// must be live for the duration of the borrow.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len, "range {lo}..{hi} of {}", self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

/// Type-erased borrowed task: a `'static`-laundered pointer to the
/// caller's closure.  Sound because `run` blocks until every claimed
/// index completes, so the closure strictly outlives all dereferences
/// (a claim holds the job's `remaining` count up, and the job owner
/// cannot return while `remaining > 0`).
struct RawTask(*const (dyn Fn(usize) + Sync));
unsafe impl Send for RawTask {}

struct Job {
    task: RawTask,
    n: usize,
    next: usize,
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct State {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

impl Shared {
    /// Poison-tolerant state lock: a panic that unwinds through `run`
    /// (task panics are re-raised there while the `run_lock` guard is
    /// live) must not brick the pool for subsequent jobs.
    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// The persistent pool.  One global instance (see [`global`]) is shared
/// by the trainer's worker fan-out and every in-sparsifier kernel.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// serializes concurrent `run` calls (the pool runs one job at a time)
    run_lock: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

thread_local! {
    /// Set while this thread executes a pooled task; nested `run` calls
    /// detect it and execute inline (serially) instead of deadlocking.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

impl ThreadPool {
    /// Spawn a pool with `threads` worker threads.  `threads == 0` is
    /// valid: every job then runs inline on the caller.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("regtopk-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, run_lock: Mutex::new(()), handles }
    }

    /// Total executors a job can use (workers + the participating caller).
    pub fn parallelism(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f(0), f(1), ..., f(tasks-1)` across the pool and block until
    /// all complete.  Which thread runs which index is unspecified;
    /// callers must make outputs index-deterministic (disjoint writes
    /// merged in index order).  Panics in any task are re-raised here
    /// after the whole job has drained.
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        if tasks == 0 {
            return;
        }
        // inline paths: trivial job, no workers, or nested call from a
        // pooled task (running inline keeps progress + avoids deadlock)
        if tasks == 1 || self.handles.is_empty() || IN_POOL_TASK.with(|c| c.get()) {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let _serial = self.run_lock.lock().unwrap_or_else(|p| p.into_inner());
        let obj: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime laundering only — this function does not
        // return until `remaining == 0`, so `f` outlives every use.
        let obj: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(obj) };
        {
            let mut st = self.shared.lock();
            debug_assert!(st.job.is_none(), "run_lock must serialize jobs");
            st.job = Some(Job {
                task: RawTask(obj as *const (dyn Fn(usize) + Sync)),
                n: tasks,
                next: 0,
                remaining: tasks,
                panic: None,
            });
            self.shared.work_cv.notify_all();
        }
        // caller participates in execution
        drain_current_job(&self.shared);
        // wait for stragglers, then collect the finished job
        let job = {
            let mut st = self.shared.lock();
            while st.job.as_ref().map(|j| j.remaining > 0).unwrap_or(false) {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            st.job.take().expect("job stays in the slot until its owner takes it")
        };
        if let Some(payload) = job.panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run `f(i, &mut items[i])` for every item in parallel and return
    /// the per-item results in index order.  The disjoint `&mut`
    /// hand-out is what the seed's per-round `thread::scope` fan-out
    /// did with scoped spawns, minus the per-round thread creation.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        {
            let items_sh = SharedSlice::new(items);
            let out_sh = SharedSlice::new(&mut out);
            self.run(n, |i| {
                // SAFETY: each index is claimed exactly once, so the
                // item and slot borrows are disjoint across tasks.
                let item = unsafe { &mut items_sh.range(i, i + 1)[0] };
                let slot = unsafe { &mut out_sh.range(i, i + 1)[0] };
                *slot = Some(f(i, item));
            });
        }
        out.into_iter()
            .map(|r| r.expect("pool job completed every index"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim-and-execute loop shared by pool workers and the participating
/// caller: repeatedly claim the next unclaimed index of the job in the
/// slot and run it; return when nothing is claimable.  The task pointer
/// is read under the same lock as the claim, so it always belongs to
/// the job the index was claimed from.
fn drain_current_job(shared: &Shared) {
    loop {
        let (i, task_ptr) = {
            let mut st = shared.lock();
            match st.job.as_mut() {
                Some(job) if job.next < job.n => {
                    let i = job.next;
                    job.next += 1;
                    (i, job.task.0)
                }
                _ => return,
            }
        };
        // SAFETY: our claim keeps `remaining > 0`, so the job owner is
        // still blocked in `run` and the closure is alive.
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*task_ptr };
        IN_POOL_TASK.with(|c| c.set(true));
        let result = catch_unwind(AssertUnwindSafe(|| f(i)));
        IN_POOL_TASK.with(|c| c.set(false));
        let mut st = shared.lock();
        let job = st.job.as_mut().expect("job lives until its owner takes it");
        job.remaining -= 1;
        if let Err(payload) = result {
            if job.panic.is_none() {
                job.panic = Some(payload);
            }
        }
        if job.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        // park until there is claimable work (or shutdown)
        {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                match st.job.as_ref() {
                    Some(job) if job.next < job.n => break,
                    _ => st = shared.work_cv.wait(st).unwrap_or_else(|p| p.into_inner()),
                }
            }
        }
        drain_current_job(shared);
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, sized to the machine (capped at 16 executors)
/// and created on first use.  Shared by the trainer fan-out and every
/// sparsifier engine so round-over-round there is exactly one set of
/// threads, all parked when idle.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        // caller participates, so spawn one fewer worker thread
        ThreadPool::new(n.clamp(1, 16) - 1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn shard_ranges_partition_exactly() {
        for &(len, shards) in &[(10usize, 3usize), (7, 7), (5, 8), (1_000_003, 16), (0, 4), (1, 1)] {
            let mut covered = 0usize;
            let mut prev_hi = 0usize;
            for s in 0..shards {
                let (lo, hi) = shard_range(len, shards, s);
                assert_eq!(lo, prev_hi, "len={len} shards={shards} s={s}");
                assert!(hi >= lo && hi <= len);
                covered += hi - lo;
                prev_hi = hi;
            }
            assert_eq!(covered, len);
            assert_eq!(prev_hi, len);
        }
    }

    #[test]
    fn run_executes_every_index_once() {
        let pool = ThreadPool::new(3);
        let counts: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.run(257, |i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn run_reusable_across_jobs() {
        let pool = ThreadPool::new(2);
        for round in 0..50 {
            let total = AtomicUsize::new(0);
            pool.run(8, |i| {
                total.fetch_add(i + round, Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), 28 + 8 * round);
        }
    }

    #[test]
    fn map_mut_gives_disjoint_mut_access() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<usize> = (0..64).collect();
        let doubled = pool.map_mut(&mut items, |i, v| {
            *v *= 2;
            *v + i
        });
        for i in 0..64 {
            assert_eq!(items[i], 2 * i);
            assert_eq!(doubled[i], 3 * i);
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        pool.run(4, |_| {
            // nested call from inside a pooled task must not deadlock
            global().run(4, |j| {
                total.fetch_add(j + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 10);
    }

    #[test]
    fn zero_workers_runs_inline() {
        let pool = ThreadPool::new(0);
        let total = AtomicUsize::new(0);
        pool.run(5, |i| {
            total.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panics_propagate_after_drain() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // pool still usable after a panicked job
        let total = AtomicUsize::new(0);
        pool.run(4, |i| {
            total.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn shared_slice_disjoint_parallel_writes() {
        let pool = ThreadPool::new(3);
        let mut v = vec![0u64; 100_000];
        {
            let sh = SharedSlice::new(&mut v);
            pool.run(8, |s| {
                let (lo, hi) = shard_range(sh.len(), 8, s);
                let part = unsafe { sh.range(lo, hi) };
                for (off, x) in part.iter_mut().enumerate() {
                    *x = (lo + off) as u64;
                }
            });
        }
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }
}
